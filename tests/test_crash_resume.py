"""Crash-resumable sessions (ISSUE 10): kill a durable drain mid-flight
and resume it in a FRESH process.

A real subprocess boundary is the contract here (as in test_persist.py):
in-process resume tests cannot prove the persisted specs + checkpointed
ledgers carry everything a cold interpreter needs.  The resumed process
must re-execute zero DONE invocations, warm its programs from the
persistent cache the crashed process seeded, pass the runtime protocol
sanitizer over the recovered history, and land bitwise-identical thetas.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

# Three lasso PLR requests on one durable single-lane session.  Lasso
# because its executables are portable across processes (pure XLA, no
# LAPACK custom calls — see PersistentProgramCache.portable), so the
# resumed process can prove it warms from the crashed process's cache.
_CASE = """
from repro.core import DMLData, DMLPlan
from repro.data import make_plr_data

SEEDS = (3, 4, 5)

def cases():
    for s in SEEDS:
        data = DMLData.from_dict(
            make_plr_data(n_obs=96, dim_x=5, theta=0.5, seed=s))
        plan = DMLPlan.for_model("plr", learner="lasso",
                                 learner_params={"reg": 0.01},
                                 n_folds=3, n_rep=3, seed=s + 4)
        yield plan, data
"""

# Child 1: submit all three, poll until the drain is mid-flight — at
# least one request COMPLETE, at least one not — then hard-crash via
# os._exit so no atexit/cleanup runs: only the atomic spec files and
# ledger checkpoints survive.
_CRASH = _CASE + """
import json, os, sys
from repro.core import DMLSession
from repro.serverless import PoolConfig

sess = DMLSession(backend="wave", pool=PoolConfig(n_workers=1),
                  session_dir=sys.argv[1])
for plan, data in cases():
    sess.submit(plan, data)
for _ in range(200):
    sess.poll()
    if sess.completion_order and sess._queue:
        break
assert sess.completion_order and sess._queue     # genuinely mid-flight
done = {p.request_id: p.req.ledger.n_done for p in sess._queue}
n_inv = {p.request_id: p.req.ledger.n_invocations for p in sess._queue}
for rid in sess.completion_order:
    led = sess.request(rid).ledger
    done[rid] = led.n_done
    n_inv[rid] = led.n_invocations
print(json.dumps({"done": done, "n_inv": n_inv,
                  "completed": sess.completion_order}), flush=True)
os._exit(0)      # the simulated crash: skip interpreter teardown entirely
"""

# Child 2: resume from the session dir in a cold process and finish the
# drain, reporting what it re-executed and what it computed.
_RESUME = _CASE + """
import json, sys
from repro.core import DMLSession
from repro.serverless import PoolConfig

sess = DMLSession.resume(sys.argv[1], backend="wave",
                         pool=PoolConfig(n_workers=1))
resumed_done = {p.request_id: p.ledger.n_done for p in sess._queue}
results = sess.run()
print(json.dumps({
    "resumed_done": resumed_done,
    "billed": {r.request_id: r.report.bill.n_invocations for r in results},
    "thetas": {r.request_id: [float(t) for t in r.thetas]
               for r in results},
    "disk_hits": sess.backend.compiler.stats.disk_hits,
}), flush=True)
"""


def _run_child(script, session_dir, cache_dir, sanitize=False):
    env = dict(os.environ,
               PYTHONPATH=SRC,
               REPRO_PROGRAM_CACHE_DIR=str(cache_dir))
    if sanitize:
        env["REPRO_SANITIZE"] = "1"
    out = subprocess.run([sys.executable, "-c", script, str(session_dir)],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_crash_mid_drain_resumes_bitwise(tmp_path):
    """The satellite gate: crash a durable drain mid-flight, resume in a
    new process under the runtime sanitizer — zero DONE invocations
    re-execute, programs warm from the crashed process's persistent
    cache, and the thetas are bitwise-identical to an uninterrupted
    in-process run."""
    from repro.core import DMLSession
    from repro.serverless import PoolConfig

    session_dir = tmp_path / "session"
    cache_dir = tmp_path / "progcache"
    first = _run_child(_CRASH, session_dir, cache_dir)
    total_inv = sum(first["n_inv"].values())
    total_done = sum(first["done"].values())
    assert first["completed"]                       # some request finished
    assert 0 < total_done < total_inv               # ...and some did not
    for rid in ("0", "1", "2"):
        assert (session_dir / f"request_0000{rid}.msgpack").exists()
        assert (session_dir / f"ledger_0000{rid}.msgpack").exists()

    second = _run_child(_RESUME, session_dir, cache_dir, sanitize=True)
    # the checkpointed ledgers carried every completed row across the
    # crash — including the fully-DONE request, which resumes complete
    assert second["resumed_done"] == first["done"]
    # zero re-executed DONE invocations: the resumed drain bills exactly
    # the invocations the crash orphaned, request by request
    for rid, billed in second["billed"].items():
        assert billed == first["n_inv"][rid] - first["done"][rid]
    assert second["billed"][str(first["completed"][0])] == 0
    # the resumed process warmed at least one program from the
    # persistent cache the crashed process seeded (ISSUE 7)
    assert second["disk_hits"] >= 1

    # bitwise vs an uninterrupted run of the same specs (determinism
    # contract: results depend only on (plan, data), never the schedule)
    ns = {}
    exec(_CASE, ns)
    for rid, (plan, data) in enumerate(ns["cases"]()):
        ref = DMLSession(backend="inline",
                         pool=PoolConfig(n_workers=2)).estimate(plan, data)
        np.testing.assert_array_equal(
            np.asarray(second["thetas"][str(rid)]),
            np.asarray([float(t) for t in ref.thetas]))
