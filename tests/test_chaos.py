"""Fault-tolerant fast path (ISSUE 10): identity-keyed fault injection,
capped-backoff retries, deadline-based hedged re-dispatch, host-loss
recovery, and crash-resumable sessions.

The load-bearing property throughout: chaos changes the SCHEDULE, never
the estimate.  Fault verdicts are drawn per (request slot, invocation,
attempt) from counter-based Philox streams (serverless/chaos.py), so
results under ANY fault schedule — any worker count, any harvest order,
any hedge race outcome, any host loss — are bitwise-identical to the
fault-free drain.
"""
import numpy as np
import pytest

from repro.core import DMLData, DMLPlan, DMLSession
from repro.core.session import assemble_result, compile_request
from repro.data import make_irm_data, make_plr_data
from repro.serverless import PoolConfig, make_backend
from repro.serverless.backends import InlineBackend, WaveBackend
from repro.serverless.chaos import ChaosPlan, chaos_plan, env_chaos_rates

FAMILIES = [
    ("ridge", {"reg": 1.0}),
    ("ols", {}),
    ("lasso", {"reg": 0.01}),
    ("logistic", {"reg": 1.0}),
    ("kernel_ridge", {"reg": 1.0, "n_landmarks": 32}),
    ("mlp", {"hidden": (8,), "n_steps": 20}),
]

# chaos everywhere, short synthetic tails so the suite stays fast:
# every straggler holds its bucket 40ms, hedging arms after 5ms
CHAOS = dict(failure_rate=0.3, straggler_rate=0.3, max_retries=10,
             straggler_hold_s=0.04, hedge_after_s=0.005, seed=0)


def _case(learner, params, seed=3):
    if learner == "logistic":
        data = DMLData.from_dict(make_irm_data(n_obs=130, dim_x=4,
                                               theta=0.4, seed=seed))
        plan = DMLPlan.for_model("irm", learner="ridge", n_folds=3,
                                 n_rep=2, seed=seed + 100)
        return plan, data
    data = DMLData.from_dict(make_plr_data(n_obs=120, dim_x=5, theta=0.5,
                                           seed=seed))
    plan = DMLPlan.for_model("plr", learner=learner, learner_params=params,
                             n_folds=3, n_rep=2, seed=seed + 100)
    return plan, data


def _run(backend, plan, data):
    req = compile_request(plan, data)
    info = backend.run_requests([req])
    assert req.ledger.complete
    return req, info


# ---------------------------------------------------------------------------
# the fault plan itself
# ---------------------------------------------------------------------------
def test_verdicts_are_order_independent():
    """Counter-based Philox keying: the verdict of (slot, inv, attempt)
    is a pure function of the identity — querying in any order, or
    skipping queries entirely, never changes a draw.  This is what lets
    chaos pools keep bucket-coherent fill and pipelined dispatch."""
    a = ChaosPlan(failure_rate=0.4, straggler_rate=0.3,
                  straggler_slowdown=4.0, simulate=True, seed=9)
    b = ChaosPlan(failure_rate=0.4, straggler_rate=0.3,
                  straggler_slowdown=4.0, simulate=True, seed=9)
    idents = [(s, i, t) for s in range(3) for i in range(4)
              for t in range(2)]
    fwd = {ident: a.verdict(*ident) for ident in idents}
    rev = {ident: b.verdict(*ident) for ident in reversed(idents)}
    assert fwd == rev
    # a fresh plan queried once agrees with a heavily-queried one
    c = ChaosPlan(failure_rate=0.4, straggler_rate=0.3,
                  straggler_slowdown=4.0, simulate=True, seed=9)
    assert c.verdict(2, 3, 1) == fwd[(2, 3, 1)]
    # failures fire on attempt 0 only: retries converge
    assert not any(v.failed for (s, i, t), v in fwd.items() if t > 0)


def test_backoff_is_capped_exponential():
    p = ChaosPlan(failure_rate=0.5, straggler_rate=0.0,
                  straggler_slowdown=4.0, simulate=False, seed=0,
                  backoff_base_s=0.01, backoff_cap_s=0.05)
    assert p.backoff_s(1) == pytest.approx(0.01)
    assert p.backoff_s(2) == pytest.approx(0.02)
    assert p.backoff_s(3) == pytest.approx(0.04)
    assert p.backoff_s(10) == pytest.approx(0.05)      # capped
    none = ChaosPlan(failure_rate=0.5, straggler_rate=0.0,
                     straggler_slowdown=4.0, simulate=False, seed=0)
    assert none.backoff_s(5) == 0.0                    # opt-in knob


def test_env_chaos_arms_fault_free_pools(monkeypatch):
    """REPRO_CHAOS is the CI chaos job's lever: it arms fault injection
    on pools that configured none, without touching explicit rates."""
    monkeypatch.delenv("REPRO_CHAOS", raising=False)
    assert env_chaos_rates() is None
    assert chaos_plan(PoolConfig()) is None            # fault-free stays so
    monkeypatch.setenv("REPRO_CHAOS", "1")
    assert env_chaos_rates() == (0.1, 0.1)
    armed = chaos_plan(PoolConfig())
    assert armed is not None and armed.failure_rate == 0.1
    monkeypatch.setenv("REPRO_CHAOS", "fail=0.25,strag=0.05")
    assert env_chaos_rates() == (0.25, 0.05)
    # an explicitly chaotic pool keeps its own configured rates
    own = chaos_plan(PoolConfig(failure_rate=0.4))
    assert own.failure_rate == 0.4


# ---------------------------------------------------------------------------
# the tentpole gate: bitwise parity under chaos, all learner families
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("learner,params", FAMILIES,
                         ids=[f[0] for f in FAMILIES])
def test_bitwise_parity_under_chaos(learner, params):
    """Faults + stragglers + backoff retries + hedge races on the wave
    backend: bitwise-identical predictions and theta vs the fault-free
    inline drain, for every learner family including the key-consuming
    ones (mlp, kernel_ridge)."""
    plan, data = _case(learner, params)
    ref, _ = _run(InlineBackend(PoolConfig(n_workers=3)), plan, data)
    chaotic = PoolConfig(n_workers=2, memory_mb=512, **CHAOS)
    req, info = _run(WaveBackend(chaotic), plan, data)
    assert req.report.failures > 0 or req.report.stragglers > 0
    np.testing.assert_array_equal(req.gathered_preds(),
                                  ref.gathered_preds())
    r = assemble_result(plan, data, req)
    r_ref = assemble_result(plan, data, ref)
    assert r.theta == r_ref.theta


def test_chaos_pools_stay_on_the_fast_path():
    """The deleted special case: chaos pools used to fall back to a
    wave-synchronous slow path.  Now they run the same fused, pipelined,
    bucket-coherent dispatch as fault-free pools — asserted via the
    drain's launch fusion and in-flight dispatch accounting."""
    cases = [_case("ridge", {"reg": 1.0}, seed=s) for s in (3, 4, 5)]
    # capacity spans requests so bucket-coherent fill really packs
    # cross-request blocks into fused launches
    backend = WaveBackend(PoolConfig(n_workers=4, memory_mb=512, **CHAOS))
    reqs = [compile_request(p, d) for p, d in cases]
    info = backend.run_requests(reqs)
    assert all(r.ledger.complete for r in reqs)
    assert sum(r.report.failures for r in reqs) > 0    # chaos really hit
    d = info.dispatch
    assert d is not None and d.dispatched > 0
    # every dispatched bucket is retired exactly once, by exactly one of
    # the three legal exits — booked, cancelled (hedge loser), or lost
    assert d.dispatched == d.harvested + d.cancelled + d.lost
    assert d.lost == 0
    # fused launches and multi-bucket in-flight pipelining under chaos
    assert info.compile.fused_launches >= 1
    assert d.in_flight_peak >= 2
    assert 0.0 <= d.overlap_ratio <= 1.0


def test_fault_pattern_is_schedule_independent():
    """The same chaotic pool at different worker counts — different wave
    shapes, different dispatch order — injects the SAME fault set and
    produces bitwise-identical predictions."""
    plan, data = _case("ridge", {"reg": 1.0})
    runs = []
    for n_workers in (1, 8):
        pool = PoolConfig(n_workers=n_workers, memory_mb=512,
                          failure_rate=0.3, max_retries=10, seed=2)
        req, _ = _run(WaveBackend(pool), plan, data)
        runs.append(req)
    assert runs[0].report.failures == runs[1].report.failures > 0
    np.testing.assert_array_equal(runs[0].gathered_preds(),
                                  runs[1].gathered_preds())


def test_backoff_gates_delay_but_complete():
    """Capped-backoff retries: failed invocations wait out their gate,
    re-enter the pending view, and the drain still completes bitwise."""
    plan, data = _case("ridge", {"reg": 1.0})
    ref, _ = _run(InlineBackend(PoolConfig(n_workers=3)), plan, data)
    pool = PoolConfig(n_workers=2, memory_mb=512, failure_rate=0.4,
                      max_retries=10, seed=2, retry_backoff_s=0.005,
                      retry_backoff_cap_s=0.02)
    req, _ = _run(WaveBackend(pool), plan, data)
    assert req.report.failures > 0
    np.testing.assert_array_equal(req.gathered_preds(),
                                  ref.gathered_preds())


# ---------------------------------------------------------------------------
# hedged re-dispatch: first landing wins, loser never double-bills
# ---------------------------------------------------------------------------
def test_hedge_race_books_once_and_attributes_waste():
    """Every invocation a straggler: each bucket's dispatch holds 40ms,
    overdue after 5ms, so a hedge duplicate launches and wins.  Exactly
    one booking per bucket (the ledger would throw on a double-book
    under the sanitizer; here we assert the bill), losers land in
    hedge_waste_s, and the result is still bitwise."""
    plan, data = _case("ridge", {"reg": 1.0})
    ref, _ = _run(InlineBackend(PoolConfig(n_workers=3)), plan, data)
    pool = PoolConfig(n_workers=2, memory_mb=512, straggler_rate=1.0,
                      straggler_hold_s=0.04, hedge_after_s=0.005,
                      max_retries=10, seed=5)
    req, info = _run(WaveBackend(pool), plan, data)
    d = info.dispatch
    assert d.hedges > 0
    assert d.hedge_wins > 0                  # the duplicate really raced
    assert d.cancelled > 0                   # and the loser was discarded
    assert d.dispatched == d.harvested + d.cancelled + d.lost
    assert d.hedge_waste_s >= 0.0
    # single-performer booking: every invocation billed exactly once
    assert req.report.bill.n_invocations == req.ledger.n_invocations
    np.testing.assert_array_equal(req.gathered_preds(),
                                  ref.gathered_preds())


def test_hedge_deadline_prices_from_roofline():
    """Without an explicit hedge_after_s the deadline comes from the
    bucket's roofline estimate — bounded below by the floor and above
    by the Lambda timeout."""
    from repro.launch.roofline import (
        HEDGE_DEADLINE_FLOOR_S, bucket_deadline_s,
    )
    d1 = bucket_deadline_s("ridge", {"reg": 1.0}, 4, 128, 8, 4,
                           n_workers=4)
    # tiny buckets clamp to the floor: never hedge sub-millisecond work
    assert d1 == HEDGE_DEADLINE_FLOOR_S
    # a bucket big enough to clear the floor prices from its roofline,
    # and more entries on the same lanes -> proportionally later deadline
    d2 = bucket_deadline_s("ridge", {"reg": 1.0}, 4, 1 << 18, 64, 512,
                           n_workers=4)
    d3 = bucket_deadline_s("ridge", {"reg": 1.0}, 4, 1 << 18, 64, 1024,
                           n_workers=4)
    assert d3 > d2 > d1


# ---------------------------------------------------------------------------
# host loss: kill a mesh mid-flight, the survivors finish everything
# ---------------------------------------------------------------------------
def test_topology_survives_host_loss_mid_flight():
    """Kill host 0 while its queue holds in-flight buckets: its pages
    are invalidated, its orphans re-route, and every admitted request
    still completes — bitwise-identical to the fault-free inline path,
    for every learner family."""
    cases = [_case(learner, params, seed=3 + i)
             for i, (learner, params) in enumerate(FAMILIES)]
    sess = DMLSession(backend="topology",
                      pool=PoolConfig(n_workers=2, memory_mb=256,
                                      n_hosts=2))
    rids = [sess.submit(plan, data) for plan, data in cases]
    backend = sess.backend
    # drive the drain until host 0 has work in flight, then kill it
    killed = False
    for _ in range(400):
        sess.poll()
        state = sess._state
        if state is None:
            break
        q = state.queues.get(0)
        if q is not None and q.in_flight > 0:
            lost = backend.kill_host(state, 0)
            assert lost > 0              # genuinely orphaned in-flight work
            killed = True
            break
    assert killed, "drain finished before any in-flight work on host 0"
    sess.run()
    t = sess.topology_info
    assert t.host_losses == 1
    assert t.lost_buckets > 0
    assert t.lost_buckets == sess.last_run_info.dispatch.lost
    # zero lost invocations: every admitted request completed
    for rid, (plan, data) in zip(rids, cases):
        assert sess.request(rid).ledger.complete
        ref = compile_request(plan, data)
        InlineBackend().run_requests([ref])
        np.testing.assert_array_equal(
            sess.request(rid).gathered_preds(), ref.gathered_preds())
    # the dead host's pool is empty and unreachable via the directory
    assert backend.topology.hosts[0].pool.n_pages == 0
    assert 0 not in backend.topology.directory._pools


def test_killed_host_never_rejoins():
    """Host death is permanent for the topology's lifetime: later drains
    route and steal over the survivors only."""
    sess = DMLSession(backend="topology",
                      pool=PoolConfig(n_workers=2, memory_mb=256,
                                      n_hosts=2))
    plan, data = _case("ridge", {"reg": 1.0})
    sess.submit(plan, data)
    sess.run()
    sess.backend.topology.kill(0)
    plan2, data2 = _case("ridge", {"reg": 1.0}, seed=9)
    rid = sess.submit(plan2, data2)
    sess.run()
    assert sess.request(rid).ledger.complete
    t = sess.topology_info
    assert t.hosts[0].waves == 0         # the corpse never stepped
    assert [h.host_id for h in sess.backend.topology.alive()] == [1]


# ---------------------------------------------------------------------------
# crash-resumable sessions (in-process half; subprocess: test_crash_resume)
# ---------------------------------------------------------------------------
def test_durable_session_resumes_partial_drain(tmp_path):
    """A durable session killed mid-drain resumes in a fresh session
    object: DONE invocations never re-execute, and the thetas are
    bitwise-identical to an uninterrupted run."""
    plan, data = _case("ridge", {"reg": 1.0})
    sdir = str(tmp_path / "sess")
    sess = DMLSession(backend="wave",
                      pool=PoolConfig(n_workers=1, memory_mb=256),
                      session_dir=sdir)
    sess.submit(plan, data)
    n_done = 0
    for _ in range(3):                   # partial drain, then "crash"
        sess.poll()
        if sess._queue and sess._queue[0].req is not None:
            n_done = sess._queue[0].req.ledger.n_done
    del sess                             # the crash: nothing carried over

    resumed = DMLSession.resume(sdir, backend="wave",
                                pool=PoolConfig(n_workers=1,
                                                memory_mb=256))
    res, = resumed.run()
    req = resumed.request(res.request_id)
    assert req.ledger.complete
    # only the not-DONE rows were re-executed in the resumed process
    assert res.report.bill.n_invocations == req.ledger.n_invocations - n_done
    ref = DMLSession(backend="inline").estimate(plan, data)
    np.testing.assert_array_equal(res.thetas, ref.thetas)
    assert res.theta == ref.theta


def test_resume_under_chaos_is_bitwise(tmp_path):
    """Crash-resume composed with fault injection: the resumed drain
    draws the SAME identity-keyed verdicts for the surviving rows (the
    checkpointed ledger carries the attempt counters), so even the
    retry schedule is reproducible and the estimate bitwise."""
    plan, data = _case("ridge", {"reg": 1.0})
    pool = PoolConfig(n_workers=2, memory_mb=256, failure_rate=0.3,
                      max_retries=10, seed=2)
    sdir = str(tmp_path / "sess")
    sess = DMLSession(backend="wave", pool=pool, session_dir=sdir)
    sess.submit(plan, data)
    for _ in range(2):
        sess.poll()
    del sess
    resumed = DMLSession.resume(sdir, backend="wave", pool=pool)
    res, = resumed.run()
    ref, _ = _run(InlineBackend(PoolConfig(n_workers=3)), plan, data)
    np.testing.assert_array_equal(
        resumed.request(res.request_id).gathered_preds(),
        ref.gathered_preds())
