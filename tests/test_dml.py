"""DML end-to-end statistical validation (the paper's §3 premise + §5.1
pipeline): theta recovery, cross-fitting necessity, model classes, bootstrap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DoubleMLServerless
from repro.core.aggregation import aggregate_thetas, confint
from repro.data import make_bonus_data, make_irm_data, make_plr_data
from repro.serverless import PoolConfig


def test_plr_recovers_theta_linear_dgp():
    data = make_plr_data(n_obs=600, dim_x=10, theta=0.5, seed=11)
    est = DoubleMLServerless(model="plr", n_folds=4, n_rep=3,
                             learner="ridge", learner_params={"reg": 0.5},
                             pool=PoolConfig(n_workers=4))
    res = est.fit(data)
    assert abs(res.theta - 0.5) < 4 * res.se + 0.05
    lo, hi = res.ci
    assert lo < hi


def test_plr_nonlinear_needs_flexible_learner():
    data = make_plr_data(n_obs=800, dim_x=12, theta=0.5, seed=5)
    def fit(learner, params):
        est = DoubleMLServerless(model="plr", n_folds=5, n_rep=2,
                                 learner=learner, learner_params=params,
                                 pool=PoolConfig(n_workers=4))
        return est.fit(data)
    krr = fit("kernel_ridge", {"reg": 1.0, "n_landmarks": 128})
    assert abs(krr.theta - 0.5) < 4 * krr.se + 0.08


def test_cross_fitting_removes_overfitting_bias():
    """No-sample-splitting + overfit learner biases theta — the reason the
    M x K grid exists (paper §3)."""
    data = make_plr_data(n_obs=300, dim_x=30, theta=0.5, seed=9)
    import repro.learners as L
    from repro.core.crossfit import draw_fold_masks, stitch_predictions
    from repro.core.scores import plr_score, solve_theta

    x = jnp.asarray(data["x"])
    # overfitting learner: interpolating kernel ridge, fit IN-SAMPLE
    fn = L.get_learner("kernel_ridge", {"reg": 1e-6, "n_landmarks": 300})
    y_t = jnp.asarray(np.stack([data["y"], data["d"]]))
    w_full = jnp.ones((2, 300), jnp.float32)
    preds_in = fn(x, y_t, w_full, jax.random.key(0))
    # overfitting confirmed: in-sample residuals (near-)vanish — the score's
    # denominator sum(v^2) degenerates and theta_in is unstable garbage
    v_in = np.asarray(data["d"]) - np.asarray(preds_in[1])
    assert np.var(v_in) < 0.05 * np.var(data["d"])
    # CROSS-FIT with the same learner family, sane regularization
    est = DoubleMLServerless(model="plr", n_folds=5, n_rep=2,
                             learner="kernel_ridge",
                             learner_params={"reg": 1.0, "n_landmarks": 150},
                             pool=PoolConfig(n_workers=4))
    res = est.fit(data)
    # cross-fitted residuals keep their variance and theta is sane
    assert abs(res.theta - 0.5) < 0.2


def test_irm_binary_treatment():
    data = make_irm_data(n_obs=900, dim_x=8, theta=0.4, seed=3)
    est = DoubleMLServerless(model="irm", n_folds=4, n_rep=2,
                             learner="ridge", learner_params={"reg": 1.0},
                             pool=PoolConfig(n_workers=4))
    res = est.fit(data)
    assert abs(res.theta - 0.4) < 5 * res.se + 0.1


def test_bonus_paper_setup_runs():
    """The paper's case study shape: K=5, M small here, 2 nuisances."""
    data = make_bonus_data()
    est = DoubleMLServerless(model="plr", n_folds=5, n_rep=4,
                             learner="ridge", learner_params={"reg": 1.0},
                             scaling="n_rep",
                             pool=PoolConfig(n_workers=8, memory_mb=1024))
    res = est.fit(data, n_boot=100)
    assert res.report.bill.n_invocations == 4 * 2     # M*L (per-split)
    assert abs(res.theta - data["theta0"]) < 5 * res.se
    assert res.boot_ci is not None


def test_median_aggregation_robust_to_outlier_rep():
    thetas = np.array([0.5, 0.52, 0.48, 5.0])
    ses = np.array([0.05, 0.05, 0.05, 0.05])
    th_med, se_med = aggregate_thetas(thetas, ses, "median")
    assert abs(th_med - 0.51) < 0.02
    th_mean, _ = aggregate_thetas(thetas, ses, "mean")
    assert abs(th_mean - 0.51) > 0.5


def test_confint_level():
    lo, hi = confint(0.0, 1.0, 0.95)
    assert lo == pytest.approx(-1.96, abs=0.01)
    assert hi == pytest.approx(1.96, abs=0.01)


def test_rep_coverage_plr():
    """CI covers theta0 in most repetitions of a small MC study."""
    cover = 0
    n_mc = 8
    for s in range(n_mc):
        data = make_plr_data(n_obs=400, dim_x=8, theta=0.5, seed=100 + s)
        est = DoubleMLServerless(model="plr", n_folds=4, n_rep=1,
                                 learner="ridge", learner_params={"reg": 0.5},
                                 pool=PoolConfig(n_workers=4),
                                 seed=100 + s)
        res = est.fit(data)
        lo, hi = res.ci
        cover += int(lo <= 0.5 <= hi)
    assert cover >= n_mc - 2
