"""Wave scheduler behaviors through the one execution path (DMLPlan +
backends): scaling equivalence, elasticity, fault tolerance,
checkpoint/restart, straggler mitigation, autoscaling, billing.

(The deprecated ``ServerlessExecutor`` raw-array facade was removed in
PR 3 and its import-compat module ``repro.serverless.executor`` — after
one release of DeprecationWarning notice — in PR 5; the behavior suite
lives on here against ``compile_request`` + the streaming
``WaveBackend``.)
"""
import os

import numpy as np
import pytest

from repro.core import DMLData, DMLPlan
from repro.core.session import compile_request
from repro.data import make_plr_data
from repro.serverless import (
    OccupancyAutoscaler, PoolConfig, TaskLedger, WaveBackend,
)
from repro.serverless.cost import speedup_of

DATA = DMLData.from_dict(make_plr_data(n_obs=120, dim_x=5, theta=0.5, seed=0))


def _plan(**kw):
    kw.setdefault("n_folds", 3)
    kw.setdefault("n_rep", 4)
    return DMLPlan.for_model("plr", learner="ridge",
                             learner_params={"reg": 1.0}, seed=11, **kw)


def _run(pool, ledger=None, **plan_kw):
    plan = _plan(scaling=pool.scaling, **plan_kw)
    req = compile_request(plan, DATA, ledger=ledger)
    WaveBackend(pool).run_requests([req])
    return req.gathered_preds(), req.ledger, req.report


def test_scaling_levels_identical_results():
    """Per-split and per-fold scaling must produce identical predictions —
    the paper's scaling knob is cost/latency only (§4.2)."""
    p1, _, _ = _run(PoolConfig(n_workers=2, scaling="n_rep"))
    p2, _, _ = _run(PoolConfig(n_workers=5, scaling="n_folds*n_rep"))
    np.testing.assert_array_equal(p1, p2)      # fixed-block B: bitwise


def test_worker_count_invariance():
    """Elasticity: results are bitwise independent of the pool size."""
    base, _, _ = _run(PoolConfig(n_workers=1, memory_mb=256))
    for w in (2, 7, 64):
        p, _, _ = _run(PoolConfig(n_workers=w, memory_mb=256))
        np.testing.assert_array_equal(base, p)


def test_fault_injection_and_retries_converge():
    pool = PoolConfig(n_workers=3, failure_rate=0.4, max_retries=8, seed=3)
    preds, ledger, rep = _run(pool)
    clean, _, _ = _run(PoolConfig(n_workers=3))
    assert rep.failures > 0
    assert ledger.complete
    np.testing.assert_array_equal(preds, clean)


def test_retry_budget_exhaustion_raises():
    pool = PoolConfig(n_workers=2, failure_rate=1.0, max_retries=0, seed=1)
    with pytest.raises(RuntimeError, match="retry budget"):
        _run(pool)


def test_ledger_checkpoint_restart(tmp_path):
    path = os.path.join(tmp_path, "ledger.msgpack")
    pool = PoolConfig(n_workers=1, memory_mb=256, checkpoint_path=path)
    preds, ledger, _ = _run(pool)
    # restart from the saved ledger: nothing left to do, same predictions
    restored = TaskLedger.load(path)
    assert restored.complete
    preds2, _, rep2 = _run(pool, ledger=restored)
    np.testing.assert_array_equal(preds, preds2)
    assert rep2.bill.n_invocations == 0          # no re-execution billed


def test_ledger_partial_resume():
    """Kill after some invocations; the restart must only run the rest."""
    pool = PoolConfig(n_workers=1, memory_mb=256)
    preds_full, led1, _ = _run(pool)
    # copy 3 done rows into a fresh ledger = crash-restored state
    led2 = TaskLedger.create(led1.n_invocations, led1.n_obs,
                             led1.tasks_per_invocation)
    led2.record_successes([0, 1, 2], led1.preds[[0, 1, 2]])
    preds2, led2, rep2 = _run(pool, ledger=led2)
    np.testing.assert_array_equal(preds_full, preds2)
    assert rep2.bill.n_invocations == led2.n_invocations - 3


def test_elastic_worker_schedule():
    """The legacy static schedule is still honored: workers leave and join
    between waves; the run completes bitwise-identically."""
    pool = PoolConfig(n_workers=4, memory_mb=256,
                      worker_schedule=[4, 1, 2, 8, 8, 8, 8, 8])
    preds, ledger, rep = _run(pool)
    assert ledger.complete
    assert rep.waves >= 2
    clean, _, _ = _run(PoolConfig(n_workers=4, memory_mb=256))
    np.testing.assert_array_equal(preds, clean)


def test_autoscaler_replaces_static_schedule():
    """Occupancy autoscaling: the wave backend derives worker counts from
    queue depth, records its decisions, and the estimate is untouched."""
    pool = PoolConfig(n_workers=2, memory_mb=256, autoscale=True,
                      min_workers=1, max_workers=16)
    plan = _plan(n_rep=8)
    req = compile_request(plan, DATA)
    backend = WaveBackend(pool)
    info = backend.run_requests([req])
    assert req.ledger.complete
    assert len(info.autoscale) == info.waves
    d0 = info.autoscale[0]
    assert d0.queue_depth == req.ledger.n_invocations
    assert d0.n_workers * pool.lanes_per_worker() == d0.capacity
    assert all(pool.min_workers <= d.n_workers <= pool.max_workers
               for d in info.autoscale)
    # bitwise invariance vs a static pool
    clean, _, _ = _run(PoolConfig(n_workers=4, memory_mb=256), n_rep=8)
    np.testing.assert_array_equal(req.gathered_preds(), clean)


def test_autoscaler_counts_in_flight_as_occupancy_not_depth():
    """Dispatched-but-unharvested work must raise occupancy, never the
    worker count — sizing for it again would double-provision the pool
    (the non-blocking-dispatch correctness rule)."""
    pool = PoolConfig(n_workers=2, memory_mb=1024, autoscale=True,
                      min_workers=1, max_workers=64)
    scaler = OccupancyAutoscaler(pool)
    base = scaler.decide(8)
    busy = scaler.decide(8, in_flight=64)
    assert busy.n_workers == base.n_workers        # no double-provision
    assert busy.in_flight == 64 and base.in_flight == 0
    assert busy.est_occupancy > base.est_occupancy
    assert busy.est_waves == base.est_waves
    assert busy.candidate_costs == base.candidate_costs


def test_autoscaler_scales_with_queue_depth():
    """Deeper queues get at least as many workers; shallow queues are not
    over-provisioned (cost-aware sizing)."""
    pool = PoolConfig(n_workers=2, memory_mb=1024, autoscale=True,
                      min_workers=1, max_workers=64)
    scaler = OccupancyAutoscaler(pool)
    shallow = scaler.decide(4)
    deep = scaler.decide(400)
    assert deep.n_workers >= shallow.n_workers
    assert shallow.capacity <= 4 * pool.lanes_per_worker()
    assert deep.est_waves < 400          # really parallelizes
    # decisions are deterministic pure functions of the observed state
    assert scaler.decide(400) == deep


def test_straggler_speculation_billed():
    pool = PoolConfig(n_workers=64, memory_mb=4096, straggler_rate=0.3,
                      simulate=True, base_work_s=0.1, seed=5)
    preds, ledger, rep = _run(pool)
    assert ledger.complete
    assert rep.stragglers > 0


def test_memory_speed_curve_diminishing_returns():
    s = [speedup_of(m) for m in (256, 512, 1024, 2048, 4096)]
    assert all(b > a for a, b in zip(s, s[1:]))          # monotone
    gains = [b / a for a, b in zip(s, s[1:])]
    assert all(g2 < g1 + 1e-9 for g1, g2 in zip(gains, gains[1:]))


def test_simulated_billing_tracks_memory():
    """Fig 3 mechanics: more memory => faster; billed GB-s is duration*mem."""
    t, c = {}, {}
    for mem in (256, 1024, 4096):
        pool = PoolConfig(n_workers=1000, memory_mb=mem, simulate=True,
                          base_work_s=0.5, seed=0)
        _, _, rep = _run(pool)
        t[mem] = rep.response_time_s
        c[mem] = rep.bill.total_gb_s
    assert t[4096] < t[1024] < t[256]
    for mem, bill in c.items():
        assert bill > 0


def test_executor_compat_module_removed():
    """PR 4 shipped the one-release DeprecationWarning notice; the
    import-compat module is now gone.  Everything it re-exported lives
    on repro.serverless / repro.core."""
    with pytest.raises(ModuleNotFoundError):
        import repro.serverless.executor  # noqa: F401
    from repro.core import DMLSession, estimate  # noqa: F401
    from repro.serverless import (                # noqa: F401
        RunReport, Segment, WaveBackend as _W, WorkRequest,
    )
    assert PoolConfig is not None
