"""Serverless executor: scaling equivalence, fault tolerance, elasticity,
checkpoint/restart, straggler mitigation, billing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.crossfit import TaskGrid, draw_fold_masks
from repro.learners import get_learner
from repro.serverless import PoolConfig, ServerlessExecutor, TaskLedger
from repro.serverless.cost import speedup_of


def _setup(m=4, k=3, l=2, n=120, p=5, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, p)).astype(np.float32)
    targets = rng.normal(size=(l, n)).astype(np.float32)
    masks = draw_fold_masks(n, k, m, seed)
    train_w = np.repeat((~masks).astype(np.float32)[:, :, None], l, axis=2)
    grid = TaskGrid(m, k, l)
    return x, targets, train_w, grid


LEARNER = get_learner("ridge", {"reg": 1.0})


def _run(pool, ledger=None, seed=0):
    x, targets, train_w, grid = _setup()
    ex = ServerlessExecutor(LEARNER, grid, pool)
    return ex.run(jnp.asarray(x), jnp.asarray(targets), train_w,
                  jax.random.key(seed), ledger=ledger)


def test_scaling_levels_identical_results():
    """Per-split and per-fold scaling must produce identical predictions —
    the paper's scaling knob is cost/latency only (§4.2)."""
    p1, _, _ = _run(PoolConfig(n_workers=2, scaling="n_rep"))
    p2, _, _ = _run(PoolConfig(n_workers=5, scaling="n_folds*n_rep"))
    np.testing.assert_allclose(p1, p2, rtol=2e-4, atol=2e-4)


def test_worker_count_invariance():
    """Elasticity: results are independent of the worker pool size."""
    base, _, _ = _run(PoolConfig(n_workers=1, memory_mb=256))
    for w in (2, 7, 64):
        p, _, rep = _run(PoolConfig(n_workers=w, memory_mb=256))
        np.testing.assert_allclose(base, p, rtol=2e-4, atol=2e-4)


def test_fault_injection_and_retries_converge():
    pool = PoolConfig(n_workers=3, failure_rate=0.4, max_retries=8, seed=3)
    preds, ledger, rep = _run(pool)
    clean, _, _ = _run(PoolConfig(n_workers=3))
    assert rep.failures > 0
    assert ledger.complete
    np.testing.assert_allclose(preds, clean, rtol=2e-4, atol=2e-4)


def test_retry_budget_exhaustion_raises():
    pool = PoolConfig(n_workers=2, failure_rate=1.0, max_retries=0, seed=1)
    with pytest.raises(RuntimeError, match="retry budget"):
        _run(pool)


def test_ledger_checkpoint_restart(tmp_path):
    path = os.path.join(tmp_path, "ledger.msgpack")
    pool = PoolConfig(n_workers=1, memory_mb=256, checkpoint_path=path)
    preds, ledger, _ = _run(pool)
    # restart from the saved ledger: nothing left to do, same predictions
    restored = TaskLedger.load(path)
    assert restored.complete
    preds2, _, rep2 = _run(pool, ledger=restored)
    np.testing.assert_allclose(preds, preds2, rtol=1e-6, atol=1e-6)
    assert rep2.bill.n_invocations == 0          # no re-execution billed


def test_ledger_partial_resume(tmp_path):
    """Kill after the first wave; the restart must only run the remainder."""
    x, targets, train_w, grid = _setup()
    pool = PoolConfig(n_workers=1, memory_mb=256)
    ex = ServerlessExecutor(LEARNER, grid, pool)
    ledger = TaskLedger.create(grid.n_invocations(pool.scaling), x.shape[0],
                               ex.tasks_per_invocation)
    # simulate: first 3 invocations already done by a previous (crashed) run
    full, _, _ = _run(pool)
    done_by_crash = [0, 1, 2]
    for inv in done_by_crash:
        tasks = ex._invocation_tasks(np.array([inv]))[0]
        m, rest = np.divmod(tasks, grid.n_folds * grid.n_nuisance)
        pass
    preds_full, led1, _ = ex.run(jnp.asarray(x), jnp.asarray(targets),
                                 train_w, jax.random.key(0))
    # copy 3 done rows into a fresh ledger = crash-restored state
    led2 = TaskLedger.create(grid.n_invocations(pool.scaling), x.shape[0],
                             ex.tasks_per_invocation)
    for inv in done_by_crash:
        led2.record_success(inv, led1.preds[inv])
    preds2, led2, rep2 = ex.run(jnp.asarray(x), jnp.asarray(targets),
                                train_w, jax.random.key(0), ledger=led2)
    np.testing.assert_allclose(preds_full, preds2, rtol=1e-6, atol=1e-6)
    assert rep2.bill.n_invocations == led2.n_invocations - len(done_by_crash)


def test_elastic_worker_schedule():
    """Workers leave and join between waves; run still completes."""
    pool = PoolConfig(n_workers=4, memory_mb=256,
                      worker_schedule=[4, 1, 2, 8, 8, 8, 8, 8])
    preds, ledger, rep = _run(pool)
    assert ledger.complete
    assert rep.waves >= 2
    clean, _, _ = _run(PoolConfig(n_workers=4, memory_mb=256))
    np.testing.assert_allclose(preds, clean, rtol=2e-4, atol=2e-4)


def test_straggler_speculation_billed():
    pool = PoolConfig(n_workers=64, memory_mb=4096, straggler_rate=0.3,
                      simulate=True, base_work_s=0.1, seed=5)
    preds, ledger, rep = _run(pool)
    assert ledger.complete
    assert rep.stragglers > 0


def test_memory_speed_curve_diminishing_returns():
    s = [speedup_of(m) for m in (256, 512, 1024, 2048, 4096)]
    assert all(b > a for a, b in zip(s, s[1:]))          # monotone
    gains = [b / a for a, b in zip(s, s[1:])]
    assert all(g2 < g1 + 1e-9 for g1, g2 in zip(gains, gains[1:]))


def test_simulated_billing_tracks_memory():
    """Fig 3 mechanics: more memory => faster; billed GB-s is duration*mem."""
    t, c = {}, {}
    for mem in (256, 1024, 4096):
        pool = PoolConfig(n_workers=1000, memory_mb=mem, simulate=True,
                          base_work_s=0.5, seed=0)
        _, _, rep = _run(pool)
        t[mem] = rep.response_time_s
        c[mem] = rep.bill.total_gb_s
    assert t[4096] < t[1024] < t[256]
    for rec_mem, bill in c.items():
        assert bill > 0
