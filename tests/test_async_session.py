"""The continuous-admission drain engine (ISSUE 3 tentpole): out-of-order
completion bitwise-parity vs InlineBackend, page-pool hit/eviction
accounting, partial-ledger resume after fault injection, early-result
delivery ordering, and continuous admission mid-drain."""
import numpy as np
import pytest

from repro.compile import PagePool
from repro.core import DMLData, DMLPlan, DMLSession
from repro.core.session import compile_request
from repro.data import make_irm_data, make_plr_data
from repro.serverless import InlineBackend, PoolConfig, make_backend


def _plr(n_obs, seed, *, learner="ridge", learner_params=None, n_rep=2,
         n_folds=3):
    data = DMLData.from_dict(make_plr_data(n_obs=n_obs, dim_x=5, theta=0.5,
                                           seed=seed))
    if learner_params is None:
        learner_params = {"reg": 1.0}
    plan = DMLPlan.for_model(
        "plr", learner=learner, learner_params=learner_params,
        n_folds=n_folds, n_rep=n_rep, seed=seed + 100)
    return plan, data


FAMILIES = [
    ("ridge", {"reg": 1.0}),
    ("ols", {}),
    ("lasso", {"reg": 0.01}),
    ("kernel_ridge", {"reg": 1.0, "n_landmarks": 32}),
    ("mlp", {"hidden": (8,), "n_steps": 20}),
]


# ---------------------------------------------------------------------------
# out-of-order completion: bitwise parity vs the synchronous inline path
# ---------------------------------------------------------------------------
def test_out_of_order_completion_bitwise_parity_all_families():
    """Tiny wave capacity forces many interleaved waves and out-of-order
    bucket completion across mixed learner families; every request's
    prediction tensor must be bitwise-identical to a synchronous
    InlineBackend drain of the same request."""
    cases = [_plr(100 + 7 * i, seed=i, learner=name, learner_params=params)
             for i, (name, params) in enumerate(FAMILIES)]
    # logistic rides along via the IRM propensity nuisance
    irm = (DMLPlan.for_model("irm", learner="ridge", n_folds=3, n_rep=2,
                             seed=77),
           DMLData.from_dict(make_irm_data(n_obs=130, dim_x=4, theta=0.4,
                                           seed=9)))
    cases.append(irm)

    sess = DMLSession(backend="wave",
                      pool=PoolConfig(n_workers=2, memory_mb=256))
    rids = [sess.submit(plan, data) for plan, data in cases]
    sess.run()
    assert sess.last_run_info.waves >= 2           # really interleaved

    for rid, (plan, data) in zip(rids, cases):
        ref = compile_request(plan, data)
        InlineBackend().run_requests([ref])
        np.testing.assert_array_equal(
            sess.request(rid).gathered_preds(), ref.gathered_preds())


def test_idle_session_keeps_telemetry_and_rejects_unknown_ids():
    """run()/wait()/poll() on an idle session neither clobber the last
    drain's telemetry nor invent a drain; unknown ids fail fast."""
    plan, data = _plr(100, seed=20)
    sess = DMLSession(backend="wave",
                      pool=PoolConfig(n_workers=2, memory_mb=256))
    rid = sess.submit(plan, data)
    sess.run()
    info = sess.last_run_info
    assert info.waves >= 1
    assert sess.run() == [] and sess.poll() == []
    assert sess.wait(rid).request_id == rid        # already-complete: ok
    assert sess.last_run_info is info              # telemetry preserved
    with pytest.raises(KeyError, match="unknown request id"):
        sess.wait(999)


def test_poll_interleaves_and_run_matches_batch():
    """Driving the engine wave-by-wave via poll() completes everything and
    matches a blocking run() bitwise."""
    plan_a, data_a = _plr(120, seed=1)
    plan_b, data_b = _plr(90, seed=2)
    sess = DMLSession(backend="wave",
                      pool=PoolConfig(n_workers=1, memory_mb=256))
    ra = sess.submit(plan_a, data_a)
    rb = sess.submit(plan_b, data_b)
    done = []
    for _ in range(100):
        done += sess.poll()
        if len(done) == 2:
            break
    assert sorted(done) == sorted([ra, rb])

    sess2 = DMLSession(backend="wave",
                       pool=PoolConfig(n_workers=1, memory_mb=256))
    sess2.submit(plan_a, data_a)
    sess2.submit(plan_b, data_b)
    res = sess2.run()
    np.testing.assert_array_equal(sess.result(ra).thetas, res[0].thetas)


def test_continuous_admission_mid_drain():
    """A request submitted while the drain is running joins the same
    drain (no barrier) and still returns its solo-run theta bitwise."""
    plan_a, data_a = _plr(150, seed=3, n_rep=4)
    plan_b, data_b = _plr(100, seed=4)
    sess = DMLSession(backend="wave",
                      pool=PoolConfig(n_workers=2, memory_mb=256))
    ra = sess.submit(plan_a, data_a)
    sess.poll()                                   # drain already moving
    rb = sess.submit(plan_b, data_b)              # late admission
    res_b = sess.wait(rb)
    info = sess.last_run_info
    assert len(info.wave_members) > 1
    assert any(rb in m and ra in m for m in info.wave_members)  # shared wave
    sess.wait(ra)

    ref = compile_request(plan_b, data_b)
    InlineBackend().run_requests([ref])
    np.testing.assert_array_equal(sess.request(rb).gathered_preds(),
                                  ref.gathered_preds())
    assert res_b.request_id == rb


# ---------------------------------------------------------------------------
# early-result delivery
# ---------------------------------------------------------------------------
def test_early_result_delivery_ordering():
    """A small request submitted after a large one completes first (its
    few invocations drain while the large grid is still executing), and
    its callback fires before the large request finishes."""
    big_plan, big_data = _plr(140, seed=5, n_rep=8)     # 16 invocations
    small_plan, small_data = _plr(80, seed=6, n_rep=1)  # 2 invocations
    order = []
    sess = DMLSession(backend="wave",
                      pool=PoolConfig(n_workers=2, memory_mb=256))
    rid_big = sess.submit(big_plan, big_data,
                          on_complete=lambda r: order.append(r.request_id))
    rid_small = sess.submit(small_plan, small_data,
                            on_complete=lambda r: order.append(r.request_id))
    res = sess.run()
    assert order == [rid_small, rid_big]          # early delivery
    assert sess.completion_order == [rid_small, rid_big]
    assert [r.request_id for r in res] == [rid_big, rid_small]  # submit order


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------
def test_page_pool_steady_state_zero_transfer():
    """Warm drains of the same datasets re-transfer nothing: page hit rate
    1.0 and zero host->device bytes after the warmup drain."""
    cases = [_plr(100 + i, seed=i) for i in range(3)]
    sess = DMLSession(backend="wave",
                      pool=PoolConfig(n_workers=8, memory_mb=1024))
    for plan, data in cases:
        sess.submit(plan, data)
    sess.run()                                    # warmup: cold transfers
    pool = sess.backend.pages
    assert pool.stats.misses >= 1
    warm0 = pool.stats.snapshot()
    for _ in range(3):                            # steady state
        for plan, data in cases:
            sess.submit(plan, data)
        sess.run()
    d = pool.stats.delta(warm0)
    assert d.bytes_h2d == 0
    assert d.misses == 0 and d.hits > 0
    assert d.hit_rate == 1.0
    assert d.stack_hits >= 1                      # same composition reused


def test_page_pool_shared_across_equal_data():
    """Two requests over equal-content datasets share one resident page
    (content fingerprint, not object identity)."""
    plan_a, data = _plr(100, seed=7)
    copy = DMLData(x=np.array(data.x), y=np.array(data.y),
                   d=np.array(data.d))
    sess = DMLSession(backend="inline")
    sess.submit(plan_a, data)
    sess.submit(_plr(100, seed=8)[0], copy)
    sess.run()
    assert sess.backend.pages.n_pages == 1


def test_page_pool_eviction_accounting(monkeypatch):
    """A byte budget below the traffic's dataset set forces LRU evictions
    and re-transfers, all visible in the stats (pages needed by the
    in-flight launch are never evicted).

    Runs chaos-free even under REPRO_CHAOS: injected retries re-touch
    resident pages and legitimately add hits, which would smear the
    exact transfer counts this test pins.  Estimate bitwise parity
    under chaos is tests/test_chaos.py's job; this one is about LRU
    byte accounting."""
    monkeypatch.delenv("REPRO_CHAOS", raising=False)
    page_bytes = 104 * 8 * 4                       # N_pad=104, P_pad=8
    pool = PagePool(byte_budget=page_bytes)        # fits exactly one page
    backend = make_backend("inline")
    backend.pages = pool
    cases = [_plr(100 + i, seed=10 + i) for i in range(3)]
    for _ in range(2):
        for p, d in cases:                         # one dataset per drain
            backend.run_requests([compile_request(p, d)])
    assert pool.stats.evictions >= 3               # LRU churn under budget
    # floor = the in-flight working set (one page + its cached stack),
    # which is never evicted even when it exceeds the budget
    assert pool.total_bytes <= 2 * page_bytes
    # every re-visit of an evicted dataset re-transferred: 2 rounds x 3
    assert pool.stats.misses == 6 and pool.stats.hits == 0
    assert pool.stats.bytes_h2d == pool.stats.misses * page_bytes

    pool.byte_budget = 10 * page_bytes             # now everything fits
    for p, d in cases:
        backend.run_requests([compile_request(p, d)])
    for p, d in cases:
        backend.run_requests([compile_request(p, d)])
    # one refill round (the tight phase's survivor is still resident),
    # then residency pays
    assert pool.stats.misses == 8
    assert pool.stats.hits == 4


def test_page_pool_disabled_by_budget_zero():
    sess = DMLSession(backend="inline",
                      pool=PoolConfig(page_pool_bytes=0))
    plan, data = _plr(100, seed=12)
    res = sess.estimate(plan, data)
    assert sess.backend.pages is None
    assert np.isfinite(res.theta)


# ---------------------------------------------------------------------------
# non-blocking dispatch (ISSUE 5)
# ---------------------------------------------------------------------------
def test_inflight_entries_excluded_from_pending_and_harvested_later(
        monkeypatch):
    """A dispatched bucket's invocations leave the scheduler's pending
    view immediately (no double dispatch) but only reach the ledger at
    harvest — a later step books them while new work dispatches.

    Chaos-free even under REPRO_CHAOS: injected failures retry and
    inflate the exact dispatched/harvested counts pinned below."""
    monkeypatch.delenv("REPRO_CHAOS", raising=False)
    backend = make_backend("inline")
    state = backend.begin_drain()
    for n, seed in ((100, 30), (300, 31)):        # two distinct buckets
        backend.admit(state, compile_request(*_plr(n, seed=seed)))

    assert backend.step(state)                    # dispatch bucket 1
    inflight = state.queue.in_flight_entries()
    assert inflight                               # really in flight
    done_before = sum(r.ledger.n_done for r in state.requests)
    groups = state.plan.pending_by_bucket(exclude=inflight)
    for entries in groups.values():               # no re-dispatch overlap
        assert not (set(entries) & inflight)

    while backend.step(state):                    # dispatch 2, harvest both
        pass
    assert state.queue.empty
    assert all(r.ledger.complete for r in state.requests)
    assert sum(r.ledger.n_done for r in state.requests) > done_before
    d = state.info.dispatch
    assert d.dispatched == d.harvested == 2
    assert d.host_overlap_s > 0.0                 # booking overlapped


def test_dispatch_queue_same_key_inflight_buckets():
    """Two in-flight buckets sharing one BucketKey (truncated topology
    waves / mid-drain admission produce these) must harvest cleanly out
    of order — regression test for the generated-dataclass __eq__ crash
    (list.remove comparing in-flight jax arrays elementwise)."""
    import jax
    from repro.compile import ProgramCache, dispatch_bucket, plan_buckets
    from repro.serverless import DispatchQueue, PendingBucket

    req = compile_request(*_plr(100, seed=50))
    bplan = plan_buckets([req])
    (bkey,) = bplan.buckets
    cache = ProgramCache()
    ents = [(0, int(i)) for i in req.ledger.pending()]
    bd1 = dispatch_bucket(bplan, cache, bkey, ents[:2])
    bd2 = dispatch_bucket(bplan, cache, bkey, ents[2:])
    q = DispatchQueue(8)
    booked = []
    book = lambda pb, res, el: booked.append(sorted(res))
    q.push(PendingBucket(dispatch=bd1), book)
    q.push(PendingBucket(dispatch=bd2), book)
    jax.block_until_ready([l.out for l in bd2.launches])
    q.harvest_ready(book)               # may book bd2 before bd1
    q.harvest_all(book)
    assert q.empty
    assert sorted(e for b in booked for e in b) == sorted(ents)


def test_dispatch_queue_inflight_cap_forces_harvest():
    """max_inflight bounds device-side liveness: pushing beyond the cap
    force-harvests the oldest bucket instead of growing the queue."""
    from repro.serverless import PoolConfig as PC
    backend = make_backend("inline", PC(max_inflight=1))
    state = backend.begin_drain()
    for i, n in enumerate((100, 300, 600)):       # three buckets
        backend.admit(state, compile_request(*_plr(n, seed=40 + i)))
    while backend.step(state):
        assert len(state.queue) <= 1
    assert all(r.ledger.complete for r in state.requests)


# ---------------------------------------------------------------------------
# partial-ledger resume after fault injection
# ---------------------------------------------------------------------------
def test_partial_ledger_resume_after_fault_abort():
    """Retry-budget exhaustion mid-drain leaves partially-complete
    ledgers; swapping in a healthy pool resumes exactly the missing
    invocations and the result matches the clean path bitwise."""
    plan, data = _plr(110, seed=13, n_rep=4)
    # seed chosen so the first wave under the identity-keyed fault plan
    # (serverless/chaos.py) mixes a success with the budget-exhausting
    # failure — the ledger is left genuinely partial
    doomed = PoolConfig(n_workers=2, memory_mb=256, failure_rate=0.5,
                        max_retries=0, seed=3)
    sess = DMLSession(backend="wave", pool=doomed)
    rid = sess.submit(plan, data)
    with pytest.raises(RuntimeError, match="retry budget"):
        sess.run()
    ledger = sess._queue[0].ledger
    n_done = ledger.n_done
    assert 0 < n_done < ledger.n_invocations       # genuinely partial
    assert not ledger.complete

    sess.backend = make_backend("wave", PoolConfig(n_workers=2,
                                                   memory_mb=256))
    res, = sess.run()
    assert res.request_id == rid
    resumed = sess.request(rid)
    assert resumed.ledger.complete
    # only the missing invocations were re-executed after the swap
    assert resumed.report.bill.n_invocations < 2 * resumed.ledger.n_invocations
    ref = compile_request(plan, data)
    InlineBackend().run_requests([ref])
    np.testing.assert_array_equal(resumed.gathered_preds(),
                                  ref.gathered_preds())
