"""End-to-end behaviour tests for the paper's system: the full
DoubleML-Serverless pipeline with faults + checkpointing + both scaling
levels, the paper's headline latency property, and serving."""
import os

import jax
import numpy as np
import pytest

from repro.core import DoubleMLServerless
from repro.data import make_bonus_data
from repro.serverless import PoolConfig


def test_full_pipeline_with_faults_and_ledger(tmp_path):
    data = make_bonus_data()
    path = os.path.join(tmp_path, "ledger.msgpack")
    est = DoubleMLServerless(
        model="plr", n_folds=5, n_rep=3, learner="ridge",
        learner_params={"reg": 1.0}, scaling="n_folds*n_rep",
        pool=PoolConfig(n_workers=4, memory_mb=512,
                        scaling="n_folds*n_rep", failure_rate=0.15,
                        max_retries=6, checkpoint_path=path, seed=1))
    res = est.fit(data)
    assert os.path.exists(path)
    assert res.report.failures > 0
    clean = DoubleMLServerless(
        model="plr", n_folds=5, n_rep=3, learner="ridge",
        learner_params={"reg": 1.0}, scaling="n_rep",
        pool=PoolConfig(n_workers=8, memory_mb=1024)).fit(data)
    # faults + different scaling level must not change the estimate
    assert res.theta == pytest.approx(clean.theta, abs=5e-4)


def test_paper_headline_latency_property():
    """Paper §3: with enough elasticity, estimating the WHOLE grid takes
    about as long as one invocation (simulated timing model)."""
    data = make_bonus_data()
    # scarce workers: wall time >> one invocation
    scarce = DoubleMLServerless(
        model="plr", n_folds=5, n_rep=10, learner="ridge",
        scaling="n_rep",
        pool=PoolConfig(n_workers=1, memory_mb=256, simulate=True,
                        base_work_s=0.5))
    r1 = scarce.fit(data)
    # elastic: every invocation in one wave
    elastic = DoubleMLServerless(
        model="plr", n_folds=5, n_rep=10, learner="ridge",
        scaling="n_rep",
        pool=PoolConfig(n_workers=1000, memory_mb=256, simulate=True,
                        base_work_s=0.5))
    r2 = elastic.fit(data)
    per_inv = np.mean([b.duration_s for b in r2.report.bill.records])
    assert r2.report.response_time_s < 1.5 * per_inv + 0.1
    assert r1.report.response_time_s > 3 * r2.report.response_time_s


def test_scaling_cost_time_tradeoff_simulated():
    """Fig 3 shape: per-fold scaling is faster, costs slightly more."""
    data = make_bonus_data()
    def run(scaling):
        est = DoubleMLServerless(
            model="plr", n_folds=5, n_rep=6, learner="ridge",
            scaling=scaling,
            pool=PoolConfig(n_workers=10_000, memory_mb=1024, simulate=True,
                            base_work_s=0.4, scaling=scaling))
        return est.fit(data).report
    per_split = run("n_rep")
    per_fold = run("n_folds*n_rep")
    assert per_fold.response_time_s < per_split.response_time_s
    assert per_fold.bill.n_invocations == 5 * per_split.bill.n_invocations
    assert per_fold.bill.total_gb_s < 2.0 * per_split.bill.total_gb_s


def test_serving_engine_slot_reuse():
    from repro.configs import get_arch
    from repro.models import build_model, init_tree
    from repro.serving import Engine

    cfg = get_arch("h2o-danube-3-4b", reduced=True)
    bundle = build_model(cfg, remat="none", attn_chunk=32)
    params = init_tree(bundle.decls, jax.random.key(0))
    eng = Engine(bundle, params)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, rng.integers(4, 16))
               .astype(np.int32) for _ in range(5)]
    outs = eng.serve_requests(prompts, batch_size=2, prompt_len=16, n_gen=4)
    assert len(outs) == 5
    assert all(o.shape == (4,) for o in outs)


def test_dml_text_confounder_smoke():
    """DML where the nuisance learner is an LM-backbone encoder — ties the
    arch zoo to the estimation layer (examples/dml_text_confounders.py)."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from examples.dml_text_confounders import run_small
    res = run_small(n_obs=120, n_rep=1, n_folds=3, steps=60)
    assert np.isfinite(res["theta"])
    assert abs(res["theta"] - res["theta0"]) < 6 * res["se"] + 0.4
