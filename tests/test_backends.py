"""ExecutionBackend parity: the same (DMLPlan, DMLData, seed) must yield
identical (M,K,L,N) predictions and theta on every backend — including the
wave backend under fault injection, retries, and speculation."""
import numpy as np
import pytest

from repro.core import DMLData, DMLPlan, estimate
from repro.core.session import assemble_result, compile_request
from repro.data import make_irm_data, make_plr_data
from repro.serverless import PoolConfig, make_backend
from repro.serverless.backends import (
    BACKEND_NAMES, InlineBackend, ShardedBackend, WaveBackend,
)

POOL = PoolConfig(n_workers=3, memory_mb=512)


def _run_backend(backend, plan, data):
    """Fresh compile + drain on one backend; returns (preds, result)."""
    req = compile_request(plan, data)
    backend.run_requests([req])
    assert req.ledger.complete
    return req.gathered_preds(), assemble_result(plan, data, req)


@pytest.fixture(scope="module")
def plr_case():
    data = DMLData.from_dict(make_plr_data(n_obs=140, dim_x=5, theta=0.5,
                                           seed=3))
    plan = DMLPlan.for_model("plr", learner="ridge",
                             learner_params={"reg": 1.0}, n_folds=3, n_rep=2,
                             seed=7)
    return plan, data


@pytest.fixture(scope="module")
def irm_case():
    data = DMLData.from_dict(make_irm_data(n_obs=160, dim_x=4, theta=0.4,
                                           seed=6))
    plan = DMLPlan.for_model("irm", learner="ridge", n_folds=3, n_rep=2,
                             seed=11)
    return plan, data


@pytest.mark.parametrize("case", ["plr_case", "irm_case"])
@pytest.mark.parametrize("scaling", ["n_rep", "n_folds*n_rep"])
def test_backend_parity(case, scaling, request):
    plan, data = request.getfixturevalue(case)
    plan = plan.replace(scaling=scaling)
    p_inline, r_inline = _run_backend(InlineBackend(POOL), plan, data)
    p_wave, r_wave = _run_backend(WaveBackend(POOL), plan, data)
    p_shard, r_shard = _run_backend(ShardedBackend(POOL), plan, data)
    np.testing.assert_allclose(p_wave, p_inline, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(p_shard, p_inline, rtol=1e-6, atol=1e-6)
    assert r_wave.theta == pytest.approx(r_inline.theta, abs=1e-7)
    # shard_map can retile the per-lane reductions, so the sharded
    # backend agrees to float tolerance, not bitwise (it is exact on a
    # 1-device mesh; the multihost-smoke job runs this 8-way)
    assert r_shard.theta == pytest.approx(r_inline.theta, abs=1e-6)


def test_wave_parity_under_faults_and_stragglers(plr_case):
    """Fault injection + retries + speculative duplicates change the
    schedule, never the estimate."""
    plan, data = plr_case
    p_ref, r_ref = _run_backend(InlineBackend(POOL), plan, data)
    chaotic = PoolConfig(n_workers=2, memory_mb=512, failure_rate=0.4,
                         straggler_rate=0.3, max_retries=10, seed=3)
    p_wave, r_wave = _run_backend(WaveBackend(chaotic), plan, data)
    assert r_wave.report.failures > 0
    np.testing.assert_allclose(p_wave, p_ref, rtol=1e-6, atol=1e-6)
    assert r_wave.theta == pytest.approx(r_ref.theta, abs=1e-7)


def test_backend_selected_via_plan(plr_case):
    plan, data = plr_case
    thetas = {name: estimate(plan.replace(backend=name), data).theta
              for name in BACKEND_NAMES}
    # the unsharded schedulers are bitwise-identical; sharded agrees to
    # float tolerance on multi-device meshes (exact on 1 device)
    assert thetas["wave"] == thetas["inline"] == thetas["topology"]
    assert thetas["sharded"] == pytest.approx(thetas["inline"], abs=1e-6)


def test_sharded_backend_stays_warm(plr_case):
    """Compiled SPMD programs are cached by learner spec, not object
    identity — a second request with an equal spec reuses the program."""
    plan, data = plr_case
    backend = ShardedBackend(POOL)
    _run_backend(backend, plan, data)
    assert len(backend._programs) == 1
    _run_backend(backend, plan, data)        # fresh partial, same spec
    assert len(backend._programs) == 1
    other = plan.replace(
        nuisances=tuple(
            type(ns).make(ns.name, ns.target, ns.learner, {"reg": 9.0})
            for ns in plan.nuisances))
    _run_backend(backend, other, data)       # different params -> new entry
    assert len(backend._programs) == 2


def test_backends_resume_from_ledger(plr_case):
    """All backends skip pre-completed ledger rows (durable resume)."""
    plan, data = plr_case
    for name in BACKEND_NAMES:
        req = compile_request(plan, data)
        make_backend(name, POOL).run_requests([req])
        done = req.ledger
        req2 = compile_request(plan, data, ledger=done)
        make_backend(name, POOL).run_requests([req2])
        assert req2.report.bill.n_invocations == 0
        np.testing.assert_array_equal(req2.gathered_preds(),
                                      req.gathered_preds())


def test_bucketed_multi_request_parity_all_backends():
    """The compiler's acceptance property: a mixed-N, mixed-model batch of
    requests drained through shared buckets yields identical predictions
    and theta on Inline, Sharded, and Wave — including Wave under fault
    injection + speculation."""
    cases = [
        (DMLPlan.for_model("plr", learner="ridge",
                           learner_params={"reg": 1.0}, n_folds=3, n_rep=2,
                           seed=7),
         DMLData.from_dict(make_plr_data(n_obs=140, dim_x=5, theta=0.5,
                                         seed=3))),
        (DMLPlan.for_model("plr", learner="ridge",
                           learner_params={"reg": 1.0}, n_folds=3, n_rep=2,
                           seed=9),
         DMLData.from_dict(make_plr_data(n_obs=200, dim_x=5, theta=0.2,
                                         seed=4))),
        (DMLPlan.for_model("irm", learner="ridge", n_folds=3, n_rep=2,
                           seed=11),
         DMLData.from_dict(make_irm_data(n_obs=120, dim_x=4, theta=0.4,
                                         seed=6))),
    ]

    def drain(backend):
        reqs = [compile_request(p, d) for p, d in cases]
        info = backend.run_requests(reqs)
        assert all(r.ledger.complete for r in reqs)
        preds = [r.gathered_preds() for r in reqs]
        thetas = [assemble_result(p, d, r).theta
                  for (p, d), r in zip(cases, reqs)]
        return preds, thetas, info

    p_in, t_in, info_in = drain(InlineBackend(POOL))
    # sublane-aligned N buckets: the plr requests (N=140 -> 144, 200 ->
    # 200) and irm (ridge + logistic at N=120) give 4 buckets for 4
    # segments; cross-request sharing now happens at the fused-launch
    # level (equal-shape blocks), not by collapsing N onto pow2
    assert info_in.buckets == 4
    chaotic = PoolConfig(n_workers=2, memory_mb=512, failure_rate=0.3,
                         straggler_rate=0.2, max_retries=10, seed=5)
    p_wv, t_wv, info_wv = drain(WaveBackend(chaotic))
    p_sh, t_sh, _ = drain(ShardedBackend(POOL))
    for a, b in zip(p_wv, p_in):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
    for a, b in zip(p_sh, p_in):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
    assert t_wv == pytest.approx(t_in, abs=1e-7)
    assert t_sh == pytest.approx(t_in, abs=1e-6)   # shard retiling noise


def test_key_consuming_learners_identical_across_backends():
    """Per-task fold_in keys fix the PR-1 caveat: kernel_ridge (key-
    consuming) now produces bitwise-identical predictions on every
    backend and under any wave composition."""
    data = DMLData.from_dict(make_plr_data(n_obs=100, dim_x=4, theta=0.5,
                                           seed=12))
    plan = DMLPlan.for_model("plr", learner="kernel_ridge",
                             learner_params={"reg": 1.0, "n_landmarks": 32},
                             n_folds=3, n_rep=1, seed=21)
    p_in, r_in = _run_backend(InlineBackend(POOL), plan, data)
    p_wv, r_wv = _run_backend(
        WaveBackend(PoolConfig(n_workers=1, memory_mb=256)), plan, data)
    p_sh, r_sh = _run_backend(ShardedBackend(POOL), plan, data)
    np.testing.assert_array_equal(p_wv, p_in)
    np.testing.assert_allclose(p_sh, p_in, rtol=1e-6, atol=1e-6)
    assert r_wv.theta == r_in.theta
    assert r_sh.theta == pytest.approx(r_in.theta, abs=1e-6)


def test_make_backend_registry():
    assert make_backend("wave", POOL).pool is POOL
    with pytest.raises(KeyError):
        make_backend("nope")
    inst = InlineBackend(POOL)
    assert make_backend(inst) is inst
