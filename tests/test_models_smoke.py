"""Per-arch smoke tests (spec: reduced config, one forward/train step on CPU,
output shapes + no NaNs) + prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch
from repro.configs.base import ShapeConfig
from repro.models import build_model, init_tree
from repro.serving.engine import init_cache

SMOKE_TRAIN = ShapeConfig("smoke_train", seq_len=64, global_batch=2,
                          kind="train")
SMOKE_PREFILL = ShapeConfig("smoke_prefill", seq_len=64, global_batch=2,
                            kind="prefill")
SMOKE_DECODE = ShapeConfig("smoke_decode", seq_len=64, global_batch=2,
                           kind="decode")


def _batch(bundle, shape, key):
    out = {}
    for name, d in bundle.input_specs(shape).items():
        key, k = jax.random.split(key)
        if d.dtype == jnp.int32:
            out[name] = jax.random.randint(k, d.shape, 0,
                                           bundle.arch.vocab_size)
        else:
            out[name] = jax.random.normal(k, d.shape).astype(d.dtype)
    return out


@pytest.fixture(scope="module")
def bundles():
    cache = {}
    def get(name):
        if name not in cache:
            cfg = get_arch(name, reduced=True)
            bundle = build_model(cfg, remat="none", attn_chunk=32)
            params = init_tree(bundle.decls, jax.random.key(0))
            cache[name] = (bundle, params)
        return cache[name]
    return get


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_shapes_and_finite(bundles, arch):
    bundle, params = bundles(arch)
    batch = _batch(bundle, SMOKE_TRAIN, jax.random.key(1))
    loss, metrics = jax.jit(bundle.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: bundle.loss_fn(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32)))) for x in
             jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_and_decode_shapes(bundles, arch):
    bundle, params = bundles(arch)
    cfg = bundle.arch
    logits, cache = jax.jit(bundle.prefill_fn)(
        params, _batch(bundle, SMOKE_PREFILL, jax.random.key(2)))
    assert logits.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    dcache = init_cache(bundle, SMOKE_DECODE)
    dbatch = _batch(bundle, SMOKE_DECODE, jax.random.key(3))
    dec = jax.jit(bundle.decode_fn)
    l2, dcache = dec(params, dcache, dbatch)
    l3, dcache = dec(params, dcache, dbatch)
    assert l2.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(l3, np.float32)))


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "deepseek-v2-lite-16b",
                                  "h2o-danube-3-4b", "xlstm-350m",
                                  "zamba2-7b"])
def test_prefill_decode_consistency(bundles, arch):
    """Greedy next-token from prefill(prompt) must match prefill(prompt+tok)
    vs decode(tok) logits — cache correctness end-to-end."""
    bundle, params = bundles(arch)
    cfg = bundle.arch
    key = jax.random.key(4)
    prompt = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": prompt}
    for name, d in bundle.input_specs(SMOKE_PREFILL).items():
        if name not in batch and d.dtype != jnp.int32:
            batch[name] = jnp.zeros((d.shape[0], *d.shape[1:]), d.dtype)
        elif name not in batch:
            batch[name] = jnp.zeros(d.shape, jnp.int32)
    if "frames" in batch:
        batch["frames"] = batch["frames"][:, :32]
    logits1, cache = jax.jit(bundle.prefill_fn)(params, batch)
    from repro.serving.engine import grow_cache
    cache = grow_cache(cfg, cache, 4)
    tok = jnp.argmax(logits1, -1)[:, None].astype(jnp.int32)
    dbatch = {"tokens": tok}
    logits2, _ = jax.jit(bundle.decode_fn)(params, cache, dbatch)
    # oracle: prefill over the extended prompt
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([prompt, tok], axis=1)
    if "frames" in batch2:
        batch2["frames"] = jnp.zeros(
            (2, 33, cfg.d_frontend), batch["frames"].dtype)
    logits3, _ = jax.jit(bundle.prefill_fn)(params, batch2)
    a = np.asarray(logits2, np.float32)
    b = np.asarray(logits3, np.float32)
    # bf16 rounding differs with chunk boundaries; require semantic agreement:
    # same greedy token and tightly correlated logits (recurrent stacks
    # re-associate more reductions => slightly looser bound)
    assert (a.argmax(-1) == b.argmax(-1)).all()
    # moe: discrete routing flips under rounding; ssm/hybrid: re-associated
    # recurrent reductions
    tol = 0.10 if cfg.family in ("ssm", "hybrid", "moe") else 0.05
    denom = np.maximum(np.abs(b).max(), 1.0)
    assert np.abs(a - b).max() / denom < tol, np.abs(a - b).max()


def test_reduced_configs_are_small():
    for arch in ARCH_NAMES:
        cfg = get_arch(arch, reduced=True)
        assert cfg.param_count() < 20e6, arch
