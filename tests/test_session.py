"""DMLSession: many estimation requests fused into shared waves on one
warm backend, each returning the theta it would get running alone."""
import numpy as np
import pytest

from repro.core import DMLData, DMLPlan, DMLSession, estimate
from repro.data import make_irm_data, make_plr_data
from repro.serverless import PoolConfig


def _plr_plan(seed, **kw):
    return DMLPlan.for_model("plr", learner="ridge",
                             learner_params={"reg": 1.0}, n_folds=3, n_rep=2,
                             seed=seed, **kw)


def test_session_batches_two_requests_into_shared_waves():
    """The acceptance property: >= 2 concurrent requests share waves on
    one backend and every theta matches its solo run exactly."""
    data_a = DMLData.from_dict(make_plr_data(n_obs=150, dim_x=5, theta=0.5,
                                             seed=1))
    data_b = DMLData.from_dict(make_plr_data(n_obs=110, dim_x=4, theta=0.2,
                                             seed=2))
    plan_a, plan_b = _plr_plan(seed=7), _plr_plan(seed=13)
    # capacity of 2 lanes/wave forces several waves -> real interleaving
    pool = PoolConfig(n_workers=2, memory_mb=256)

    sess = DMLSession(backend="wave", pool=pool)
    rid_a = sess.submit(plan_a, data_a)
    rid_b = sess.submit(plan_b, data_b)
    res_a, res_b = sess.run()
    info = sess.last_run_info

    assert info.shared_waves >= 1                 # grids really fused
    assert info.waves >= 2                        # capacity-limited batching
    assert {rid_a, rid_b} <= {m for mm in info.wave_members for m in mm}

    # solo runs (default capacity): wave composition differs, thetas don't
    # (fused-batch shape only moves float32 reduction order, ~1e-8)
    solo_a = estimate(plan_a, data_a, backend="wave")
    solo_b = estimate(plan_b, data_b, backend="wave")
    np.testing.assert_allclose(res_a.theta, solo_a.theta, rtol=0, atol=1e-6)
    np.testing.assert_allclose(res_b.theta, solo_b.theta, rtol=0, atol=1e-6)
    np.testing.assert_allclose(res_a.se, solo_a.se, rtol=1e-5)
    assert sess.result(rid_a).theta == res_a.theta
    assert res_a.request_id == rid_a


def test_session_mixed_models_and_faults():
    """PLR + IRM co-scheduled under fault injection: schedules differ,
    estimates don't."""
    data_p = DMLData.from_dict(make_plr_data(n_obs=130, dim_x=4, theta=0.5,
                                             seed=3))
    data_i = DMLData.from_dict(make_irm_data(n_obs=170, dim_x=4, theta=0.4,
                                             seed=4))
    plan_p = _plr_plan(seed=21)
    plan_i = DMLPlan.for_model("irm", learner="ridge", n_folds=3, n_rep=2,
                               seed=22)
    chaotic = PoolConfig(n_workers=2, memory_mb=256, failure_rate=0.3,
                         max_retries=10, seed=5)
    sess = DMLSession(backend="wave", pool=chaotic)
    sess.submit(plan_p, data_p)
    sess.submit(plan_i, data_i)
    res_p, res_i = sess.run()
    assert res_p.report.failures + res_i.report.failures > 0
    clean_p = estimate(plan_p, data_p)
    clean_i = estimate(plan_i, data_i)
    np.testing.assert_allclose(res_p.theta, clean_p.theta, rtol=0, atol=1e-7)
    np.testing.assert_allclose(res_i.theta, clean_i.theta, rtol=0, atol=1e-7)


@pytest.mark.parametrize("backend", ["inline", "sharded"])
def test_session_other_backends(backend):
    data = DMLData.from_dict(make_plr_data(n_obs=120, dim_x=4, theta=0.5,
                                           seed=6))
    sess = DMLSession(backend=backend)
    sess.submit(_plr_plan(seed=31), data)
    sess.submit(_plr_plan(seed=32), data)
    res = sess.run()
    solo = estimate(_plr_plan(seed=31), data, backend=backend)
    assert res[0].theta == solo.theta


def test_session_stays_warm_across_runs():
    """The backend (and its caches) persist across run() calls."""
    data = DMLData.from_dict(make_plr_data(n_obs=100, dim_x=3, theta=0.5,
                                           seed=8))
    sess = DMLSession(backend="sharded")
    first = sess.estimate(_plr_plan(seed=41), data)
    programs = dict(sess.backend._programs)
    second = sess.estimate(_plr_plan(seed=41), data)
    assert first.theta == second.theta
    assert sess.backend._programs.keys() >= programs.keys()


def test_session_keeps_queue_and_ledgers_on_backend_abort():
    """A mid-drain backend failure (retry budget) must not discard queued
    requests: they stay queued with their ledgers and a later run()
    resumes them."""
    from repro.serverless import make_backend

    data = DMLData.from_dict(make_plr_data(n_obs=90, dim_x=3, theta=0.5,
                                           seed=10))
    doomed = PoolConfig(n_workers=2, failure_rate=1.0, max_retries=0, seed=1)
    sess = DMLSession(backend="wave", pool=doomed)
    rid = sess.submit(_plr_plan(seed=61), data)
    with pytest.raises(RuntimeError, match="retry budget"):
        sess.run()
    assert len(sess._queue) == 1                   # request not lost
    sess.backend = make_backend("wave", PoolConfig(n_workers=2))
    res, = sess.run()
    assert res.request_id == rid
    solo = estimate(_plr_plan(seed=61), data)
    np.testing.assert_allclose(res.theta, solo.theta, rtol=0, atol=1e-6)


def test_session_empty_run_and_billing_split():
    sess = DMLSession(backend="wave", pool=PoolConfig(n_workers=4))
    assert sess.run() == []
    data = DMLData.from_dict(make_plr_data(n_obs=100, dim_x=3, theta=0.5,
                                           seed=9))
    sess.submit(_plr_plan(seed=51), data)
    sess.submit(_plr_plan(seed=52), data)
    res = sess.run()
    # per-request billing: each request pays exactly its own M*L invocations
    for r in res:
        assert r.report.bill.n_invocations == 2 * 2
    assert sess.run() == []                       # queue drained
