"""Megabatch compiler: bucket planning edge cases, padding parity, and the
warm spec-keyed program cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compile import plan_buckets, run_bucket
from repro.core import DMLData, DMLPlan, DMLSession, TaskGrid, estimate
from repro.core.crossfit import PaddingStats, pow2_bucket
from repro.core.session import compile_raw_request, compile_request
from repro.data import make_irm_data, make_plr_data
from repro.learners import get_batched_learner, get_learner
from repro.serverless import InlineBackend, PoolConfig, WaveBackend


def _plr(n_obs, seed, *, n_folds=3, n_rep=2, learner="ridge", **kw):
    data = DMLData.from_dict(make_plr_data(n_obs=n_obs, dim_x=5, theta=0.5,
                                           seed=seed))
    plan = DMLPlan.for_model("plr", learner=learner,
                             learner_params=kw.pop("learner_params",
                                                   {"reg": 1.0}),
                             n_folds=n_folds, n_rep=n_rep, seed=seed + 100,
                             **kw)
    return plan, data


# ---------------------------------------------------------------------------
# bucket planning
# ---------------------------------------------------------------------------
def test_pow2_bucket_rule():
    assert pow2_bucket(1) == 8           # floor
    assert pow2_bucket(8) == 8
    assert pow2_bucket(9) == 16
    assert pow2_bucket(100) == 128
    assert pow2_bucket(128) == 128


def test_mixed_n_requests_share_one_bucket():
    """Requests with different N in the same sublane-aligned bucket share
    a program; mixed learner families (IRM ridge + logistic) split
    buckets.  (N buckets are aligned to the 8-row sublane quantum since
    ISSUE 5 — the pow2 rule left N as the dominant waste axis.)"""
    reqs = [compile_request(*_plr(n, seed=i))
            for i, n in enumerate((97, 100, 104))]
    plan = plan_buckets(reqs)
    assert len(plan.buckets) == 1                      # all align to N=104
    key = plan.buckets[0]
    assert key.n_pad == 104 and key.p_pad == 8
    assert plan.page(0, key).shape == (104, 8)

    irm_data = DMLData.from_dict(make_irm_data(n_obs=100, dim_x=4, theta=0.4,
                                               seed=5))
    irm_plan = DMLPlan.for_model("irm", learner="ridge",
                                 learner_params={"reg": 1.0}, n_folds=3,
                                 n_rep=2, seed=9)
    plan2 = plan_buckets(reqs + [compile_request(irm_plan, irm_data)])
    # ridge buckets fuse across PLR+IRM (both N=104); logistic is its own
    assert len(plan2.buckets) == 2


def test_pending_by_bucket_skips_done_rows():
    req = compile_request(*_plr(100, seed=0))
    InlineBackend().run_requests([req])
    assert req.ledger.complete
    plan = plan_buckets([req])
    assert plan.pending_by_bucket() == {}


def test_opaque_callable_buckets_use_exact_shapes():
    grid = TaskGrid(2, 3, 2)
    n, p = 101, 5
    rng = np.random.default_rng(0)
    from repro.core.crossfit import draw_fold_masks
    masks = draw_fold_masks(n, 3, 2, 0)
    train_w = np.repeat((~masks).astype(np.float32)[:, :, None], 2, axis=2)
    req = compile_raw_request(
        grid, "n_rep", rng.normal(size=(n, p)).astype(np.float32),
        rng.normal(size=(2, n)).astype(np.float32), train_w,
        get_learner("ridge", {"reg": 1.0}), jax.random.key(0))
    plan = plan_buckets([req])
    key = plan.buckets[0]
    assert (key.n_pad, key.p_pad) == (n, p)            # no padding proof


def test_single_task_buckets_execute():
    """Per-fold scaling with n_rep=1: every invocation is a single task;
    buckets of size 1 still pad, compile, and round-trip correctly."""
    plan, data = _plr(60, seed=3, n_rep=1, scaling="n_folds*n_rep")
    req = compile_request(plan, data)
    bplan = plan_buckets([req])
    (bkey,) = bplan.buckets
    from repro.compile import ProgramCache
    cache = ProgramCache()
    results, _ = run_bucket(bplan, cache, bkey, [(0, 0)])
    assert results[(0, 0)].shape == (1, data.n_obs)
    ref = estimate(plan, data, backend="inline")
    wav = estimate(plan, data, backend="wave")
    np.testing.assert_allclose(ref.theta, wav.theta, rtol=0, atol=1e-7)


def test_ragged_folds_parity():
    """K does not divide N: fold sizes differ by one; bucketed execution
    must agree with the inline reference exactly."""
    plan, data = _plr(101, seed=4, n_folds=3)
    req_i = compile_request(plan, data)
    InlineBackend().run_requests([req_i])
    req_w = compile_request(plan, data)
    WaveBackend(PoolConfig(n_workers=2, memory_mb=256)).run_requests([req_w])
    np.testing.assert_allclose(req_w.gathered_preds(),
                               req_i.gathered_preds(), rtol=1e-6, atol=1e-6)


def test_irm_subset_masks_shrink_effective_n():
    """IRM's d0/d1 nuisances train on strict subsets; the padded-masked
    bucket fits must agree with the inline reference."""
    data = DMLData.from_dict(make_irm_data(n_obs=150, dim_x=4, theta=0.4,
                                           seed=6))
    plan = DMLPlan.for_model("irm", learner="ridge", n_folds=3, n_rep=2,
                             seed=11)
    req = compile_request(plan, data)
    # subset weights really shrink the training rows
    w_all = req.train_w[0, 0, 2]                     # ml_m: subset "all"
    w_d1 = req.train_w[0, 0, 1]                      # ml_g1: subset d1
    assert w_d1.sum() < w_all.sum()
    req_i = compile_request(plan, data)
    InlineBackend().run_requests([req_i])
    req_w = compile_request(plan, data)
    WaveBackend(PoolConfig(n_workers=3, memory_mb=256)).run_requests([req_w])
    np.testing.assert_allclose(req_w.gathered_preds(),
                               req_i.gathered_preds(), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# padded-masked fit parity, every learner family
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,params,tol", [
    ("ridge", {"reg": 1.0}, 1e-5),
    ("ols", {}, 1e-5),
    ("lasso", {"reg": 0.01}, 1e-4),
    ("logistic", {"reg": 1.0}, 1e-5),
    ("kernel_ridge", {"reg": 1.0, "n_landmarks": 32, "gamma": 0.2}, 1e-5),
    ("mlp", {"hidden": (8,), "n_steps": 30}, 1e-4),
])
def test_padded_masked_fit_matches_unpadded(name, params, tol):
    """The compiler's contract: padding rows (valid=0, w=0) and padded
    feature lanes never move a fit.  Exact parity (to float reduction
    order) on every learner family, key-consuming ones included."""
    rng = np.random.default_rng(0)
    B, N, P = 6, 100, 5
    xs = rng.normal(size=(B, N, P)).astype(np.float32)
    y = rng.normal(size=(B, N)).astype(np.float32)
    w = (rng.random((B, N)) > 0.3).astype(np.float32)
    if name == "logistic":
        y = (y > 0).astype(np.float32)
    valid = np.ones((B, N), np.float32)
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.key(7), i))(
        jnp.arange(B))
    fn = get_batched_learner(name, params)

    def pad(a, n_extra, p_extra=0):
        if a.ndim == 3:
            return np.pad(a, ((0, 0), (0, n_extra), (0, p_extra)))
        return np.pad(a, ((0, 0), (0, n_extra)))

    p_extra = 0 if name == "mlp" else 3      # mlp buckets at exact P
    out = np.asarray(fn(jnp.asarray(xs), jnp.asarray(y), jnp.asarray(w),
                        jnp.asarray(valid), keys))
    outp = np.asarray(fn(jnp.asarray(pad(xs, 28, p_extra)),
                         jnp.asarray(pad(y, 28)), jnp.asarray(pad(w, 28)),
                         jnp.asarray(pad(valid, 28)), keys))
    np.testing.assert_allclose(outp[:, :N], out, rtol=tol, atol=tol)
    assert float(np.abs(outp[:, N:]).max()) == 0.0   # masked tail exact 0


# ---------------------------------------------------------------------------
# warm program cache + padding accounting
# ---------------------------------------------------------------------------
def test_program_cache_hits_on_repeat_traffic():
    """Repeat traffic through a session re-uses compiled programs: the
    second run() of same-bucket requests traces nothing new."""
    sess = DMLSession(backend="wave", pool=PoolConfig(n_workers=8))
    sess.submit(*_plr(98, seed=1))
    sess.submit(*_plr(100, seed=2))
    sess.run()
    stats = sess.backend.compiler.stats
    misses_first = stats.misses
    assert misses_first >= 1
    assert sess.last_run_info.buckets == 1           # N=98/100 align to 104
    sess.submit(*_plr(99, seed=3))                   # new N, same bucket
    sess.submit(*_plr(104, seed=4))                  # aligns to 104 too
    sess.run()
    assert stats.misses == misses_first              # zero new traces
    assert stats.hits > 0
    assert 0.0 < stats.hit_rate <= 1.0


def test_padding_stats_accounting():
    s = PaddingStats(true_cells=80, padded_cells=100, tasks=8,
                     padded_tasks=16)
    assert s.waste_frac == pytest.approx(0.2)
    merged = s.merge(PaddingStats(20, 100, 2, 4))
    assert merged.true_cells == 100 and merged.padded_cells == 200
    assert PaddingStats().waste_frac == 0.0


def test_padding_stats_per_axis_breakdown():
    """B/N/P waste decompose independently: padded lanes, padded rows
    inside real lanes, padded feature columns inside real lanes."""
    s = PaddingStats(true_cells=600, padded_cells=2048, tasks=8,
                     padded_tasks=16, lane_cells=8 * 128,
                     true_feats=8 * 5, padded_feats=8 * 8)
    assert s.b_waste_frac == pytest.approx(0.5)
    assert s.n_waste_frac == pytest.approx(1 - 600 / 1024)
    assert s.p_waste_frac == pytest.approx(1 - 5 / 8)
    assert PaddingStats().n_waste_frac == 0.0
    assert PaddingStats().p_waste_frac == 0.0


def test_small_bucket_launches_at_aligned_tail_size():
    """The ISSUE 4 padding fix: a bucket with fewer tasks than B_BLOCK
    launches at its sublane-aligned size instead of padding to the full
    block (the regression that put asyncdrain B-waste at ~65%)."""
    from repro.compile import ProgramCache
    plan, data = _plr(100, seed=6)                 # 4 inv x 3 tasks = 12
    req = compile_request(plan, data)
    bplan = plan_buckets([req])
    (bkey,) = bplan.buckets
    cache = ProgramCache()
    entries = [(0, int(i)) for i in req.ledger.pending()]
    run_bucket(bplan, cache, bkey, entries)
    pad = cache.stats.padding
    assert pad.tasks == 12
    assert pad.padded_tasks == 16                  # aligned, not 32
    assert pad.b_waste_frac <= 0.25


@pytest.mark.parametrize("name,params", [
    ("ridge", {"reg": 1.0}),
    ("kernel_ridge", {"reg": 1.0, "n_landmarks": 16}),
    ("mlp", {"hidden": (8,), "n_steps": 10}),
])
def test_tail_launch_b_invariance(name, params):
    """Canonical launch blocks make chunking invisible: executing a
    bucket whole, one invocation at a time, or in ragged slices yields
    bitwise-identical predictions, because every task always launches
    at its canonical block's compiled B (missing lanes ride as padding
    and lane contents don't couple)."""
    from repro.compile import ProgramCache
    plan, data = _plr(100, seed=7, learner=name, learner_params=params,
                      n_rep=4)                     # 8 inv x 3 = 24 tasks
    req = compile_request(plan, data)
    bplan = plan_buckets([req])
    (bkey,) = bplan.buckets
    entries = [(0, int(i)) for i in req.ledger.pending()]

    whole, _ = run_bucket(bplan, ProgramCache(), bkey, entries)
    one_at_a_time = {}
    for e in entries:                   # out-of-order, one invocation each
        res, _ = run_bucket(bplan, ProgramCache(), bkey, [e])
        one_at_a_time.update(res)
    ragged = {}
    for sl in (entries[:3], entries[3:4], entries[4:]):
        res, _ = run_bucket(bplan, ProgramCache(), bkey, sl)
        ragged.update(res)
    for e in entries:
        np.testing.assert_array_equal(whole[e], one_at_a_time[e])
        np.testing.assert_array_equal(whole[e], ragged[e])


# ---------------------------------------------------------------------------
# same-shape block fusion (ISSUE 5): bitwise parity, every learner family
# ---------------------------------------------------------------------------
FUSION_FAMILIES = [
    ("ridge", {"reg": 1.0}),
    ("ols", {}),
    ("lasso", {"reg": 0.01}),
    ("logistic", {"reg": 1.0}),
    ("kernel_ridge", {"reg": 1.0, "n_landmarks": 16}),
    ("mlp", {"hidden": (8,), "n_steps": 10}),
]


@pytest.mark.parametrize("name,params", FUSION_FAMILIES)
def test_fused_multi_request_launch_bitwise_parity(name, params):
    """The fusion invariance contract: packing equal-canonical-B blocks
    of DIFFERENT requests into one launch (leading block axis, shared
    union page stack) yields bitwise the predictions each request gets
    from its own single-block launch — for every learner family."""
    from repro.compile import ProgramCache
    cases = [_plr(97 + i, seed=10 + i, learner=name, learner_params=params)
             for i in range(3)]                    # all align to N=104
    reqs = [compile_request(p, d) for p, d in cases]

    # solo single-block launches, one fresh cache per request
    solo = {}
    for ri, req in enumerate(reqs):
        bplan = plan_buckets([req])
        (bkey,) = bplan.buckets
        res, _ = run_bucket(bplan, ProgramCache(), bkey,
                            [(0, int(i)) for i in req.ledger.pending()],
                            fuse=False)
        solo[ri] = res

    # one fused multi-request drain
    reqs2 = [compile_request(p, d) for p, d in cases]
    bplan = plan_buckets(reqs2)
    (bkey,) = bplan.buckets                        # one shared bucket
    cache = ProgramCache()
    entries = [(ri, int(i)) for ri, req in enumerate(reqs2)
               for i in req.ledger.pending()]
    fused, _ = run_bucket(bplan, cache, bkey, entries, fuse=True)
    assert cache.stats.fused_launches >= 1
    assert cache.stats.launches < cache.stats.blocks   # really packed
    for ri in range(len(reqs2)):
        for inv in solo[ri]:
            np.testing.assert_array_equal(fused[(ri, inv[1])],
                                          solo[ri][inv])


def test_fusion_off_matches_fused_and_launch_counts():
    """coalesce=False + fuse=False is the canonical baseline (one launch
    per canonical block); fusion and cross-shape coalescing each
    strictly reduce the launch count with bitwise-identical results."""
    from repro.compile import ProgramCache
    reqs = [compile_request(*_plr(100 + i, seed=i)) for i in range(3)]
    bplan = plan_buckets(reqs)
    (bkey,) = bplan.buckets
    entries = [(ri, int(i)) for ri, req in enumerate(reqs)
               for i in req.ledger.pending()]
    cache_f, cache_u = ProgramCache(), ProgramCache()
    cache_b = ProgramCache()
    res_f, _ = run_bucket(bplan, cache_f, bkey, entries, fuse=True)
    res_u, _ = run_bucket(bplan, cache_u, bkey, entries, fuse=False)
    res_b, _ = run_bucket(bplan, cache_b, bkey, entries, fuse=False,
                          coalesce=False)
    # canonical baseline: one launch per canonical block, none coalesced
    assert cache_b.stats.launches == cache_b.stats.blocks
    assert cache_b.stats.coalesced_blocks == 0
    # coalescing packs tail blocks even unfused; fusion cuts further
    assert cache_u.stats.launches < cache_b.stats.launches
    assert cache_f.stats.launches < cache_u.stats.launches
    for e in entries:
        np.testing.assert_array_equal(res_f[e], res_u[e])
        np.testing.assert_array_equal(res_f[e], res_b[e])


@pytest.mark.parametrize("name,params", FUSION_FAMILIES)
def test_morphed_tail_launch_bitwise_parity(name, params):
    """The cross-shape coalescing contract (ISSUE 7): padding a tail
    block up to a neighbor's canonical B and fusing across the formerly
    different shapes yields BITWISE the per-block results — for every
    family in MORPH_BITWISE_FAMILIES (all six; zero-padded lanes are
    proven not to perturb real lanes on this platform).  Three 6-entry
    requests force the interesting shape mix: two tails pack to a
    16-lane launch block, the third rides an 8-lane block that must be
    MORPHED up to 16 before the shapes can fuse."""
    from repro.compile import ProgramCache
    from repro.compile.program import MORPH_BITWISE_FAMILIES, bucket_family
    cases = [_plr(97 + i, seed=20 + i, learner=name, learner_params=params)
             for i in range(3)]                     # 6 entries/request
    reqs = [compile_request(p, d) for p, d in cases]
    bplan = plan_buckets(reqs)
    (bkey,) = bplan.buckets
    assert bucket_family(bkey) in MORPH_BITWISE_FAMILIES
    entries = [(ri, int(i)) for ri, req in enumerate(reqs)
               for i in req.ledger.pending()]

    cache_m = ProgramCache()
    res_m, _ = run_bucket(bplan, cache_m, bkey, entries,
                          fuse=True, coalesce=True)
    # the morph really happened: tails were packed into shared launches
    assert cache_m.stats.coalesced_blocks >= 2
    assert cache_m.stats.launches < cache_m.stats.blocks

    reqs_b = [compile_request(p, d) for p, d in cases]
    bplan_b = plan_buckets(reqs_b)
    (bkey_b,) = bplan_b.buckets
    res_b, _ = run_bucket(bplan_b, ProgramCache(), bkey_b, entries,
                          fuse=False, coalesce=False)
    for e in entries:
        np.testing.assert_array_equal(res_m[e], res_b[e])


def test_morph_tolerance_gate():
    """A family outside MORPH_BITWISE_FAMILIES only morphs under an
    explicit opt-in tolerance (PoolConfig.morph_tolerance > 0); the
    default 0.0 keeps it on canonical shapes."""
    from repro.compile.program import (MORPH_TOLERANCE_FAMILIES,
                                       morph_allowed)
    from repro.compile.buckets import BucketKey
    # every current family is bitwise-proven, so synthesize the key of a
    # hypothetical tolerance-tier family to pin the gate's behavior
    key = BucketKey(learner=("hypothetical", ()), n_pad=8, p_pad=8)
    assert "hypothetical" not in MORPH_TOLERANCE_FAMILIES
    assert not morph_allowed(key, 0.0)
    assert not morph_allowed(key, 1e-6)    # not registered: never morphs
    ridge = BucketKey(learner=("ridge", (("reg", 1.0),)), n_pad=8, p_pad=8)
    assert morph_allowed(ridge, 0.0)       # bitwise tier needs no opt-in


@pytest.mark.parametrize("name,params", FUSION_FAMILIES)
def test_sharded_fused_launch_bitwise_parity(name, params):
    """The ISSUE 8 sharded-fusion contract (the B_BLOCK caveat in
    compile/program.py points here): a partitioned cache with a fused
    partition hook launches shard_map(lax.map body) and reproduces the
    unsharded fused launch — BITWISE on a 1-device mesh, and to the
    established sharded float tier (1e-6, same as the unfused sharded
    path) on an m-way mesh, where each shard compiles the body at B/m
    lanes and XLA may retile small-B reductions.  The multihost-smoke
    job runs this 8-way where the shard really splits."""
    from repro.compile import ProgramCache
    from repro.launch.mesh import make_host_mesh
    from repro.serverless.backends import make_sharded_compiler
    cases = [_plr(97 + i, seed=30 + i, learner=name, learner_params=params)
             for i in range(2)]                    # all align to N=104
    reqs = [compile_request(p, d) for p, d in cases]
    bplan = plan_buckets(reqs)
    (bkey,) = bplan.buckets
    entries = [(ri, int(i)) for ri, req in enumerate(reqs)
               for i in req.ledger.pending()]

    base, _ = run_bucket(bplan, ProgramCache(), bkey, entries, fuse=True)

    mesh = make_host_mesh()
    sharded = make_sharded_compiler(mesh)
    assert sharded.partition_fused is not None
    res, _ = run_bucket(bplan, sharded, bkey, entries, fuse=True,
                        b_align=mesh.shape["data"])
    assert sharded.stats.fused_launches >= 1       # really took the path
    for e in entries:
        if mesh.shape["data"] == 1:
            np.testing.assert_array_equal(res[e], base[e])
        else:
            np.testing.assert_allclose(res[e], base[e], rtol=1e-6,
                                       atol=2e-6)


# ---------------------------------------------------------------------------
# per-bucket parallelization-axis planner (ISSUE 8)
# ---------------------------------------------------------------------------
def test_axis_planner_pinned_decisions(monkeypatch):
    """The roofline planner's choices on the canonical shapes, pinned so
    a pricing-model edit that flips a layout is a visible diff:

      * tall-N Gram bucket (N_pad exceeds one device page): only the
        data-parallel blocked-Gram layout is executable — data@8;
      * wide-P lasso (huge P, many sweeps, one task): the column split
        amortizes its all-gather — feature@8;
      * many small tasks: per-task work is below the shard tax —
        task@1 (classic serverless task parallelism);
      * compute-heavy mlp bucket (non-Gram): only the task axis exists,
        and the per-task work amortizes the multi-shard launch —
        task@8.

    Pins price against the analytic SHARD_OVERHEAD_FRAC: an earlier
    test constructing a DMLSession memoizes a *measured* fraction
    (honest at runtime, unpinnable under CI load), so it is cleared
    here — the absolute choices, not the argmin invariant, are what
    this test owns."""
    from repro.compile.buckets import BucketKey, plan_bucket_axis
    from repro.launch import roofline
    monkeypatch.setattr(roofline, "_MEASURED_SHARD_OVERHEAD_FRAC", None)

    def decide(learner, ptuple, n_pad, p_pad, b):
        key = BucketKey(learner=(learner, ptuple), n_pad=n_pad, p_pad=p_pad)
        return plan_bucket_axis(key, n_tasks=b, n_devices=8)

    tall = decide("ridge", (("reg", 1.0),), 1 << 17, 8, 4)
    assert (tall.axis, tall.shards) == ("data", 8)
    # the task candidates really were inexecutable, not merely pricier
    assert all(not ok for ax, _, _, ok in tall.candidate_costs
               if ax == "task")

    wide = decide("lasso", (("reg", 0.01), ("n_iter", 500)), 4096, 16384, 1)
    assert (wide.axis, wide.shards) == ("feature", 8)

    small = decide("ols", (), 256, 16, 64)
    assert (small.axis, small.shards) == ("task", 1)

    mlp = decide("mlp", (("hidden", (32,)), ("n_steps", 300)), 2048, 32, 32)
    assert (mlp.axis, mlp.shards) == ("task", 8)


def test_axis_planner_never_picks_strictly_worse():
    """By construction the decision is the argmin over executable
    candidates — sweep a shape grid and verify no executable candidate
    is priced strictly cheaper than the chosen one."""
    from repro.compile.buckets import BucketKey, plan_bucket_axis
    shapes = [("ridge", (("reg", 1.0),)), ("ols", ()),
              ("lasso", (("reg", 0.01), ("n_iter", 200))),
              ("logistic", (("reg", 1.0), ("n_iter", 100))),
              ("mlp", (("hidden", (8,)), ("n_steps", 100)))]
    for learner, ptuple in shapes:
        for n_pad in (256, 4096, 1 << 17):
            for b in (1, 16, 64):
                key = BucketKey((learner, ptuple), n_pad, 32)
                d = plan_bucket_axis(key, n_tasks=b, n_devices=8)
                best = d.est_s
                for ax, sh, est, ok in d.candidate_costs:
                    if ok:
                        assert est >= best or (ax, sh) == (d.axis, d.shards)


def test_axis_planner_opaque_and_nongram_fallbacks():
    """Opaque buckets get no decision (they always run task-parallel
    unsharded); a tall-N non-Gram family has NO executable candidate and
    falls back to the task axis rather than crashing."""
    from repro.compile.buckets import BucketKey, plan_bucket_axis
    assert plan_bucket_axis(BucketKey(("opaque", 123), 256, 8),
                            n_tasks=4, n_devices=8) is None
    tallmlp = plan_bucket_axis(
        BucketKey(("mlp", (("hidden", (8,)), ("n_steps", 100))),
                  1 << 17, 8), n_tasks=4, n_devices=8)
    assert tallmlp.axis == "task"
    assert all(not ok for _, _, _, ok in tallmlp.candidate_costs)


def test_sharded_backend_logs_axis_plans():
    """The drain engine prices each spec-identified bucket once per mesh
    and logs the decision on BackendRunInfo.axis_plans, autoscale-style."""
    from repro.serverless.backends import ShardedBackend
    plan, data = _plr(100, seed=40)
    req = compile_request(plan, data)
    info = ShardedBackend(PoolConfig(n_workers=3, memory_mb=512)) \
        .run_requests([req])
    assert len(info.axis_plans) >= 1
    d = info.axis_plans[0]
    assert d.axis in ("task", "data", "feature")
    assert d.priced_by == "roofline"
    assert d.candidate_costs                     # full table logged
    # serving-size ridge buckets stay classic task-parallel
    assert d.axis == "task"


def test_out_of_order_harvest_parity():
    """Non-blocking dispatch: buckets harvested in reverse dispatch
    order return exactly what the synchronous path returns."""
    from repro.compile import ProgramCache, dispatch_bucket
    reqs = [compile_request(*_plr(100, seed=0)),
            compile_request(*_plr(300, seed=1))]   # two distinct buckets
    bplan = plan_buckets(reqs)
    groups = bplan.pending_by_bucket()
    assert len(groups) == 2
    cache = ProgramCache()
    dispatched = [dispatch_bucket(bplan, cache, key, ents)
                  for key, ents in groups.items()]
    harvested = {}
    for bd in reversed(dispatched):                # out-of-order harvest
        harvested.update(bd.harvest())
    cache2 = ProgramCache()
    expected = {}
    for key, ents in groups.items():
        res, _ = run_bucket(bplan, cache2, key, ents)
        expected.update(res)
    assert set(harvested) == set(expected)
    for e, v in expected.items():
        np.testing.assert_array_equal(harvested[e], v)


def test_block_tensor_cache_keys_on_full_data_content():
    """Two datasets sharing one X but different y must never share
    cached block tensors (work_key is the FULL content identity, not
    just the feature-page fingerprint) — regression test for the
    stale-prediction bug a fingerprint-only key produces."""
    plan, data1 = _plr(100, seed=21)
    data2 = DMLData(x=np.array(data1.x), y=np.array(data1.y) + 1.0,
                    d=np.array(data1.d))
    assert data1.fingerprint() == data2.fingerprint()     # same X page
    assert data1.content_key() != data2.content_key()     # different y
    backend = InlineBackend()
    r1 = compile_request(plan, data1)
    backend.run_requests([r1])
    r2 = compile_request(plan, data2)
    backend.run_requests([r2])
    assert not np.array_equal(r1.gathered_preds(), r2.gathered_preds())
    # and a solo fresh-backend run of data2 agrees bitwise
    ref = compile_request(plan, data2)
    InlineBackend().run_requests([ref])
    np.testing.assert_array_equal(r2.gathered_preds(),
                                  ref.gathered_preds())


def test_n_buckets_sublane_aligned():
    """The ISSUE 5 N rule: buckets align N to the 8-row sublane quantum
    (mirroring the B tail rule) instead of pow2 — 100 pads to 104, not
    128 — and the pow2 comparator is tracked in the padding stats."""
    from repro.compile import ProgramCache
    req = compile_request(*_plr(100, seed=3))
    bplan = plan_buckets([req])
    (bkey,) = bplan.buckets
    assert bkey.n_pad == 104
    cache = ProgramCache()
    run_bucket(bplan, cache, bkey,
               [(0, int(i)) for i in req.ledger.pending()])
    pad = cache.stats.padding
    assert pad.n_waste_frac < pad.n_waste_frac_pow2
    assert pad.lane_cells_pow2 == pad.tasks * 128


def test_scaling_levels_share_launch_shapes():
    """Canonical blocks are built over flat task ids, which both scaling
    levels share — so per-split and per-fold runs compile the same B and
    agree bitwise even when the segment spans multiple blocks."""
    from repro.core import estimate
    plan_a, data = _plr(90, seed=11, n_rep=6)      # 36 tasks: 32 + tail 4
    plan_b = DMLPlan.for_model("plr", learner="ridge",
                               learner_params={"reg": 1.0}, n_folds=3,
                               n_rep=6, seed=111, scaling="n_folds*n_rep")
    ra = estimate(plan_a, data, backend="inline")
    rb = estimate(plan_b, data, backend="inline")
    np.testing.assert_array_equal(ra.thetas, rb.thetas)


def test_multi_request_checkpoints_do_not_clobber(tmp_path):
    """Batched inline/sharded drains write one checkpoint per request
    (same .r{i} layout as the wave backend), never one shared file."""
    import os
    path = os.path.join(tmp_path, "ck")
    from repro.serverless import TaskLedger
    reqs = [compile_request(*_plr(n, seed=i)) for i, n in enumerate((90, 70))]
    InlineBackend(PoolConfig(checkpoint_path=path)).run_requests(reqs)
    for i, req in enumerate(reqs):
        led = TaskLedger.load(f"{path}.r{i}")
        assert led.complete and led.n_obs == req.ledger.n_obs
    # single request: bare path, as before
    req = compile_request(*_plr(80, seed=9))
    InlineBackend(PoolConfig(checkpoint_path=path)).run_requests([req])
    assert TaskLedger.load(path).n_obs == 80


def test_backend_info_reports_compile_stats():
    req = compile_request(*_plr(100, seed=8))
    backend = InlineBackend()
    info = backend.run_requests([req])
    assert info.compile is not None
    assert info.compile.launches >= 1
    assert info.compile.padding.padded_tasks >= info.compile.padding.tasks
    assert 0.0 <= info.compile.padding.waste_frac < 1.0
