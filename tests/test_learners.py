"""Learner correctness on masked batched fits."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.learners import get_learner
from repro.learners.linear import lasso_fit_predict, ridge_fit_predict


def _problem(n=200, p=6, t=5, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, p)).astype(np.float32)
    beta = rng.normal(size=p).astype(np.float32)
    y = (x @ beta + 0.1 * rng.normal(size=n)).astype(np.float32)
    ys = np.tile(y, (t, 1))
    w = (rng.random((t, n)) > 0.3).astype(np.float32)
    return x, ys, w, beta


def test_ridge_matches_numpy_closed_form():
    x, ys, w, _ = _problem()
    preds = ridge_fit_predict(jnp.asarray(x), jnp.asarray(ys), jnp.asarray(w),
                              reg=2.0)
    xa = np.concatenate([x, np.ones((x.shape[0], 1), np.float32)], axis=1)
    for t in range(ys.shape[0]):
        wd = np.diag(w[t])
        g = xa.T @ wd @ xa + 2.0 * np.eye(xa.shape[1])
        g[-1, -1] -= 2.0 - 1e-8                    # unpenalized intercept
        beta = np.linalg.solve(g, xa.T @ (w[t] * ys[t]))
        np.testing.assert_allclose(np.asarray(preds[t]), xa @ beta,
                                   rtol=2e-3, atol=2e-3)


def test_masked_fit_equals_subset_fit():
    """Weighted fit with 0/1 mask == fitting on the subset only."""
    x, ys, w, _ = _problem(t=1)
    preds = ridge_fit_predict(jnp.asarray(x), jnp.asarray(ys), jnp.asarray(w),
                              reg=1.0)
    keep = w[0] > 0
    xa = np.concatenate([x, np.ones((x.shape[0], 1), np.float32)], axis=1)
    xs = xa[keep]
    g = xs.T @ xs + np.eye(xa.shape[1])
    g[-1, -1] += -1.0 + 1e-8
    beta = np.linalg.solve(g, xs.T @ ys[0][keep])
    np.testing.assert_allclose(np.asarray(preds[0]), xa @ beta,
                               rtol=2e-3, atol=2e-3)


def test_lasso_sparsity_and_fit():
    x, ys, w, beta = _problem(n=300)
    # strong penalty: predictions ~ (weighted) constant
    p_big = lasso_fit_predict(jnp.asarray(x), jnp.asarray(ys),
                              jnp.asarray(w), reg=1e3)
    assert float(jnp.std(p_big[0])) < 0.2
    # weak penalty: close to truth
    p_small = lasso_fit_predict(jnp.asarray(x), jnp.asarray(ys),
                                jnp.asarray(w), reg=1e-3)
    resid = np.asarray(p_small[0]) - x @ beta
    assert np.sqrt(np.mean(resid**2)) < 0.25


def test_logistic_recovers_probabilities():
    rng = np.random.default_rng(1)
    n = 800
    x = rng.normal(size=(n, 3)).astype(np.float32)
    logits = 1.5 * x[:, 0] - x[:, 1]
    pz = 1 / (1 + np.exp(-logits))
    y = (rng.random(n) < pz).astype(np.float32)
    fn = get_learner("logistic", {"reg": 1e-3})
    preds = fn(jnp.asarray(x), jnp.asarray(y[None]),
               jnp.ones((1, n), jnp.float32), jax.random.key(0))
    p = np.asarray(preds[0])
    assert ((p > 0) & (p < 1)).all()
    assert np.corrcoef(p, pz)[0, 1] > 0.95


def test_kernel_ridge_beats_linear_on_nonlinear_target():
    rng = np.random.default_rng(2)
    n = 400
    x = rng.normal(size=(n, 2)).astype(np.float32)
    y = np.sin(2 * x[:, 0]) + 0.1 * rng.normal(size=n).astype(np.float32)
    w = np.ones((1, n), np.float32)
    lin = get_learner("ridge", {"reg": 1.0})
    krr = get_learner("kernel_ridge", {"reg": 0.5, "n_landmarks": 128,
                                       "gamma": 1.0})
    p_lin = lin(jnp.asarray(x), jnp.asarray(y[None]), jnp.asarray(w),
                jax.random.key(0))
    p_krr = krr(jnp.asarray(x), jnp.asarray(y[None]), jnp.asarray(w),
                jax.random.key(0))
    mse = lambda p: float(np.mean((np.asarray(p[0]) - np.sin(2 * x[:, 0]))**2))
    assert mse(p_krr) < 0.5 * mse(p_lin)


def test_mlp_fits_nonlinear():
    rng = np.random.default_rng(3)
    n = 300
    x = rng.normal(size=(n, 2)).astype(np.float32)
    y = (x[:, 0] * x[:, 1]).astype(np.float32)
    fn = get_learner("mlp", {"n_steps": 400, "hidden": (32, 32)})
    preds = fn(jnp.asarray(x), jnp.asarray(y[None]),
               jnp.ones((1, n), jnp.float32), jax.random.key(0))
    resid = np.asarray(preds[0]) - y
    assert np.mean(resid**2) < 0.5 * np.mean(y**2)
