"""Cross-fitting grid properties (partitions, scaling bijections, stitching)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.crossfit import (
    TaskGrid, TaskKey, check_partition, draw_fold_masks, stitch_predictions,
)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(11, 200), k=st.integers(2, 7), m=st.integers(1, 5),
       seed=st.integers(0, 2**20))
def test_fold_masks_partition(n, k, m, seed):
    masks = draw_fold_masks(n, k, m, seed)
    assert masks.shape == (m, k, n)
    assert check_partition(masks)
    sizes = masks.sum(axis=2)
    assert (np.abs(sizes - n / k) <= 1).all()      # balanced folds


def test_fold_masks_deterministic():
    a = draw_fold_masks(100, 5, 3, seed=7)
    b = draw_fold_masks(100, 5, 3, seed=7)
    assert (a == b).all()
    c = draw_fold_masks(100, 5, 3, seed=8)
    assert (a != c).any()


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 6), k=st.integers(2, 6), l=st.integers(1, 4),
       scaling=st.sampled_from(["n_rep", "n_folds*n_rep"]))
def test_invocation_mapping_bijection(m, k, l, scaling):
    grid = TaskGrid(m, k, l)
    seen = set()
    for inv in range(grid.n_invocations(scaling)):
        for key in grid.tasks_of_invocation(inv, scaling):
            assert grid.invocation_of(key, scaling) == inv
            flat = key.flat(k, l)
            assert flat not in seen
            seen.add(flat)
    assert len(seen) == grid.n_tasks


def test_paper_invocation_counts():
    """PLR with K=5, M=100, L=2: 200 vs 1000 invocations (paper §4.2)."""
    grid = TaskGrid(100, 5, 2)
    assert grid.n_invocations("n_rep") == 200
    assert grid.n_invocations("n_folds*n_rep") == 1000
    assert grid.n_tasks == 1000


def test_stitch_predictions():
    masks = draw_fold_masks(30, 3, 2, seed=0)
    preds = np.random.default_rng(0).normal(size=(2, 3, 30)).astype(np.float32)
    out = stitch_predictions(masks, preds)
    assert out.shape == (2, 30)
    m, k, i = 1, 2, int(np.where(masks[1, 2])[0][0])
    assert out[m, i] == pytest.approx(preds[m, k, i])
