"""Cross-fitting grid properties (partitions, scaling bijections, stitching).

Formerly hypothesis property tests; now seeded parametrize sweeps so the
tier-1 suite collects on a clean environment (no hypothesis dependency).
"""
import numpy as np
import pytest

from repro.core.crossfit import (
    TaskGrid, check_partition, draw_fold_masks, stitch_predictions,
)


@pytest.mark.parametrize("n,k,m,seed", [
    (11, 2, 1, 0), (23, 3, 2, 7), (57, 5, 3, 123), (100, 7, 5, 2**19),
    (128, 4, 2, 31337), (199, 6, 4, 1), (200, 2, 5, 999983), (64, 7, 1, 42),
])
def test_fold_masks_partition(n, k, m, seed):
    masks = draw_fold_masks(n, k, m, seed)
    assert masks.shape == (m, k, n)
    assert check_partition(masks)
    sizes = masks.sum(axis=2)
    assert (np.abs(sizes - n / k) <= 1).all()      # balanced folds


def test_fold_masks_deterministic():
    a = draw_fold_masks(100, 5, 3, seed=7)
    b = draw_fold_masks(100, 5, 3, seed=7)
    assert (a == b).all()
    c = draw_fold_masks(100, 5, 3, seed=8)
    assert (a != c).any()


@pytest.mark.parametrize("m,k,l", [
    (1, 2, 1), (2, 3, 2), (3, 5, 3), (6, 2, 4), (4, 6, 1), (5, 4, 5),
])
@pytest.mark.parametrize("scaling", ["n_rep", "n_folds*n_rep"])
def test_invocation_mapping_bijection(m, k, l, scaling):
    grid = TaskGrid(m, k, l)
    seen = set()
    for inv in range(grid.n_invocations(scaling)):
        for key in grid.tasks_of_invocation(inv, scaling):
            assert grid.invocation_of(key, scaling) == inv
            flat = key.flat(k, l)
            assert flat not in seen
            seen.add(flat)
    assert len(seen) == grid.n_tasks


@pytest.mark.parametrize("m,k,l", [(2, 3, 2), (3, 5, 1), (4, 2, 5)])
@pytest.mark.parametrize("scaling", ["n_rep", "n_folds*n_rep"])
def test_invocation_task_ids_matches_scalar_mapping(m, k, l, scaling):
    """The vectorized mapping used by the backends must agree with the
    per-key reference."""
    grid = TaskGrid(m, k, l)
    inv = np.arange(grid.n_invocations(scaling))
    mat = grid.invocation_task_ids(inv, scaling)
    assert mat.shape == (len(inv), grid.tasks_per_invocation(scaling))
    for i in inv:
        expect = [key.flat(k, l) for key in grid.tasks_of_invocation(int(i),
                                                                     scaling)]
        assert list(mat[i]) == expect
    tm, tk, tl = grid.task_coords()
    for key in grid.keys():
        flat = key.flat(k, l)
        assert (tm[flat], tk[flat], tl[flat]) == (key.rep, key.fold,
                                                  key.nuisance)


def test_paper_invocation_counts():
    """PLR with K=5, M=100, L=2: 200 vs 1000 invocations (paper §4.2)."""
    grid = TaskGrid(100, 5, 2)
    assert grid.n_invocations("n_rep") == 200
    assert grid.n_invocations("n_folds*n_rep") == 1000
    assert grid.n_tasks == 1000


def test_stitch_predictions():
    masks = draw_fold_masks(30, 3, 2, seed=0)
    preds = np.random.default_rng(0).normal(size=(2, 3, 30)).astype(np.float32)
    out = stitch_predictions(masks, preds)
    assert out.shape == (2, 30)
    m, k, i = 1, 2, int(np.where(masks[1, 2])[0][0])
    assert out[m, i] == pytest.approx(preds[m, k, i])
