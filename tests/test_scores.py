"""Score-function unit tests: linearity, orthogonality, SE sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scores import (
    SPECS, irm_score, plr_score, score_se,
    solve_theta,
)


def _plr_fixture(n=400, theta=0.7, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 3)).astype(np.float32)
    g = np.tanh(x[:, 0])
    m = 0.5 * x[:, 1]
    d = m + rng.normal(size=n).astype(np.float32)
    y = theta * d + g + rng.normal(size=n).astype(np.float32)
    data = {"y": jnp.asarray(y), "d": jnp.asarray(d)}
    eta = {"ml_l": jnp.asarray(theta * m + g), "ml_m": jnp.asarray(m)}
    return data, eta, theta


def test_plr_score_linearity():
    data, eta, theta = _plr_fixture()
    pa, pb = plr_score(data, eta)
    # psi(theta) = theta*psi_a + psi_b must be zero at the solution
    th = solve_theta(pa, pb)
    psi = th * pa + pb
    assert abs(float(jnp.mean(psi))) < 1e-5


def test_plr_recovers_theta_with_true_nuisance():
    data, eta, theta = _plr_fixture()
    pa, pb = plr_score(data, eta)
    th = float(solve_theta(pa, pb))
    assert abs(th - theta) < 0.15


def test_plr_neyman_orthogonality():
    """d/dr E[psi(theta0, eta0 + r*h)] at r=0 must vanish."""
    data, eta, theta = _plr_fixture(n=20_000)
    rng = np.random.default_rng(1)
    h_l = jnp.asarray(rng.normal(size=data["y"].shape).astype(np.float32))
    h_m = jnp.asarray(rng.normal(size=data["y"].shape).astype(np.float32))

    def mean_psi(r):
        pert = {"ml_l": eta["ml_l"] + r * h_l, "ml_m": eta["ml_m"] + r * h_m}
        pa, pb = plr_score(data, pert)
        return jnp.mean(theta * pa + pb)

    d0 = float(jax.grad(mean_psi)(0.0))
    # scale-free comparison: the second derivative is O(E[h_l h_m])
    d2 = float(jax.grad(jax.grad(mean_psi))(0.0))
    assert abs(d0) < 1e-2 * max(abs(d2), 1.0)


def test_non_orthogonal_score_fails_the_same_check():
    """A naive (prediction-error) score violates orthogonality — the reason
    DML exists.  psi_naive = d*(y - d*theta - ghat)."""
    data, eta, theta = _plr_fixture(n=20_000)
    rng = np.random.default_rng(2)
    h = jnp.asarray(rng.normal(size=data["y"].shape).astype(np.float32))

    def mean_psi_naive(r):
        ghat = (eta["ml_l"] - theta * eta["ml_m"]) + r * h
        return jnp.mean(data["d"] * (data["y"] - data["d"] * theta - ghat))

    d0 = float(jax.grad(mean_psi_naive)(0.0))
    assert abs(d0) > 1e-2          # first-order sensitivity is O(E[d*h]) != 0


def test_score_se_positive_and_shrinks():
    data, eta, _ = _plr_fixture(n=400)
    pa, pb = plr_score(data, eta)
    th = solve_theta(pa, pb)
    se400 = float(score_se(pa, pb, th))
    data2, eta2, _ = _plr_fixture(n=6400)
    pa2, pb2 = plr_score(data2, eta2)
    se6400 = float(score_se(pa2, pb2, solve_theta(pa2, pb2)))
    assert se400 > 0 and se6400 > 0
    assert se6400 < se400


def test_irm_score_ate_identity():
    n = 50_000
    rng = np.random.default_rng(3)
    x = rng.normal(size=n).astype(np.float32)
    m = 1 / (1 + np.exp(-x))
    d = (rng.random(n) < m).astype(np.float32)
    g0 = np.tanh(x)
    theta = 0.3
    y = (g0 + theta * d + 0.1 * rng.normal(size=n)).astype(np.float32)
    data = {"y": jnp.asarray(y), "d": jnp.asarray(d)}
    eta = {"ml_g0": jnp.asarray(g0), "ml_g1": jnp.asarray(g0 + theta),
           "ml_m": jnp.asarray(m.astype(np.float32))}
    pa, pb = irm_score(data, eta)
    assert abs(float(solve_theta(pa, pb)) - theta) < 0.05


def test_all_specs_have_consistent_nuisance_counts():
    assert SPECS["plr"].n_nuisance == 2
    assert SPECS["pliv"].n_nuisance == 3
    assert SPECS["irm"].n_nuisance == 3
    assert SPECS["iivm"].n_nuisance == 5
