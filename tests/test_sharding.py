"""Sharding rules + a small-mesh dry-run smoke in a subprocess (the full
512-device sweep lives in launch/dryrun.py; here a 8-device reduced-config
version proves the machinery end-to-end inside CI)."""
import json
import os
import subprocess
import sys

import pytest

from repro.sharding.axes import MEGATRON_FSDP, SMALL_DP, rules_for
from jax.sharding import PartitionSpec as P


def test_rules_resolve_basic():
    spec = MEGATRON_FSDP.resolve(("embed", "heads", None))
    assert spec == P("data", "model", None)


def test_rules_no_duplicate_axis():
    # batch=("pod","data") then embed->"data" must drop the duplicate
    spec = MEGATRON_FSDP.resolve(("batch", "embed"))
    assert spec[0] == ("pod", "data") or spec[0] == "data"
    assert spec[1] is None or spec[1] != "data" or spec[0] != ("pod", "data")


def test_mesh_axis_filtering():
    from repro import runtime
    runtime.mesh_axes = ("data", "model")       # single-pod mesh
    try:
        spec = MEGATRON_FSDP.resolve(("batch", None, "act_heads"))
        assert spec == P("data", None, "model")
    finally:
        runtime.mesh_axes = None


def test_rules_for_small_vs_big():
    assert rules_for("xlstm-350m", "train", 1024) is SMALL_DP
    assert rules_for("qwen2.5-32b", "train", 5120) is MEGATRON_FSDP
    # long-context decode (batch 1): batch unsharded, KV over (data, model)
    r = rules_for("h2o-danube-3-4b", "decode", 3840, global_batch=1)
    assert r.resolve(("batch", "kv_seq")) == P(None, ("data", "model"))


_SMOKE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro import runtime
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.launch.dryrun import lower_cell
from repro.launch.roofline import parse_collective_bytes

from repro.sharding.compat import make_mesh_compat
mesh = make_mesh_compat((4, 2), ("data", "model"))
runtime.mesh_axes = ("data", "model")
cfg = get_arch("{arch}", reduced=True)
shape = ShapeConfig("t", seq_len=64, global_batch=8, kind="{kind}")
compiled, ls, cs = lower_cell(cfg, shape, mesh, attn_chunk=32, remat="none")
ma = compiled.memory_analysis()
colls = parse_collective_bytes(compiled.as_text())
print(json.dumps({{"arg": ma.argument_size_in_bytes,
                   "colls": {{k: int(v) for k, v in colls.items()}}}}))
"""


@pytest.mark.parametrize("arch,kind,expect_coll", [
    ("qwen2.5-32b", "train", "all-reduce"),
    ("deepseek-v2-lite-16b", "train", "all-to-all"),
    ("codeqwen1.5-7b", "decode", "all-reduce"),
])
def test_small_mesh_dryrun_subprocess(arch, kind, expect_coll):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = _SMOKE.format(arch=arch, kind=kind)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["arg"] > 0
    assert expect_coll in rec["colls"], rec["colls"]
