"""Per-kernel shape/dtype sweeps + seeded invariant sweeps vs the jnp
oracles.  All Pallas kernels run in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.crossfit_gram import crossfit_gram_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.megabatch import (
    batched_gram_blocked_pallas, batched_gram_pallas,
    batched_predict_pallas,
)
from repro.kernels.ssd_scan import ssd_scan_pallas

TOL = {jnp.float32: 2e-4, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# crossfit_gram
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,p,t,bn", [
    (256, 8, 8, 64), (512, 16, 16, 128), (1024, 24, 8, 256), (128, 4, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_crossfit_gram_sweep(n, p, t, bn, dtype):
    k = jax.random.key(n + p + t)
    x = jax.random.normal(k, (n, p), jnp.float32).astype(dtype)
    w = (jax.random.uniform(jax.random.fold_in(k, 1), (t, n)) > 0.4) \
        .astype(dtype)
    y = jax.random.normal(jax.random.fold_in(k, 2), (t, n)).astype(dtype)
    g, b = crossfit_gram_pallas(x, w, y, block_t=8, block_n=bn,
                                interpret=True)
    g0, b0 = ref.crossfit_gram_ref(x, w, y)
    scale = max(float(jnp.max(jnp.abs(g0))), 1.0)
    assert float(jnp.max(jnp.abs(g - g0))) / scale < TOL[dtype]
    bscale = max(float(jnp.max(jnp.abs(b0))), 1.0)
    assert float(jnp.max(jnp.abs(b - b0))) / bscale < TOL[dtype]


@pytest.mark.parametrize("seed", [0, 17, 256, 511, 999])
def test_gram_mask_of_ones_equals_plain_gram(seed):
    k = jax.random.key(seed)
    x = jax.random.normal(k, (128, 6), jnp.float32)
    w = jnp.ones((8, 128), jnp.float32)
    y = jax.random.normal(jax.random.fold_in(k, 1), (8, 128), jnp.float32)
    g, _ = crossfit_gram_pallas(x, w, y, block_t=8, block_n=64,
                                interpret=True)
    plain = x.T @ x
    for t in range(8):
        np.testing.assert_allclose(np.asarray(g[t]), np.asarray(plain),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("seed", [1, 42, 300, 777, 1000])
def test_gram_additivity_over_disjoint_masks(seed):
    """G(w1) + G(w2) == G(w1+w2) for disjoint masks — the fold-partition
    structure the paper's grid relies on."""
    k = jax.random.key(seed)
    x = jax.random.normal(k, (128, 5), jnp.float32)
    m = jax.random.uniform(jax.random.fold_in(k, 1), (128,)) > 0.5
    ones = jnp.ones_like(m)
    w = jnp.stack([m, ~m, ones, m, ~m, ones, m, ~m]).astype(jnp.float32)
    y = jnp.ones((8, 128), jnp.float32)
    g, b = crossfit_gram_pallas(x, w, y, block_t=8, block_n=64,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(g[0] + g[1]), np.asarray(g[2]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(b[0] + b[1]), np.asarray(b[2]),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# megabatch kernels (per-task feature pages)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,n,p,bn", [
    (8, 128, 8, 8), (16, 256, 16, 128), (8, 64, 24, 8),
])
def test_batched_gram_sweep(b, n, p, bn):
    k = jax.random.key(b + n + p)
    xs = jax.random.normal(k, (b, n, p), jnp.float32)
    w = (jax.random.uniform(jax.random.fold_in(k, 1), (b, n)) > 0.4) \
        .astype(jnp.float32)
    y = jax.random.normal(jax.random.fold_in(k, 2), (b, n), jnp.float32)
    xs_pad = jnp.pad(xs, ((0, 0), (0, 0), (0, 128 - p)))
    g, bv = batched_gram_pallas(xs_pad, w, y, block_b=8, block_n=bn,
                                interpret=True)
    g0, b0 = ref.batched_gram_ref(xs, w, y)
    scale = max(float(jnp.max(jnp.abs(g0))), 1.0)
    assert float(jnp.max(jnp.abs(g[:, :p, :p] - g0))) / scale < 2e-4
    bscale = max(float(jnp.max(jnp.abs(b0))), 1.0)
    assert float(jnp.max(jnp.abs(bv[:, :p] - b0))) / bscale < 2e-4


def test_batched_gram_matches_crossfit_gram_on_shared_x():
    """A bucket whose tasks all share one dataset must reproduce the
    shared-X crossfit_gram kernel exactly (same math, new layout)."""
    k = jax.random.key(3)
    n, p, t = 128, 8, 8
    x = jax.random.normal(k, (n, p), jnp.float32)
    w = (jax.random.uniform(jax.random.fold_in(k, 1), (t, n)) > 0.3) \
        .astype(jnp.float32)
    y = jax.random.normal(jax.random.fold_in(k, 2), (t, n), jnp.float32)
    xs = jnp.broadcast_to(x, (t, n, p))
    xs_pad = jnp.pad(xs, ((0, 0), (0, 0), (0, 128 - p)))
    g, bv = batched_gram_pallas(xs_pad, w, y, block_b=8, block_n=8,
                                interpret=True)
    g0, b0 = ref.crossfit_gram_ref(x, w, y)
    np.testing.assert_allclose(np.asarray(g[:, :p, :p]), np.asarray(g0),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(bv[:, :p]), np.asarray(b0),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,n,p,bn", [(8, 128, 8, 8), (16, 256, 32, 128)])
def test_batched_predict_masks_padding(b, n, p, bn):
    k = jax.random.key(b * n + p)
    xs = jax.random.normal(k, (b, n, p), jnp.float32)
    beta = jax.random.normal(jax.random.fold_in(k, 1), (b, p), jnp.float32)
    valid = (jax.random.uniform(jax.random.fold_in(k, 2), (b, n)) > 0.25) \
        .astype(jnp.float32)
    xs_pad = jnp.pad(xs, ((0, 0), (0, 0), (0, 128 - p)))
    beta_pad = jnp.pad(beta, ((0, 0), (0, 128 - p)))
    o = batched_predict_pallas(xs_pad, beta_pad, valid, block_b=8,
                               block_n=bn, interpret=True)
    o0 = ref.batched_predict_ref(xs, beta, valid)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o0), rtol=1e-4,
                               atol=1e-4)
    assert float(jnp.max(jnp.abs(jnp.where(valid == 0, o, 0.0)))) == 0.0


# ---------------------------------------------------------------------------
# streaming blocked Gram (ISSUE 8 tall-N path)
# ---------------------------------------------------------------------------
def _tall_case(b, n, p, seed=0):
    k = jax.random.key(seed)
    xs = jax.random.normal(k, (b, n, p), jnp.float32)
    w = (jax.random.uniform(jax.random.fold_in(k, 1), (b, n)) > 0.3) \
        .astype(jnp.float32)
    y = jax.random.normal(jax.random.fold_in(k, 2), (b, n), jnp.float32)
    return xs, w, y


@pytest.mark.parametrize("chunk", [256, 512])
def test_blocked_gram_pallas_bitwise_on_exact_tiling(chunk):
    """Exact tiling (chunk divides N at kernel-block boundaries) keeps
    the blocked kernel's partial-sum order identical to the unblocked
    kernel's n-block loop: BITWISE equality, the contract the Gram
    families (BLOCKED_GRAM_BITWISE_FAMILIES) rely on.  chunk == N is
    the single-chunk degenerate case."""
    from repro.kernels import ops
    b, n, p = 8, 512, 16
    xs, w, y = _tall_case(b, n, p, seed=chunk)
    xs_pad = jnp.pad(xs, ((0, 0), (0, 0), (0, 128 - p)))
    g0, b0 = batched_gram_pallas(xs_pad, w, y, block_b=8, block_n=256,
                                 interpret=True)
    xc, wc, yc = ops.chunk_tall_n(xs_pad, w, y, chunk)
    g, bv = batched_gram_blocked_pallas(xc, wc, yc, block_b=8,
                                        block_n=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g0))
    np.testing.assert_array_equal(np.asarray(bv), np.asarray(b0))


@pytest.mark.parametrize("n,chunk", [(1024, 256), (512, 512), (768, 128)])
def test_blocked_gram_ops_bitwise_exact_tiling(n, chunk):
    """The ops-level wrapper pair: chunk_tall_n + batched_gram_blocked
    reproduces batched_gram bitwise whenever the chunk grid tiles N
    exactly (including the reg epilogue)."""
    from repro.kernels import ops
    xs, w, y = _tall_case(4, n, 12, seed=n)
    g0, b0 = ops.batched_gram(xs, w, y, reg=0.5)
    xc, wc, yc = ops.chunk_tall_n(xs, w, y, chunk)
    g, bv = ops.batched_gram_blocked(xc, wc, yc, reg=0.5)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g0))
    np.testing.assert_array_equal(np.asarray(bv), np.asarray(b0))


@pytest.mark.parametrize("n,chunk", [(1000, 384), (700, 256)])
def test_blocked_gram_ragged_tail_tolerance(n, chunk):
    """A ragged tail (chunk does not divide N) re-chunks the N-axis
    reduction tree, so equality is the explicit ~1e-4 tolerance tier —
    never bitwise, and tests must not pretend otherwise."""
    from repro.kernels import ops
    xs, w, y = _tall_case(4, n, 12, seed=n)
    g0, b0 = ops.batched_gram(xs, w, y)
    xc, wc, yc = ops.chunk_tall_n(xs, w, y, chunk)
    assert xc.shape[1] * xc.shape[2] > n          # really padded
    g, bv = ops.batched_gram_blocked(xc, wc, yc)
    scale = max(float(jnp.max(jnp.abs(g0))), 1.0)
    assert float(jnp.max(jnp.abs(g - g0))) / scale < 1e-3
    bscale = max(float(jnp.max(jnp.abs(b0))), 1.0)
    assert float(jnp.max(jnp.abs(bv - b0))) / bscale < 1e-3


def test_blocked_gram_masked_padding_rows_inert():
    """Zero-weight padded rows are exact no-ops: garbage feature values
    in w == 0 rows produce bitwise the same statistics as zero rows —
    the proof obligation for chunk_tall_n's tail padding."""
    from repro.kernels import ops
    xs, w, y = _tall_case(4, 512, 12, seed=7)
    xc, wc, yc = ops.chunk_tall_n(xs, w, y, 256)
    # poison the last 100 rows of the final chunk and zero their weight
    wc = wc.at[:, -1, -100:].set(0.0)
    poisoned = xc.at[:, -1, -100:, :].set(1e6)
    zeroed = xc.at[:, -1, -100:, :].set(0.0)
    yp = yc.at[:, -1, -100:].set(1e6)
    g1, b1 = ops.batched_gram_blocked(poisoned, wc, yp)
    g2, b2 = ops.batched_gram_blocked(zeroed, wc, yc)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))


def test_data_and_feature_parallel_gram_executors():
    """The in-mesh executors for the planner's non-task axes agree with
    the single-device statistics to the documented tolerance tier:
    data-parallel psums row-shard partials (reduction tree changes) and
    feature-parallel's narrower column blocks let XLA retile the N
    contraction — neither is a bitwise path (task-parallel is)."""
    from jax.sharding import Mesh
    from repro.kernels import ref
    from repro.sharding.gram import (
        data_parallel_gram, feature_parallel_gram, gram_solve,
    )
    mesh = Mesh(np.array(jax.devices()), ("data",))
    m = mesh.shape["data"]
    b, n, p = 4, 64 * max(m, 2), 8 * max(m, 2)
    xs, w, y = _tall_case(b, n, p, seed=5)
    g0, b0 = ref.batched_gram_ref(xs, w, y)
    gd, bd = data_parallel_gram(mesh, xs, w, y)
    scale = max(float(jnp.max(jnp.abs(g0))), 1.0)
    assert float(jnp.max(jnp.abs(gd - g0))) / scale < 1e-3
    gf, bf = feature_parallel_gram(mesh, xs, w, y)
    assert float(jnp.max(jnp.abs(gf - g0))) / scale < 1e-3
    bscale = max(float(jnp.max(jnp.abs(b0))), 1.0)
    assert float(jnp.max(jnp.abs(bf - b0))) / bscale < 1e-3
    # reassembled statistics solve to the same coefficients
    beta = gram_solve(gd + 0.1 * jnp.eye(p), bd)
    beta0 = gram_solve(g0 + 0.1 * jnp.eye(p), b0)
    np.testing.assert_allclose(np.asarray(beta), np.asarray(beta0),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sq,skv,d,bq,bk", [
    (128, 128, 32, 64, 64), (256, 256, 64, 64, 128),
    (64, 256, 32, 32, 64),                       # chunked-prefill shape
])
@pytest.mark.parametrize("causal,window", [
    (True, None), (True, 48), (False, None),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(sq, skv, d, bq, bk, causal, window, dtype):
    k = jax.random.key(sq + skv + d)
    q = jax.random.normal(k, (3, sq, d), jnp.float32).astype(dtype)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (3, skv, d),
                           jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(k, 2), (3, skv, d),
                          jnp.float32).astype(dtype)
    o = flash_attention_pallas(q, kk, v, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=True)
    o0 = ref.flash_attention_ref(q, kk, v, causal=causal, window=window)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                - o0.astype(jnp.float32))))
    assert err < TOL[dtype], err


@pytest.mark.parametrize("seed", [0, 5, 123, 888])
def test_flash_attention_batch_permutation_equivariance(seed):
    k = jax.random.key(seed)
    q = jax.random.normal(k, (4, 64, 16), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (4, 64, 16), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (4, 64, 16), jnp.float32)
    perm = jax.random.permutation(jax.random.fold_in(k, 3), 4)
    o1 = flash_attention_pallas(q, kk, v, block_q=32, block_k=32,
                                interpret=True)[perm]
    o2 = flash_attention_pallas(q[perm], kk[perm], v[perm],
                                block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_uniform_values():
    """With identical V rows the output equals V regardless of scores."""
    q = jax.random.normal(jax.random.key(0), (2, 64, 16), jnp.float32)
    kk = jax.random.normal(jax.random.key(1), (2, 64, 16), jnp.float32)
    v = jnp.broadcast_to(jnp.arange(16, dtype=jnp.float32), (2, 64, 16))
    o = flash_attention_pallas(q, kk, v, block_q=32, block_k=32,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(v), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,p,n,chunk", [
    (128, 16, 8, 32), (256, 64, 16, 64), (64, 32, 32, 64),
])
def test_ssd_scan_sweep(s, p, n, chunk):
    k = jax.random.key(s + p + n)
    xb = jax.random.normal(k, (2, s, p), jnp.float32)
    la = -jax.random.uniform(jax.random.fold_in(k, 1), (2, s)) * 2.0
    bm = jax.random.normal(jax.random.fold_in(k, 2), (2, s, n), jnp.float32)
    cm = jax.random.normal(jax.random.fold_in(k, 3), (2, s, n), jnp.float32)
    y = ssd_scan_pallas(xb, la, bm, cm, chunk=chunk, interpret=True)
    y0, _ = ref.ssd_scan_ref(xb, la, bm, cm)
    scale = max(float(jnp.max(jnp.abs(y0))), 1.0)
    assert float(jnp.max(jnp.abs(y - y0))) / scale < 2e-4


def test_ssd_zero_decay_is_cumulative_outer_product():
    """la = 0 => S_t = sum_j<=t B_j x_j^T: y_t = C_t . cumsum."""
    s, p, n = 32, 4, 3
    k = jax.random.key(0)
    xb = jax.random.normal(k, (1, s, p), jnp.float32)
    bm = jax.random.normal(jax.random.fold_in(k, 1), (1, s, n), jnp.float32)
    cm = jax.random.normal(jax.random.fold_in(k, 2), (1, s, n), jnp.float32)
    la = jnp.zeros((1, s), jnp.float32)
    y = ssd_scan_pallas(xb, la, bm, cm, chunk=16, interpret=True)
    states = jnp.cumsum(jnp.einsum("bsn,bsp->bsnp", bm, xb), axis=1)
    y0 = jnp.einsum("bsn,bsnp->bsp", cm, states)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("seed", [2, 64, 500, 901])
def test_ssd_strong_decay_forgets(seed):
    """Very negative la: state resets, y_t ~= C_t.(B_t x_t^T) only."""
    k = jax.random.key(seed)
    s = 64
    xb = jax.random.normal(k, (1, s, 8), jnp.float32)
    bm = jax.random.normal(jax.random.fold_in(k, 1), (1, s, 4), jnp.float32)
    cm = jax.random.normal(jax.random.fold_in(k, 2), (1, s, 4), jnp.float32)
    la = jnp.full((1, s), -50.0)
    y = ssd_scan_pallas(xb, la, bm, cm, chunk=16, interpret=True)
    y0 = jnp.einsum("bsn,bsn,bsp->bsp", cm, bm, xb)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0), rtol=1e-3,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# ops wrappers route to the oracle on CPU
# ---------------------------------------------------------------------------
def test_ops_cpu_routing():
    from repro.kernels import ops
    x = jax.random.normal(jax.random.key(0), (100, 7), jnp.float32)
    w = jnp.ones((3, 100), jnp.float32)
    y = jnp.ones((3, 100), jnp.float32)
    g, b = ops.crossfit_gram(x, w, y, reg=1.0)
    g0, b0 = ref.crossfit_gram_ref(x, w, y, reg=1.0)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0), rtol=1e-5)
