"""MoE dispatch-path equivalence: the shard_map a2a/dense-EP paths must match
the local sort-scatter oracle (same routing, same outputs) on a small mesh."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.moe import _moe_local, moe_decls, padded_experts
from repro.models.param import init_tree


def test_local_path_routing_weights_sum():
    cfg = get_arch("qwen2-moe-a2.7b", reduced=True)
    decls = moe_decls(cfg, ep_size=1)
    params = init_tree(decls, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.bfloat16)
    y, aux = _moe_local(params, cfg, x, padded_experts(cfg.moe, 1))
    assert y.shape == x.shape
    assert np.isfinite(float(aux))


def test_padded_experts_never_selected():
    cfg = get_arch("qwen2-moe-a2.7b", reduced=True)   # 8 routed in reduced
    e_pad = padded_experts(cfg.moe, ep_size=16)       # pads 8 -> 16
    assert e_pad == 16
    decls = moe_decls(cfg, ep_size=16)
    params = init_tree(decls, jax.random.key(0))
    from repro.models.moe import _route
    x = jax.random.normal(jax.random.key(1), (64, cfg.d_model), jnp.bfloat16)
    _, top_e, _ = _route(params, cfg.moe, x, e_pad)
    assert int(jnp.max(top_e)) < cfg.moe.n_routed


_EP_EQUIV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp, numpy as np
from repro import runtime
from repro.configs import get_arch
from repro.models.moe import moe_forward, moe_decls, _moe_local, padded_experts
from repro.models.param import init_tree
from repro.sharding.axes import MEGATRON_FSDP

from repro.sharding.compat import make_mesh_compat
mesh = make_mesh_compat((2, 2), ("data", "model"))
runtime.mesh_axes = ("data", "model")
cfg = get_arch("deepseek-v2-lite-16b", reduced=True)
decls = moe_decls(cfg, ep_size=2)
params = init_tree(decls, jax.random.key(0))
params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
x = jax.random.normal(jax.random.key(1), (4, 128, cfg.d_model), jnp.float32)

with mesh:
    y_ep, aux_ep = jax.jit(lambda p, x: moe_forward(
        p, cfg, x, MEGATRON_FSDP, mesh=mesh, ep_axis="model"))(params, x)
y_loc, aux_loc = _moe_local(params, cfg, x, padded_experts(cfg.moe, 2))
if "shared" in params:
    from repro.models.layers import mlp_forward
    sh = mlp_forward(params["shared"], x, cfg.act, glu=True,
                     rules=MEGATRON_FSDP)
    y_loc = y_loc + sh
err = float(jnp.max(jnp.abs(y_ep - y_loc)))
scale = float(jnp.max(jnp.abs(y_loc))) + 1e-6
print(json.dumps({"rel_err": err / scale}))
"""


def test_ep_a2a_matches_local_oracle():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _EP_EQUIV],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # capacity boundaries can drop different tokens across layouts; the
    # overwhelming majority of outputs must agree
    assert rec["rel_err"] < 0.05, rec