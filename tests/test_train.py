"""Optimizer, checkpointing, trainer integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import Checkpointer, payload_to_tree, tree_to_payload
from repro.train.optimizer import (
    OptConfig, adamw_update, compress_int8, init_opt_state,
    schedule,
)


def test_schedule_warmup_and_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    s = lambda t: float(schedule(cfg, jnp.asarray(t)))
    assert s(0) == 0.0
    assert abs(s(10) - 1.0) < 1e-6
    assert s(5) == pytest.approx(0.5)
    assert s(110) == pytest.approx(0.1, abs=1e-6)
    assert s(60) > s(100)


def test_adamw_converges_on_quadratic():
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=200,
                    weight_decay=0.0, clip_norm=1e9)
    target = jnp.asarray([[1.0, -2.0], [3.0, 0.5]])
    params = {"w": jnp.zeros((2, 2), jnp.bfloat16)}
    state = init_opt_state(params, cfg)
    for _ in range(300):
        g = {"w": (state["master"]["w"] - target)}
        params, state, m = adamw_update(params, g, state, cfg)
    assert float(jnp.max(jnp.abs(state["master"]["w"] - target))) < 0.05


def test_grad_clipping_bounds_update():
    cfg = OptConfig(lr=1.0, warmup_steps=0, total_steps=10, clip_norm=1.0,
                    weight_decay=0.0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = init_opt_state(params, cfg)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, m = adamw_update(params, g, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    # post-clip the effective gradient norm is 1.0 => bounded moments


def test_compress_int8_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    err = jnp.zeros_like(g)
    total_deq = jnp.zeros_like(g)
    for _ in range(30):
        deq, err = compress_int8(g, err)
        total_deq = total_deq + deq
    # long-run average of dequantized grads approaches the true gradient
    np.testing.assert_allclose(np.asarray(total_deq / 30), np.asarray(g),
                               atol=0.02)


def test_compressed_training_matches_uncompressed_approximately():
    target = jnp.asarray(np.random.default_rng(1).normal(size=(8, 8))
                         .astype(np.float32))

    def train(compress):
        cfg = OptConfig(lr=0.05, warmup_steps=0, total_steps=300,
                        weight_decay=0.0, clip_norm=1e9,
                        compress_grads=compress)
        params = {"w": jnp.zeros((8, 8), jnp.float32)}
        state = init_opt_state(params, cfg)
        for _ in range(300):
            g = {"w": state["master"]["w"] - target}
            params, state, _ = adamw_update(params, g, state, cfg)
        return float(jnp.mean(jnp.abs(state["master"]["w"] - target)))

    assert train(True) < 0.1
    assert abs(train(True) - train(False)) < 0.05


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {
        "a": jnp.asarray(np.random.default_rng(0).normal(size=(3, 5)),
                         jnp.bfloat16),
        "b": {"c": jnp.arange(7, dtype=jnp.int32)},
    }
    payload = tree_to_payload(tree)
    back = payload_to_tree(payload, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpointer_gc_and_restore(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_write=False)
    params = {"w": jnp.ones((2,), jnp.float32)}
    opt = {"step": jnp.zeros((), jnp.int32)}
    for s in (10, 20, 30, 40):
        ck.save(s, jax.tree.map(lambda x: x * s, params), opt,
                extra={"data_step": s})
    assert ck.steps() == [30, 40]
    p, o, step, extra = ck.restore(params, opt)
    assert step == 40 and extra["data_step"] == 40
    assert float(p["w"][0]) == 40.0
    p, o, step, _ = ck.restore(params, opt, step=30)
    assert float(p["w"][0]) == 30.0


def test_trainer_loss_decreases_and_resumes(tmp_path):
    from repro.configs import get_arch
    from repro.data.lm_data import LMDataConfig, SyntheticLM
    from repro.models import build_model
    from repro.train import Trainer, TrainerConfig

    cfg = get_arch("h2o-danube-3-4b", reduced=True)
    bundle = build_model(cfg, remat="none", attn_chunk=32)
    data = SyntheticLM(LMDataConfig(cfg.vocab_size, 32, 4, seed=0))
    tr = Trainer(bundle,
                 OptConfig(lr=5e-3, warmup_steps=2, total_steps=20),
                 TrainerConfig(steps=15, log_every=5, ckpt_every=5,
                               ckpt_dir=str(tmp_path)))
    params, opt = tr.init(jax.random.key(0))
    params, opt, hist = tr.run(params, opt, data.iterate())
    assert hist[-1]["loss"] < hist[0]["loss"]
    p2, o2, s2 = tr.resume()
    assert s2 == 15
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(params)[0], np.float32),
        np.asarray(jax.tree.leaves(p2)[0], np.float32))


def test_microbatch_equals_full_batch_gradients():
    from repro.configs import get_arch
    from repro.data.lm_data import LMDataConfig, SyntheticLM
    from repro.models import build_model
    from repro.train.trainer import make_train_step

    cfg = get_arch("h2o-danube-3-4b", reduced=True)
    bundle = build_model(cfg, remat="none", attn_chunk=32)
    data = SyntheticLM(LMDataConfig(cfg.vocab_size, 32, 4, seed=0))
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    ocfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    from repro.models import init_tree
    params = init_tree(bundle.decls, jax.random.key(0))
    s1 = init_opt_state(params, ocfg)
    s2 = init_opt_state(params, ocfg)
    p1, _, m1 = jax.jit(make_train_step(bundle, ocfg, 1))(params, s1, batch)
    p2, _, m2 = jax.jit(make_train_step(bundle, ocfg, 2))(params, s2, batch)
    # microbatched grads average the same loss; params should track closely
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-2
