"""Persistent on-disk program cache (ISSUE 7): cross-process reuse,
spec-keyed invalidation, and corruption eviction.

The cross-process tests are the contract the cache exists for: a fresh
process serving the SAME session workload must compile **zero** programs
— every traced executable comes off disk — while any change to the
learner spec (a fingerprint component) must miss.  They run real
subprocesses because in-process tests cannot prove the serialized
executables survive an interpreter boundary.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")

# One tiny drain: a single lasso PLR request through the wave backend,
# printing the compiler + persist counters as JSON on the last line.
# Lasso because its coordinate-descent solver is pure XLA (no LAPACK
# custom calls), so its executables are portable across processes —
# see PersistentProgramCache.portable.
_CHILD = """
import json, sys
from repro.core import DMLData, DMLPlan, DMLSession
from repro.data import make_plr_data

reg = float(sys.argv[1])
data = DMLData.from_dict(make_plr_data(n_obs=64, dim_x=5, theta=0.5, seed=3))
plan = DMLPlan.for_model("plr", learner="lasso", learner_params={"reg": reg},
                         n_folds=2, n_rep=1, seed=7)
sess = DMLSession(backend="wave")
rid = sess.submit(plan, data)
sess.run()
theta = float(sess.result(rid).theta)
s = sess.backend.compiler.stats
persist = sess.backend.compiler.persist
print(json.dumps({
    "theta": theta,
    "compiled": s.misses,
    "disk_hits": s.disk_hits,
    "disk_misses": s.disk_misses,
    "persist": persist.summary() if persist is not None else None,
}))
"""


def _run_child(cache_dir, reg=0.01):
    env = dict(os.environ,
               PYTHONPATH=SRC,
               REPRO_PROGRAM_CACHE_DIR=str(cache_dir))
    out = subprocess.run([sys.executable, "-c", _CHILD, str(reg)],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_second_process_compiles_zero_programs(tmp_path):
    """Same session workload twice in fresh processes: the first seeds
    the on-disk store, the second's cold drain compiles NOTHING — every
    program deserializes from the persistent cache."""
    cache_dir = tmp_path / "progcache"
    first = _run_child(cache_dir)
    assert first["compiled"] >= 1          # cold process really compiled
    assert first["disk_hits"] == 0
    assert first["persist"] is not None
    assert first["persist"]["disk_stores"] >= 1

    second = _run_child(cache_dir)
    assert second["compiled"] == 0         # THE contract: zero compiles
    assert second["disk_hits"] >= 1
    assert second["persist"]["disk_errors"] == 0
    # and the deserialized executables compute the same estimate
    np.testing.assert_allclose(second["theta"], first["theta"], rtol=0,
                               atol=0)


@pytest.mark.slow
def test_spec_change_invalidates_cache(tmp_path):
    """Bumping a learner spec field (lasso reg) changes the program
    fingerprint: the warm store must MISS and recompile, never serve the
    old executable."""
    cache_dir = tmp_path / "progcache"
    _run_child(cache_dir, reg=0.01)
    changed = _run_child(cache_dir, reg=0.02)
    assert changed["compiled"] >= 1        # spec change → fresh compile
    assert changed["disk_misses"] >= 1
    assert changed["disk_hits"] == 0


def test_roundtrip_and_corruption_eviction(tmp_path):
    """In-process store/lookup round trip, plus the failure mode: a
    corrupted entry is evicted and reported as a miss, never raised."""
    import jax
    import jax.numpy as jnp

    from repro.compile.persist import (PersistentProgramCache,
                                       backend_platform, jax_build)

    cache = PersistentProgramCache(str(tmp_path / "store"))
    build, platform = jax_build(), backend_platform()
    fp = ("test-v1", "ridge", 8, 8, 8, 8, None, (), False)

    compiled = jax.jit(lambda x: x * 2.0).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile()
    assert cache.lookup(build, platform, fp) is None     # cold miss
    cache.store(build, platform, fp, compiled)
    loaded = cache.lookup(build, platform, fp)
    assert loaded is not None
    np.testing.assert_array_equal(
        np.asarray(loaded(jnp.arange(4, dtype=jnp.float32))),
        np.asarray([0.0, 2.0, 4.0, 6.0]))
    # a different fingerprint never hits
    assert cache.lookup(build, platform, fp[:-1] + (True,)) is None
    # corrupt the entry on disk: lookup evicts it instead of raising
    # (clear the in-process tier first so the disk path actually runs)
    PersistentProgramCache._process_programs.clear()
    (entry,) = Path(cache.cache_dir).glob("*.prog")
    entry.write_bytes(b"not a serialized executable")
    assert cache.lookup(build, platform, fp) is None
    assert not entry.exists()
    assert cache.errors >= 1


def test_custom_call_programs_are_not_persisted(tmp_path):
    """A program whose optimized HLO contains custom calls (LAPACK
    cholesky here) must be REFUSED by the store: its serialized form
    embeds host function pointers and segfaults in the next process.
    Measured on this jaxlib build — see PersistentProgramCache.portable."""
    import jax
    import jax.numpy as jnp

    from repro.compile.persist import (PersistentProgramCache,
                                       backend_platform, jax_build)

    def solve_chol(x, y):
        xtx = x.T @ x + jnp.eye(x.shape[1])
        return jax.scipy.linalg.cho_solve(
            jax.scipy.linalg.cho_factor(xtx), x.T @ y)

    compiled = jax.jit(solve_chol).lower(
        jax.ShapeDtypeStruct((16, 4), jnp.float32),
        jax.ShapeDtypeStruct((16,), jnp.float32)).compile()
    assert "custom-call" in compiled.as_text()     # probe really applies
    cache = PersistentProgramCache(str(tmp_path / "store"))
    fp = ("test-v1", "chol", 16, 4, 8, 8, None, (), False)
    assert not cache.store(jax_build(), backend_platform(), fp, compiled)
    assert cache.skipped_unportable == 1
    assert list(Path(cache.cache_dir).glob("*.prog")) == []   # no disk entry
    # ...but the IN-PROCESS tier still serves it (pointers are valid
    # within the process — recycled-container reuse), operand-pinned
    # like every AOT executable the cache hands out
    served = cache.lookup(jax_build(), backend_platform(), fp)
    assert served._prog is compiled
    assert cache.loads == 0 and cache.process_hits == 1


def test_aot_calls_pin_host_operands(tmp_path):
    """Direct AOT executable calls (fresh or deserialized) read their
    host operands asynchronously WITHOUT retaining them — a temp numpy
    operand freed right after dispatch is a use-after-free the device
    books as garbage predictions (caught as nondeterministic thetas on
    disk-warm resumed drains).  Every executable the persistent cache
    hands out must therefore be operand-pinned: each call's argument
    tuple stays referenced until that call's outputs land."""
    import jax
    import jax.numpy as jnp

    from repro.compile.persist import (PersistentProgramCache,
                                       _PinnedExecutable,
                                       backend_platform, jax_build,
                                       pin_executable)

    cache = PersistentProgramCache(str(tmp_path / "store"))
    build, platform = jax_build(), backend_platform()
    fp = ("test-v1", "pin", 8, 8, 8, 8, None, (), False)
    compiled = jax.jit(lambda x: x * 2.0).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile()
    cache.store(build, platform, fp, compiled)

    # the process tier serves a pinned wrapper; so does a cold
    # deserialize in a cleared process
    assert isinstance(cache.lookup(build, platform, fp),
                      _PinnedExecutable)
    PersistentProgramCache._process_programs.clear()
    loaded = cache.lookup(build, platform, fp)
    assert isinstance(loaded, _PinnedExecutable)

    # the pin itself: the operand tuple is held from dispatch until the
    # outputs land, then released by the next call's lazy drain
    x = np.arange(4, dtype=np.float32)
    out = loaded(x)
    assert any(a is x for (_, args) in loaded._inflight for a in args)
    jax.block_until_ready(out)
    np.testing.assert_array_equal(np.asarray(out), x * 2.0)
    out2 = loaded(np.zeros(4, np.float32))       # drains the landed call
    jax.block_until_ready(out2)
    assert not any(a is x for (_, args) in loaded._inflight for a in args)

    # a raw wrapper over a plain callable still pins and releases
    pinned = pin_executable(lambda *a: np.float32(0.0))
    y = np.ones(3, np.float32)
    pinned(y)
    ((_, args),) = pinned._inflight
    assert args[0] is y
    pinned(np.zeros(1, np.float32))              # landed (numpy: always
    ((_, args2),) = pinned._inflight             # ready) -> released
    assert args2[0] is not y
