"""ISSUE 9 — the drain executes the axis planner's data/feature layouts.

``dispatch_bucket`` lowers a data@m/feature@m ``AxisDecision`` through
the in-mesh Gram executors (sharding/gram.py) and stamps the axis it
actually ran back on the decision.  These tests pin:

  * dispatch-level parity of the executed layouts against the bitwise
    task-axis reference for every Gram family (explicit tolerance tier
    — the split reductions retile, never bitwise);
  * the fallback contract: a non-divisible layout runs task-axis,
    bitwise, and stamps ``executed == "task"``;
  * out-of-order harvest of in-flight axis launches;
  * the chunk-paged tall-N path: a bucket whose N_pad exceeds
    DEVICE_PAGE_ROWS completes under a continuous ShardedBackend drain
    via data-parallel chunk streaming (impossible on the one-page
    task layout), with the decision's ``executed`` field logged;
  * TopologyBackend routing: tall-N Gram buckets land only on hosts
    whose data axis can stream them.

All tests run on 1-device and forced 8-device platforms alike: the
decisions adapt (data@1 chunk rescue vs data@8 sharding) but the
parity and bookkeeping contracts are identical.
"""
import numpy as np
import pytest

from repro.compile import plan_buckets, run_bucket
from repro.compile.buckets import AxisDecision
from repro.compile.program import ProgramCache, dispatch_bucket
from repro.core import DMLData, DMLPlan
from repro.core.session import compile_request
from repro.data import make_plr_data
from repro.launch.mesh import make_host_mesh
from repro.launch.roofline import GRAM_FAMILIES
from repro.serverless import InlineBackend, PoolConfig, ShardedBackend
from repro.serverless.topology import TopologyBackend

#: the sharded-axis float tolerance tier (module docstring in
#: sharding/gram.py): split reductions retile, parity is ~1e-6 — the
#: gate leaves an order of magnitude of headroom
AXIS_ATOL = 5e-4

_PARAMS = {"ols": {}, "ridge": {"reg": 1.0},
           "lasso": {"reg": 0.01, "n_iter": 60}}


def _req(learner, n_obs=104, seed=0, dim_x=5):
    data = DMLData.from_dict(make_plr_data(n_obs=n_obs, dim_x=dim_x,
                                           theta=0.5, seed=seed))
    plan = DMLPlan.for_model("plr", learner=learner,
                             learner_params=_PARAMS[learner],
                             n_folds=3, n_rep=2, seed=seed + 100)
    return compile_request(plan, data)


def _decision(bkey, axis, m, n_tasks):
    return AxisDecision(bucket=bkey, axis=axis, shards=m,
                        n_tasks=n_tasks, n_pad=bkey.n_pad,
                        p_pad=bkey.p_pad, mesh_devices=m)


@pytest.mark.parametrize("family", GRAM_FAMILIES)
@pytest.mark.parametrize("axis", ["data", "feature"])
def test_dispatch_executes_planned_axis(family, axis):
    """A hand-built data/feature decision executes through the in-mesh
    Gram program and agrees with the task-axis reference to the
    explicit tolerance tier; the executed axis is stamped."""
    mesh = make_host_mesh()
    m = int(mesh.shape["data"])
    req = _req(family)
    bplan = plan_buckets([req])
    (bkey,) = bplan.buckets
    entries = bplan.pending_by_bucket()[bkey]
    ref, _ = run_bucket(bplan, ProgramCache(), bkey, entries)

    dec = _decision(bkey, axis, m, len(entries))
    bd = dispatch_bucket(bplan, ProgramCache(), bkey, entries,
                         axis_decision=dec, mesh=mesh)
    got = bd.harvest()
    divisible = (bkey.n_pad if axis == "data" else bkey.p_pad) % m == 0
    if divisible:
        assert dec.executed == axis
        for e in entries:
            np.testing.assert_allclose(got[e], ref[e], rtol=0,
                                       atol=AXIS_ATOL)
    else:                       # fallback is the bitwise task program
        assert dec.executed == "task"
        for e in entries:
            np.testing.assert_array_equal(got[e], ref[e])


def test_task_decision_stamps_executed():
    """A task-axis decision (and a missing mesh) keep the bitwise task
    path and stamp ``executed == "task"``."""
    req = _req("ridge")
    bplan = plan_buckets([req])
    (bkey,) = bplan.buckets
    entries = bplan.pending_by_bucket()[bkey]
    ref, _ = run_bucket(bplan, ProgramCache(), bkey, entries)

    for dec, mesh in [(_decision(bkey, "task", 1, len(entries)),
                       make_host_mesh()),
                      (_decision(bkey, "data", 1, len(entries)), None)]:
        bd = dispatch_bucket(bplan, ProgramCache(), bkey, entries,
                             axis_decision=dec, mesh=mesh)
        got = bd.harvest()
        assert dec.executed == "task"
        for e in entries:
            np.testing.assert_array_equal(got[e], ref[e])


def test_axis_dispatch_out_of_order_harvest():
    """Two in-flight axis launches harvest in reverse dispatch order —
    the non-blocking drain never assumes FIFO settlement."""
    mesh = make_host_mesh()
    m = int(mesh.shape["data"])
    reqs = [_req("ridge", n_obs=104, seed=0),
            _req("ridge", n_obs=144, seed=1)]
    bplan = plan_buckets(reqs)
    groups = bplan.pending_by_bucket()
    assert len(groups) == 2
    refs = {k: run_bucket(bplan, ProgramCache(), k, es)[0]
            for k, es in groups.items()}
    cache = ProgramCache()
    bds = []
    for bkey, entries in groups.items():
        dec = _decision(bkey, "data", m, len(entries))
        bds.append((bkey, dec, dispatch_bucket(
            bplan, cache, bkey, entries, axis_decision=dec, mesh=mesh)))
    for bkey, dec, bd in reversed(bds):
        got = bd.harvest()
        assert dec.executed in ("data", "task")
        ref = refs[bkey]
        for e, r in ref.items():
            if dec.executed == "data":
                np.testing.assert_allclose(got[e], r, rtol=0,
                                           atol=AXIS_ATOL)
            else:
                np.testing.assert_array_equal(got[e], r)


def test_tall_bucket_chunk_paged_drain(monkeypatch):
    """The headline path: a bucket with N_pad > DEVICE_PAGE_ROWS
    completes under a continuous ShardedBackend drain by chunk-paged
    data-parallel streaming, the planner's decision is executed, and
    the results agree with the inline reference to the tolerance
    tier."""
    from repro.launch import roofline
    monkeypatch.setattr(roofline, "DEVICE_PAGE_ROWS", 16)

    ref_req = _req("ridge", n_obs=264, seed=3)
    InlineBackend().run_requests([ref_req])

    req = _req("ridge", n_obs=264, seed=3)
    info = ShardedBackend().run_requests([req])
    assert req.ledger.complete
    np.testing.assert_allclose(req.gathered_preds(),
                               ref_req.gathered_preds(),
                               rtol=0, atol=AXIS_ATOL)
    assert len(info.axis_plans) == 1
    dec = info.axis_plans[0]
    assert dec.axis == "data"           # task layout can't hold the page
    assert dec.executed == "data"       # ...and the drain ran the plan


def test_forced_feature_decision_executes_in_drain(monkeypatch):
    """A feature@m decision injected at the planner seam executes
    through the drain (executed stamp + tolerance-tier parity) — the
    drain's wiring is axis-agnostic."""
    import repro.compile.buckets as buckets_mod

    mesh = make_host_mesh()
    m = int(mesh.shape["data"])

    def force_feature(key, *, n_tasks, n_devices):
        return _decision(key, "feature", n_devices, n_tasks)

    monkeypatch.setattr(buckets_mod, "plan_bucket_axis", force_feature)

    ref_req = _req("ols", n_obs=120, seed=5)
    InlineBackend().run_requests([ref_req])
    req = _req("ols", n_obs=120, seed=5)
    info = ShardedBackend().run_requests([req])
    assert req.ledger.complete
    dec = info.axis_plans[0]
    expect = "feature" if dec.p_pad % m == 0 else "task"
    assert dec.executed == expect
    np.testing.assert_allclose(req.gathered_preds(),
                               ref_req.gathered_preds(),
                               rtol=0, atol=AXIS_ATOL)


def test_sharded_drain_small_bucket_stays_task():
    """The serving-size pin: a small fitting bucket keeps the untaxed
    task layout and the drain stamps ``executed == "task"`` — the
    decision-vs-executed mix is auditable end to end."""
    req = _req("ridge")
    info = ShardedBackend().run_requests([req])
    assert req.ledger.complete
    assert len(info.axis_plans) == 1
    dec = info.axis_plans[0]
    assert dec.axis == "task"
    assert dec.executed == "task"


def test_topology_routes_tall_buckets_to_streaming_hosts(monkeypatch):
    """Tall-N Gram buckets are routed (and stolen) only by hosts whose
    data axis can stream them, and the drain completes them via the
    executed data layout."""
    from repro.launch import roofline
    monkeypatch.setattr(roofline, "DEVICE_PAGE_ROWS", 16)

    ref_req = _req("ridge", n_obs=280, seed=7)
    InlineBackend().run_requests([ref_req])

    backend = TopologyBackend(PoolConfig(n_workers=4), n_hosts=2)
    req = _req("ridge", n_obs=280, seed=7)
    state = backend.begin_drain()
    backend.admit(state, req)
    while backend.step(state):
        pass
    backend._finish(state)
    assert req.ledger.complete
    np.testing.assert_allclose(req.gathered_preds(),
                               ref_req.gathered_preds(),
                               rtol=0, atol=AXIS_ATOL)
    assert state.info.axis_plans
    assert all(d.executed == "data" for d in state.info.axis_plans
               if d.axis == "data")
    # every placement respected the bucket's eligible-host set
    for key, host, _ in state.info.topology.placements:
        assert host in backend._eligible_hosts(key)
