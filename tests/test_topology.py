"""Topology-aware drain (ISSUE 4 tentpole): bucket→host placement
follows page residency, idle hosts steal work, per-mesh streams step
round-robin from the session's event loop, the autoscaler prices each
host's waves with roofline FLOP estimates, and the whole thing is
bitwise-identical to the single-host inline drain for every learner
family.

CI additionally runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
multihost-smoke job), where each simulated host's page pool pins pages
to a distinct device; on a single-device run the hosts share the device
but keep disjoint pools, so every assertion below still holds."""
import jax
import numpy as np
import pytest

from repro.compile import PagePool, plan_buckets
from repro.core import DMLData, DMLPlan, DMLSession
from repro.core.session import compile_request
from repro.data import make_irm_data, make_plr_data
from repro.serverless import (
    InlineBackend, PoolConfig, Topology, TopologyBackend,
)
from repro.sharding.policy import place_bucket, steal_choice


def _plr(n_obs, seed, *, learner="ridge", learner_params=None, n_rep=2,
         n_folds=3):
    data = DMLData.from_dict(make_plr_data(n_obs=n_obs, dim_x=5, theta=0.5,
                                           seed=seed))
    if learner_params is None:
        learner_params = {"reg": 1.0}
    plan = DMLPlan.for_model(
        "plr", learner=learner, learner_params=learner_params,
        n_folds=n_folds, n_rep=n_rep, seed=seed + 100)
    return plan, data


FAMILIES = [
    ("ridge", {"reg": 1.0}),
    ("ols", {}),
    ("lasso", {"reg": 0.01}),
    ("kernel_ridge", {"reg": 1.0, "n_landmarks": 32}),
    ("mlp", {"hidden": (8,), "n_steps": 20}),
]


def _family_cases():
    cases = [_plr(100 + 7 * i, seed=i, learner=name, learner_params=params)
             for i, (name, params) in enumerate(FAMILIES)]
    cases.append((DMLPlan.for_model("irm", learner="ridge", n_folds=3,
                                    n_rep=2, seed=77),
                  DMLData.from_dict(make_irm_data(n_obs=130, dim_x=4,
                                                  theta=0.4, seed=9))))
    return cases


# ---------------------------------------------------------------------------
# bitwise parity vs the single-host inline path
# ---------------------------------------------------------------------------
def test_topology_bitwise_parity_all_families():
    """Every learner family (logistic rides along via IRM) drained over
    two host streams — with placement, stealing, and per-host
    autoscaling live — matches a solo single-host inline drain bitwise."""
    cases = _family_cases()
    sess = DMLSession(backend="topology",
                      pool=PoolConfig(n_workers=2, memory_mb=256,
                                      autoscale=True, n_hosts=2))
    rids = [sess.submit(plan, data) for plan, data in cases]
    sess.run()
    t = sess.topology_info
    assert t is not None and t.n_hosts == 2
    assert sum(h.waves for h in t.hosts) == sess.last_run_info.waves
    assert all(h.waves > 0 for h in t.hosts)       # both streams really ran
    for rid, (plan, data) in zip(rids, cases):
        ref = compile_request(plan, data)
        InlineBackend().run_requests([ref])
        np.testing.assert_array_equal(
            sess.request(rid).gathered_preds(), ref.gathered_preds())


# ---------------------------------------------------------------------------
# placement follows residency
# ---------------------------------------------------------------------------
def test_routing_follows_page_residency():
    """Round 1 seeds residency (cold placement balances load); every
    later round routes each bucket back to the host holding its pages:
    steady-state hit rate 1.0, zero h2d bytes, zero cross-host fetches."""
    cases = [_plr(100 + i, seed=i) for i in range(2)] + \
            [_plr(300, seed=5), _plr(310, seed=6)]   # two N-buckets
    sess = DMLSession(backend="topology",
                      pool=PoolConfig(n_hosts=2, n_workers=8))
    for plan, data in cases:
        sess.submit(plan, data)
    sess.run()                                      # warmup: cold placement
    cold = {key: host for key, host, _ in sess.topology_info.placements}
    topo = sess.backend.topology
    warm0 = topo.page_stats().snapshot()
    fetches0 = topo.directory.fetches
    for _ in range(3):                              # steady state
        for plan, data in cases:
            sess.submit(plan, data)
        sess.run()
        warm = {key: host for key, host, _
                in sess.topology_info.placements}
        assert warm == cold                         # residency-stable routes
    d = topo.page_stats().delta(warm0)
    assert d.bytes_h2d == 0 and d.misses == 0
    assert d.hit_rate == 1.0
    assert topo.directory.fetches == fetches0      # no cross-host traffic
    # warm placements scored resident (>0), cold ones didn't
    assert all(s > 0 for _, _, s in sess.topology_info.placements)


def test_place_bucket_scoring_and_determinism():
    """Unit-level policy: stack-cached beats pages-resident beats cold;
    ties break to the least-loaded host, then the lowest id."""
    class FakePool:
        def __init__(self, pages=(), stacks=()):
            self._p, self._s = set(pages), set(stacks)

        def resident(self, pk):
            return pk in self._p

        def stack_cached(self, pkeys):
            return tuple(pkeys) in self._s

    pk = ("fp", 128, 8)
    cold = FakePool()
    resident = FakePool(pages=[pk])
    stacked = FakePool(pages=[pk], stacks=[(pk,)])
    p = place_bucket([pk], [cold, resident, stacked], loads=[0, 0, 0])
    assert p.host == 2 and p.stacked == 1 and p.score == 2.0
    p = place_bucket([pk], [cold, resident], loads=[0, 100])
    assert p.host == 1 and p.score == 1.0   # residency outweighs load
    p = place_bucket([pk], [cold, cold], loads=[5, 3])
    assert p.host == 1                  # cold tie -> least loaded
    p = place_bucket([pk], [cold, cold], loads=[3, 3])
    assert p.host == 0                  # full tie -> lowest id


def test_steal_choice_picks_least_local_from_most_loaded():
    class FakePool:
        def __init__(self, pages=()):
            self._p = set(pages)

        def resident(self, pk):
            return pk in self._p

        def stack_cached(self, pkeys):
            return False

    pools = [FakePool(pages=["a"]), FakePool()]
    queues = {0: ["ka", "kb", "kc"]}
    pick = steal_choice(queues, pools,
                        lambda k: ["a"] if k == "ka" else [k])
    assert pick == (0, "kb")            # kb/kc cold on donor; kb first
    assert steal_choice({0: ["ka"]}, pools, lambda k: [k]) is None
    assert steal_choice({}, pools, lambda k: [k]) is None


# ---------------------------------------------------------------------------
# work stealing
# ---------------------------------------------------------------------------
def _same_data_cases():
    """Multiple learner families over ONE dataset: distinct buckets that
    all share one feature page."""
    data = DMLData.from_dict(make_plr_data(n_obs=100, dim_x=5, theta=0.5,
                                           seed=3))
    return [(DMLPlan.for_model("plr", learner=name, learner_params=params,
                               n_folds=3, n_rep=2, seed=50 + i), data)
            for i, (name, params) in enumerate(FAMILIES)]


def _seed_host0_residency(backend, cases):
    """Pre-warm host 0's pool with every page the cases need, so
    residency scoring routes ALL their buckets to host 0 and leaves
    host 1 idle — the stealing scenario."""
    pool = backend.topology.hosts[0].pool
    for plan, data in cases:
        req = compile_request(plan, data)
        for key in plan_buckets([req]).buckets:
            pool._page(PagePool.page_key(req, key.n_pad, key.p_pad),
                       req, key.n_pad, key.p_pad)


def test_work_stealing_triggers_on_idle_host():
    cases = _same_data_cases()
    backend = TopologyBackend(PoolConfig(n_hosts=2, n_workers=1,
                                         memory_mb=256))
    _seed_host0_residency(backend, cases)
    reqs = [compile_request(p, d) for p, d in cases]
    info = backend.run_requests(reqs)
    t = info.topology
    # every bucket was *placed* on the resident host...
    assert all(host == 0 for _, host, _ in t.placements)
    # ...so the idle second host stole some of the queue
    assert t.steals >= 1
    assert t.hosts[1].steals >= 1 and t.hosts[1].waves >= 1
    # the stolen bucket's page arrived device-to-device, not via host
    topo = backend.topology
    assert topo.directory.fetches >= 1
    assert topo.page_stats().cross_host_fetches >= 1
    # and stealing never moved an estimate
    for req, (plan, data) in zip(reqs, cases):
        ref = compile_request(plan, data)
        InlineBackend().run_requests([ref])
        np.testing.assert_array_equal(req.gathered_preds(),
                                      ref.gathered_preds())


def test_fusion_under_placement_and_stealing():
    """Same-shape block fusion stays bitwise-correct when the fused
    bucket is placed by residency, stolen by an idle host, and harvested
    out of order across host streams: four same-bucket ridge requests
    fuse into multi-block launches wherever they land."""
    cases = [_plr(100 + i, seed=40 + i) for i in range(4)]  # one bucket
    # capacity 8 = half the bucket: a wave spans 2+ requests (so their
    # equal-shape blocks fuse) while the rest stays stealable
    backend = TopologyBackend(PoolConfig(n_hosts=2, n_workers=2,
                                         memory_mb=1024))
    _seed_host0_residency(backend, cases)
    reqs = [compile_request(p, d) for p, d in cases]
    info = backend.run_requests(reqs)
    assert backend.compiler.stats.fused_launches >= 1
    assert info.dispatch is not None
    assert info.dispatch.harvested == info.dispatch.dispatched
    for req, (plan, data) in zip(reqs, cases):
        ref = compile_request(plan, data)
        InlineBackend().run_requests([ref])
        np.testing.assert_array_equal(req.gathered_preds(),
                                      ref.gathered_preds())


def test_steal_disabled_keeps_buckets_on_resident_host():
    cases = _same_data_cases()
    backend = TopologyBackend(PoolConfig(n_hosts=2, n_workers=1,
                                         memory_mb=256, steal=False))
    _seed_host0_residency(backend, cases)
    reqs = [compile_request(p, d) for p, d in cases]
    info = backend.run_requests(reqs)               # pileup, no stealing
    assert info.topology.steals == 0
    assert backend.topology.directory.fetches == 0
    busy = [h for h in info.topology.hosts if h.waves > 0]
    assert [h.host_id for h in busy] == [0]         # the other stayed idle


# ---------------------------------------------------------------------------
# per-mesh streams from the session event loop
# ---------------------------------------------------------------------------
def test_poll_steps_host_streams_round_robin():
    """poll() advances one host stream per call; ledgers complete out of
    order across hosts; completion set matches a blocking run()."""
    # four distinct N-buckets so cold placement spreads over both hosts
    cases = [_plr(n, seed=i, n_rep=2)
             for i, n in enumerate((100, 300, 600, 1200))]
    sess = DMLSession(backend="topology",
                      pool=PoolConfig(n_hosts=2, n_workers=1,
                                      memory_mb=256))
    rids = [sess.submit(p, d) for p, d in cases]
    done = []
    for _ in range(200):
        done += sess.poll()
        if len(done) == len(rids):
            break
    assert sorted(done) == sorted(rids)
    t = sess.topology_info
    assert all(h.waves > 0 for h in t.hosts)


def test_worker_schedule_honored_per_host_stream():
    """The legacy static ramp sizes each host stream's waves by that
    host's own wave count (parity with the wave backend's contract), and
    the estimate is untouched."""
    backend = TopologyBackend(PoolConfig(n_hosts=2, memory_mb=256,
                                         worker_schedule=[1, 2, 8, 8]))
    plan, data = _plr(100, seed=31, n_rep=4)
    req = compile_request(plan, data)
    info = backend.run_requests([req])
    assert req.ledger.complete
    busy = [h for h in info.topology.hosts if h.waves > 0]
    assert busy and busy[0].waves >= 2          # the ramp really waved
    ref = compile_request(plan, data)
    InlineBackend().run_requests([ref])
    np.testing.assert_array_equal(req.gathered_preds(),
                                  ref.gathered_preds())


def test_topology_from_pod_mesh():
    """A multi-pod production-style mesh splits into one host stream per
    pod, each pinned to its own device set."""
    if jax.device_count() < 8:
        pytest.skip("needs the forced 8-device host platform")
    from repro.sharding.compat import make_mesh_compat
    mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
    topo = Topology.from_mesh(mesh)
    assert len(topo) == 2
    assert topo.hosts[0].n_devices == 4
    devs0 = {d.id for d in np.asarray(topo.hosts[0].mesh.devices).flat}
    devs1 = {d.id for d in np.asarray(topo.hosts[1].mesh.devices).flat}
    assert devs0.isdisjoint(devs1)
    assert topo.hosts[0].device.id != topo.hosts[1].device.id

    backend = TopologyBackend(PoolConfig(n_workers=4), topology=topo)
    plan, data = _plr(100, seed=21)
    req = compile_request(plan, data)
    backend.run_requests([req])
    ref = compile_request(plan, data)
    InlineBackend().run_requests([ref])
    np.testing.assert_array_equal(req.gathered_preds(),
                                  ref.gathered_preds())


# ---------------------------------------------------------------------------
# roofline-priced autoscaling
# ---------------------------------------------------------------------------
def test_autoscaler_first_decision_roofline_priced():
    """Before any duration is observed, candidates are priced by the
    compiler's per-bucket FLOP estimates (not the unit-work model), the
    full candidate cost table is logged, and later waves switch to the
    measured EMA."""
    sess = DMLSession(backend="topology",
                      pool=PoolConfig(n_hosts=2, n_workers=2,
                                      memory_mb=256, autoscale=True,
                                      max_workers=4))
    for i, n in enumerate((100, 300, 600)):        # distinct buckets
        sess.submit(*_plr(n, seed=i, n_rep=4))
    sess.run()
    decisions = sess.last_run_info.autoscale
    assert decisions
    assert decisions[0].priced_by == "roofline"
    assert len(decisions[0].candidate_costs) >= 2
    for w, time_s, gb_s, score in decisions[0].candidate_costs:
        assert w >= 1 and time_s > 0 and gb_s > 0 and score > 0
    assert any(d.priced_by == "ema" for d in decisions[1:])
    assert {d.host for d in decisions} == {0, 1}   # each mesh sized itself


def test_roofline_task_models_scale_sanely():
    from repro.launch.roofline import (
        invocation_roofline_s, megabatch_task_flops,
    )
    for fam, params in [("ridge", {}), ("lasso", {"n_iter": 50}),
                        ("logistic", {}), ("mlp", {"hidden": (8,)}),
                        ("kernel_ridge", {"n_landmarks": 16})]:
        small = megabatch_task_flops(fam, 128, 8, params)
        big = megabatch_task_flops(fam, 512, 8, params)
        assert 0 < small < big
    assert invocation_roofline_s("ridge", {}, 6, 128, 8) == \
        2 * invocation_roofline_s("ridge", {}, 3, 128, 8)
