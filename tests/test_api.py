"""The declarative front-end: DMLData validation, DMLPlan construction,
config immutability (the PoolConfig aliasing regression), and the
deprecated DoubleMLServerless shim."""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    DMLData, DMLPlan, DoubleMLServerless, NuisanceSpec, estimate,
)
from repro.core.session import compile_request
from repro.data import make_irm_data, make_plr_data
from repro.serverless import PoolConfig, TaskLedger


# ---------------------------------------------------------------------------
# DMLData
# ---------------------------------------------------------------------------
def test_dmldata_validates_and_coerces():
    data = DMLData(x=np.ones((10, 3), np.float64), y=range(10),
                   d=np.zeros(10))
    assert data.x.dtype == np.float32 and data.x.shape == (10, 3)
    assert data.n_obs == 10 and data.dim_x == 3
    assert "z" not in data and "d" in data
    assert data.score_arrays().keys() == {"y", "d"}


def test_dmldata_rejects_bad_shapes_and_nans():
    with pytest.raises(ValueError, match="rows"):
        DMLData(x=np.ones((10, 3)), y=np.ones(9), d=np.ones(10))
    with pytest.raises(ValueError, match="2-d"):
        DMLData(x=np.ones(10), y=np.ones(10), d=np.ones(10))
    bad = np.ones(10)
    bad[3] = np.nan
    with pytest.raises(ValueError, match="NaN"):
        DMLData(x=np.ones((10, 3)), y=bad, d=np.ones(10))


def test_dmldata_from_dict_roundtrip():
    raw = make_plr_data(n_obs=50, dim_x=4, theta=0.3, seed=1)
    data = DMLData.from_dict(raw)
    assert data.theta0 == pytest.approx(0.3)
    np.testing.assert_array_equal(data.role("y"), raw["y"])
    assert DMLData.from_dict(data) is data          # idempotent
    with pytest.raises(KeyError, match="no 'z'"):
        data.role("z")


def test_dmldata_is_immutable():
    data = DMLData(x=np.ones((5, 2)), y=np.ones(5), d=np.ones(5))
    with pytest.raises(dataclasses.FrozenInstanceError):
        data.y = np.zeros(5)


# ---------------------------------------------------------------------------
# DMLPlan
# ---------------------------------------------------------------------------
def test_for_model_uniform_plr():
    plan = DMLPlan.for_model("plr", learner="ridge",
                             learner_params={"reg": 0.5}, n_folds=3, n_rep=2)
    assert [ns.name for ns in plan.nuisances] == ["ml_l", "ml_m"]
    assert plan.uniform
    assert plan.nuisances[1].target == "d"
    assert plan.nuisances[0].param_dict == {"reg": 0.5}


def test_for_model_irm_propensity_goes_logistic():
    """The old ``_learner_key`` classify-hack, now an explicit plan rule:
    linear learners get a logistic propensity for binary treatments."""
    plan = DMLPlan.for_model("irm", learner="ridge",
                             learner_params={"reg": 2.0})
    by_name = {ns.name: ns for ns in plan.nuisances}
    assert by_name["ml_m"].learner == "logistic"
    assert by_name["ml_m"].param_dict == {"reg": 2.0}
    assert by_name["ml_g0"].learner == "ridge"
    assert not plan.uniform


def test_for_model_override_nuisance():
    plan = DMLPlan.for_model(
        "plr", learner="ridge",
        overrides={"ml_m": NuisanceSpec.make("ml_m", "d", "lasso",
                                             {"reg": 0.01})})
    by_name = {ns.name: ns for ns in plan.nuisances}
    assert by_name["ml_m"].learner == "lasso"
    assert by_name["ml_m"].target == "d"        # role comes from the model
    assert by_name["ml_l"].learner == "ridge"


def test_plan_accepts_unhashable_param_values():
    """List-valued hyperparameters (e.g. mlp hidden sizes) are
    canonicalized to tuples so specs stay hashable and groupable."""
    plan = DMLPlan.for_model("plr", learner="mlp",
                             learner_params={"hidden": [8, 8], "lr": 1e-3})
    assert plan.nuisances[0].param_dict["hidden"] == (8, 8)
    assert plan.uniform
    data = make_plr_data(n_obs=40, dim_x=3, theta=0.5, seed=1)
    req = compile_request(plan.replace(
        resampling=type(plan.resampling)(n_folds=2, n_rep=1)),
        DMLData.from_dict(data))
    assert len(req.segments) == 1            # grouping worked via hashing


def test_plan_validation():
    with pytest.raises(KeyError):
        DMLPlan.for_model("nope")
    with pytest.raises(ValueError, match="scaling"):
        DMLPlan.for_model("plr", scaling="bogus")
    with pytest.raises(ValueError, match="backend"):
        DMLPlan.for_model("plr", backend="bogus")
    with pytest.raises(ValueError, match="n_folds"):
        DMLPlan.for_model("plr", n_folds=1)
    plan = DMLPlan.for_model("plr")
    with pytest.raises(dataclasses.FrozenInstanceError):
        plan.score = "IV-type"


# ---------------------------------------------------------------------------
# config immutability — the aliasing regression
# ---------------------------------------------------------------------------
def test_shared_pool_is_never_mutated():
    """One PoolConfig reused across estimators must not leak settings:
    the old ``__init__`` did ``self.pool.scaling = scaling`` on the
    caller's object."""
    pool = PoolConfig(n_workers=4, scaling="n_rep")
    plan_a = DMLPlan.for_model("plr", n_rep=2, n_folds=3,
                               scaling="n_folds*n_rep", pool=pool)
    plan_b = DMLPlan.for_model("plr", n_rep=2, n_folds=3,
                               scaling="n_rep", pool=pool)
    assert pool.scaling == "n_rep"                  # untouched
    assert plan_a.scaling == "n_folds*n_rep"
    assert plan_b.scaling == "n_rep"
    with pytest.raises(dataclasses.FrozenInstanceError):
        pool.scaling = "n_folds*n_rep"

    with pytest.warns(DeprecationWarning):
        DoubleMLServerless(model="plr", scaling="n_folds*n_rep", pool=pool)
    assert pool.scaling == "n_rep"                  # shim is clean too

    # the two plans really do execute at different granularity
    data = make_plr_data(n_obs=120, dim_x=4, theta=0.5, seed=2)
    ra = estimate(plan_a, data)
    rb = estimate(plan_b, data)
    assert ra.report.bill.n_invocations == 2 * 3 * 2     # M*K*L
    assert rb.report.bill.n_invocations == 2 * 2         # M*L
    assert ra.theta == pytest.approx(rb.theta, abs=5e-4)


# ---------------------------------------------------------------------------
# shim equivalence + the mixed-learner ledger regression
# ---------------------------------------------------------------------------
def test_shim_matches_declarative_api():
    data = make_plr_data(n_obs=150, dim_x=5, theta=0.5, seed=4)
    plan = DMLPlan.for_model("plr", learner="ridge",
                             learner_params={"reg": 1.0}, n_folds=3, n_rep=2,
                             seed=9, pool=PoolConfig(n_workers=4))
    res_new = estimate(plan, DMLData.from_dict(data))
    with pytest.warns(DeprecationWarning):
        est = DoubleMLServerless(model="plr", learner="ridge",
                                 learner_params={"reg": 1.0}, n_folds=3,
                                 n_rep=2, seed=9,
                                 pool=PoolConfig(n_workers=4))
    res_old = est.fit(data)
    assert res_old.theta == res_new.theta
    assert res_old.se == res_new.se


def test_mixed_learner_grid_honors_caller_ledger():
    """IRM grids run one segment per learner; the old fit() dropped the
    caller's ledger on that path, so resume re-billed everything."""
    data = make_irm_data(n_obs=200, dim_x=4, theta=0.4, seed=5)
    plan = DMLPlan.for_model("irm", learner="ridge", n_folds=3, n_rep=2,
                             pool=PoolConfig(n_workers=4))
    req_probe = compile_request(plan, DMLData.from_dict(data))
    ledger = TaskLedger.create(req_probe.ledger.n_invocations,
                               req_probe.ledger.n_obs,
                               req_probe.ledger.tasks_per_invocation)
    first = estimate(plan, data, ledger=ledger)
    assert ledger.complete
    assert first.report.bill.n_invocations == ledger.n_invocations
    resumed = estimate(plan, data, ledger=ledger)
    assert resumed.report.bill.n_invocations == 0        # nothing re-run
    assert resumed.theta == first.theta

    with pytest.warns(DeprecationWarning):
        est = DoubleMLServerless(model="irm", learner="ridge", n_folds=3,
                                 n_rep=2, pool=PoolConfig(n_workers=4))
    shim_resumed = est.fit(data, ledger=ledger)
    assert shim_resumed.report.bill.n_invocations == 0
    assert shim_resumed.theta == first.theta
