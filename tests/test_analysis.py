"""repro.analysis auditor: clean on HEAD, and each pass demonstrably
catches its seeded mutation (red) that the pristine tree passes (green).

The static passes are pure-AST, so mutations are applied textually to a
copy of the source tree in tmp_path — nothing broken is ever imported.
"""
import shutil

import jax
import numpy as np
import pytest

from repro.analysis import astutil, cache_keys, deadcode, protocol


def _mutated_tree(tmp_path, rel, old, new):
    root = astutil.default_root()
    tmp = tmp_path / "repro"
    shutil.copytree(root, tmp)
    src = (tmp / rel).read_text()
    assert old in src, f"mutation anchor missing from {rel}"
    (tmp / rel).write_text(src.replace(old, new))
    return tmp


# ---------------------------------------------------------------------------
# green: HEAD is clean
# ---------------------------------------------------------------------------
def test_static_passes_clean_on_head():
    assert cache_keys.run() == []
    assert protocol.run() == []
    assert deadcode.run() == []


def test_registry_covers_expected_caches():
    from repro.analysis import REGISTRY
    import repro.compile.buckets     # noqa: F401  (decorators register
    import repro.compile.pages       # noqa: F401   on import)
    import repro.compile.program     # noqa: F401
    import repro.serverless.backends  # noqa: F401
    import repro.sharding.gram       # noqa: F401
    assert set(cache_keys.EXPECTED_CACHES) <= set(REGISTRY)
    spec = REGISTRY["block_tensors"]
    assert "req.work_key" in spec.key
    assert "req.wave_arrays" in spec.covers["req.work_key"]


# ---------------------------------------------------------------------------
# red: cache-key pass vs seeded staleness mutations
# ---------------------------------------------------------------------------
def test_content_key_role_drop_fails_cache_pass(tmp_path):
    """Dropping role arrays from DMLData.content_key re-creates the PR 5
    staleness bug — the pass must turn it into a lint failure."""
    tmp = _mutated_tree(tmp_path, "core/spec.py",
                        "for r in _ROLES if", "for r in _ROLES[:2] if")
    rules = {f.rule for f in cache_keys.run(tmp)}
    assert "content-key-covers-roles" in rules


def test_key_component_drop_fails_cache_pass(tmp_path):
    """Removing work_key from the block-tensor contract leaves its reads
    unjustified and the key unable to pin the cached tensors."""
    tmp = _mutated_tree(
        tmp_path, "compile/program.py",
        'key=("req.work_key", "seg_idx", "blk.members", "blk.b_pad",',
        'key=("seg_idx", "blk.members", "blk.b_pad",')
    found = [f for f in cache_keys.run(tmp) if "program" in f.where]
    rules = {f.rule for f in found}
    assert rules & {"cover-not-a-key", "uncovered-read",
                    "unkeyed-parameter"}


def test_undeclared_bounded_put_fails_cache_pass(tmp_path):
    """A new bounded cache insert without a @warm_cache contract."""
    tmp = _mutated_tree(
        tmp_path, "serverless/backends.py",
        "@warm_cache(name=\"fold_in_key_tables\",\n"
        "            key=(\"base_key\", \"n_tasks\", \"key_ref\"))\n", "")
    rules = {f.rule for f in cache_keys.run(tmp)}
    assert "unregistered-bounded-put" in rules
    assert "missing-cache" in rules


# ---------------------------------------------------------------------------
# red: protocol pass vs seeded scheduler mutations
# ---------------------------------------------------------------------------
def test_unexcluded_pending_view_fails_protocol_pass(tmp_path):
    tmp = _mutated_tree(
        tmp_path, "serverless/backends.py",
        "groups = state.plan.pending_by_bucket(\n"
        "            exclude=q.in_flight_entries())",
        "groups = state.plan.pending_by_bucket()")
    rules = {f.rule for f in protocol.run(tmp)}
    assert "pending-view-excludes-in-flight" in rules


def test_rogue_booking_site_fails_protocol_pass(tmp_path):
    tmp = _mutated_tree(
        tmp_path, "serverless/backends.py",
        "    def _checkpoint(self, state: DrainState):",
        "    def _checkpoint(self, state: DrainState):\n"
        "        state.requests[0].ledger.record_failure(0)")
    rules = {f.rule for f in protocol.run(tmp)}
    assert "booking-performer" in rules


def test_rogue_cancel_site_fails_protocol_pass(tmp_path):
    """A ``.cancel()`` outside HedgePair.settle could cancel BOTH legs
    of a race (bucket never booked) or cancel after booking (double
    accounting) — the single-cancel-performer rule must catch it."""
    tmp = _mutated_tree(
        tmp_path, "serverless/backends.py",
        "    def _checkpoint(self, state: DrainState):",
        "    def _checkpoint(self, state: DrainState):\n"
        "        state.queue.cancel(state.queue._pending[0])")
    rules = {f.rule for f in protocol.run(tmp)}
    assert "cancel-performer" in rules


def test_rogue_abandon_site_fails_protocol_pass(tmp_path):
    """An ``.abandon()`` outside TopologyBackend.kill_host silently
    drops in-flight work without the ledger/pending-view bookkeeping
    that re-dispatches it."""
    tmp = _mutated_tree(
        tmp_path, "serverless/backends.py",
        "    def _checkpoint(self, state: DrainState):",
        "    def _checkpoint(self, state: DrainState):\n"
        "        state.queue.abandon()")
    rules = {f.rule for f in protocol.run(tmp)}
    assert "abandon-performer" in rules


def test_identity_equality_regression_fails_protocol_pass(tmp_path):
    tmp = _mutated_tree(
        tmp_path, "serverless/dispatch.py",
        "@dataclass(eq=False)\nclass PendingBucket:",
        "@dataclass\nclass PendingBucket:")
    rules = {f.rule for f in protocol.run(tmp)}
    assert "identity-equality" in rules


# ---------------------------------------------------------------------------
# red: jaxpr audit vs a vmap-built fused program
# ---------------------------------------------------------------------------
def test_vmap_fused_program_fails_jaxpr_audit():
    from repro.analysis import jaxpr_audit as ja
    run, _ = ja._program_pair("ols")

    def run_vmapped(pages, data_idx, y, w, valid, key_data):
        return jax.vmap(lambda *t: run(pages, *t))(
            data_idx, y, w, valid, key_data)

    single = jax.make_jaxpr(run)(*ja._probe_avals(fused=False))
    bad = jax.make_jaxpr(run_vmapped)(*ja._probe_avals(fused=True))
    rules = {f.rule for f in ja.audit_fused_pair(single, bad, "ols/mut")}
    assert "fused-lowers-through-scan" in rules
    # and the real lax.map build passes the same check
    _, run_fused = ja._program_pair("ols")
    good = jax.make_jaxpr(run_fused)(*ja._probe_avals(fused=True))
    assert ja.audit_fused_pair(single, good, "ols/fused") == []


def test_vmap_sharded_fused_fails_jaxpr_audit():
    """The ISSUE 8 sharded-fused contract: shard_map(lax.map body) is
    bitwise because each device runs the per-block program unchanged —
    a vmap-built body inside the shard must still be rejected."""
    from repro.analysis import jaxpr_audit as ja
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.compat import shard_map_compat
    from repro.sharding.policy import megabatch_specs

    run, run_fused = ja._program_pair("ols")
    single = jax.make_jaxpr(run)(*ja._probe_avals(fused=False))
    in_specs, out_specs = megabatch_specs("data", fused=True)
    mesh = make_host_mesh()

    def run_vmapped(pages, data_idx, y, w, valid, key_data):
        return jax.vmap(lambda *t: run(pages, *t))(
            data_idx, y, w, valid, key_data)

    bad_fn = shard_map_compat(run_vmapped, mesh=mesh,
                              in_specs=in_specs, out_specs=out_specs)
    bad = jax.make_jaxpr(bad_fn)(*ja._probe_avals(fused=True))
    rules = {f.rule for f in ja.audit_sharded_fused(single, bad,
                                                    "ols/mut")}
    assert "sharded-fused-wraps-scan" in rules
    # and the real shard_map(lax.map) build passes the same check
    good_fn = shard_map_compat(run_fused, mesh=mesh,
                               in_specs=in_specs, out_specs=out_specs)
    good = jax.make_jaxpr(good_fn)(*ja._probe_avals(fused=True))
    assert ja.audit_sharded_fused(single, good, "ols/sf") == []
    # a bare (unsharded) fused program must also be rejected: the
    # sharded-fused cache's contract is shard_map at the top
    bare = jax.make_jaxpr(run_fused)(*ja._probe_avals(fused=True))
    assert {f.rule for f in ja.audit_sharded_fused(single, bare,
                                                   "ols/bare")} \
        == {"sharded-fused-wraps-scan"}


def test_data_derived_prng_fails_taint_analysis():
    from repro.analysis import jaxpr_audit as ja
    run, _ = ja._program_pair("ols")

    def run_leaky(pages, data_idx, y, w, valid, key_data):
        # derive PRNG state from a runtime data value: schedule-variant
        leaked = jax.random.fold_in(
            jax.random.key(0), data_idx[0].astype(np.uint32))
        _ = jax.random.uniform(leaked)
        return run(pages, data_idx, y, w, valid, key_data)

    bad = jax.make_jaxpr(run_leaky)(*ja._probe_avals(fused=False))
    findings = []
    ja._taint_jaxpr(bad.jaxpr, ja._data_key_marks(bad.jaxpr),
                    "ols/leak", findings)
    assert any(f.rule == "prng-key-from-runtime-data" for f in findings)


def test_mutated_axis_programs_fail_jaxpr_audit():
    """The ISSUE 9 in-mesh drain-form pins: a data-axis body whose psum
    was dropped (each shard would solve on its local rows only) and a
    feature-axis body whose row all-gather was dropped (cross-column
    Gram blocks from the wrong operand) must be rejected; the real
    lowered forms pass."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.analysis import jaxpr_audit as ja
    from repro.kernels import ops
    from repro.launch.mesh import make_host_mesh
    from repro.learners.linear import _augment_b
    from repro.sharding.compat import shard_map_compat
    from repro.sharding.gram import (
        _data_fit_body, _feature_fit_body, gram_solve,
    )

    mesh = make_host_mesh()
    avals = ja._probe_avals(fused=False)
    params = (("intercept", True), ("reg", 1.0))
    data_specs = dict(
        in_specs=(P(None, "data", None), P(None), P(None, "data"),
                  P(None, "data"), P(None, "data"), P(None, None)),
        out_specs=P(None, "data"))
    feat_specs = dict(
        in_specs=(P(None, None, "data"), P(None), P(None, None),
                  P(None, None), P(None, None), P(None, None)),
        out_specs=P(None, None))

    # the real lowered forms pass their pins
    good_d = jax.make_jaxpr(shard_map_compat(
        _data_fit_body("data", "ridge", params), mesh=mesh,
        **data_specs))(*avals)
    assert ja.audit_data_axis(good_d, "ridge/data") == []
    good_f = jax.make_jaxpr(shard_map_compat(
        _feature_fit_body("data", "ridge", params), mesh=mesh,
        **feat_specs))(*avals)
    assert ja.audit_feature_axis(good_f, "ridge/feature") == []

    # mutation: shard-local statistics, no psum reassembly
    def local_fit(pages, data_idx, y, w, valid, key_data):
        xa = _augment_b(pages[data_idx].astype(jnp.float32))
        g, b = ops.batched_gram(xa, w, y, 1.0)
        return ops.batched_predict(xa, gram_solve(g, b), valid)

    bad_d = jax.make_jaxpr(shard_map_compat(
        local_fit, mesh=mesh, **data_specs))(*avals)
    assert {f.rule for f in ja.audit_data_axis(bad_d, "ridge/mut")} \
        == {"data-axis-psums-moments"}

    # mutation: column-local Gram, no row all-gather
    bad_f = jax.make_jaxpr(shard_map_compat(
        local_fit, mesh=mesh, **feat_specs))(*avals)
    assert {f.rule for f in ja.audit_feature_axis(bad_f, "ridge/mut")} \
        == {"feature-axis-gathers-rows"}

    # mutation: the shard_map wrapper itself dropped
    bare = jax.make_jaxpr(local_fit)(*avals)
    assert {f.rule for f in ja.audit_data_axis(bare, "ridge/bare")} \
        == {"data-axis-wraps-shard-map"}
    assert {f.rule for f in ja.audit_feature_axis(bare, "ridge/bare")} \
        == {"feature-axis-wraps-shard-map"}


# ---------------------------------------------------------------------------
# runtime sanitizer (REPRO_SANITIZE=1)
# ---------------------------------------------------------------------------
def _dispatched_bucket():
    from repro.compile import plan_buckets
    from repro.compile.program import ProgramCache, dispatch_bucket
    from repro.core import DMLData, DMLPlan
    from repro.core.session import compile_request
    from repro.data import make_plr_data

    data = DMLData.from_dict(
        make_plr_data(n_obs=40, dim_x=3, theta=0.5, seed=0))
    plan = DMLPlan.for_model("plr", learner="ridge",
                             learner_params={"reg": 1.0},
                             n_folds=2, n_rep=1, seed=7)
    req = compile_request(plan, data)
    mp = plan_buckets([req])
    key, entries = next(iter(mp.pending_by_bucket().items()))
    return req, dispatch_bucket(mp, ProgramCache(), key, entries)


def test_sanitizer_trips_on_double_harvest(monkeypatch):
    from repro.serverless.sanitize import ProtocolError
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    _, bd = _dispatched_bucket()
    bd.harvest()
    with pytest.raises(ProtocolError, match="harvested twice"):
        bd.harvest()


def test_sanitizer_off_allows_double_harvest(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    _, bd = _dispatched_bucket()
    first = bd.harvest()
    again = bd.harvest()
    assert set(first) == set(again)


def test_sanitizer_trips_on_booking_done_rows(monkeypatch):
    from repro.serverless.sanitize import ProtocolError, check_booking
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    req, bd = _dispatched_bucket()
    results = bd.harvest()
    invs = sorted({inv for _, inv in bd.entries})
    req.ledger.record_successes(
        invs, np.stack([results[(0, inv)] for inv in invs]))
    with pytest.raises(ProtocolError, match="record_successes"):
        check_booking(req.ledger, invs, "record_successes")


def test_sanitizer_trips_on_lost_bucket(monkeypatch):
    from repro.serverless.dispatch import DispatchQueue, PendingBucket
    from repro.serverless.sanitize import ProtocolError, check_drained
    monkeypatch.setenv("REPRO_SANITIZE", "1")

    class _State:
        queue = DispatchQueue()
        queues = {}

    _, bd = _dispatched_bucket()
    _State.queue._pending.append(PendingBucket(dispatch=bd))
    with pytest.raises(ProtocolError, match="in\\s?flight"):
        check_drained(_State, "test retire")
    _State.queue._pending.clear()
    check_drained(_State, "test retire")     # empty queue passes


def test_sanitizer_trips_on_double_hedge(monkeypatch):
    """Hedging an already-HEDGED bucket would launch a third leg the
    settle logic doesn't know about."""
    from repro.serverless.dispatch import PendingBucket
    from repro.serverless.sanitize import ProtocolError, check_hedge
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    _, bd = _dispatched_bucket()
    pb = PendingBucket(dispatch=bd)
    check_hedge(pb)                          # DISPATCHED: legal
    pb.state = "HEDGED"
    with pytest.raises(ProtocolError, match="hedge .* HEDGED"):
        check_hedge(pb)


def test_sanitizer_trips_on_booking_cancelled_bucket(monkeypatch):
    """Booking a CANCELLED bucket means a losing hedge leg's results
    are entering the ledger alongside the winner's — double-booking.
    Cancelling it again means two settle sites fired."""
    from repro.serverless.dispatch import PendingBucket
    from repro.serverless.sanitize import (
        ProtocolError, check_bucket_bookable, check_cancel,
    )
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    _, bd = _dispatched_bucket()
    pb = PendingBucket(dispatch=bd)
    check_bucket_bookable(pb)                # DISPATCHED: legal
    check_cancel(pb)
    pb.state = "CANCELLED"
    with pytest.raises(ProtocolError, match="harvest .* CANCELLED"):
        check_bucket_bookable(pb)
    with pytest.raises(ProtocolError, match="cancel .* CANCELLED"):
        check_cancel(pb)


def test_transition_table_matches_ledger():
    """The table the sanitizer and static checker share names real
    TaskLedger methods and the module's state constants."""
    from repro.serverless import ledger as L
    for name in protocol.LEDGER_TRANSITIONS:
        assert callable(getattr(L.TaskLedger, name))
    for sname, code in protocol.INVOCATION_STATES.items():
        assert getattr(L, sname) == code
    # the bucket lifecycle table only names declared states, and every
    # non-initial state is reachable
    reached = set()
    for action, (srcs, dst) in protocol.BUCKET_TRANSITIONS.items():
        assert set(srcs) <= set(protocol.BUCKET_STATES), action
        assert dst in protocol.BUCKET_STATES, action
        reached.add(dst)
    assert reached == set(protocol.BUCKET_STATES) - {"PLANNED"}
