"""vmapped-MLP nuisance learner: every task trains its own small MLP with
Adam for a fixed number of full-batch steps; all T tasks train
simultaneously as one batched computation (the serverless concurrency of
the paper collapsed into a vmap axis)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


def _init_mlp(key, p: int, hidden: Tuple[int, ...]):
    dims = (p,) + hidden + (1,)
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        params.append({
            "w": jax.random.normal(k, (a, b), F32) * np.sqrt(2.0 / a),
            "b": jnp.zeros((b,), F32),
        })
    return params


def _fwd(params, x):
    h = x
    for i, layer in enumerate(params):
        h = h @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.gelu(h)
    return h[..., 0]


def _train_one_fn(xs, hidden, lr, n_steps, classify):
    """Build the single-task trainer closed over standardized features."""
    def train_one(yt, wt, k):
        params = _init_mlp(k, xs.shape[1], tuple(hidden))
        m0 = jax.tree.map(jnp.zeros_like, params)
        v0 = jax.tree.map(jnp.zeros_like, params)

        def loss_fn(params):
            pred = _fwd(params, xs)
            if classify:
                ll = wt * (jax.nn.softplus(pred) - yt * pred)
                return jnp.sum(ll) / jnp.maximum(jnp.sum(wt), 1.0)
            return jnp.sum(wt * (pred - yt) ** 2) / jnp.maximum(jnp.sum(wt), 1.0)

        def step(carry, i):
            params, m, v = carry
            g = jax.grad(loss_fn)(params)
            m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
            bc1 = 1 - 0.9 ** (i + 1.0)
            bc2 = 1 - 0.999 ** (i + 1.0)
            params = jax.tree.map(
                lambda p, mm, vv: p - lr * (mm / bc1)
                / (jnp.sqrt(vv / bc2) + 1e-8),
                params, m, v)
            return (params, m, v), None

        (params, _, _), _ = jax.lax.scan(step, (params, m0, v0),
                                         jnp.arange(n_steps))
        pred = _fwd(params, xs)
        return jax.nn.sigmoid(pred) if classify else pred

    return train_one


def mlp_fit_predict(x, y, w, key, *, hidden=(64, 64), lr: float = 3e-3,
                    n_steps: int = 300, classify: bool = False):
    """x (N,P); y/w (T,N) -> preds (T,N)."""
    x = x.astype(F32)
    mu = jnp.mean(x, 0)
    sd = jnp.std(x, 0) + 1e-8
    xs = (x - mu) / sd
    t = y.shape[0]
    keys = jax.random.split(key, t)
    train_one = _train_one_fn(xs, hidden, lr, n_steps, classify)
    return jax.vmap(train_one)(y.astype(F32), w.astype(F32), keys)


def mlp_batched_fit_predict(xs, y, w, valid, keys, *, hidden=(64, 64),
                            lr: float = 3e-3, n_steps: int = 300,
                            classify: bool = False):
    """Megabatch form: every task trains on its own (padded) feature page.

    Standardization uses masked moments over the valid rows only, so
    padding rows (zero features, zero weight) never shift mu/sd and the
    padded fit matches the unpadded one; per-task keys come from the
    compiler (fold_in of the request seed by flat task id), making results
    independent of bucket composition and wave schedule.
    """
    def one(x1, yt, wt, v1, k):
        x1 = x1.astype(F32)
        nv = jnp.maximum(jnp.sum(v1), 1.0)
        mu = jnp.sum(x1 * v1[:, None], 0) / nv
        var = jnp.sum(v1[:, None] * (x1 - mu) ** 2, 0) / nv
        sd = jnp.sqrt(var) + 1e-8
        x1 = (x1 - mu) / sd * v1[:, None]      # padding rows stay exactly 0
        train_one = _train_one_fn(x1, hidden, lr, n_steps, classify)
        return train_one(yt, wt, k) * v1

    return jax.vmap(one)(xs, y.astype(F32), w.astype(F32),
                         valid.astype(F32), keys)
