"""Learner registry.

A learner is a batched pure function
    fn(x (N,P), y (T,N), w (T,N), key) -> preds (T,N)
operating on the fold-mask task batch (paper: one scikit-learn fit per
lambda; here: the whole task batch in fused/vmapped form).

``get_learner(name, params)`` binds hyperparameters.  Classification-capable
learners accept ``classify=True`` via params (used for IRM/IIVM propensity
nuisances).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Mapping

import jax

from repro.learners.kernel_ridge import kernel_ridge_fit_predict
from repro.learners.linear import (
    lasso_fit_predict, logistic_fit_predict, ols_fit_predict,
    ridge_fit_predict,
)
from repro.learners.mlp import mlp_fit_predict

LearnerFn = Callable


LEARNERS: Dict[str, Callable] = {
    "ols": ols_fit_predict,
    "ridge": ridge_fit_predict,
    "lasso": lasso_fit_predict,
    "logistic": logistic_fit_predict,
    "kernel_ridge": kernel_ridge_fit_predict,
    "mlp": mlp_fit_predict,
}


def get_learner(name: str, params: Mapping | None = None) -> LearnerFn:
    if name not in LEARNERS:
        raise KeyError(f"unknown learner {name!r}; known: {list(LEARNERS)}")
    params = dict(params or {})
    fn = LEARNERS[name]
    if name in ("ols", "ridge", "lasso") and params.pop("classify", False):
        # linear probability model for propensities: fit as regression,
        # clip in the score (scores.py clips) — the DoubleML-compatible path.
        pass
    if params:
        fn = functools.partial(fn, **params)
    return fn
