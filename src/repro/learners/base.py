"""Learner registry.

Every family registers two pure functions:

  shared-X form   fn(x (N,P), y (T,N), w (T,N), key) -> preds (T,N)
                  the fold-mask task batch over one dataset (paper: one
                  scikit-learn fit per lambda; here: fused/vmapped).
  megabatch form  fn(xs (B,N,P), y (B,N), w (B,N), valid (B,N), keys (B,))
                  -> preds (B,N) — per-task feature pages with padding
                  masks, executed by the bucketed programs the compiler
                  (repro/compile) builds.  ``keys`` is a (B,) typed key
                  array (one PRNG stream per task).

``get_learner`` / ``get_batched_learner`` bind hyperparameters.
``resolve_params`` binds data-dependent defaults (e.g. kernel_ridge's
gamma) at *compile* time so padded execution is padding-invariant.
Classification-capable learners accept ``classify=True`` via params (used
for IRM/IIVM propensity nuisances).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Mapping

import jax

from repro.learners.kernel_ridge import (
    kernel_ridge_batched_fit_predict, kernel_ridge_fit_predict,
)
from repro.learners.linear import (
    lasso_batched_fit_predict, lasso_fit_predict,
    logistic_batched_fit_predict, logistic_fit_predict,
    ols_batched_fit_predict, ols_fit_predict,
    ridge_batched_fit_predict, ridge_fit_predict,
)
from repro.learners.mlp import mlp_batched_fit_predict, mlp_fit_predict

LearnerFn = Callable


LEARNERS: Dict[str, Callable] = {
    "ols": ols_fit_predict,
    "ridge": ridge_fit_predict,
    "lasso": lasso_fit_predict,
    "logistic": logistic_fit_predict,
    "kernel_ridge": kernel_ridge_fit_predict,
    "mlp": mlp_fit_predict,
}

BATCHED_LEARNERS: Dict[str, Callable] = {
    "ols": ols_batched_fit_predict,
    "ridge": ridge_batched_fit_predict,
    "lasso": lasso_batched_fit_predict,
    "logistic": logistic_batched_fit_predict,
    "kernel_ridge": kernel_ridge_batched_fit_predict,
    "mlp": mlp_batched_fit_predict,
}

# Families whose megabatch form is invariant to zero-padded feature lanes
# (linear algebra sees inert columns; kernel_ridge's rbf distances ignore
# zero columns once gamma is resolved).  mlp is excluded: its init scale
# is sqrt(2/P), so the bucket planner keeps mlp buckets at the exact P.
FEATURE_PAD_SAFE = frozenset(
    {"ols", "ridge", "lasso", "logistic", "kernel_ridge"})


def resolve_params(name: str, params: Mapping | None, *, n_obs: int,
                   dim_x: int) -> Dict:
    """Bind data-dependent hyperparameter defaults at compile time.

    The megabatch programs run on padded shapes, so any default derived
    from the *data* shape (kernel_ridge's gamma = 1/P, landmark count
    capped by N) must be pinned to the true shape before bucketing —
    otherwise padding would leak into the estimate.
    """
    params = dict(params or {})
    if name == "kernel_ridge":
        if params.get("gamma") is None:
            params["gamma"] = 1.0 / dim_x
        params["n_landmarks"] = min(params.get("n_landmarks", 128), n_obs)
    return params


def _bind(table: Dict[str, Callable], name: str,
          params: Mapping | None) -> LearnerFn:
    if name not in table:
        raise KeyError(f"unknown learner {name!r}; known: {list(table)}")
    params = dict(params or {})
    fn = table[name]
    if name in ("ols", "ridge", "lasso") and params.pop("classify", False):
        # linear probability model for propensities: fit as regression,
        # clip in the score (scores.py clips) — the DoubleML-compatible path.
        pass
    if params:
        fn = functools.partial(fn, **params)
    return fn


def get_learner(name: str, params: Mapping | None = None) -> LearnerFn:
    return _bind(LEARNERS, name, params)


def get_batched_learner(name: str, params: Mapping | None = None) -> LearnerFn:
    """Resolve the megabatch form: fn(xs, y, w, valid, keys) -> preds."""
    return _bind(BATCHED_LEARNERS, name, params)


def as_batched(fn: Callable) -> Callable:
    """Adapt an opaque shared-X learner callable to the megabatch
    signature (one vmap lane per task, per-task key streams) — the
    fallback for user-supplied learner functions that never registered a
    batched form (legacy ``ServerlessExecutor`` path)."""
    def batched(xs, y, w, valid, keys):
        return jax.vmap(
            lambda x1, y1, w1, k1: fn(x1, y1[None], w1[None], k1)[0]
        )(xs, y, w, keys)
    return batched
