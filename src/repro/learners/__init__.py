from repro.learners.base import (
    BATCHED_LEARNERS, FEATURE_PAD_SAFE, LEARNERS, LearnerFn, as_batched,
    get_batched_learner, get_learner, resolve_params,
)

__all__ = [
    "LearnerFn", "get_learner", "get_batched_learner", "as_batched",
    "resolve_params", "LEARNERS", "BATCHED_LEARNERS", "FEATURE_PAD_SAFE",
]
