from repro.learners.base import LearnerFn, get_learner, LEARNERS

__all__ = ["LearnerFn", "get_learner", "LEARNERS"]
