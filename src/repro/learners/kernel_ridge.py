"""Nyström kernel ridge — the TPU-friendly stand-in for the paper's random
forest (DESIGN.md §2 "Changed assumptions"): nonparametric capacity with
MXU-shaped math.  RBF features via m landmarks, then the fused ridge path.

Landmark selection is a Gumbel top-k over the valid rows, with one scalar
Gumbel drawn per row from fold_in(key, row): the draw depends only on
(key, row index) — never on the array length — so the megabatch form is
*padding-invariant*: appending masked padding rows cannot change which
landmarks are chosen.  (A single shaped gumbel(key, (n,)) draw would not
give this: jax's bit generation depends on the full requested shape.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.learners.linear import ridge_batched_fit_predict, ridge_fit_predict

F32 = jnp.float32


def _rbf(a, b, gamma: float):
    d2 = (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :]
          - 2.0 * a @ b.T)
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def _landmark_idx(key, n: int, m: int, valid=None):
    """m row indices drawn uniformly without replacement (Gumbel top-k),
    restricted to valid rows when a mask is given.  Per-row fold_in
    streams keep the draw independent of n (padding-invariant)."""
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))
    gz = jax.vmap(lambda k: jax.random.gumbel(k, ()))(keys)
    if valid is not None:
        gz = jnp.where(valid > 0, gz, -jnp.inf)
    _, idx = jax.lax.top_k(gz, m)
    return idx


def nystrom_features(x, key, *, n_landmarks: int = 128,
                     gamma: float | None = None, valid=None):
    """phi(x) (N, m) with K ~= phi phi^T.

    ``valid`` (N,) restricts landmark candidates to real rows (megabatch
    padding); callers must keep n_landmarks <= #valid rows.
    """
    x = x.astype(F32)
    n, p = x.shape
    m = min(n_landmarks, n)
    lm = x[_landmark_idx(key, n, m, valid)]
    if gamma is None:
        gamma = 1.0 / p            # sklearn's "scale"-ish default
    kmm = _rbf(lm, lm, gamma) + 1e-6 * jnp.eye(m, dtype=F32)
    knm = _rbf(x, lm, gamma)
    # K ≈ Knm Kmm^{-1} Kmn  =>  phi = Knm Kmm^{-1/2}
    evals, evecs = jnp.linalg.eigh(kmm)
    inv_sqrt = evecs @ jnp.diag(1.0 / jnp.sqrt(jnp.maximum(evals, 1e-8))) \
        @ evecs.T
    return knm @ inv_sqrt


def kernel_ridge_fit_predict(x, y, w, key, *, reg: float = 1.0,
                             n_landmarks: int = 128,
                             gamma: float | None = None):
    phi = nystrom_features(x, key, n_landmarks=n_landmarks, gamma=gamma)
    return ridge_fit_predict(phi, y, w, reg=reg, intercept=True)


def kernel_ridge_batched_fit_predict(xs, y, w, valid, keys, *,
                                     reg: float = 1.0,
                                     n_landmarks: int = 128,
                                     gamma: float | None = None):
    """Megabatch Nyström ridge: per-task landmarks (per-task keys), then
    the fused batched ridge on the feature pages."""
    def feat(x1, v1, k1):
        return nystrom_features(x1, k1, n_landmarks=n_landmarks,
                                gamma=gamma, valid=v1)

    phi = jax.vmap(feat)(xs, valid, keys)
    return ridge_batched_fit_predict(phi, y, w, valid, reg=reg,
                                     intercept=True)
