"""Nyström kernel ridge — the TPU-friendly stand-in for the paper's random
forest (DESIGN.md §2 "Changed assumptions"): nonparametric capacity with
MXU-shaped math.  RBF features via m landmarks, then the fused ridge path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.learners.linear import ridge_fit_predict

F32 = jnp.float32


def _rbf(a, b, gamma: float):
    d2 = (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :]
          - 2.0 * a @ b.T)
    return jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def nystrom_features(x, key, *, n_landmarks: int = 128,
                     gamma: float | None = None):
    """phi(x) (N, m) with K ~= phi phi^T."""
    x = x.astype(F32)
    n, p = x.shape
    m = min(n_landmarks, n)
    idx = jax.random.choice(key, n, (m,), replace=False)
    lm = x[idx]
    if gamma is None:
        gamma = 1.0 / p            # sklearn's "scale"-ish default
    kmm = _rbf(lm, lm, gamma) + 1e-6 * jnp.eye(m, dtype=F32)
    knm = _rbf(x, lm, gamma)
    # K ≈ Knm Kmm^{-1} Kmn  =>  phi = Knm Kmm^{-1/2}
    evals, evecs = jnp.linalg.eigh(kmm)
    inv_sqrt = evecs @ jnp.diag(1.0 / jnp.sqrt(jnp.maximum(evals, 1e-8))) \
        @ evecs.T
    return knm @ inv_sqrt


def kernel_ridge_fit_predict(x, y, w, key, *, reg: float = 1.0,
                             n_landmarks: int = 128,
                             gamma: float | None = None):
    phi = nystrom_features(x, key, n_landmarks=n_landmarks, gamma=gamma)
    return ridge_fit_predict(phi, y, w, reg=reg, intercept=True)
