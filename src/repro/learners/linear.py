"""Linear nuisance learners on batched masked fits.

Two entry points per family (learners/base.py registers both):

  shared-X form   fn(x (N,P), y (T,N), w (T,N), key) -> preds (T,N)
                  all T tasks share one dataset; w holds per-task training
                  weights (0 on the held-out fold).
  megabatch form  fn(xs (B,N,P), y (B,N), w (B,N), valid (B,N), keys (B,))
                  -> preds (B,N) — every task carries its own (padded)
                  feature page, so one compiled program serves tasks from
                  many requests/datasets at once (repro/compile buckets).
                  ``valid`` marks real observation rows (0 = N-padding);
                  training weights are already 0 on padded rows, and
                  predictions on padded rows are returned as exactly 0.

Fits are fused across tasks (crossfit_gram / batched_gram kernels), the
paper's M*K*L task grid collapsing into MXU batch dimensions.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

F32 = jnp.float32


def _augment(x):
    """Add intercept column."""
    return jnp.concatenate([x, jnp.ones((x.shape[0], 1), x.dtype)], axis=1)


def ridge_fit_predict(x, y, w, key=None, *, reg: float = 1.0,
                      intercept: bool = True):
    """Closed-form (weighted) ridge for all T tasks in one fused pass."""
    xa = _augment(x) if intercept else x
    g, b = ops.crossfit_gram(xa, w, y, reg=float(reg))
    # keep the intercept unpenalized
    if intercept and reg:
        p = xa.shape[1]
        g = g.at[:, p - 1, p - 1].add(-float(reg))
        g = g.at[:, p - 1, p - 1].add(1e-8)
    chol = jax.vmap(jnp.linalg.cholesky)(g)
    beta = jax.vmap(lambda c, bb: jax.scipy.linalg.cho_solve((c, True), bb))(
        chol, b)
    return jnp.einsum("np,tp->tn", xa, beta)


def ols_fit_predict(x, y, w, key=None, *, intercept: bool = True):
    return ridge_fit_predict(x, y, w, key, reg=1e-8, intercept=intercept)


# ---------------------------------------------------------------------------
# megabatch (per-task feature page) forms
# ---------------------------------------------------------------------------
def _augment_b(xs):
    """Add intercept column to every task page: (B,N,P) -> (B,N,P+1)."""
    ones = jnp.ones(xs.shape[:2] + (1,), xs.dtype)
    return jnp.concatenate([xs, ones], axis=-1)


def _solve_spd(g, b):
    """Batched SPD solve via Cholesky: g (B,P,P), b (B,P) -> (B,P)."""
    chol = jax.vmap(jnp.linalg.cholesky)(g)
    return jax.vmap(lambda c, bb: jax.scipy.linalg.cho_solve((c, True), bb))(
        chol, b)


def ridge_batched_fit_predict(xs, y, w, valid, keys=None, *, reg: float = 1.0,
                              intercept: bool = True):
    """Closed-form weighted ridge over a megabatch bucket.

    Padded feature lanes (zero columns) get beta == 0 under the ridge
    penalty and padded rows carry w == 0, so padding never leaks into the
    fit — the bucketed result equals the unpadded fit to float precision.
    """
    xa = _augment_b(xs) if intercept else xs
    g, b = ops.batched_gram(xa, w, y, reg=float(reg))
    if intercept and reg:
        p = xa.shape[-1]
        g = g.at[:, p - 1, p - 1].add(-float(reg) + 1e-8)
    beta = _solve_spd(g, b)
    return ops.batched_predict(xa, beta, valid)


def ols_batched_fit_predict(xs, y, w, valid, keys=None, *,
                            intercept: bool = True):
    return ridge_batched_fit_predict(xs, y, w, valid, keys, reg=1e-8,
                                     intercept=intercept)


def _fista_beta(g, b, w, *, reg: float, intercept: bool, n_iter: int):
    """FISTA on per-task normal equations (shared by both lasso forms).

    g (T,P,P), b (T,P) unnormalized moments; w (T,N) training weights
    (used only for the per-observation normalization).  Fixed iteration
    count so the whole solve stays vmappable/jittable.
    """
    nw = jnp.maximum(jnp.sum(w, axis=1), 1.0)                 # (T,)
    return _fista_beta_moments(g, b, nw, reg=reg, intercept=intercept,
                               n_iter=n_iter)


def _fista_beta_moments(g, b, nw, *, reg: float, intercept: bool,
                        n_iter: int):
    """The moments form of the FISTA solve: identical math to
    ``_fista_beta`` but with the weight normalizer ``nw`` (T,)
    precomputed by the caller — the in-mesh data-parallel executor
    (sharding/gram.py) psums per-shard weight sums into ``nw`` because
    no single device holds the full w row to reduce locally.
    """
    p = g.shape[-1]
    g = g / nw[:, None, None]
    b = b / nw[:, None]
    # Lipschitz constant via a few power iterations on each G_t.
    def lmax(gt):
        v = jnp.ones((p,), F32) / np.sqrt(p)
        def it(v, _):
            v = gt @ v
            return v / jnp.maximum(jnp.linalg.norm(v), 1e-12), None
        v, _ = jax.lax.scan(it, v, None, length=16)
        return v @ gt @ v
    step = 1.0 / jnp.maximum(jax.vmap(lmax)(g), 1e-6)         # (T,)
    lam = reg
    pen = jnp.ones((p,), F32)
    if intercept:
        pen = pen.at[p - 1].set(0.0)                          # no l1 on bias

    def soft(z, t):
        return jnp.sign(z) * jnp.maximum(jnp.abs(z) - t, 0.0)

    def body(carry, _):
        beta, zeta, tk = carry
        grad = jnp.einsum("tpq,tq->tp", g, zeta) - b
        beta_new = soft(zeta - step[:, None] * grad,
                        (lam * step)[:, None] * pen[None])
        tk1 = (1.0 + jnp.sqrt(1.0 + 4.0 * tk * tk)) / 2.0
        zeta = beta_new + ((tk - 1.0) / tk1) * (beta_new - beta)
        return (beta_new, zeta, tk1), None

    beta0 = jnp.zeros((g.shape[0], p), F32)
    (beta, _, _), _ = jax.lax.scan(body, (beta0, beta0, jnp.ones((), F32)),
                                   None, length=n_iter)
    return beta


def lasso_fit_predict(x, y, w, key=None, *, reg: float = 0.01,
                      n_iter: int = 200, intercept: bool = True):
    """FISTA on the weighted lasso; fixed iteration count (vmappable).

    reg is the l1 penalty on standardized features, per-observation scale.
    """
    xa = _augment(x) if intercept else x
    g, b = ops.crossfit_gram(xa, w, y)                        # (T,P,P),(T,P)
    beta = _fista_beta(g, b, w, reg=reg, intercept=intercept, n_iter=n_iter)
    return jnp.einsum("np,tp->tn", xa, beta)


def lasso_batched_fit_predict(xs, y, w, valid, keys=None, *,
                              reg: float = 0.01, n_iter: int = 200,
                              intercept: bool = True):
    """Megabatch lasso: identical FISTA solve on per-task feature pages.

    Padded feature lanes see zero gradient and the l1 penalty keeps their
    beta at exactly 0; padded rows carry w == 0 and drop out of the
    moments, so bucketing is invisible to the estimate.
    """
    xa = _augment_b(xs) if intercept else xs
    g, b = ops.batched_gram(xa, w, y)
    beta = _fista_beta(g, b, w, reg=reg, intercept=intercept, n_iter=n_iter)
    return ops.batched_predict(xa, beta, valid)


def logistic_fit_predict(x, y, w, key=None, *, reg: float = 1.0,
                         n_iter: int = 32, intercept: bool = True):
    """Weighted l2-regularized logistic regression via Newton steps
    (vmapped IRLS with fixed iterations).  Returns probabilities."""
    xa = _augment(x) if intercept else x
    n, p = xa.shape
    t = w.shape[0]
    xf = xa.astype(F32)

    def one(yt, wt):
        beta = jnp.zeros((p,), F32)
        eye = jnp.eye(p, dtype=F32) * reg

        def newton(beta, _):
            eta = xf @ beta
            mu = jax.nn.sigmoid(eta)
            s = wt * mu * (1.0 - mu) + 1e-6
            grad = xf.T @ (wt * (mu - yt)) + reg * beta
            hess = jnp.einsum("np,n,nq->pq", xf, s, xf) + eye
            delta = jax.scipy.linalg.solve(hess, grad, assume_a="pos")
            return beta - delta, None

        beta, _ = jax.lax.scan(newton, beta, None, length=n_iter)
        return jax.nn.sigmoid(xf @ beta)

    return jax.vmap(one)(y.astype(F32), w.astype(F32))


def logistic_batched_fit_predict(xs, y, w, valid, keys=None, *,
                                 reg: float = 1.0, n_iter: int = 32,
                                 intercept: bool = True):
    """Megabatch IRLS logistic: per-task Newton solves on per-task pages.

    The s-smoothing term (1e-6) adds a vanishing curvature on padded rows
    and the l2 penalty keeps padded-lane betas near 0; predictions on
    padded rows are masked to exactly 0 on return.
    """
    xa = _augment_b(xs) if intercept else xs
    p = xa.shape[-1]

    def one(xf, yt, wt):
        xf = xf.astype(F32)
        beta = jnp.zeros((p,), F32)
        eye = jnp.eye(p, dtype=F32) * reg

        def newton(beta, _):
            eta = xf @ beta
            mu = jax.nn.sigmoid(eta)
            s = wt * mu * (1.0 - mu) + 1e-6
            grad = xf.T @ (wt * (mu - yt)) + reg * beta
            hess = jnp.einsum("np,n,nq->pq", xf, s, xf) + eye
            delta = jax.scipy.linalg.solve(hess, grad, assume_a="pos")
            return beta - delta, None

        beta, _ = jax.lax.scan(newton, beta, None, length=n_iter)
        return jax.nn.sigmoid(xf @ beta)

    probs = jax.vmap(one)(xa, y.astype(F32), w.astype(F32))
    return probs * valid.astype(F32)
