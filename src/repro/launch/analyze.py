import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

DOC = """Collective-schedule analyzer for §Perf hillclimbing.

Lowers ONE cell at probe depth with inner loops unrolled and prints every
collective grouped by (op, tensor type), with per-device wire bytes — the
"profile" a dry-run can give (spec: Pallas-specific hints).

  PYTHONPATH=src python -m repro.launch.analyze --arch qwen2.5-32b \
      --shape train_4k [--variant no_seqpar]
"""
import argparse
import re
from collections import Counter, defaultdict

import numpy as np

from repro import runtime
from repro.configs import SHAPE_BY_NAME, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import _GROUPS_RE, _shape_bytes, probe_plan

_LINE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="default")
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell
    cfg = get_arch(args.arch)
    shape = SHAPE_BY_NAME[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    runtime.mesh_axes = tuple(mesh.shape.keys())
    rules = None
    attn_chunk, remat = args.attn_chunk, args.remat
    if args.variant != "default":
        from repro.sharding.policy import apply_variant
        rules, v = apply_variant(args.arch, shape.kind, cfg.d_model,
                                 args.variant)
        attn_chunk = v.attn_chunk or attn_chunk
        remat = v.remat or remat

    plan = probe_plan(cfg)
    pcfg, trips = plan.probes[-1]          # deepest probe (2 layer trips)
    with runtime.flags(unroll_inner=True):
        compiled, ls, cs = lower_cell(pcfg, shape, mesh,
                                      attn_chunk=attn_chunk, remat=remat,
                                      rules=rules, donate=False)
    print(f"# {args.arch} {args.shape} {args.mesh} variant={args.variant} "
          f"(probe depth {pcfg.n_layers}, lower {ls:.0f}s compile {cs:.0f}s)")
    groups = defaultdict(lambda: [0, 0.0])
    for line in compiled.as_text().splitlines():
        m = _LINE.search(line)
        if not m or m.group(2) + "-done" in line:
            continue
        typ, op = m.group(1), m.group(2)
        g = _GROUPS_RE.search(line)
        n = int(g.group(2)) if g else 2
        if n <= 1:
            continue
        ring = (n - 1) / n
        factor = {"all-reduce": 2 * ring, "all-gather": ring,
                  "reduce-scatter": ring, "all-to-all": ring,
                  "collective-permute": 1.0}[op]
        key = (op, typ if len(typ) < 70 else typ[:67] + "...", n)
        groups[key][0] += 1
        groups[key][1] += _shape_bytes(typ) * factor
    rows = sorted(groups.items(), key=lambda kv: -kv[1][1])[: args.top]
    total = sum(v[1] for v in groups.values())
    print(f"total wire bytes/device (probe): {total/2**30:.2f} GiB")
    for (op, typ, n), (cnt, byt) in rows:
        print(f"  {byt/2**30:8.3f} GiB  x{cnt:<3d} n={n:<3d} {op:<18s} {typ}")
    ca = compiled.cost_analysis()
    print(f"flops/dev {ca.get('flops', 0):.3e}  "
          f"bytes/dev {ca.get('bytes accessed', 0):.3e}")


if __name__ == "__main__":
    main()
