"""Roofline accounting from compiled dry-run artifacts (spec: ROOFLINE
ANALYSIS).

Hardware target: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

  compute_term_s    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory_term_s     = HLO_bytes_per_device / HBM_BW
  collective_term_s = collective_bytes_per_device / ICI_BW

``cost_analysis()`` counts a while-loop (lax.scan) body ONCE (verified
empirically), so per-cell costs are measured on small probe configs with
every *inner* loop unrolled (runtime.unroll_inner) and the *layer* scans
extrapolated linearly: cost(probe) = c0 + sum_i trips_i(probe) * c_i,
solved from len(dims)+1 probes, then evaluated at the full config.
Collective bytes come from the HLO text with ring-model wire factors.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective op (ring model).

    all-reduce = 2(n-1)/n x bytes; all-gather / reduce-scatter / all-to-all
    = (n-1)/n x full bytes; collective-permute = bytes.
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        typ, op = m.group(1), m.group(2)
        if op + "-done" in line:
            continue
        size = _shape_bytes(typ)
        g = _GROUPS_RE.search(line)
        n = int(g.group(2)) if g else 2
        if n <= 1:
            continue
        ring = (n - 1) / n
        factor = {"all-reduce": 2 * ring, "all-gather": ring,
                  "reduce-scatter": ring, "all-to-all": ring,
                  "collective-permute": 1.0}[op]
        out[op] = out.get(op, 0.0) + size * factor
    return out


# ---------------------------------------------------------------------------
# Probe configs: layer-scan trip counts per arch family
# ---------------------------------------------------------------------------
@dataclass
class ProbePlan:
    """probes[i] = (cfg_variant, trips vector a_i); full_trips for the real
    config.  cost_full = c0 + full_trips . c  with [c0, c] solved from probes.
    """
    probes: List[Tuple[ArchConfig, Tuple[float, ...]]]
    full_trips: Tuple[float, ...]


def probe_plan(cfg: ArchConfig) -> ProbePlan:
    fam = cfg.family
    if fam in ("dense", "moe"):
        head = cfg.moe.first_dense_layers if fam == "moe" else 0
        full = cfg.n_layers - head
        return ProbePlan(
            probes=[(replace(cfg, n_layers=head + 1), (1.0,)),
                    (replace(cfg, n_layers=head + 2), (2.0,))],
            full_trips=(float(full),))
    if fam == "ssm":                      # xlstm: groups of slstm_every
        per = cfg.ssm.slstm_every or cfg.n_layers
        return ProbePlan(
            probes=[(replace(cfg, n_layers=per), (1.0,)),
                    (replace(cfg, n_layers=2 * per), (2.0,))],
            full_trips=(float(cfg.n_layers // per),))
    if fam == "hybrid":                   # groups of 6 + mamba tail
        per = cfg.shared_attn_every
        return ProbePlan(
            probes=[(replace(cfg, n_layers=per), (1.0, 0.0)),
                    (replace(cfg, n_layers=2 * per), (2.0, 0.0)),
                    (replace(cfg, n_layers=per + 1), (1.0, 1.0))],
            full_trips=(float(cfg.n_layers // per),
                        float(cfg.n_layers % per)))
    if fam == "audio":                    # encoder / decoder stacks
        return ProbePlan(
            probes=[(replace(cfg, n_encoder_layers=1, n_layers=1), (1.0, 1.0)),
                    (replace(cfg, n_encoder_layers=2, n_layers=1), (2.0, 1.0)),
                    (replace(cfg, n_encoder_layers=1, n_layers=2), (1.0, 2.0))],
            full_trips=(float(cfg.n_encoder_layers), float(cfg.n_layers)))
    if fam == "vlm":                      # groups of cross_attn_every
        per = cfg.cross_attn_every
        return ProbePlan(
            probes=[(replace(cfg, n_layers=per), (1.0,)),
                    (replace(cfg, n_layers=2 * per), (2.0,))],
            full_trips=(float(cfg.n_layers // per),))
    raise KeyError(fam)


def solve_extrapolation(plan: ProbePlan,
                        probe_costs: List[Dict[str, float]]) -> Dict[str, float]:
    """Least-squares solve of cost = c0 + trips . c per metric key."""
    keys = set()
    for c in probe_costs:
        keys.update(c)
    a = np.array([[1.0, *trips] for _, trips in plan.probes])
    out = {}
    for k in keys:
        b = np.array([c.get(k, 0.0) for c in probe_costs])
        coef, *_ = np.linalg.lstsq(a, b, rcond=None)
        full = coef[0] + float(np.dot(coef[1:], np.array(plan.full_trips)))
        out[k] = max(full, 0.0)
    return out


# ---------------------------------------------------------------------------
# Analytic corrections for loops that cannot be unrolled (sLSTM time scan)
# ---------------------------------------------------------------------------
def analytic_extra_flops(cfg: ArchConfig, shape: ShapeConfig,
                         n_devices: int) -> float:
    """Per-device FLOPs invisible to cost_analysis (rolled time scans)."""
    if cfg.family != "ssm" or not (cfg.ssm and cfg.ssm.slstm_every):
        return 0.0
    n_slstm = cfg.n_layers // cfg.ssm.slstm_every
    d = cfg.d_model
    nh = cfg.attention.n_heads
    hd = d // nh
    steps = 1 if shape.kind == "decode" else shape.seq_len
    per_step = 2 * nh * hd * 4 * hd + 40 * d      # R matmul + gate flops
    total = n_slstm * steps * shape.global_batch * per_step
    if shape.kind == "train":
        total *= 3.0                              # fwd + bwd
    return total / n_devices


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Useful-model FLOPs for the whole step (all devices)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch    # one token per sequence


# ---------------------------------------------------------------------------
# Megabatch bucket pricing (ISSUE 4: roofline-priced autoscaling)
# ---------------------------------------------------------------------------
def megabatch_task_flops(learner: str, n: int, p: int,
                         params: Dict = None) -> float:
    """Analytic FLOPs of ONE task lane of a megabatch bucket launch at
    the bucket's padded (n, p) — the same counting convention as
    ``model_flops`` (multiply-add = 2 FLOPs), per learner family.

    Padded rows/columns do real arithmetic (that is the padding-waste
    signal's whole point), so the estimate is taken at the *padded*
    shape.  These feed the occupancy autoscaler's candidate pricing
    before any duration has been observed — the "first-wave decision
    cost-accurate too" ROADMAP item — so fidelity to ~2x is plenty;
    ranking candidates only needs relative scale.
    """
    params = dict(params or ())
    gram = 2.0 * n * p * p               # X^T W X
    solve = (2.0 / 3.0) * p ** 3         # cholesky-ish SPD solve
    predict = 2.0 * n * p
    if learner in ("ridge", "ols"):
        return gram + solve + predict
    if learner == "lasso":               # FISTA: one gram, iterated grads
        n_iter = int(params.get("n_iter", 200))
        return gram + n_iter * (4.0 * p * p + 8.0 * p) + predict
    if learner == "logistic":            # IRLS: gram + solve per newton step
        n_iter = int(params.get("n_iter", 32))
        return n_iter * (gram + solve + 4.0 * n * p) + predict
    if learner == "kernel_ridge":        # m landmarks: K_nm, K_mm, solve
        m = int(params.get("n_landmarks", 128))
        return (2.0 * n * m * p + 2.0 * m * m * p
                + (2.0 / 3.0) * m ** 3 + 2.0 * n * m)
    if learner == "mlp":                 # fwd+bwd per step over the widths
        hidden = tuple(params.get("hidden", (64, 64)))
        n_steps = int(params.get("n_steps", 300))
        dims = (p,) + hidden + (1,)
        per_row = sum(2.0 * a * b for a, b in zip(dims, dims[1:]))
        return n_steps * 6.0 * n * per_row + 2.0 * n * per_row
    return gram + solve + predict        # unknown family: linear-ish guess


def megabatch_task_bytes(n: int, p: int) -> float:
    """HBM bytes one task lane moves per launch: its feature page plus
    the y/w/valid rows in, the prediction row out (f32)."""
    return 4.0 * (n * p + 4.0 * n)


# Host-side cost of dispatching ONE compiled program (jit call + runtime
# enqueue), measured ~0.3 ms on the serving hosts.  It dwarfs the
# compute/memory terms for small buckets — which is exactly why the
# dispatcher packs same-shape blocks into one fused launch: the overhead
# is paid once per launch, not once per block.  This constant is the
# FALLBACK; ``measure_launch_overhead_s`` replaces it with a per-session
# measurement on the actual runtime (session init calls it once).
LAUNCH_OVERHEAD_S = 3e-4

# session-measured override; None until measure_launch_overhead_s runs
_MEASURED_LAUNCH_OVERHEAD_S: Optional[float] = None


def launch_overhead_s() -> float:
    """Host dispatch cost of one compiled-program launch: the session
    measurement when one has been taken, else the hardcoded fallback."""
    if _MEASURED_LAUNCH_OVERHEAD_S is not None:
        return _MEASURED_LAUNCH_OVERHEAD_S
    return LAUNCH_OVERHEAD_S


def measure_launch_overhead_s(repeats: int = 30) -> float:
    """Measure the per-launch dispatch overhead with a timed no-op
    program: compile a trivial jit once, then time warm re-dispatches
    and take the median.  Memoized module-globally — sessions call this
    at init so autoscaler pricing uses the runtime actually underneath
    us instead of the serving-host constant.  Clamped to a sane band
    (10 us .. 10 ms); any failure falls back to ``LAUNCH_OVERHEAD_S``.
    """
    global _MEASURED_LAUNCH_OVERHEAD_S
    if _MEASURED_LAUNCH_OVERHEAD_S is not None:
        return _MEASURED_LAUNCH_OVERHEAD_S
    try:
        import time

        import jax
        import jax.numpy as jnp

        noop = jax.jit(lambda x: x + 1.0)
        x = jnp.zeros((8,), jnp.float32)
        noop(x).block_until_ready()            # compile outside the timer
        samples = []
        for _ in range(max(int(repeats), 3)):
            t0 = time.perf_counter()
            noop(x).block_until_ready()
            samples.append(time.perf_counter() - t0)
        samples.sort()
        measured = samples[len(samples) // 2]
        _MEASURED_LAUNCH_OVERHEAD_S = min(max(measured, 1e-5), 1e-2)
    except Exception:
        _MEASURED_LAUNCH_OVERHEAD_S = LAUNCH_OVERHEAD_S
    return _MEASURED_LAUNCH_OVERHEAD_S


def invocation_roofline_s(learner: str, params, tasks_per_invocation: int,
                          n_pad: int, p_pad: int, *,
                          amortized_launches: float = 0.0) -> float:
    """Roofline lower bound on one invocation's duration: max of the
    compute and memory terms over its task lanes, on the same hardware
    model as the rest of this module.

    ``amortized_launches`` is this invocation's share of its bucket's
    fused program launches (e.g. 1/len(bucket) when the whole bucket
    rides one fused launch): the autoscaler passes it so cold pricing
    reflects the dispatch overhead the fused hot path actually pays.
    The default 0 keeps the pure compute/memory bound."""
    t = max(int(tasks_per_invocation), 1)
    flops = t * megabatch_task_flops(learner, n_pad, p_pad, params)
    byts = t * megabatch_task_bytes(n_pad, p_pad)
    return max(flops / PEAK_FLOPS, byts / HBM_BW) \
        + amortized_launches * launch_overhead_s()


# Hedge-deadline shape (ISSUE 10): a bucket is declared overdue — and a
# duplicate dispatch raced against it — once its in-flight age exceeds
# FACTOR x the roofline estimate of the whole slice, floored so that
# sub-millisecond serving buckets are not hedged on scheduler jitter.
# 4x mirrors the speculative-duplicate threshold used by gg-style
# serverless launchers (stragglers there run 5-10x the median).
HEDGE_DEADLINE_FACTOR = 4.0
HEDGE_DEADLINE_FLOOR_S = 0.05


def bucket_deadline_s(learner: str, params, tasks_per_invocation: int,
                      n_pad: int, p_pad: int, n_entries: int,
                      n_workers: int = 1) -> float:
    """Roofline-derived hedge deadline for one dispatched bucket slice:
    FACTOR x the estimated wall of its ``n_entries`` invocations over
    ``n_workers`` lanes (plus one launch overhead), floored.  Backends
    cap this by ``PoolConfig.timeout_s`` — whichever is tighter drives
    the hedged re-dispatch."""
    per_inv = invocation_roofline_s(learner, params, tasks_per_invocation,
                                    n_pad, p_pad)
    lanes = max(int(n_workers), 1)
    waves = -(-max(int(n_entries), 1) // lanes)      # ceil division
    est = waves * per_inv + launch_overhead_s()
    return max(HEDGE_DEADLINE_FACTOR * est, HEDGE_DEADLINE_FLOOR_S)


# ---------------------------------------------------------------------------
# Parallelization-axis pricing (ISSUE 8: the per-bucket axis planner)
# ---------------------------------------------------------------------------
# Hardware-model ceiling on the rows of one device-resident feature
# page: a bucket whose N_pad exceeds this cannot run the one-page
# task-parallel layout and must stream N-chunks through the blocked
# Gram kernel (kernels/ops.py::batched_gram_blocked).
DEVICE_PAGE_ROWS = 1 << 16

# Dispatch-side tax of an m-way shard_map launch relative to the
# single-device program: extra argument sharding/unsharding and the
# runtime's per-shard bookkeeping, expressed as a fraction of one launch
# overhead per extra shard.  Keeps the planner honest on tiny serving
# buckets, where sharding 8 ways costs more host time than it saves.
# This constant is the FALLBACK; ``measure_shard_overhead_frac``
# replaces it with a per-session probe on the actual runtime (session
# init calls it once, like ``measure_launch_overhead_s``) — BENCH_axisplan
# showed the analytic 0.15 mispricing 1-device meshes, where the
# shard_map wrapper alone ran data-parallel at 0.47x task.
SHARD_OVERHEAD_FRAC = 0.15

# session-measured override; None until measure_shard_overhead_frac runs
_MEASURED_SHARD_OVERHEAD_FRAC: Optional[float] = None


def shard_overhead_frac() -> float:
    """Per-extra-shard dispatch tax (fraction of one launch overhead):
    the session measurement when one has been taken, else the
    hardcoded fallback."""
    if _MEASURED_SHARD_OVERHEAD_FRAC is not None:
        return _MEASURED_SHARD_OVERHEAD_FRAC
    return SHARD_OVERHEAD_FRAC


def measure_shard_overhead_frac(repeats: int = 20) -> float:
    """Measure the shard_map dispatch tax with a timed no-op pair:
    compile a trivial jit and the same body shard_map'd over the host
    mesh's "data" axis, time warm re-dispatches of both (medians), and
    express the extra cost as a fraction of one plain launch per extra
    shard — the exact ``launch_cost`` model ``axis_candidate_costs``
    charges.  A 1-device mesh still measures the wrapper's own tax
    (attributed to one "extra shard" so data@1 rescue pricing stays
    honest).  Memoized module-globally; clamped to [0.02, 2.0]; any
    failure falls back to ``SHARD_OVERHEAD_FRAC``."""
    global _MEASURED_SHARD_OVERHEAD_FRAC
    if _MEASURED_SHARD_OVERHEAD_FRAC is not None:
        return _MEASURED_SHARD_OVERHEAD_FRAC
    try:
        import time

        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import make_host_mesh
        from repro.sharding.compat import shard_map_compat

        mesh = make_host_mesh()
        m = int(mesh.shape["data"])
        body = lambda x: x + 1.0
        plain = jax.jit(body)
        sharded = jax.jit(shard_map_compat(
            body, mesh=mesh, in_specs=(P("data"),), out_specs=P("data")))
        x = jnp.zeros((8 * m,), jnp.float32)

        def median_s(fn):
            fn(x).block_until_ready()      # compile outside the timer
            samples = []
            for _ in range(max(int(repeats), 3)):
                t0 = time.perf_counter()
                fn(x).block_until_ready()
                samples.append(time.perf_counter() - t0)
            samples.sort()
            return samples[len(samples) // 2]

        t_plain = max(median_s(plain), 1e-7)
        t_sharded = median_s(sharded)
        extra = max(t_sharded - t_plain, 0.0)
        frac = extra / (t_plain * max(m - 1, 1))
        _MEASURED_SHARD_OVERHEAD_FRAC = min(max(frac, 0.02), 2.0)
    except Exception:
        _MEASURED_SHARD_OVERHEAD_FRAC = SHARD_OVERHEAD_FRAC
    return _MEASURED_SHARD_OVERHEAD_FRAC

#: families whose fit is a pure function of (X'X, X'y) — the data-
#: parallel blocked-Gram axis reconstructs their exact statistics from
#: per-shard partial sums, and the feature axis can split their
#: coordinate updates.  Everything else prices only the task axis.
GRAM_FAMILIES = ("ols", "ridge", "lasso")


def chunked_gram_flops(n: int, p: int, chunk_rows: int) -> float:
    """FLOPs of accumulating X'X / X'y over ceil(n/chunk) N-chunks (the
    streaming blocked Gram kernel): the same 2np^2 + 2np MACs as the
    unblocked Gram, plus one (p, p) accumulator add per extra chunk —
    the term that prices chunk granularity."""
    n_chunks = max(int(np.ceil(n / max(int(chunk_rows), 1))), 1)
    return 2.0 * n * p * p + 2.0 * n * p + (n_chunks - 1) * float(p) * p


def _solve_flops(learner: str, n: int, p: int, params: Dict) -> float:
    """The non-Gram remainder of a Gram-family fit: the part data-
    parallel sharding cannot split (solve / iterated coordinate
    updates run on the reduced statistics, replicated per shard)."""
    gram = 2.0 * n * p * p
    total = megabatch_task_flops(learner, n, p, params)
    return max(total - gram, 0.0)


def axis_candidate_costs(learner: str, params, n_tasks: int, n_pad: int,
                         p_pad: int, n_devices: int,
                         ) -> List[Tuple[str, int, float, bool]]:
    """Price every parallelization-axis candidate for one bucket.

    Returns ``[(axis, shards, est_s, executable), ...]`` — the roofline
    wall-clock of draining ``n_tasks`` tasks of padded shape
    (n_pad, p_pad) on an ``n_devices`` mesh under each layout:

    * ``task``     — whole tasks round-robin over shards (the fused
                     sharded launch; shards=1 is today's single-device
                     baseline).  No collectives; an m-way launch pays a
                     shard_map dispatch tax.
    * ``data``     — every shard accumulates a partial Gram over N/m
                     rows through the blocked kernel, psums the (P, P)
                     statistics, then solves on the reduced moments.
                     Splits the N axis: the only layout that can run a
                     bucket whose N_pad exceeds DEVICE_PAGE_ROWS.
    * ``feature``  — each shard owns P/m columns (LightGBM's feature-
                     parallel analogue): compute splits by column,
                     iterative families all-gather their coefficient
                     block per sweep, and the final predictions gather
                     the column partials.

    ``executable`` marks candidates the current launch layer can
    actually run (task always; data/feature only for GRAM_FAMILIES,
    through the standalone in-mesh executors in sharding/gram.py).
    Pure pricing — no jax, no device access — so planner decisions are
    deterministic and unit-testable.
    """
    params = dict(params or ())
    b = max(int(n_tasks), 1)
    m = max(int(n_devices), 1)
    lo = launch_overhead_s()
    f1 = megabatch_task_flops(learner, n_pad, p_pad, params)
    by1 = megabatch_task_bytes(n_pad, p_pad)
    gram_ok = learner in GRAM_FAMILIES
    fits_page = n_pad <= DEVICE_PAGE_ROWS

    frac = shard_overhead_frac()

    def launch_cost(shards: int) -> float:
        return lo * (1.0 + frac * (shards - 1))

    out: List[Tuple[str, int, float, bool]] = []
    # ---- task axis: ceil(b/m) whole tasks per shard, no collectives
    for shards in sorted({1, m}):
        per_dev = float(int(np.ceil(b / shards)))
        est = max(per_dev * f1 / PEAK_FLOPS, per_dev * by1 / HBM_BW) \
            + launch_cost(shards)
        out.append(("task", shards, est, fits_page))
    if m == 1:
        # chunk-streamed data@1: the page-overflow rescue path — the
        # blocked Gram streams N-chunks through one device, so a tall
        # bucket still drains on a 1-device mesh (ISSUE 9).  Priced
        # with the 1-way shard_map wrapper's own dispatch tax (the
        # measured 0.47x-of-task overhead) and marked executable only
        # when the task layout is NOT (a fitting page always prefers
        # the untaxed task program).
        if gram_ok:
            gram_dev = b * chunked_gram_flops(n_pad, p_pad,
                                              DEVICE_PAGE_ROWS)
            tail = b * _solve_flops(learner, n_pad, p_pad, params)
            est = max((gram_dev + tail) / PEAK_FLOPS, by1 * b / HBM_BW) \
                + lo * (1.0 + frac)
            out.append(("data", 1, est, not fits_page))
        return out

    # ---- data axis: blocked-Gram partials over N/m rows + psum(P^2)
    if gram_ok or learner == "logistic":
        chunk = max(int(np.ceil(n_pad / m)), 1)
        gram_dev = b * chunked_gram_flops(n_pad, p_pad, chunk) / m
        tail = b * _solve_flops(learner, n_pad, p_pad, params)
        psum_rounds = 1.0 if learner != "logistic" \
            else float(params.get("n_iter", 32))
        psum_bytes = b * (p_pad * p_pad + p_pad) * 4.0 * psum_rounds
        coll = psum_bytes * 2.0 * (m - 1) / m / ICI_BW
        est = max((gram_dev + tail) / PEAK_FLOPS, by1 * b / m / HBM_BW) \
            + coll + launch_cost(m)
        out.append(("data", m, est, gram_ok))
    else:
        # no analytic data-parallel decomposition for this family
        out.append(("data", m, float("inf"), False))

    # ---- feature axis: P/m columns per shard + coefficient gathers
    if gram_ok:
        sweeps = float(params.get("n_iter", 200)) \
            if learner == "lasso" else 1.0
        gather_bytes = b * (n_pad * p_pad / m + sweeps * p_pad) * 4.0
        coll = gather_bytes * (m - 1) / m / ICI_BW
        est = max(f1 * b / m / PEAK_FLOPS, by1 * b / m / HBM_BW) \
            + coll + launch_cost(m)
        out.append(("feature", m, est, fits_page))
    else:
        out.append(("feature", m, float("inf"), False))
    return out


@dataclass
class RooflineTerms:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    n_devices: int
    model_flops_total: float
    coll_detail: Dict[str, float] = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """No-overlap upper bound on step time."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        hlo_total = self.flops_per_dev * self.n_devices
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        denom = self.step_s * self.n_devices * PEAK_FLOPS
        return self.model_flops_total / denom if denom else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops_total": self.model_flops_total,
            "useful_ratio": self.useful_ratio,
            "mfu_bound": self.mfu_bound,
            "coll_detail": self.coll_detail,
        }
