"""Batched serving driver (decode cells' runtime analogue).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-moe-a2.7b \
      --reduced --requests 12 --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import build_model, init_tree
from repro.serving import Engine
from repro.sharding.axes import rules_for


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    rules = rules_for(cfg.name, "decode", cfg.d_model)
    bundle = build_model(cfg, rules, remat="none",
                         attn_chunk=min(1024, args.prompt_len))
    params = init_tree(bundle.decls, jax.random.key(args.seed))
    engine = Engine(bundle, params)

    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab_size,
                            rng.integers(4, args.prompt_len)).astype(np.int32)
               for _ in range(args.requests)]
    outs = engine.serve_requests(prompts, args.batch, args.prompt_len,
                                 n_gen=args.gen)
    for i, o in enumerate(outs[:4]):
        print(f"req{i}: {o[:10]}...")
    # throughput probe on a full batch
    toks = np.stack([np.resize(p, args.prompt_len) for p in prompts[:args.batch]])
    res = engine.generate({"tokens": jax.numpy.asarray(toks)}, n_gen=args.gen)
    print(f"prefill {res.prefill_s*1e3:.1f} ms, decode {res.decode_s*1e3:.1f} ms, "
          f"{res.tokens_per_s:.1f} tok/s")


if __name__ == "__main__":
    main()
