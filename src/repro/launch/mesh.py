"""Production meshes (spec: MULTI-POD DRY-RUN step 1).

Importing this module never touches jax device state; meshes are built
inside the function.  Single pod: (16, 16) = 256 chips, axes
("data", "model").  Multi-pod: (2, 16, 16) = 512 chips with a leading
"pod" axis that composes with "data" for batch/grid/FSDP sharding.

Topology (ISSUE 4): the drain engine's host streams are built from these
meshes — ``split_pod_meshes`` carves a multi-pod production mesh into one
("data", "model") mesh per pod, and ``make_sim_host_meshes`` fakes N
hosts out of whatever devices this process has (the forced-host-platform
CI path: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.sharding.compat import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host offers (tests / examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return make_mesh_compat((n // model_parallel, model_parallel),
                            ("data", "model"))


def split_pod_meshes(mesh):
    """One ("data", "model")-style mesh per index of the leading "pod"
    axis — the per-host meshes the topology layer streams over."""
    if "pod" not in mesh.axis_names:
        return [mesh]
    from jax.sharding import Mesh
    pod_axis = mesh.axis_names.index("pod")
    axes = tuple(a for a in mesh.axis_names if a != "pod")
    devs = np.asarray(mesh.devices)
    return [Mesh(np.take(devs, i, axis=pod_axis), axes)
            for i in range(devs.shape[pod_axis])]


def make_sim_host_meshes(n_hosts: int, model_parallel: int = 1):
    """N simulated host meshes over this process's devices.

    Devices are split contiguously; with fewer devices than hosts the
    tail hosts reuse devices round-robin (pure simulation — residency
    separation still holds because each host owns its own page pool).
    A host group too small for the requested ``model_parallel`` falls
    back to data-parallel-only rather than failing.
    """
    from jax.sharding import Mesh
    devs = jax.devices()
    per = max(len(devs) // max(n_hosts, 1), 1)
    meshes = []
    for h in range(n_hosts):
        group = devs[h * per:(h + 1) * per] or [devs[h % len(devs)]]
        mp = model_parallel if len(group) % model_parallel == 0 else 1
        arr = np.asarray(group).reshape(len(group) // mp, mp)
        meshes.append(Mesh(arr, ("data", "model")))
    return meshes
