"""Production meshes (spec: MULTI-POD DRY-RUN step 1).

Importing this module never touches jax device state; meshes are built
inside the function.  Single pod: (16, 16) = 256 chips, axes
("data", "model").  Multi-pod: (2, 16, 16) = 512 chips with a leading
"pod" axis that composes with "data" for batch/grid/FSDP sharding.
"""
from __future__ import annotations

import jax

from repro.sharding.compat import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host offers (tests / examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return make_mesh_compat((n // model_parallel, model_parallel),
                            ("data", "model"))
