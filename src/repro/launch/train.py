"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch codeqwen1.5-7b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

``--reduced`` trains the smoke-size config (CPU-friendly); omit it on a real
pod.  ``--resume`` restarts from the latest checkpoint (resume-exact).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_arch
from repro.data.lm_data import LMDataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.sharding.axes import rules_for
from repro.train import OptConfig, Trainer, TrainerConfig
from repro import runtime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    mesh = make_host_mesh()
    runtime.mesh_axes = tuple(mesh.shape.keys())
    rules = rules_for(cfg.name, "train", cfg.d_model)
    bundle = build_model(cfg, rules, mesh=mesh,
                         remat="none" if args.reduced else "full",
                         attn_chunk=min(1024, args.seq))
    data = SyntheticLM(LMDataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch,
                                    seed=args.seed))
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                    total_steps=args.steps,
                    compress_grads=args.compress_grads)
    trainer = Trainer(bundle, opt,
                      TrainerConfig(steps=args.steps, log_every=10,
                                    ckpt_every=args.ckpt_every,
                                    ckpt_dir=args.ckpt_dir,
                                    n_microbatch=args.microbatch),
                      mesh=mesh)
    with mesh:
        if args.resume and args.ckpt_dir:
            params, opt_state, start = trainer.resume()
            print(f"resumed at step {start}")
        else:
            params, opt_state = trainer.init(jax.random.key(args.seed))
            start = 0
        params, opt_state, hist = trainer.run(
            params, opt_state, data.iterate(start), start_step=start)
    print(f"final loss: {hist[-1]['loss']:.4f}" if hist else "no steps run")


if __name__ == "__main__":
    main()
