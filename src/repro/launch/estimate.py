"""DML estimation driver — the ``fit_aws_lambda()`` analogue (paper §5).

  PYTHONPATH=src python -m repro.launch.estimate                 # bonus PLR
  PYTHONPATH=src python -m repro.launch.estimate --scaling 'n_folds*n_rep' \
      --memory 512 --workers 16
  PYTHONPATH=src python -m repro.launch.estimate --backend sharded  # SPMD
      execution of the same plan (ExecutionBackend selection)
  PYTHONPATH=src python -m repro.launch.estimate --dryrun        # production
      mesh lowering + roofline of the fused cross-fit step (paper-technique
      dry-run cell)

The --dryrun path lowers the fused crossfit estimation (Gram + Cholesky +
predict for the whole M*K*L grid) on the 256/512-chip production mesh with
the task grid sharded over every mesh axis — the paper's elasticity story
as one SPMD program.
"""
from __future__ import annotations

import argparse
import json


def run_fit(args):
    from repro.core import DMLData, DMLPlan, estimate
    from repro.data import make_bonus_data, make_plr_data
    from repro.serverless import PoolConfig

    raw = make_bonus_data() if args.data == "bonus" else make_plr_data(
        n_obs=args.n_obs, theta=0.5, seed=args.seed)
    data = DMLData.from_dict(raw)
    pool = PoolConfig(n_workers=args.workers, memory_mb=args.memory,
                      failure_rate=args.failure_rate,
                      straggler_rate=args.straggler_rate,
                      checkpoint_path=args.ledger,
                      simulate=args.simulate, base_work_s=0.2)
    plan = DMLPlan.for_model(
        args.model, n_folds=args.folds, n_rep=args.reps,
        learner=args.learner, learner_params={"reg": args.reg},
        scaling=args.scaling, backend=args.backend, pool=pool,
        seed=args.seed, n_boot=args.boot)
    res = estimate(plan, data)
    print(json.dumps(res.summary(), indent=1, default=float))
    if data.theta0 is not None:
        print(f"true theta: {data.theta0}")


def run_dryrun(args):
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import functools
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import (
        RooflineTerms, parse_collective_bytes)

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    grid_axes = tuple(mesh.shape.keys())

    n, p = args.n_obs, args.dim_x + 1
    if args.pad_features:
        p = ((p + 127) // 128) * 128     # MXU lane alignment (§Perf)
    t = args.reps * args.folds * 2
    t_pad = ((t + n_dev - 1) // n_dev) * n_dev

    def crossfit_step(x, w, y):
        from repro.kernels import ops
        g, b = ops.crossfit_gram(x, w, y, reg=args.reg)
        chol = jax.vmap(jnp.linalg.cholesky)(g)
        beta = jax.vmap(lambda c, bb: jax.scipy.linalg.cho_solve((c, True), bb))(
            chol, b)
        preds = jnp.einsum("np,tp->tn", x, beta)
        return preds

    xs = jax.ShapeDtypeStruct((n, p), jnp.float32)
    ws = jax.ShapeDtypeStruct((t_pad, n), jnp.float32)
    ys = jax.ShapeDtypeStruct((t_pad, n), jnp.float32)
    if args.shard_n:
        # huge-N regime (paper §6 "big data"): shard observations over
        # "data", tasks over the remaining axes; Gram accumulates via psum
        n_axes = ("data",)
        t_axes = tuple(a for a in grid_axes if a != "data")
        t_pad = ((t + 63) // 64) * 64
        ws = jax.ShapeDtypeStruct((t_pad, n), jnp.float32)
        ys = jax.ShapeDtypeStruct((t_pad, n), jnp.float32)
        x_sharding = NamedSharding(mesh, P(n_axes, None))
        task_sharding = NamedSharding(mesh, P(t_axes, n_axes))
        out_sharding = NamedSharding(mesh, P(t_axes, n_axes))
    else:
        x_sharding = NamedSharding(mesh, P())
        task_sharding = NamedSharding(mesh, P(grid_axes, None))
        out_sharding = task_sharding
    with mesh:
        lowered = jax.jit(crossfit_step,
                          in_shardings=(x_sharding, task_sharding,
                                        task_sharding),
                          out_shardings=out_sharding).lower(xs, ws, ys)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    colls = parse_collective_bytes(compiled.as_text())
    terms = RooflineTerms(
        flops_per_dev=float(ca.get("flops", 0.0)),
        bytes_per_dev=float(ca.get("bytes accessed", 0.0)),
        coll_bytes_per_dev=sum(colls.values()),
        n_devices=n_dev,
        # useful flops: Gram (N*P^2) + chol (P^3/3) + solve + predict per task
        model_flops_total=float(t) * (2 * n * p * p + p**3 / 3
                                      + 2 * p * p + 2 * n * p),
        coll_detail=colls)
    print(json.dumps({
        "cell": f"dml_crossfit__{args.mesh}",
        "tasks": t, "tasks_padded": t_pad, "n_obs": n, "features": p,
        "arg_bytes": int(ma.argument_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "roofline": terms.to_dict(),
    }, indent=1))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"cell": f"dml_crossfit__{args.mesh}",
                       "roofline": terms.to_dict()}, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--data", default="bonus", choices=["bonus", "plr"])
    ap.add_argument("--model", default="plr")
    ap.add_argument("--learner", default="ridge")
    ap.add_argument("--reg", type=float, default=1.0)
    ap.add_argument("--folds", type=int, default=5)
    ap.add_argument("--reps", type=int, default=100)
    ap.add_argument("--scaling", default="n_rep",
                    choices=["n_rep", "n_folds*n_rep"])
    ap.add_argument("--backend", default="wave",
                    choices=["wave", "sharded", "inline"])
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--memory", type=int, default=1024)
    ap.add_argument("--failure-rate", type=float, default=0.0)
    ap.add_argument("--straggler-rate", type=float, default=0.0)
    ap.add_argument("--simulate", action="store_true")
    ap.add_argument("--ledger", default=None)
    ap.add_argument("--boot", type=int, default=0)
    ap.add_argument("--n-obs", type=int, default=5099)
    ap.add_argument("--dim-x", type=int, default=17)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--pad-features", action="store_true")
    ap.add_argument("--shard-n", action="store_true")
    args = ap.parse_args()
    if args.dryrun:
        run_dryrun(args)
    else:
        run_fit(args)


if __name__ == "__main__":
    main()
