"""Aggregate dry-run artifacts into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.launch.report --dir artifacts/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load_all(d: str) -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


HBM = 16 * 2**30


def tpu_peak(rec: Dict) -> int:
    """TPU-corrected peak: train/decode donate params+opt / cache, so their
    outputs alias inputs on a real backend; the CPU backend does not
    implement donation and double-counts them.  Prefill outputs (fresh KV
    cache) are real and stay counted."""
    f = rec["full"]
    base = f["arg_bytes"] + f["temp_bytes"]
    if rec["shape"].startswith("prefill"):
        base += f["output_bytes"] - f["alias_bytes"]
    return int(base)


def dryrun_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | peak GiB/dev (tpu-corrected) | "
        "fits 16G | compile s | collective ops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if "cell" in r:            # dml cell, separate table
            continue
        key = f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        if r.get("skipped"):
            lines.append(key + f"| SKIP: {r['reason'][:44]} | — | — | — | — |")
            continue
        if "error" in r:
            lines.append(key + f"| ERROR: {r['error'][:44]} | — | — | — | — |")
            continue
        f = r["full"]
        peak = tpu_peak(r)
        colls = ", ".join(f"{k.split('-')[-1][:6]}:{fmt_bytes(v)}G"
                          for k, v in sorted(f["collective_ops"].items()))
        lines.append(
            key + f"| ok | {fmt_bytes(peak)} | "
            f"{'Y' if peak <= HBM else 'N'} | {f['compile_s']:.0f} | "
            f"{colls or '—'} |")
    return "\n".join(lines)


def roofline_table(recs: List[Dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck | "
        "MODEL/HLO flops | MFU bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or "roofline" not in r:
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"{t['bottleneck']} | {t['useful_ratio']:.2f} | "
            f"{t['mfu_bound']:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load_all(args.dir)
    done = [r for r in recs if "full" in r]
    skipped = [r for r in recs if r.get("skipped")]
    errors = [r for r in recs if "error" in r]
    print(f"cells: {len(recs)} (ok {len(done)}, skipped {len(skipped)}, "
          f"errors {len(errors)})\n")
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print(f"\n## Roofline ({args.mesh}-pod, 256 chips)\n")
    print(roofline_table(recs, args.mesh))
    if errors:
        print("\n### Errors\n")
        for r in errors:
            print(f"- {r['arch']} {r['shape']} {r['mesh']}: {r['error']}")


if __name__ == "__main__":
    main()
