import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run (spec: MULTI-POD DRY-RUN).

For every (architecture x input-shape x mesh) cell:
  1. FULL compile — ``jax.jit(step).lower(...).compile()`` on the production
     mesh with real shardings; ``memory_analysis()`` proves per-device fit,
     the HLO text yields the collective schedule.
  2. COST probes — small-depth variants with inner loops unrolled; layer
     scans extrapolated linearly (launch/roofline.py) to the full depth.
  3. Roofline terms + bottleneck + MODEL_FLOPS ratio -> JSON artifact.

Run:  PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun
      PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
          --shape train_4k --mesh multi
"""

import argparse
import functools
import gc
import json
import time
import traceback
from typing import Dict, Optional

import jax
import numpy as np

from repro import runtime
from repro.configs import ARCH_NAMES, SHAPE_BY_NAME, SHAPES, get_arch
from repro.configs.base import ArchConfig, ShapeConfig, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    RooflineTerms, analytic_extra_flops, model_flops, parse_collective_bytes,
    probe_plan, solve_extrapolation,
)
from repro.models import build_model
from repro.models.param import sharding_tree, struct_tree
from repro.sharding.axes import rules_for
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.trainer import make_train_step

HBM_PER_CHIP = 16 * 2**30          # v5e


def _cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    out = {"flops": float(ca.get("flops", 0.0)),
           "bytes": float(ca.get("bytes accessed", 0.0))}
    for op, val in parse_collective_bytes(compiled.as_text()).items():
        out[f"coll_{op}"] = val
    return out


def microbatches(cfg: ArchConfig, shape: ShapeConfig, mesh) -> int:
    """Gradient-accumulation factor: cap per-device micro tokens at ~16k for
    big models (napkin: activation checkpoints + attention transients scale
    linearly with micro tokens; 16k keeps them ~1-4 GiB beside the FSDP
    optimizer shards).  Small models (<2B) keep larger micros."""
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh.shape.get(a, 1)
    per_dev_tokens = shape.tokens // dp
    budget = 16_384 if cfg.param_count() > 2e9 else 65_536
    m = max(1, per_dev_tokens // budget)
    while shape.global_batch % (m * dp) and m > 1:   # micro must divide
        m -= 1
    return m


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
               attn_chunk: int = 1024, remat: str = "full",
               rules=None, donate: bool = True,
               n_microbatch: Optional[int] = None):
    """Build + lower + compile one cell. Returns (compiled, lower_s, compile_s)."""
    kind = shape.kind
    rules = rules or rules_for(cfg.name, kind, cfg.d_model,
                               shape.global_batch)
    with mesh:
        bundle = build_model(cfg, rules, mesh=mesh, remat=remat,
                             attn_chunk=attn_chunk)
        p_struct = struct_tree(bundle.decls)
        p_shard = sharding_tree(bundle.decls, mesh, rules)
        in_decl = bundle.input_specs(shape)
        b_struct = struct_tree(in_decl)
        b_shard = sharding_tree(in_decl, mesh, rules)

        if kind == "train":
            opt_cfg = OptConfig()
            o_struct = jax.eval_shape(
                functools.partial(init_opt_state, cfg=opt_cfg), p_struct)
            scalar = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            o_shard = {"step": scalar, "master": p_shard, "m": p_shard,
                       "v": p_shard}
            step = make_train_step(
                bundle, opt_cfg,
                n_microbatch=n_microbatch or microbatches(cfg, shape, mesh))
            fn = jax.jit(step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1) if donate else ())
            args = (p_struct, o_struct, b_struct)
        elif kind == "prefill":
            fn = jax.jit(bundle.prefill_fn, in_shardings=(p_shard, b_shard))
            args = (p_struct, b_struct)
        else:  # decode
            c_decl = bundle.cache_decls(shape)
            c_struct = struct_tree(c_decl)
            c_shard = sharding_tree(c_decl, mesh, rules)
            fn = jax.jit(bundle.decode_fn,
                         in_shardings=(p_shard, c_shard, b_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(1,) if donate else ())
            args = (p_struct, c_struct, b_struct)

        t0 = time.perf_counter()
        lowered = fn.lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
    return compiled, t1 - t0, t2 - t1


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             attn_chunk: int = 1024, remat: str = "full",
             rules=None, skip_probes: bool = False,
             variant: str = "default") -> Dict:
    cfg = get_arch(arch_name)
    shape = SHAPE_BY_NAME[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec: Dict = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
                 "variant": variant}
    if not ok:
        rec.update({"skipped": True, "reason": reason})
        return rec
    n_micro = None
    if variant != "default":
        from repro.sharding.policy import apply_variant
        rules, v = apply_variant(arch_name, shape.kind, cfg.d_model, variant)
        attn_chunk = v.attn_chunk or attn_chunk
        remat = v.remat or remat
        n_micro = v.n_microbatch

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    runtime.mesh_axes = tuple(mesh.shape.keys())

    # ---- 1. full compile: memory proof + collective schedule --------------
    compiled, lower_s, compile_s = lower_cell(
        cfg, shape, mesh, attn_chunk=attn_chunk, remat=remat, rules=rules,
        n_microbatch=n_micro)
    ma = compiled.memory_analysis()
    full_colls = parse_collective_bytes(compiled.as_text())
    peak = ma.argument_size_in_bytes + ma.temp_size_in_bytes \
        + ma.output_size_in_bytes - ma.alias_size_in_bytes
    rec["full"] = {
        "lower_s": round(lower_s, 2), "compile_s": round(compile_s, 2),
        "arg_bytes": int(ma.argument_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_bytes": int(peak),
        "fits_hbm": bool(peak <= HBM_PER_CHIP),
        "collective_ops": {k: int(v) for k, v in full_colls.items()},
    }
    del compiled
    gc.collect()

    # ---- 2. cost probes (inner loops unrolled, layer scans extrapolated) --
    if not skip_probes:
        plan = probe_plan(cfg)
        probe_costs = []
        with runtime.flags(unroll_inner=True):
            for pcfg, _trips in plan.probes:
                c, _, _ = lower_cell(pcfg, shape, mesh,
                                     attn_chunk=attn_chunk, remat=remat,
                                     rules=rules, donate=False,
                                     n_microbatch=n_micro)
                probe_costs.append(_cost_dict(c))
                del c
                gc.collect()
        cost = solve_extrapolation(plan, probe_costs)
        flops_dev = cost.get("flops", 0.0) \
            + analytic_extra_flops(cfg, shape, n_dev)
        coll_detail = {k[5:]: v for k, v in cost.items()
                       if k.startswith("coll_")}
        terms = RooflineTerms(
            flops_per_dev=flops_dev,
            bytes_per_dev=cost.get("bytes", 0.0),
            coll_bytes_per_dev=sum(coll_detail.values()),
            n_devices=n_dev,
            model_flops_total=model_flops(cfg, shape),
            coll_detail=coll_detail,
        )
        rec["roofline"] = terms.to_dict()
        rec["probe_costs"] = probe_costs
    rec["params"] = cfg.param_count()
    rec["active_params"] = cfg.active_param_count()
    return rec


def cell_list():
    """Fast-compiling families first (dense/moe/audio/vlm), recurrent stacks
    (unrolled SSD probes are compile-heavy on 1 CPU core) last."""
    def fam_rank(a):
        fam = get_arch(a).family
        return {"ssm": 2, "hybrid": 2}.get(fam, 0)
    for a in sorted(ARCH_NAMES, key=fam_rank):
        for s in SHAPES:
            yield a, s.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--skip-probes", action="store_true")
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--variant", default="default")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s, m) for a, s in cell_list() for m in meshes]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, m) for m in meshes]

    results = []
    tag = "" if args.variant == "default" else f"__{args.variant}"
    for arch, shape, mesh_kind in cells:
        path = os.path.join(args.out,
                            f"{arch}__{shape}__{mesh_kind}{tag}.json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                prev = json.load(f)
            if "error" not in prev:        # errored cells are retried
                print(f"[skip] {arch} {shape} {mesh_kind}")
                continue
        print(f"[cell] {arch} {shape} {mesh_kind} ...", flush=True)
        t0 = time.perf_counter()
        try:
            # multi-pod cells only need the compile + memory proof — the
            # roofline table is single-pod (spec §ROOFLINE) — so probes are
            # skipped there.
            rec = run_cell(arch, shape, mesh_kind,
                           attn_chunk=args.attn_chunk, remat=args.remat,
                           skip_probes=args.skip_probes or mesh_kind == "multi",
                           variant=args.variant)
        except Exception as e:  # noqa: BLE001 — record, continue the sweep
            rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        rec["wall_s"] = round(time.perf_counter() - t0, 1)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = ("SKIP " + rec.get("reason", "")) if rec.get("skipped") \
            else ("ERROR " + rec.get("error", "")) if "error" in rec \
            else (f"ok fits={rec['full']['fits_hbm']} "
                  f"peak={rec['full']['peak_bytes']/2**30:.2f}GiB "
                  + (f"bottleneck={rec['roofline']['bottleneck']} "
                     f"mfu_bound={rec['roofline']['mfu_bound']:.3f}"
                     if "roofline" in rec else ""))
        print(f"       {status} ({rec['wall_s']}s)", flush=True)
        results.append(rec)
        gc.collect()
    print(f"done: {len(results)} cells")


if __name__ == "__main__":
    main()
