"""Inject generated dry-run/roofline tables into EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.fill_experiments
"""
from __future__ import annotations

import argparse
import re

from repro.launch.report import dryrun_table, load_all, roofline_table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--file", default="EXPERIMENTS.md")
    args = ap.parse_args()
    recs = load_all(args.dir)
    recs = [r for r in recs if r.get("variant", "default") == "default"]

    with open(args.file) as f:
        text = f.read()

    dr = dryrun_table(recs)
    rf = roofline_table(recs, "single")
    text = re.sub(r"<!-- DRYRUN_TABLE -->(.|\n)*?(?=\n## §Roofline)",
                  f"<!-- DRYRUN_TABLE -->\n\n{dr}\n",
                  text) if "<!-- DRYRUN_TABLE -->" in text else text
    text = re.sub(r"<!-- ROOFLINE_TABLE -->(.|\n)*?(?=\n## §Perf)",
                  f"<!-- ROOFLINE_TABLE -->\n\n{rf}\n",
                  text) if "<!-- ROOFLINE_TABLE -->" in text else text
    with open(args.file, "w") as f:
        f.write(text)
    ok = sum(1 for r in recs if "full" in r)
    sk = sum(1 for r in recs if r.get("skipped"))
    er = sum(1 for r in recs if "error" in r)
    print(f"updated {args.file}: {ok} ok, {sk} skipped, {er} errors")


if __name__ == "__main__":
    main()
