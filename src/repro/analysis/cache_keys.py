"""Pass 2 — cache-key soundness over every registered warm cache.

PR 5 fixed a staleness bug of exactly the class this pass eliminates:
the stacked block-tensor cache was keyed by a fingerprint of the X page
while the cached tensors also derived from y/d/z — two datasets sharing
one X silently shared cached targets.  The fix (``DMLData.content_key``)
was example-tested; this pass makes the whole *class* of bug a lint
failure:

  * every bounded warm cache must be registered with ``@warm_cache``
    (``analysis/registry.py``) declaring its key paths, extra reads,
    and the coverage justification tying each read to the key component
    that pins it;
  * the decorated body is AST-checked: an attribute chain read on a
    cache-relevant parameter that is not a key path, a declared read,
    or ambient state scoped to the cache's own lifetime fails the audit
    — so a new read cannot land without extending the key or
    consciously documenting why the key already pins it;
  * two targeted structural checks guard the key *sources* themselves:
    ``DMLData.content_key`` must fingerprint every role in ``_ROLES``,
    and ``compile_request``'s ``work_key`` must be built from
    ``content_key()`` (never the X-only ``fingerprint()``).

Everything here is pure-AST over source text (``astutil``): the
mutation regression tests run this pass against deliberately-broken
copies of the tree without importing them.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis import astutil
from repro.analysis.report import Finding

#: every bounded warm cache on the hot path must register under exactly
#: these names — a new bounded_put call site without a registration (or
#: a silently dropped decoration) fails the audit
EXPECTED_CACHES: Tuple[str, ...] = (
    "program_cache",            # ProgramCache.program
    "fused_program_cache",      # ProgramCache.fused_program
    # the shard_map-wrapped fused form (ISSUE 8): keyed additionally by
    # the mesh axes the partition_fused transform closes over, because
    # the same bucket on a differently-shaped mesh compiles differently
    "sharded_fused_program_cache",  # ProgramCache.sharded_fused_program
    "block_layouts",            # compile/program.py::_request_block_layout
    "block_tensors",            # compile/program.py::_block_tensors
    "fold_in_key_tables",       # serverless/backends.py::_segment_key_table
    "work_request_index_maps",  # serverless/backends.py::_index_maps
    "page_pool_stacks",         # compile/pages.py::PagePool.stack
    "plan_pages",               # compile/buckets.py::MegabatchPlan.page
    "persistent_program_cache",  # compile/persist.py::PersistentProgramCache.lookup
    # the process-wide L1 over the disk tier shares the same triple key;
    # its single insert site (PersistentProgramCache._process_put) is
    # what lookup() and store() both remember through
    "persistent_program_cache_process_tier",
    # the in-mesh axis-executor programs (ISSUE 9): one jitted
    # shard_map program per (mesh, mesh_axis, family, params) —
    # standalone Gram form and the drain's bucket fit-predict form
    # share each cache
    "data_gram_programs",       # sharding/gram.py::_data_gram_fn
    "feature_gram_programs",    # sharding/gram.py::_feature_gram_fn
)

#: the persistent program cache outlives the process, so its key must
#: pin everything that can differ between two processes sharing the
#: cache directory: the jax build (serialized executables are not
#: portable across versions), the backend platform (an executable
#: compiled for one device kind is wrong on another), and the program
#: fingerprint (shapes, dtypes, learner spec, x64 mode)
PERSIST_KEY_COMPONENTS: Tuple[str, ...] = (
    "build", "platform", "fingerprint")


def _covered(chain: str, paths: Sequence[str]) -> bool:
    """A read chain is pinned if a declared path equals it, prefixes it
    (reading a sub-field of a keyed value), or extends it (reading an
    object whose sub-field is keyed — e.g. ``self.grid`` when
    ``self.grid.n_rep`` is a key component)."""
    for p in paths:
        if chain == p or chain.startswith(p + ".") \
                or p.startswith(chain + "."):
            return True
    return False


def _check_contract(rel: str, qual: str, fn: ast.FunctionDef,
                    kwargs: Dict) -> List[Finding]:
    where = f"{rel}:{fn.lineno}"
    findings: List[Finding] = []
    key = tuple(kwargs.get("key", ()))
    reads = tuple(kwargs.get("reads", ()))
    covers = {k: tuple(v) for k, v in dict(kwargs.get("covers",
                                                      {})).items()}
    ambient = tuple(kwargs.get("ambient", ()))
    declared = key + reads + ambient

    # structural sanity of the contract itself
    for ck in covers:
        if ck not in key:
            findings.append(Finding(
                "cache-keys", "cover-not-a-key", where,
                f"{qual}: covers[{ck!r}] is not a declared key path"))
    covered_reads: Set[str] = set()
    for vals in covers.values():
        covered_reads.update(vals)
    for r in reads:
        if r not in covered_reads:
            findings.append(Finding(
                "cache-keys", "unjustified-read", where,
                f"{qual}: read {r!r} is not pinned by any key component "
                "(add it to covers with the key path that determines "
                "it, or extend the key)"))

    # every parameter must be accounted for
    params = [p for p in astutil.func_params(fn)]
    for p in params:
        if not _covered(p, declared):
            findings.append(Finding(
                "cache-keys", "unkeyed-parameter", where,
                f"{qual}: parameter {p!r} is neither a key component, "
                "a declared read, nor ambient — its value can change "
                "the cached result without changing the cache key"))

    # every attribute chain the body reads must be pinned
    for chain in sorted(astutil.attribute_reads(fn, set(params))):
        if not _covered(chain, declared):
            findings.append(Finding(
                "cache-keys", "uncovered-read", where,
                f"{qual}: reads {chain} but the cache key does not "
                "cover it — a stale hit can serve results computed "
                "from different contents (declare it in key, or in "
                "reads + covers with justification)"))
    return findings


def _check_content_key(tree: ast.Module, rel: str) -> List[Finding]:
    """``DMLData.content_key`` must fingerprint every role in
    ``_ROLES`` — dropping one array re-creates the PR 5 staleness bug."""
    findings: List[Finding] = []
    roles: Optional[Tuple[str, ...]] = None
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == "_ROLES"
                        for t in node.targets):
            try:
                roles = tuple(ast.literal_eval(node.value))
            except (ValueError, SyntaxError):
                pass
    if roles is None:
        return [Finding("cache-keys", "content-key-covers-roles", rel,
                        "_ROLES literal not found in core/spec.py")]
    for qual, fn in astutil.iter_functions(tree):
        if qual != "DMLData.content_key":
            continue
        # the iteration must range over the bare _ROLES name itself
        # (a slice/subset evades a mere name-presence check), or spell
        # out every role literally — a hardcoded subset fails
        iters = [n.iter for n in ast.walk(fn)
                 if isinstance(n, (ast.For, ast.comprehension))]
        bare = any(isinstance(i, ast.Name) and i.id == "_ROLES"
                   for i in iters)
        lits = {n.value for n in ast.walk(fn)
                if isinstance(n, ast.Constant) and isinstance(n.value,
                                                              str)}
        if bare or set(roles) <= lits:
            return findings
        missing = sorted(set(roles) - lits)
        findings.append(Finding(
            "cache-keys", "content-key-covers-roles",
            f"{rel}:{fn.lineno}",
            f"DMLData.content_key does not fingerprint roles {missing} "
            "— two datasets differing only in those arrays would share "
            "every content-keyed cache entry"))
        return findings
    findings.append(Finding(
        "cache-keys", "content-key-covers-roles", rel,
        "DMLData.content_key not found"))
    return findings


def _check_work_key(tree: ast.Module, rel: str) -> List[Finding]:
    """``compile_request``'s ``work_key`` must be built from
    ``data.content_key()`` — ``fingerprint()`` keys only the X page."""
    for qual, fn in astutil.iter_functions(tree):
        if qual != "compile_request":
            continue
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Assign)
                    and any(isinstance(t, ast.Name)
                            and t.id == "work_key"
                            for t in node.targets)):
                continue
            calls = [astutil.call_name(c) for c in ast.walk(node.value)
                     if isinstance(c, ast.Call)]
            calls = [c for c in calls if c is not None]
            if any(c.endswith(".content_key") for c in calls):
                return []
            return [Finding(
                "cache-keys", "work-key-uses-content-key",
                f"{rel}:{node.lineno}",
                "compile_request builds work_key without "
                "data.content_key() — the stacked-block-tensor cache "
                "would collide across datasets sharing one X (the "
                "exact PR 5 staleness bug)")]
        return [Finding(
            "cache-keys", "work-key-uses-content-key", rel,
            "compile_request no longer assigns work_key — migrate this "
            "check to wherever the provenance key is now built")]
    return [Finding(
        "cache-keys", "work-key-uses-content-key", rel,
        "compile_request not found in core/session.py")]


def run(root: Optional[Path] = None) -> List[Finding]:
    root = root or astutil.default_root()
    findings: List[Finding] = []
    registered: Dict[str, Tuple[str, str]] = {}

    for path in astutil.iter_py_files(root):
        rel = path.relative_to(root).as_posix()
        if rel.startswith("analysis/"):
            continue                    # the auditor itself holds no caches
        tree = astutil.parse(path)

        decorated_quals: Set[str] = set()
        for qual, fn in astutil.iter_functions(tree):
            dec = astutil.decorator_call(fn, "warm_cache")
            if dec is None:
                continue
            decorated_quals.add(qual)
            try:
                kwargs = astutil.literal_kwargs(dec)
            except ValueError as e:
                findings.append(Finding(
                    "cache-keys", "non-literal-contract",
                    f"{rel}:{fn.lineno}", f"{qual}: {e}"))
                continue
            name = kwargs.get("name")
            if not isinstance(name, str):
                findings.append(Finding(
                    "cache-keys", "non-literal-contract",
                    f"{rel}:{fn.lineno}",
                    f"{qual}: @warm_cache needs a literal name="))
                continue
            if name in registered:
                findings.append(Finding(
                    "cache-keys", "duplicate-cache-name",
                    f"{rel}:{fn.lineno}",
                    f"cache {name!r} already registered at "
                    f"{registered[name][0]} ({registered[name][1]})"))
            registered[name] = (rel, qual)
            findings.extend(_check_contract(rel, qual, fn, kwargs))
            if name.startswith("persistent_program_cache"):
                key = tuple(kwargs.get("key", ()))
                missing = [c for c in PERSIST_KEY_COMPONENTS
                           if c not in key]
                if missing:
                    findings.append(Finding(
                        "cache-keys", "persist-key-components",
                        f"{rel}:{fn.lineno}",
                        f"{qual}: persistent (cross-process) cache key "
                        f"is missing {missing} — a shared cache dir "
                        "would serve executables across jax builds, "
                        "backend platforms, or program shapes"))

        # every bounded_put insertion must sit inside a registered cache
        for qual, lineno, callee in astutil.module_calls(tree):
            if callee.rsplit(".", 1)[-1] != "bounded_put":
                continue
            outer = qual.split(".<locals>", 1)[0]
            if qual not in decorated_quals and outer not in \
                    decorated_quals:
                findings.append(Finding(
                    "cache-keys", "unregistered-bounded-put",
                    f"{rel}:{lineno}",
                    f"{qual} inserts into a bounded cache without a "
                    "@warm_cache contract"))

        if rel == "core/spec.py":
            findings.extend(_check_content_key(tree, rel))
        if rel == "core/session.py":
            findings.extend(_check_work_key(tree, rel))

    for name in EXPECTED_CACHES:
        if name not in registered:
            findings.append(Finding(
                "cache-keys", "missing-cache", name,
                "expected warm cache is not registered with "
                "@warm_cache — if it was removed, update "
                "EXPECTED_CACHES; if renamed, keep the registry name "
                "stable"))
    return findings
