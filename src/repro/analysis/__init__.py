"""repro.analysis — static determinism, cache-key, and async-protocol
auditor (ISSUE 6 tentpole).

The repo's correctness story rests on three machine-checkable invariant
families that example-based parity tests enforce only by sampling:

  1. **Jaxpr determinism** (``jaxpr_audit``): fused launches must lower
     through ``lax.map``/``scan`` — never a vmap-batched leading axis
     over reductions — with no data-dependent shapes, and every PRNG
     operand reachable only from the compile-time ``fold_in`` key
     tables, never from runtime data.
  2. **Cache-key soundness** (``cache_keys``): every bounded warm cache
     (programs, fold_in key tables, index maps, block layouts, block
     tensors, page stacks) is registered via ``@warm_cache`` and its
     declared key provably covers every field the cached computation
     reads — a missing-``content_key``-array bug is a lint failure, not
     a stale-result heisenbug.
  3. **Async protocol** (``protocol``): the TaskLedger / DispatchQueue /
     PendingBucket state machine is an explicit transition table; every
     call site in the serverless layer performs only legal transitions.
     The same table drives the opt-in ``REPRO_SANITIZE=1`` runtime
     sanitizer (serverless/sanitize.py).

Run ``python -m repro.analysis`` (add ``--dead-code`` for the
import-graph report).  Each pass returns ``Finding`` records; an empty
list is a clean audit.  CI runs the auditor in the ``lint`` job and the
sanitizer across the async/topology suites in the ``sanitize`` job.
"""
from __future__ import annotations

from repro.analysis.registry import REGISTRY, WarmCacheSpec, warm_cache
from repro.analysis.report import Finding

__all__ = ["Finding", "warm_cache", "WarmCacheSpec", "REGISTRY"]
