"""Shared pure-AST helpers for the static passes.

Every static pass (cache_keys, protocol, deadcode) analyzes **source
text**, never imported modules: the mutation regression tests run the
passes against deliberately-broken copies of the tree in a tmp dir, and
importing mutated hot-path code would be both slow and unsafe.  All
helpers therefore operate on ``ast`` nodes parsed from files under a
caller-supplied source root (defaulting to the installed ``src/repro``).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Set, Tuple


def default_root() -> Path:
    """The ``src/repro`` tree this installed package was loaded from."""
    return Path(__file__).resolve().parents[1]


def iter_py_files(root: Path) -> Iterator[Path]:
    for p in sorted(root.rglob("*.py")):
        yield p


def parse(path: Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


def iter_functions(tree: ast.Module,
                   ) -> Iterator[Tuple[str, ast.FunctionDef]]:
    """Yield ``(qualname, node)`` for every (async) function def, with
    class nesting reflected in the qualname (``Cls.meth``)."""
    def walk(node: ast.AST, prefix: str) -> Iterator:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from walk(child, f"{q}.")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
            else:
                yield from walk(child, prefix)
    yield from walk(tree, "")


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a pure Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """Dotted name of the callee (``q.push``, ``dispatch_bucket``).
    A chain interrupted by a subscript/call still reports its method
    leaf as ``?.leaf`` (``state.requests[0].ledger.record_failure(0)``
    -> ``?.record_failure``) so method-allowlist checks cannot be evaded
    by indexing."""
    cn = dotted(node.func)
    if cn is None and isinstance(node.func, ast.Attribute):
        return f"?.{node.func.attr}"
    return cn


def func_params(fn: ast.FunctionDef) -> List[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def attribute_reads(fn: ast.FunctionDef, roots: Set[str]) -> Set[str]:
    """Maximal dotted attribute chains rooted at ``roots`` read anywhere
    in the function body (nested defs included; their own parameters
    shadow outer roots and are excluded).

    ``req._index_maps()[0]`` contributes ``req._index_maps`` — method
    access counts as a read of that path, so cache contracts must either
    key it or justify it under ``covers``.  Simple aliases are followed:
    after ``g = self.grid``, a read of ``g.n_rep`` is reported as
    ``self.grid.n_rep`` (single-assignment approximation — good enough
    for lint; reassigned aliases may over- or under-report one chain).
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            src = dotted(node.value)
            if src is not None and src != node.targets[0].id:
                aliases[node.targets[0].id] = src

    def expand(chain: str) -> str:
        seen: Set[str] = set()
        while True:
            head, dot, rest = chain.partition(".")
            if head in seen or head not in aliases:
                return chain
            seen.add(head)
            chain = aliases[head] + (dot + rest if dot else "")

    out: Set[str] = set()

    def visit(node: ast.AST, roots: Set[str], parent_attr: bool) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            inner = roots - ({p.arg for p in node.args.args}
                             | {p.arg for p in node.args.kwonlyargs}
                             | ({node.args.vararg.arg}
                                if node.args.vararg else set())
                             | ({node.args.kwarg.arg}
                                if node.args.kwarg else set()))
            for child in ast.iter_child_nodes(node):
                visit(child, inner, False)
            return
        if isinstance(node, ast.Attribute):
            if not parent_attr:
                path = dotted(node)
                if path is not None:
                    path = expand(path)
                    if path.split(".", 1)[0] in roots:
                        out.add(path)
            visit(node.value, roots, True)
            return
        for child in ast.iter_child_nodes(node):
            visit(child, roots, False)

    for stmt in fn.body:
        visit(stmt, roots, False)
    return out


def decorator_call(fn: ast.FunctionDef, name: str) -> Optional[ast.Call]:
    """The ``@name(...)`` decorator Call node on ``fn``, if present
    (matches both ``name`` and ``mod.name`` spellings)."""
    for dec in fn.decorator_list:
        if isinstance(dec, ast.Call):
            cn = call_name(dec)
            if cn is not None and cn.split(".")[-1] == name:
                return dec
    return None


def literal_kwargs(call: ast.Call) -> Dict[str, object]:
    """Keyword arguments of a call evaluated as literals; non-literal
    values raise ValueError (contracts must be compile-time constants)."""
    out: Dict[str, object] = {}
    for kw in call.keywords:
        if kw.arg is None:
            raise ValueError("**kwargs not allowed in contract")
        try:
            out[kw.arg] = ast.literal_eval(kw.value)
        except (ValueError, SyntaxError) as e:
            raise ValueError(
                f"non-literal contract value for {kw.arg!r}") from e
    return out


def calls_in(fn: ast.FunctionDef) -> List[Tuple[int, str, ast.Call]]:
    """Every call in the body as ``(lineno, dotted_callee, node)`` in
    source order; calls with non-chain callees are skipped."""
    out: List[Tuple[int, str, ast.Call]] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            cn = call_name(node)
            if cn is not None:
                out.append((node.lineno, cn, node))
    out.sort(key=lambda t: t[0])
    return out


def module_calls(tree: ast.Module) -> List[Tuple[str, int, str]]:
    """Every call in a module as ``(enclosing_qualname, lineno,
    dotted_callee)``; module-level calls get qualname ``"<module>"``."""
    covered: Set[int] = set()
    out: List[Tuple[str, int, str]] = []
    for qual, fn in iter_functions(tree):
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and id(node) not in covered:
                covered.add(id(node))
                cn = call_name(node)
                if cn is not None:
                    out.append((qual, node.lineno, cn))
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and id(node) not in covered:
            cn = call_name(node)
            if cn is not None:
                out.append(("<module>", node.lineno, cn))
    return out


def imports_of(tree: ast.Module, module_name: str) -> Set[str]:
    """Absolute module names imported by a module (``import x.y`` and
    ``from x.y import z`` both contribute ``x.y``; relative imports are
    resolved against ``module_name``)."""
    out: Set[str] = set()
    pkg_parts = module_name.split(".")
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out.add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = pkg_parts[:-node.level] if node.level <= len(
                    pkg_parts) else []
                mod = ".".join(base + ([node.module] if node.module
                                       else []))
            else:
                mod = node.module or ""
            if mod:
                out.add(mod)
                for alias in node.names:
                    out.add(f"{mod}.{alias.name}")
    return out
