"""CLI: ``python -m repro.analysis [--dead-code] [--pass NAME]``.

Default run executes the three invariant passes (jaxpr determinism,
cache-key soundness, async protocol) and exits nonzero on any finding;
``--dead-code`` runs the import-reachability report instead.  CI runs
both (jobs ``lint`` and ``sanitize`` in .github/workflows/ci.yml).
"""
from __future__ import annotations

import argparse
import sys
from typing import Callable, Dict, List

from repro.analysis.report import Finding, render


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="static determinism / cache-key / protocol auditor")
    ap.add_argument("--dead-code", action="store_true",
                    help="run the import-reachability report instead of "
                         "the invariant passes")
    ap.add_argument("--pass", dest="only", default=None,
                    choices=("jaxpr", "cache-keys", "protocol"),
                    help="run a single invariant pass")
    args = ap.parse_args(argv)

    passes: Dict[str, Callable[[], List[Finding]]] = {}
    if args.dead_code:
        from repro.analysis import deadcode
        passes["dead-code"] = deadcode.run
    else:
        if args.only in (None, "cache-keys"):
            from repro.analysis import cache_keys
            passes["cache-keys"] = cache_keys.run
        if args.only in (None, "protocol"):
            from repro.analysis import protocol
            passes["protocol"] = protocol.run
        if args.only in (None, "jaxpr"):
            # imported last: jax init is the slow part
            from repro.analysis import jaxpr_audit
            passes["jaxpr"] = jaxpr_audit.run

    findings: List[Finding] = []
    for name, fn in passes.items():
        got = fn()
        status = "OK" if not got else f"{len(got)} finding(s)"
        print(f"[{name}] {status}")
        findings.extend(got)
    if findings:
        print()
        print(render(findings, header=f"{len(findings)} finding(s):"))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
