"""The ``@warm_cache`` registry: declared key/read contracts for every
bounded warm cache on the hot path.

PR 5's ``work_key`` staleness bug — stacked block tensors cached under a
key that fingerprinted only the X page while the cached computation read
the full data content — is the exact bug class this registry turns into
a lint failure.  Every warm cache decorates its accessor with the fields
its key is built from (``key``), the additional fields the cached
computation reads (``reads``), and the justification map tying each read
to the key component that pins it (``covers``).  The static pass
(``analysis/cache_keys.py``) then AST-checks the decorated body: an
attribute read on a cache-relevant argument that is neither a key
component, a declared read, nor ambient state scoped to the cache's own
lifetime fails the audit — so adding a read without extending the key
(or consciously documenting why the key already pins it) cannot land.

Cross-process caches raise the bar: a cache whose entries outlive the
process (the on-disk ``persistent_program_cache``, compile/persist.py)
must additionally key everything that can differ between two processes
sharing the store — toolchain build, backend platform, and a full
program fingerprint — because no in-memory ambient state survives to
disambiguate entries.  ``cache_keys.py`` enforces those components by
name for the persistent cache.

This module is imported by hot-path runtime code (compile/, serverless/)
and therefore has **no repro-internal imports** (no cycle risk) and no
runtime cost beyond attaching metadata.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping, Sequence, Tuple, TypeVar

F = TypeVar("F", bound=Callable)


@dataclass(frozen=True)
class WarmCacheSpec:
    """Declared caching contract of one warm cache accessor.

    ``key``     dotted parameter paths the cache key is computed from
                (e.g. ``"req.work_key"``, ``"n_pad"``).
    ``reads``   parameter paths the cached computation reads that are
                NOT key components — each must be covered below.
    ``covers``  key path -> read paths it pins, with the justification
                recorded where the declaration lives (code comment).
    ``ambient`` paths (or whole roots like ``"self"``) exempt from the
                coverage check because the cache dict itself is scoped
                to that object's lifetime — e.g. a per-instance program
                cache may read instance configuration freely.
    """
    name: str
    key: Tuple[str, ...]
    reads: Tuple[str, ...] = ()
    covers: Mapping[str, Tuple[str, ...]] = field(default_factory=dict)
    ambient: Tuple[str, ...] = ()
    module: str = ""
    qualname: str = ""


#: runtime registry (introspection / docs); the static pass re-derives
#: the same specs from source so mutation tests can audit unimported
#: file trees.
REGISTRY: Dict[str, WarmCacheSpec] = {}


def warm_cache(*, name: str, key: Sequence[str],
               reads: Sequence[str] = (),
               covers: Mapping[str, Sequence[str]] | None = None,
               ambient: Sequence[str] = ()) -> Callable[[F], F]:
    """Register a warm-cache accessor's caching contract (metadata only —
    the wrapped function is returned unchanged)."""
    def deco(fn: F) -> F:
        spec = WarmCacheSpec(
            name=name, key=tuple(key), reads=tuple(reads),
            covers={k: tuple(v) for k, v in (covers or {}).items()},
            ambient=tuple(ambient),
            module=getattr(fn, "__module__", ""),
            qualname=getattr(fn, "__qualname__", ""))
        REGISTRY[name] = spec
        fn.__warm_cache__ = spec  # type: ignore[attr-defined]
        return fn
    return deco
