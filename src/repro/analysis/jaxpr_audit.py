"""Pass 1 — jaxpr-level determinism audit of the megabatch programs.

The float-pinning contract (PR 5) says a fused launch is **bitwise**
equal to per-block launches because ``lax.map`` compiles the mapped body
exactly as the single-block program — where ``vmap`` would add a batched
leading axis that lets XLA retile the per-lane reductions (~1e-7
drift).  The parity tests check this by example on sampled inputs; this
pass checks it structurally on the closed jaxpr, for every learner
family and program form the ``ProgramCache`` can build:

  * **fused-lowers-through-scan** — the fused program's top-level jaxpr
    must be exactly one ``scan`` equation (``lax.map`` is scan with no
    carry); any other top-level primitive means a batched lowering
    leaked in.
  * **fused-body-equals-block** — the scan body's primitive sequence
    must equal the single-block program's primitive sequence: the
    mapped body IS the per-block computation, so fused results cannot
    drift from per-block ones.
  * **sharded-wraps-shard-map** — the partitioned form must lower
    through one ``shard_map`` whose body passes the same PRNG/shape
    audit (sharded parity is tolerance-level by contract, so body
    equality is not required there).
  * **sharded-fused-wraps-scan** — the sharded-fused form (ISSUE 8:
    partitioned caches now fuse) must lower through exactly one
    ``shard_map`` whose body is exactly one ``scan`` whose body's
    primitive sequence equals the single-block program: the shard only
    splits the task axis, so each device runs the identical fused
    ``lax.map`` over its B/m lane slice.  This pins the *structure*;
    numeric parity vs the unsharded fused launch is bitwise on a
    1-device mesh and the ~1e-6 sharded float tier on m-way meshes
    (compiled-B retiling below 16 lanes — see the B_BLOCK caveat in
    compile/program.py).
  * **data-axis-wraps-shard-map / data-axis-psums-moments** — the
    in-mesh data@m drain program (ISSUE 9, sharding/gram.py) must be
    one ``shard_map`` whose body reassembles the per-shard partial
    (G, b, nw) moments by ``psum`` and never ``all_gather``s: the N
    split exists to keep rows local, so only O(P^2) statistics may
    cross the wire.
  * **feature-axis-wraps-shard-map / feature-axis-gathers-rows** — the
    in-mesh feature@m program must be one ``shard_map`` whose body
    ``all_gather``s the row matrix (the wire term the axis planner
    prices): a gather-free body means each shard contracted only its
    own columns and the cross-column Gram blocks are wrong.
  * **prng-key-from-runtime-data** — taint analysis over the jaxpr:
    primitives that consume PRNG keys may only be reached from the
    ``key_data`` input (the compile-time ``fold_in`` tables), never
    from the data inputs — a learner that derived randomness from its
    batch would break schedule invariance.
  * **data-dependent-shape** — every intermediate aval must have
    concrete integer dimensions; a data-dependent shape would make the
    compiled program's output depend on bucket composition.
  * **morph-classified** — every family must be classified by the
    cross-shape coalescer (ISSUE 7): in ``MORPH_BITWISE_FAMILIES``
    (bitwise-proven B-invariant, morph freely) or
    ``MORPH_TOLERANCE_FAMILIES`` (morph only under an explicit opt-in
    tolerance on ``PoolConfig``), never silently unclassified — and the
    two sets must be disjoint.
  * **morph-structural-b-pin** — a bitwise-morphable family's program
    must trace to the identical primitive sequence at two different B
    paddings: padding a tail block up to a neighbor's canonical B may
    never change the computation's structure, only its lane count.

Unlike the other passes this one imports jax and the learner registry —
it audits what actually traces, not what the source says.
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.report import Finding
from repro.learners import get_batched_learner, resolve_params

#: the six registry families (kept literal so a silently dropped
#: registry entry fails the audit instead of shrinking its coverage)
FAMILIES: Tuple[str, ...] = ("ols", "ridge", "lasso", "logistic",
                             "kernel_ridge", "mlp")

#: primitives that consume or produce PRNG state
PRNG_PRIMS: Set[str] = {
    "random_wrap", "random_unwrap", "random_seed", "random_bits",
    "random_fold_in", "random_gamma", "threefry2x32",
}

# probe shape: small but structurally faithful (B tasks, N rows, P
# features, G fused blocks).  Tracing only — nothing is compiled or run.
_B, _N, _P, _G = 8, 32, 8, 3


# ---------------------------------------------------------------------------
# taint analysis over (nested) jaxprs
# ---------------------------------------------------------------------------
def _unwrap(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


def _taint_jaxpr(jaxpr, invar_marks: List[Set[str]], where: str,
                 findings: List[Finding], depth: int = 0) -> None:
    """Propagate {"data", "key"} marks through one jaxpr, flagging PRNG
    primitives that touch data-derived values.  Sub-jaxprs with a known
    1:1 invar mapping (pjit, scan, shard_map, call-like) are recursed
    with per-position marks; unknown higher-order primitives union-taint
    their outputs without recursing (conservative, no false positives).
    """
    if depth > 32:
        return
    marks: Dict[int, Set[str]] = {}
    for var, m in zip(jaxpr.invars, invar_marks):
        marks[id(var)] = set(m)
    for var in jaxpr.constvars:
        marks[id(var)] = set()

    def of(atom) -> Set[str]:
        return marks.get(id(atom), set())

    for eqn in jaxpr.eqns:
        in_marks: Set[str] = set()
        for a in eqn.invars:
            in_marks |= of(a)
        pname = eqn.primitive.name

        if pname in PRNG_PRIMS:
            bad = sorted({m for a in eqn.invars for m in of(a)
                          if m == "data"})
            if bad:
                findings.append(Finding(
                    "jaxpr", "prng-key-from-runtime-data",
                    where,
                    f"primitive {pname!r} consumes a value derived "
                    "from the data inputs — PRNG state must derive "
                    "only from the compile-time fold_in key tables"))
            in_marks = in_marks | {"key"}

        # recurse into sub-jaxprs whose invars map 1:1 onto eqn.invars
        params = eqn.params
        subs: List[Tuple[object, List[Set[str]]]] = []
        eq_marks = [of(a) for a in eqn.invars]
        if pname in ("pjit", "scan", "shard_map", "closed_call",
                     "core_call", "xla_call", "remat", "checkpoint",
                     "custom_jvp_call", "custom_vjp_call"):
            sub = params.get("jaxpr") or params.get("call_jaxpr")
            if sub is not None:
                sub = _unwrap(sub)
                if len(sub.invars) == len(eqn.invars):
                    subs.append((sub, eq_marks))
        elif pname == "cond":
            for br in params.get("branches", ()):
                sub = _unwrap(br)
                if len(sub.invars) == len(eqn.invars) - 1:
                    subs.append((sub, eq_marks[1:]))
        elif pname == "while":
            cn = params.get("cond_nconsts", 0)
            bn = params.get("body_nconsts", 0)
            body = _unwrap(params.get("body_jaxpr"))
            cond = _unwrap(params.get("cond_jaxpr"))
            if body is not None:
                subs.append((body, eq_marks[cn:]))
            if cond is not None:
                subs.append((cond, eq_marks[:cn] + eq_marks[cn + bn:]))
        for sub, sub_marks in subs:
            _taint_jaxpr(sub, sub_marks, where, findings, depth + 1)

        shaped = [v for v in eqn.outvars if hasattr(v, "aval")]
        for v in shaped:
            aval = v.aval
            dims = getattr(aval, "shape", ())
            if not all(isinstance(d, int) for d in dims):
                findings.append(Finding(
                    "jaxpr", "data-dependent-shape", where,
                    f"primitive {pname!r} produces aval {aval} with a "
                    "non-concrete dimension — compiled shapes must be "
                    "pure functions of the bucket spec"))
            marks[id(v)] = set(in_marks)


# ---------------------------------------------------------------------------
# program forms
# ---------------------------------------------------------------------------
def _probe_avals(fused: bool, b: int = _B):
    kw = jax.random.key_data(jax.random.key(0)).shape
    lead = (_G,) if fused else ()
    f32, i32, u32 = jnp.float32, jnp.int32, jnp.uint32
    return (jax.ShapeDtypeStruct((1, _N, _P), f32),          # pages
            jax.ShapeDtypeStruct(lead + (b,), i32),          # data_idx
            jax.ShapeDtypeStruct(lead + (b, _N), f32),       # y
            jax.ShapeDtypeStruct(lead + (b, _N), f32),       # w
            jax.ShapeDtypeStruct(lead + (b, _N), f32),       # valid
            jax.ShapeDtypeStruct(lead + (b,) + kw, u32))     # key_data


def _program_pair(family: str):
    """(single-block run, lax.map-fused run) for one learner family —
    the exact bodies ``ProgramCache.program`` / ``fused_program`` jit."""
    params = resolve_params(family, None, n_obs=_N, dim_x=_P)
    batched_fn = get_batched_learner(family, params)

    def run(pages, data_idx, y, w, valid, key_data):
        xb = pages[data_idx]
        keys = jax.random.wrap_key_data(key_data)
        return batched_fn(xb, y, w, valid, keys)

    def run_fused(pages, data_idx, y, w, valid, key_data):
        return jax.lax.map(lambda t: run(pages, *t),
                           (data_idx, y, w, valid, key_data))

    return run, run_fused


def _prim_seq(jaxpr) -> List[str]:
    return [e.primitive.name for e in jaxpr.eqns]


def audit_fused_pair(single_jaxpr, fused_jaxpr, where: str,
                     ) -> List[Finding]:
    """The structural fused-launch checks, factored out so the mutation
    tests can feed a deliberately vmap-built fused program."""
    findings: List[Finding] = []
    top = _prim_seq(fused_jaxpr.jaxpr)
    if top != ["scan"]:
        findings.append(Finding(
            "jaxpr", "fused-lowers-through-scan", where,
            f"fused program's top-level jaxpr is {top} — must be "
            "exactly one scan (lax.map); a vmap-batched lowering lets "
            "XLA retile reductions and breaks bitwise block parity"))
        return findings
    body = _unwrap(fused_jaxpr.jaxpr.eqns[0].params["jaxpr"])
    if _prim_seq(body) != _prim_seq(single_jaxpr.jaxpr):
        findings.append(Finding(
            "jaxpr", "fused-body-equals-block", where,
            "fused scan body's primitive sequence differs from the "
            "single-block program — the mapped body must compile to "
            "exactly the per-block computation"))
    return findings


def audit_sharded_fused(single_jaxpr, sharded_fused_jaxpr, where: str,
                        ) -> List[Finding]:
    """Structural checks for the sharded-fused form (ISSUE 8): one
    shard_map, whose body is one scan, whose body is the single-block
    program.  Factored out (like ``audit_fused_pair``) so the mutation
    tests can feed a deliberately vmap-built body and watch it fail."""
    findings: List[Finding] = []
    tops = _prim_seq(sharded_fused_jaxpr.jaxpr)
    if tops != ["shard_map"]:
        findings.append(Finding(
            "jaxpr", "sharded-fused-wraps-scan", where,
            f"sharded-fused program's top-level jaxpr is {tops} — must "
            "be exactly one shard_map so the partition only splits the "
            "task axis"))
        return findings
    body = _unwrap(sharded_fused_jaxpr.jaxpr.eqns[0].params["jaxpr"])
    inner = _prim_seq(body)
    if inner != ["scan"]:
        findings.append(Finding(
            "jaxpr", "sharded-fused-wraps-scan", where,
            f"shard_map body's primitive sequence is {inner} — must be "
            "exactly one scan (lax.map); a vmap-batched body inside the "
            "shard would retile reductions and break the bitwise "
            "sharded-fused contract"))
        return findings
    scan_body = _unwrap(body.eqns[0].params["jaxpr"])
    if _prim_seq(scan_body) != _prim_seq(single_jaxpr.jaxpr):
        findings.append(Finding(
            "jaxpr", "sharded-fused-wraps-scan", where,
            "sharded-fused scan body's primitive sequence differs from "
            "the single-block program — each device's fused lanes must "
            "compile to exactly the per-block computation"))
    return findings


def _sub_jaxprs(eqn):
    """Every sub-jaxpr an equation's params reference (pjit/scan bodies,
    cond branches, ...), unwrapped."""
    for v in eqn.params.values():
        for s in (v if isinstance(v, (tuple, list)) else (v,)):
            s = _unwrap(s)
            if hasattr(s, "eqns"):
                yield s


def _all_prims(jaxpr, depth: int = 0) -> List[str]:
    """Every primitive name in a jaxpr, recursing through sub-jaxprs."""
    if depth > 32:
        return []
    out: List[str] = []
    for eqn in jaxpr.eqns:
        out.append(eqn.primitive.name)
        for sub in _sub_jaxprs(eqn):
            out.extend(_all_prims(sub, depth + 1))
    return out


def audit_data_axis(fit_jaxpr, where: str) -> List[Finding]:
    """Structural checks for the data@m in-mesh fit program (ISSUE 9):
    one shard_map whose body reassembles the per-shard partial moments
    by ``psum`` — never by gathering rows.  Factored out so the mutation
    tests can feed a deliberately broken lowering."""
    findings: List[Finding] = []
    top = _prim_seq(fit_jaxpr.jaxpr)
    if top != ["shard_map"]:
        findings.append(Finding(
            "jaxpr", "data-axis-wraps-shard-map", where,
            f"data-axis fit program's top-level jaxpr is {top} — must "
            "be exactly one shard_map so the layout only splits the N "
            "axis"))
        return findings
    prims = _all_prims(_unwrap(fit_jaxpr.jaxpr.eqns[0].params["jaxpr"]))
    if "psum" not in prims:
        findings.append(Finding(
            "jaxpr", "data-axis-psums-moments", where,
            "data-axis fit body contains no psum — each shard's partial "
            "(G, b, nw) moments are never reassembled into the full-N "
            "statistics, so every device would solve on its rows only"))
    if "all_gather" in prims:
        findings.append(Finding(
            "jaxpr", "data-axis-psums-moments", where,
            "data-axis fit body all-gathers — the N split must move "
            "only O(P^2) moments (psum), never replicate the rows it "
            "exists to shard"))
    return findings


def audit_feature_axis(fit_jaxpr, where: str) -> List[Finding]:
    """Structural checks for the feature@m in-mesh fit program
    (ISSUE 9): one shard_map whose body all-gathers — the row-matrix
    wire term the axis planner prices; a gather-free body means each
    shard contracted only its own columns and the cross-column Gram
    blocks are wrong."""
    findings: List[Finding] = []
    top = _prim_seq(fit_jaxpr.jaxpr)
    if top != ["shard_map"]:
        findings.append(Finding(
            "jaxpr", "feature-axis-wraps-shard-map", where,
            f"feature-axis fit program's top-level jaxpr is {top} — "
            "must be exactly one shard_map so the layout only splits "
            "the P axis"))
        return findings
    prims = _all_prims(_unwrap(fit_jaxpr.jaxpr.eqns[0].params["jaxpr"]))
    if "all_gather" not in prims:
        findings.append(Finding(
            "jaxpr", "feature-axis-gathers-rows", where,
            "feature-axis fit body contains no all_gather — the column "
            "split needs the full row matrix (the priced wire term) to "
            "form its (P, P/m) Gram block; without it the cross-column "
            "blocks are computed from the wrong operand"))
    return findings


def audit_axis_programs() -> List[Finding]:
    """Trace the two in-mesh drain forms (sharding/gram.py fit bodies
    under shard_map, ISSUE 9) for every Gram family and run the
    structural axis pins plus the PRNG/shape audit on each."""
    from repro.launch.mesh import make_host_mesh
    from repro.launch.roofline import GRAM_FAMILIES
    from repro.sharding.compat import shard_map_compat
    from repro.sharding.gram import _data_fit_body, _feature_fit_body
    from jax.sharding import PartitionSpec as P

    findings: List[Finding] = []
    mesh = make_host_mesh()
    avals = _probe_avals(fused=False)
    for family in GRAM_FAMILIES:
        params = tuple(sorted(resolve_params(
            family, None, n_obs=_N, dim_x=_P).items()))
        data_fn = shard_map_compat(
            _data_fit_body("data", family, params), mesh=mesh,
            in_specs=(P(None, "data", None), P(None), P(None, "data"),
                      P(None, "data"), P(None, "data"), P(None, None)),
            out_specs=P(None, "data"))
        data = jax.make_jaxpr(data_fn)(*avals)
        findings.extend(audit_data_axis(data, f"{family}/data-axis"))
        _taint_jaxpr(data.jaxpr, _data_key_marks(data.jaxpr),
                     f"{family}/data-axis", findings)

        feat_fn = shard_map_compat(
            _feature_fit_body("data", family, params), mesh=mesh,
            in_specs=(P(None, None, "data"), P(None), P(None, None),
                      P(None, None), P(None, None), P(None, None)),
            out_specs=P(None, None))
        feat = jax.make_jaxpr(feat_fn)(*avals)
        findings.extend(audit_feature_axis(feat,
                                           f"{family}/feature-axis"))
        _taint_jaxpr(feat.jaxpr, _data_key_marks(feat.jaxpr),
                     f"{family}/feature-axis", findings)
    return findings


def _data_key_marks(jaxpr) -> List[Set[str]]:
    """Input marks for the program signature: everything but the
    trailing key_data operand is runtime data."""
    n = len(jaxpr.invars)
    return [{"data"}] * (n - 1) + [{"key"}]


def audit_morph_classification() -> List[Finding]:
    """Every learner family must be placed by the cross-shape coalescer:
    bitwise-morphable or tolerance-gated, never silently unclassified —
    an unclassified family would quietly opt out of tail coalescing and
    shrink the launch-efficiency win without any test noticing."""
    from repro.compile.program import (MORPH_BITWISE_FAMILIES,
                                       MORPH_TOLERANCE_FAMILIES)
    findings: List[Finding] = []
    both = MORPH_BITWISE_FAMILIES & MORPH_TOLERANCE_FAMILIES
    if both:
        findings.append(Finding(
            "jaxpr", "morph-classified", "compile/program.py",
            f"families {sorted(both)} are in BOTH morph sets — bitwise "
            "and tolerance-gated are mutually exclusive contracts"))
    for family in FAMILIES:
        if family not in MORPH_BITWISE_FAMILIES \
                and family not in MORPH_TOLERANCE_FAMILIES:
            findings.append(Finding(
                "jaxpr", "morph-classified", f"{family}/morph",
                f"family {family!r} is in neither MORPH_BITWISE_FAMILIES "
                "nor MORPH_TOLERANCE_FAMILIES — classify it (prove "
                "bitwise B-invariance or register the tolerance tier) "
                "so the coalescer's behavior is an explicit contract"))
    return findings


def audit_family(family: str) -> List[Finding]:
    findings: List[Finding] = []
    run, run_fused = _program_pair(family)

    single = jax.make_jaxpr(run)(*_probe_avals(fused=False))
    fused = jax.make_jaxpr(run_fused)(*_probe_avals(fused=True))

    # structural B-pin: a morphable family's primitive sequence may not
    # depend on the B padding (the bitwise proof's structural shadow)
    from repro.compile.program import MORPH_BITWISE_FAMILIES
    if family in MORPH_BITWISE_FAMILIES:
        wide = jax.make_jaxpr(run)(*_probe_avals(fused=False, b=2 * _B))
        if _prim_seq(wide.jaxpr) != _prim_seq(single.jaxpr):
            findings.append(Finding(
                "jaxpr", "morph-structural-b-pin", f"{family}/morph",
                f"primitive sequence changes between B={_B} and "
                f"B={2 * _B} — a B-dependent computation cannot be "
                "bitwise-morphed; move the family to "
                "MORPH_TOLERANCE_FAMILIES or fix the learner"))

    findings.extend(audit_fused_pair(single, fused, f"{family}/fused"))
    _taint_jaxpr(single.jaxpr, _data_key_marks(single.jaxpr),
                 f"{family}/block", findings)
    _taint_jaxpr(fused.jaxpr, _data_key_marks(fused.jaxpr),
                 f"{family}/fused", findings)

    # the partitioned (ShardedBackend) form: shard_map over "data"
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.compat import shard_map_compat
    from repro.sharding.policy import megabatch_specs
    in_specs, out_specs = megabatch_specs("data")
    sharded_fn = shard_map_compat(run, mesh=make_host_mesh(),
                                  in_specs=in_specs, out_specs=out_specs)
    sharded = jax.make_jaxpr(sharded_fn)(*_probe_avals(fused=False))
    tops = _prim_seq(sharded.jaxpr)
    if "shard_map" not in tops:
        findings.append(Finding(
            "jaxpr", "sharded-wraps-shard-map", f"{family}/sharded",
            f"partitioned program's top-level jaxpr is {tops} — the "
            "sharded form must lower through shard_map"))
    _taint_jaxpr(sharded.jaxpr, _data_key_marks(sharded.jaxpr),
                 f"{family}/sharded", findings)

    # the sharded-FUSED form (ISSUE 8): shard_map around the lax.map
    # fused body, task axis sharded, pages replicated — the form
    # ProgramCache.sharded_fused_program jits for partitioned buckets
    fin_specs, fout_specs = megabatch_specs("data", fused=True)
    sharded_fused_fn = shard_map_compat(
        run_fused, mesh=make_host_mesh(),
        in_specs=fin_specs, out_specs=fout_specs)
    sharded_fused = jax.make_jaxpr(sharded_fused_fn)(
        *_probe_avals(fused=True))
    findings.extend(audit_sharded_fused(single, sharded_fused,
                                        f"{family}/sharded-fused"))
    _taint_jaxpr(sharded_fused.jaxpr,
                 _data_key_marks(sharded_fused.jaxpr),
                 f"{family}/sharded-fused", findings)
    return findings


def run(root=None) -> List[Finding]:
    """Audit every (family, program form); ``root`` is accepted for
    signature uniformity with the static passes and ignored."""
    findings: List[Finding] = []
    findings.extend(audit_morph_classification())
    for family in FAMILIES:
        findings.extend(audit_family(family))
    findings.extend(audit_axis_programs())
    return findings
