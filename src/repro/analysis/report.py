"""Finding records and report formatting shared by every analysis pass."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence


@dataclass(frozen=True)
class Finding:
    """One audit violation.

    ``passname`` names the pass ("jaxpr" | "cache-keys" | "protocol" |
    "dead-code"); ``rule`` the specific invariant (stable identifiers —
    CI logs and the mutation tests key on them); ``where`` the location
    (``file:line`` for static findings, ``family/form`` for traced
    ones); ``detail`` the human explanation.
    """
    passname: str
    rule: str
    where: str
    detail: str

    def __str__(self) -> str:
        return f"[{self.passname}] {self.rule} @ {self.where}: {self.detail}"


def render(findings: Sequence[Finding], header: str = "") -> str:
    lines: List[str] = []
    if header:
        lines.append(header)
    for f in findings:
        lines.append(f"  FAIL {f}")
    return "\n".join(lines)
