"""Pass 3 — the async drain protocol as an explicit, checked table.

The TaskLedger / DispatchQueue / PendingBucket machine was previously
documented only in prose (PR 3/5 docstrings).  This module declares it
as data — invocation states, legal ledger transitions, and the *sole*
call sites allowed to perform each protocol action — and statically
checks every audited file against the table.  The same table drives the
opt-in runtime sanitizer (``repro/serverless/sanitize.py``,
``REPRO_SANITIZE=1``), so the static allowlist and the live assertions
cannot drift apart.

The protocol (one bucket slice's life):

    PLANNED ──mark_running──▶ DISPATCHED ──harvest──▶ HARVESTED
        ──record_success(es)/record_failure──▶ BOOKED

    DISPATCHED ──hedge (overdue)──▶ HEDGED ──harvest──▶ HARVESTED
    DISPATCHED/HEDGED ──cancel (lost the race)──▶ CANCELLED (discarded)
    DISPATCHED/HEDGED ──abandon (host died)──▶ LOST (re-dispatched)

  * every ``dispatch_bucket`` launch is preceded by ``mark_running`` on
    its invocations (a checkpoint taken mid-flight must re-queue them);
  * a bucket is harvested exactly once, and only the dispatch queue (or
    the synchronous ``run_bucket`` wrapper) may harvest;
  * only the two booking functions may write ledger results — booking
    anywhere else would bypass billing, retry, and finalization;
  * schedulers must view pending work through
    ``pending_by_bucket(exclude=<in-flight>)`` so an invocation whose
    launch is on device is never dispatched twice (the one allowlisted
    exception is a pricing thunk that runs while the queue is empty);
  * a hedge race is settled by exactly ONE performer
    (``HedgePair.settle``): the winning leg books, the loser is
    cancelled and discarded through the same harvest-once flag, so no
    fault schedule can ever double-book or double-bill a bucket;
  * only ``TopologyBackend.kill_host`` may abandon a queue — LOST
    buckets' invocations stay RUNNING in the ledger and resurface via
    the pending view once the dead host's queue is gone.

The ROADMAP's multi-process topology item starts from this table: a
remote host stream must perform exactly these transitions over the wire.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.analysis import astutil
from repro.analysis.report import Finding

# ---------------------------------------------------------------------------
# the state machine, as data
# ---------------------------------------------------------------------------
#: invocation states (mirrors serverless/ledger.py PENDING..FAILED)
INVOCATION_STATES: Dict[str, int] = {
    "PENDING": 0, "RUNNING": 1, "DONE": 2, "FAILED": 3,
}

#: ledger method -> (legal source states, destination state).  RUNNING
#: is a legal source of mark_running (re-dispatch of orphaned rows) and
#: PENDING a legal source of the record methods (resume path: a loaded
#: ledger books rows the previous process had already computed).
LEDGER_TRANSITIONS: Dict[str, Tuple[Tuple[str, ...], str]] = {
    "mark_running": (("PENDING", "FAILED", "RUNNING"), "RUNNING"),
    "record_success": (("RUNNING", "PENDING"), "DONE"),
    "record_successes": (("RUNNING", "PENDING"), "DONE"),
    "record_failure": (("RUNNING",), "FAILED"),
}

#: bucket states (PendingBucket's life in a DispatchQueue)
BUCKET_STATES: Tuple[str, ...] = (
    "PLANNED", "DISPATCHED", "HARVESTED", "BOOKED",
    "HEDGED", "CANCELLED", "LOST")

#: bucket action -> (legal source states, destination state) — drives
#: the runtime sanitizer's check_hedge / check_cancel /
#: check_bucket_bookable hooks exactly as LEDGER_TRANSITIONS drives
#: check_booking, so the fault-tolerance path cannot drift from this
#: table.  "harvest" from HEDGED is the winning original leg;
#: CANCELLED/LOST are terminal (no legal outgoing transitions).
BUCKET_TRANSITIONS: Dict[str, Tuple[Tuple[str, ...], str]] = {
    "dispatch": (("PLANNED",), "DISPATCHED"),
    "harvest": (("DISPATCHED", "HEDGED"), "HARVESTED"),
    "book": (("HARVESTED",), "BOOKED"),
    "hedge": (("DISPATCHED",), "HEDGED"),
    "cancel": (("DISPATCHED", "HEDGED"), "CANCELLED"),
    "abandon": (("DISPATCHED", "HEDGED"), "LOST"),
}

# ---------------------------------------------------------------------------
# performer allowlists: (file relative to src/repro, function qualname)
# ---------------------------------------------------------------------------
#: files the static checker audits (relative to the source root)
AUDITED_FILES: Tuple[str, ...] = (
    "serverless/backends.py", "serverless/dispatch.py",
    "serverless/topology.py", "serverless/ledger.py",
    "serverless/chaos.py",
    "core/session.py", "compile/program.py", "compile/buckets.py",
)

#: the ONLY call sites allowed to write ledger results
BOOKING_PERFORMERS: FrozenSet[Tuple[str, str]] = frozenset({
    ("serverless/backends.py", "_StreamBackend._book_direct"),
    ("serverless/backends.py", "WaveBackend._book_request_wave"),
})
_BOOKING_METHODS = ("record_success", "record_successes", "record_failure")

#: the ONLY call sites allowed to harvest in-flight work
HARVEST_PERFORMERS: FrozenSet[Tuple[str, str]] = frozenset({
    ("serverless/dispatch.py", "DispatchQueue.push"),
    ("serverless/dispatch.py", "DispatchQueue._harvest"),
    ("serverless/dispatch.py", "DispatchQueue.harvest_ready"),
    ("serverless/dispatch.py", "DispatchQueue.harvest_next"),
    ("serverless/dispatch.py", "DispatchQueue.harvest_all"),
    ("serverless/backends.py", "_BucketStreamBackend.step"),
    ("serverless/backends.py", "WaveBackend.step"),
    ("serverless/topology.py", "TopologyBackend.step"),
    ("compile/program.py", "run_bucket"),
})
_HARVEST_METHODS = ("harvest", "harvest_ready", "harvest_next",
                    "harvest_all", "discard")

#: the ONLY call site allowed to cancel a hedge leg — the race's single
#: settle point.  A rogue ``.cancel()`` elsewhere could cancel BOTH legs
#: (bucket never booked) or cancel after booking (double accounting).
CANCEL_PERFORMERS: FrozenSet[Tuple[str, str]] = frozenset({
    ("serverless/dispatch.py", "HedgePair.settle"),
})

#: the ONLY call site allowed to abandon a queue — host-death recovery.
#: Abandoning anywhere else silently drops in-flight work without the
#: ledger/pending-view bookkeeping that re-dispatches it.
ABANDON_PERFORMERS: FrozenSet[Tuple[str, str]] = frozenset({
    ("serverless/topology.py", "TopologyBackend.kill_host"),
})

#: call sites allowed to view pending work WITHOUT excluding in-flight
#: entries — only the wave autoscaler's roofline pricing thunk, which
#: runs strictly between harvest_all and the next dispatch (queue empty)
PENDING_VIEW_EXEMPT: FrozenSet[Tuple[str, str]] = frozenset({
    ("serverless/backends.py", "WaveBackend._wave_workers"),
})

#: files whose dispatch_bucket calls must be preceded by mark_running in
#: the same function (the compiler's own synchronous run_bucket wrapper
#: sits below the ledger layer and is exempt by scope)
_LEDGER_LAYER = ("serverless/backends.py", "serverless/topology.py",
                 "core/session.py")

#: dataclasses whose generated __eq__ would compare in-flight jax arrays
#: elementwise — identity equality (eq=False) is load-bearing
IDENTITY_DATACLASSES: Dict[str, str] = {
    "PendingBucket": "serverless/dispatch.py",
    "Launch": "compile/program.py",
    "BucketDispatch": "compile/program.py",
}


def _last(callee: str) -> str:
    return callee.rsplit(".", 1)[-1]


def _check_file(rel: str, tree: ast.Module) -> List[Finding]:
    findings: List[Finding] = []
    calls = astutil.module_calls(tree)

    for qual, lineno, callee in calls:
        leaf = _last(callee)
        if leaf in _BOOKING_METHODS and "." in callee:
            if (rel, qual) not in BOOKING_PERFORMERS:
                findings.append(Finding(
                    "protocol", "booking-performer", f"{rel}:{lineno}",
                    f"{callee}() in {qual} — ledger results may only be "
                    "written by the declared booking functions "
                    f"{sorted(q for _, q in BOOKING_PERFORMERS)}"))
        if leaf in _HARVEST_METHODS and "." in callee:
            if (rel, qual) not in HARVEST_PERFORMERS:
                findings.append(Finding(
                    "protocol", "harvest-performer", f"{rel}:{lineno}",
                    f"{callee}() in {qual} — only the dispatch queue and "
                    "the declared scheduler steps may harvest"))
        if leaf == "cancel" and "." in callee:
            if (rel, qual) not in CANCEL_PERFORMERS:
                findings.append(Finding(
                    "protocol", "cancel-performer", f"{rel}:{lineno}",
                    f"{callee}() in {qual} — only HedgePair.settle may "
                    "cancel a hedge leg; a rogue cancel site can cancel "
                    "both legs (never booked) or cancel after booking"))
        if leaf == "abandon" and "." in callee:
            if (rel, qual) not in ABANDON_PERFORMERS:
                findings.append(Finding(
                    "protocol", "abandon-performer", f"{rel}:{lineno}",
                    f"{callee}() in {qual} — only TopologyBackend."
                    "kill_host may abandon a queue; anywhere else drops "
                    "in-flight work without re-dispatch bookkeeping"))

    # pending_by_bucket(exclude=...) — never re-dispatch in-flight work
    for qual, fn in astutil.iter_functions(tree):
        for lineno, callee, node in astutil.calls_in(fn):
            if _last(callee) != "pending_by_bucket":
                continue
            has_exclude = any(kw.arg == "exclude" for kw in node.keywords) \
                or len(node.args) >= 1
            if not has_exclude and (rel, qual) not in PENDING_VIEW_EXEMPT:
                findings.append(Finding(
                    "protocol", "pending-view-excludes-in-flight",
                    f"{rel}:{lineno}",
                    f"{qual} calls pending_by_bucket() without "
                    "exclude= — dispatched-but-unharvested invocations "
                    "would be dispatched twice"))

    # mark_running must precede dispatch_bucket in the same function
    if rel in _LEDGER_LAYER:
        for qual, fn in astutil.iter_functions(tree):
            cs = astutil.calls_in(fn)
            dispatches = [ln for ln, c, _ in cs
                          if _last(c) == "dispatch_bucket"]
            if not dispatches:
                continue
            marks = [ln for ln, c, _ in cs if _last(c) == "mark_running"]
            for ln in dispatches:
                if not any(m < ln for m in marks):
                    findings.append(Finding(
                        "protocol", "mark-before-dispatch",
                        f"{rel}:{ln}",
                        f"{qual} dispatches a bucket without first "
                        "mark_running() its invocations — a checkpoint "
                        "taken mid-flight would not re-queue them"))

    # identity-equality dataclasses
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        if IDENTITY_DATACLASSES.get(node.name) != rel:
            continue
        ok = False
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) and \
                    astutil.call_name(dec) is not None and \
                    _last(astutil.call_name(dec)) == "dataclass":
                for kw in dec.keywords:
                    if kw.arg == "eq" and \
                            isinstance(kw.value, ast.Constant) and \
                            kw.value.value is False:
                        ok = True
        if not ok:
            findings.append(Finding(
                "protocol", "identity-equality", f"{rel}:{node.lineno}",
                f"{node.name} must be @dataclass(eq=False): a generated "
                "__eq__ compares in-flight jax arrays elementwise and "
                "raises when two pending buckets share a key"))

    # ledger.py: transition methods exist, save() is atomic
    if rel == "serverless/ledger.py":
        methods = {q.rsplit(".", 1)[-1]
                   for q, _ in astutil.iter_functions(tree)
                   if q.startswith("TaskLedger.")}
        for name in LEDGER_TRANSITIONS:
            if name not in methods:
                findings.append(Finding(
                    "protocol", "transition-table-drift", rel,
                    f"LEDGER_TRANSITIONS names TaskLedger.{name} but the "
                    "method does not exist — update the table with the "
                    "rename"))
        for qual, fn in astutil.iter_functions(tree):
            if qual != "TaskLedger.save":
                continue
            if not any(_last(c) == "replace" and c.startswith("os.")
                       for _, c, _ in astutil.calls_in(fn)):
                findings.append(Finding(
                    "protocol", "atomic-ledger-save",
                    f"{rel}:{fn.lineno}",
                    "TaskLedger.save must write tmp + os.replace — a "
                    "crash mid-write must never corrupt the ledger"))
    return findings


def run(root: Optional[Path] = None) -> List[Finding]:
    """Statically check every audited file against the protocol table."""
    root = root or astutil.default_root()
    findings: List[Finding] = []
    for action, (srcs, dst) in BUCKET_TRANSITIONS.items():
        for s in srcs + (dst,):
            if s not in BUCKET_STATES:
                findings.append(Finding(
                    "protocol", "transition-table-drift",
                    "analysis/protocol.py",
                    f"BUCKET_TRANSITIONS[{action!r}] names state {s!r} "
                    "missing from BUCKET_STATES — update the table with "
                    "the rename"))
    for rel in AUDITED_FILES:
        path = root / rel
        if not path.exists():
            findings.append(Finding(
                "protocol", "missing-audited-file", rel,
                "audited file disappeared — update AUDITED_FILES with "
                "the move"))
            continue
        findings.extend(_check_file(rel, astutil.parse(path)))
    return findings
