"""Dead-code report — static import reachability over ``src/repro``.

Builds the module import graph by parsing every source file (no
imports executed), roots it at what the CI entry points actually load —
the tier-1 tests, the benchmark drivers, and the auditor itself — and
reports every module nothing reachable imports.  Seed-era launch CLIs
that no test exercises show up here instead of rotting silently.

Modules that are loaded dynamically (``repro.configs.*`` goes through
``importlib`` in ``get_arch``) are whitelisted as roots; anything else
unreachable is a finding, so keeping a module means either wiring it to
a test or consciously adding it to ``WHITELIST`` with a reason.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, List, Optional, Set

from repro.analysis import astutil
from repro.analysis.report import Finding

#: dynamically-imported or intentionally-kept modules (module name or
#: trailing-dot prefix), with the reason they stay
WHITELIST: Dict[str, str] = {
    "repro.configs.": "arch configs load via importlib in get_arch()",
    "repro.analysis.": "the auditor is its own CI entry point",
    "repro.launch.dryrun": "imported inside the subprocess smoke "
                           "snippet in tests/test_sharding.py (a string "
                           "literal, invisible to static imports)",
}


def _module_name(root: Path, path: Path) -> str:
    rel = path.relative_to(root).with_suffix("")
    parts = ("repro",) + rel.parts
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _whitelisted(mod: str) -> bool:
    for w in WHITELIST:
        if mod == w.rstrip(".") or (w.endswith(".")
                                    and mod.startswith(w)):
            return True
    return False


def _with_parents(mod: str, out: Set[str]) -> None:
    parts = mod.split(".")
    for i in range(1, len(parts) + 1):
        out.add(".".join(parts[:i]))


def _external_roots(src_root: Path) -> Set[str]:
    """repro modules imported by the test suite and benchmark drivers."""
    repo = src_root.parents[1]
    roots: Set[str] = set()
    for d in (repo / "tests", repo / "benchmarks"):
        if not d.is_dir():
            continue
        for path in sorted(d.glob("*.py")):
            tree = astutil.parse(path)
            for name in astutil.imports_of(tree, path.stem):
                if name == "repro" or name.startswith("repro."):
                    roots.add(name)
    return roots


def graph(root: Optional[Path] = None):
    """``(modules, edges, roots)`` of the static import graph."""
    root = root or astutil.default_root()
    paths = {p: _module_name(root, p) for p in astutil.iter_py_files(root)}
    modules = set(paths.values()) | {"repro"}
    edges: Dict[str, Set[str]] = {m: set() for m in modules}
    for path, mod in paths.items():
        for name in astutil.imports_of(astutil.parse(path), mod):
            # "from repro.x import y" contributes both repro.x and
            # repro.x.y — keep whichever are real modules
            if name in modules and name != mod:
                edges[mod].add(name)
    roots: Set[str] = set()
    for name in _external_roots(root):
        if name in modules:
            _with_parents(name, roots)
    for mod in modules:
        if _whitelisted(mod):
            _with_parents(mod, roots)
    return modules, edges, roots


def run(root: Optional[Path] = None) -> List[Finding]:
    modules, edges, roots = graph(root)
    seen: Set[str] = set()
    frontier = sorted(roots & modules)
    while frontier:
        mod = frontier.pop()
        if mod in seen:
            continue
        seen.add(mod)
        for dep in edges.get(mod, ()):
            ext: Set[str] = set()
            _with_parents(dep, ext)
            frontier.extend(ext - seen)
    findings: List[Finding] = []
    for mod in sorted(modules - seen):
        findings.append(Finding(
            "dead-code", "unreachable-module", mod,
            "no test, benchmark, or whitelisted entry point reaches "
            "this module — delete it or whitelist it with a reason"))
    return findings
