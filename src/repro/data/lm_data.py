"""Synthetic LM token pipeline: a deterministic Zipf-ish token stream with
local structure (so loss actually decreases), sharded host->device feed with
a resumable cursor.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass
class LMDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticLM:
    """Order-2 Markov-ish stream: next token depends on previous two through
    a hashed transition — learnable structure at any vocab size."""

    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg
        rng = np.random.Generator(np.random.Philox(key=cfg.seed))
        self._mix_a = int(rng.integers(1, 2**31 - 1)) | 1
        self._mix_b = int(rng.integers(1, 2**31 - 1))
        # Zipf-ish marginal for the noise branch
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self._marginal = probs / probs.sum()

    def _next(self, rng, prev1, prev2):
        v = self.cfg.vocab_size
        det = ((prev1 * self._mix_a + prev2 * 31 + self._mix_b) % v)
        noise = rng.choice(v, size=prev1.shape, p=self._marginal)
        pick = rng.random(prev1.shape) < 0.75
        return np.where(pick, det, noise).astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic function of (seed, step) — resume-exact."""
        cfg = self.cfg
        rng = np.random.Generator(
            np.random.Philox(key=cfg.seed, counter=step))
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, b)
        toks[:, 1] = rng.integers(0, cfg.vocab_size, b)
        for t in range(2, s + 1):
            toks[:, t] = self._next(rng, toks[:, t - 1], toks[:, t - 2])
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
