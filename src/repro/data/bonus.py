"""Schema-faithful synthetic replica of the Pennsylvania Reemployment Bonus
experiment dataset (paper §5: N=5099 after the standard DoubleML
preprocessing; outcome = log unemployment duration, treatment = bonus
offer tgdep≠0 collapsed to binary, 17 control columns).

The container is offline, so the exact CSV cannot be fetched; the replica
matches row count, column names/types and realistic marginals, with a known
planted effect so the pipeline remains checkable end-to-end.  EXPERIMENTS.md
reports paper-claim comparisons on *timing/cost* (the paper's empirical
axis), not on the point estimate.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

N_BONUS = 5099
X_COLS = [
    "female", "black", "othrace", "dep1", "dep2",
    "q2", "q3", "q4", "q5", "q6",
    "agelt35", "agegt54", "durable", "lusd", "husd",
    "nondurable", "married",
]
TRUE_EFFECT = -0.08     # planted; the published estimate is ~ -0.07..-0.08


def make_bonus_data(seed: int = 3141) -> Dict[str, np.ndarray]:
    rng = np.random.Generator(np.random.Philox(key=seed))
    n = N_BONUS
    cols = {}
    probs = {
        "female": 0.39, "black": 0.11, "othrace": 0.01, "dep1": 0.20,
        "dep2": 0.25, "agelt35": 0.43, "agegt54": 0.11, "durable": 0.17,
        "lusd": 0.40, "husd": 0.27, "nondurable": 0.15, "married": 0.56,
    }
    for c, p in probs.items():
        cols[c] = (rng.random(n) < p).astype(np.float32)
    # quarter-of-enrollment dummies q2..q6 (one-hot-ish)
    q = rng.integers(1, 7, n)
    for i in range(2, 7):
        cols[f"q{i}"] = (q == i).astype(np.float32)
    x = np.stack([cols[c] for c in X_COLS], axis=1)
    # randomized treatment (it was an RCT), mild dependence for realism
    d = (rng.random(n) < 0.34).astype(np.float32)
    # log-duration outcome with covariate effects + planted treatment effect
    beta = rng.normal(0.0, 0.15, x.shape[1])
    base = 2.1 + x @ beta
    y = base + TRUE_EFFECT * d + rng.gumbel(0.0, 0.55, n)
    return {"x": x.astype(np.float32), "y": y.astype(np.float32),
            "d": d, "theta0": TRUE_EFFECT, "columns": X_COLS}
