from repro.data.bonus import make_bonus_data, N_BONUS, TRUE_EFFECT
from repro.data.dgp import make_irm_data, make_pliv_data, make_plr_data

__all__ = ["make_bonus_data", "N_BONUS", "TRUE_EFFECT", "make_irm_data",
           "make_pliv_data", "make_plr_data"]
