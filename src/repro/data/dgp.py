"""Synthetic data-generating processes for DML validation.

``make_plr_data`` follows Chernozhukov et al. (2018) §5.1 style PLR DGPs
(nonlinear confounding, known theta0) so estimator bias/coverage is
checkable.  ``make_irm_data`` gives a binary-treatment interactive model.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def _toeplitz_cov(p: int, rho: float = 0.7) -> np.ndarray:
    idx = np.arange(p)
    return rho ** np.abs(idx[:, None] - idx[None, :])


def make_plr_data(n_obs: int = 500, dim_x: int = 20, theta: float = 0.5,
                  seed: int = 1) -> Dict[str, np.ndarray]:
    rng = np.random.Generator(np.random.Philox(key=seed))
    cov = _toeplitz_cov(dim_x)
    chol = np.linalg.cholesky(cov)
    x = rng.standard_normal((n_obs, dim_x)) @ chol.T
    m0 = x[:, 0] + 0.25 * np.exp(x[:, 2]) / (1 + np.exp(x[:, 2]))
    g0 = np.exp(x[:, 0]) / (1 + np.exp(x[:, 0])) + 0.25 * x[:, 2]
    d = m0 + rng.standard_normal(n_obs)
    y = theta * d + g0 + rng.standard_normal(n_obs)
    return {"x": x.astype(np.float32), "y": y.astype(np.float32),
            "d": d.astype(np.float32), "theta0": theta}


def make_irm_data(n_obs: int = 500, dim_x: int = 20, theta: float = 0.5,
                  seed: int = 1) -> Dict[str, np.ndarray]:
    rng = np.random.Generator(np.random.Philox(key=seed))
    cov = _toeplitz_cov(dim_x, 0.5)
    chol = np.linalg.cholesky(cov)
    x = rng.standard_normal((n_obs, dim_x)) @ chol.T
    idx = x[:, 0] + 0.5 * x[:, 1]
    pz = 1.0 / (1.0 + np.exp(-idx))
    d = (rng.random(n_obs) < pz).astype(np.float32)
    g = np.exp(x[:, 0]) / (1 + np.exp(x[:, 0])) + 0.25 * x[:, 2]
    y = theta * d + g + rng.standard_normal(n_obs)
    return {"x": x.astype(np.float32), "y": y.astype(np.float32),
            "d": d, "theta0": theta}


def make_pliv_data(n_obs: int = 500, dim_x: int = 20, theta: float = 0.5,
                   seed: int = 1) -> Dict[str, np.ndarray]:
    rng = np.random.Generator(np.random.Philox(key=seed))
    x = rng.standard_normal((n_obs, dim_x))
    z = x[:, 0] + rng.standard_normal(n_obs)        # instrument
    u = rng.standard_normal(n_obs)                  # endogeneity
    d = z + 0.3 * x[:, 1] + u + 0.5 * rng.standard_normal(n_obs)
    g = 0.25 * x[:, 2] + np.tanh(x[:, 0])
    y = theta * d + g + u + rng.standard_normal(n_obs)
    return {"x": x.astype(np.float32), "y": y.astype(np.float32),
            "d": d.astype(np.float32), "z": z.astype(np.float32),
            "theta0": theta}
