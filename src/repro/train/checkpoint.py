"""msgpack-based checkpointing (orbax is not available offline).

Trees are flattened to path-keyed raw buffers; restore is resume-exact
(params, optimizer state incl. step, data cursor, RNG key).  Writes are
atomic (tmp + rename) and keep a rolling window of checkpoints.  On a real
multi-host pod each host writes its addressable shards under its process
index; here (single host) the full tree is written — the layout keeps the
per-shard extension point explicit in ``_shard_suffix``.
"""
from __future__ import annotations

import os
import re
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _shard_suffix() -> str:
    return f".{jax.process_index()}" if jax.process_count() > 1 else ""


def tree_to_payload(tree) -> Dict[str, Any]:
    flat = {}
    def visit(path, leaf):
        arr = np.asarray(leaf)
        flat[_path_str(path)] = {
            "dtype": arr.dtype.name if arr.dtype != jnp.bfloat16 else "bfloat16",
            "shape": list(arr.shape),
            "data": (arr.view(np.uint16) if arr.dtype == jnp.bfloat16
                     else arr).tobytes(),
        }
    jax.tree_util.tree_map_with_path(visit, tree)
    return flat


def payload_to_tree(payload: Dict[str, Any], like):
    leaves_by_path = {}
    def visit(path, leaf):
        rec = payload[_path_str(path)]
        if rec["dtype"] == "bfloat16":
            arr = np.frombuffer(rec["data"], np.uint16).reshape(rec["shape"])
            arr = arr.view(jnp.bfloat16)
        else:
            arr = np.frombuffer(rec["data"], np.dtype(rec["dtype"])).reshape(
                rec["shape"])
        leaves_by_path[_path_str(path)] = jnp.asarray(arr)
        return leaves_by_path[_path_str(path)]
    return jax.tree_util.tree_map_with_path(visit, like)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.msgpack{_shard_suffix()}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, params, opt_state, extra: Optional[dict] = None):
        """Device->host copy happens synchronously; serialization + IO move
        to a writer thread (compute/IO overlap)."""
        payload = {
            "step": step,
            "params": tree_to_payload(params),
            "opt": tree_to_payload(opt_state),
            "extra": extra or {},
        }
        self.wait()

        def write():
            path = self._path(step)
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(msgpack.packb(payload, use_bin_type=True))
            os.replace(tmp, path)
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def _gc(self):
        ckpts = sorted(self.steps())
        for s in ckpts[: -self.keep]:
            try:
                os.remove(self._path(s))
            except OSError:
                pass

    def steps(self):
        pat = re.compile(r"ckpt_(\d+)\.msgpack")
        out = []
        for f in os.listdir(self.dir):
            m = pat.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(set(out))

    def restore(self, params_like, opt_like,
                step: Optional[int] = None) -> Tuple[Any, Any, int, dict]:
        steps = self.steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        step = steps[-1] if step is None else step
        with open(self._path(step), "rb") as f:
            payload = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
        params = payload_to_tree(payload["params"], params_like)
        opt = payload_to_tree(payload["opt"], opt_like)
        return params, opt, payload["step"], payload.get("extra", {})
