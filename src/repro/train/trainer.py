"""Training loop: jit'd train_step (grad + AdamW) with optional microbatch
gradient accumulation (scanned), sharded params/opt-state, periodic
checkpointing with resume, and straggler-insensitive metrics.

``make_train_step`` is also what the dry-run lowers for the ``train_4k``
cells — the compiled artifact includes the optimizer update and the DP
gradient all-reduce, so the roofline sees the full step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.lm import ModelBundle
from repro.models.param import init_tree, sharding_tree, struct_tree
from repro.runtime import maybe_scan
from repro.train.checkpoint import Checkpointer
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


def make_train_step(bundle: ModelBundle, opt_cfg: OptConfig,
                    n_microbatch: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    loss_fn = bundle.loss_fn
    from repro.models.param import spec_tree
    grad_specs = spec_tree(bundle.decls, bundle.rules)

    def pin(grads):
        """Keep gradients on the parameter layout: without this the
        microbatch accumulator picks up a different propagated sharding and
        the partitioner degrades to replicate+reshard per step."""
        def one(g, spec):
            try:
                return jax.lax.with_sharding_constraint(g, spec)
            except (ValueError, RuntimeError):
                return g
        return jax.tree.map(one, grads, grad_specs)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, pin(grads)

    def step(params, opt_state, batch):
        if n_microbatch == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(n_microbatch, b // n_microbatch, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                loss_a, grads_a = carry
                loss, metrics, grads = grads_of(params, mb)
                grads = pin(jax.tree.map(jnp.add, grads_a, grads))
                return (loss_a + loss, grads), metrics

            zeros = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), metrics = maybe_scan(
                acc_fn, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / n_microbatch
            grads = jax.tree.map(lambda g: g / n_microbatch, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


@dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0
    ckpt_dir: Optional[str] = None
    n_microbatch: int = 1


class Trainer:
    def __init__(self, bundle: ModelBundle, opt_cfg: OptConfig,
                 tcfg: TrainerConfig, mesh=None):
        self.bundle = bundle
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.step_fn = None
        self.ckpt = (Checkpointer(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir and tcfg.ckpt_every else None)

    def init(self, key):
        params = init_tree(self.bundle.decls, key)
        opt_state = init_opt_state(params, self.opt_cfg)
        if self.mesh is not None:
            shardings = sharding_tree(self.bundle.decls, self.mesh,
                                      self.bundle.rules)
            params = jax.device_put(params, shardings)
            opt_state = jax.device_put(opt_state, {
                "step": jax.sharding.NamedSharding(
                    self.mesh, jax.sharding.PartitionSpec()),
                "master": shardings, "m": shardings, "v": shardings,
            } | ({"err": shardings} if self.opt_cfg.compress_grads else {}))
        return params, opt_state

    def run(self, params, opt_state, data_iter, start_step: int = 0):
        step_fn = jax.jit(make_train_step(
            self.bundle, self.opt_cfg, self.tcfg.n_microbatch),
            donate_argnums=(0, 1))
        history = []
        t0 = time.perf_counter()
        for step in range(start_step, self.tcfg.steps):
            batch = next(data_iter)
            batch = jax.tree.map(jnp.asarray, batch)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if (step + 1) % self.tcfg.log_every == 0 or step == start_step:
                metrics = {k: float(v) for k, v in metrics.items()}
                metrics["step"] = step + 1
                metrics["wall_s"] = time.perf_counter() - t0
                history.append(metrics)
                print("  " + " ".join(
                    f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in sorted(metrics.items())))
            if self.ckpt and (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step + 1, params, opt_state,
                               extra={"data_step": step + 1})
        if self.ckpt:
            self.ckpt.wait()
        return params, opt_state, history

    def resume(self):
        """(params, opt_state, start_step) from the latest checkpoint."""
        assert self.ckpt is not None
        params_like = struct_tree(self.bundle.decls)
        params0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                               params_like)
        opt_like = init_opt_state(params0, self.opt_cfg)
        params, opt_state, step, extra = self.ckpt.restore(params0, opt_like)
        return params, opt_state, extra.get("data_step", step)
