"""AdamW from scratch: bf16 params, f32 master weights + moments (sharded
like the params), global-norm clipping, warmup+cosine schedule, optional
int8 gradient compression with error feedback for the DP all-reduce.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False      # int8 + error feedback


def schedule(cfg: OptConfig, step):
    step = step.astype(F32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptConfig):
    state = {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(lambda p: p.astype(F32), params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def compress_int8(g, err):
    """int8 quantize with error feedback: returns (q_dequantized, new_err).

    When applied *before* the DP all-reduce (trainer wires this through a
    reduce-over-int8 shard_map in the optimized path) it cuts gradient
    exchange bytes 4x; the error-feedback accumulator keeps the optimizer
    unbiased in the long run (1-bit Adam / EF-SGD lineage).
    """
    gf = g.astype(F32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(F32) * scale
    return deq, gf - deq


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)

    if cfg.compress_grads:
        pairs = jax.tree.map(compress_int8, grads, state["err"])
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    bc1 = 1.0 - cfg.b1 ** step.astype(F32)
    bc2 = 1.0 - cfg.b2 ** step.astype(F32)

    def upd(p_master, g, m, v):
        g = g.astype(F32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        decay = cfg.weight_decay * p_master if p_master.ndim >= 2 else 0.0
        p_new = p_master - lr * (update + decay)
        return p_new, m, v

    out = jax.tree.map(upd, state["master"], grads, state["m"], state["v"])
    master = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[2], out,
                     is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
    new_state = {"step": step, "master": master, "m": m, "v": v}
    if cfg.compress_grads:
        new_state["err"] = new_err
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
