from repro.train.checkpoint import Checkpointer
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, schedule
from repro.train.trainer import Trainer, TrainerConfig, make_train_step

__all__ = [
    "Checkpointer", "OptConfig", "adamw_update", "init_opt_state", "schedule",
    "Trainer", "TrainerConfig", "make_train_step",
]
