"""Parameter declaration trees.

A model is declared once as a pytree of :class:`PDecl`; from it we derive
(1) random initialization, (2) ``ShapeDtypeStruct`` trees for the dry-run,
(3) ``NamedSharding`` trees via the logical-axis rules.  This keeps the three
views structurally identical by construction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.axes import LogicalRules


@dataclass(frozen=True)
class PDecl:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones
    scale: Optional[float] = None  # stddev; default 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)

    def initialize(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        scale = self.scale if self.scale is not None else 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32) * scale).astype(self.dtype)

    @property
    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_decl(x) -> bool:
    return isinstance(x, PDecl)


def init_tree(decls, key):
    """Materialize a declaration tree into parameter arrays."""
    leaves, treedef = jax.tree.flatten(decls, is_leaf=is_decl)
    keys = jax.random.split(key, len(leaves))
    arrs = [d.initialize(k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def struct_tree(decls):
    return jax.tree.map(lambda d: d.struct, decls, is_leaf=is_decl)


def spec_tree(decls, rules: LogicalRules):
    return jax.tree.map(lambda d: rules.resolve(d.logical), decls, is_leaf=is_decl)


def sharding_tree(decls, mesh, rules: LogicalRules):
    """jit in_shardings require even divisibility — the rules resolver drops
    mesh axes a dim cannot evenly use (and frees them for later dims:
    batch 128 over ("data","model") degrades to "data", leaving "model" for
    the kv_seq dim)."""
    sizes = dict(mesh.shape)

    def mk(d: PDecl):
        spec = rules.resolve(d.logical, shape=d.shape, mesh_sizes=sizes)
        return jax.sharding.NamedSharding(mesh, spec)
    return jax.tree.map(mk, decls, is_leaf=is_decl)


def param_bytes(decls) -> int:
    tot = 0
    for d in jax.tree.leaves(decls, is_leaf=is_decl):
        tot += int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
    return tot


def param_count(decls) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree.leaves(decls, is_leaf=is_decl))
