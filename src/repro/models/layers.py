"""Shared neural-net layers: norms, RoPE, MLPs, attention (GQA/SWA/MLA).

All functions are pure; parameters come in as pytrees built from the PDecl
trees in the sibling modules.  Activations are bf16 with f32 softmax/norm
statistics.  Attention over long KV uses a chunked online-softmax scan
(flash-attention dataflow in pure jnp) so neither the CPU dry-run nor the
TPU path ever materializes an (Sq, Skv) score matrix; the Pallas kernel in
``repro.kernels.flash_attention`` implements the same contract for TPU.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionConfig
from repro.models.param import PDecl
from repro.sharding.axes import LogicalRules, logical_constraint

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Norms / activations / MLP
# ---------------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-5):
    """Stats in f32, application in the input dtype: keeping the (B,S,d)
    elementwise products bf16 keeps the TP activation all-reduces (which XLA
    places on these tensors) at 2 bytes/elt instead of 4 (§Perf It-5)."""
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * w


def layer_norm(x, w, b, eps: float = 1e-5):
    h = x.astype(F32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    return ((h - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp_decls(d_model: int, d_ff: int, glu: bool) -> Dict[str, PDecl]:
    if glu:
        return {
            "wi": PDecl((d_model, 2, d_ff), ("embed", None, "ff")),
            "wo": PDecl((d_ff, d_model), ("ff", "embed")),
        }
    return {
        "wi": PDecl((d_model, d_ff), ("embed", "ff")),
        "wo": PDecl((d_ff, d_model), ("ff", "embed")),
    }


def mlp_forward(p, x, act: str, glu: bool, rules: LogicalRules):
    if glu:
        uv = jnp.einsum("...d,dcf->...cf", x, p["wi"])
        u, v = uv[..., 0, :], uv[..., 1, :]
        h = act_fn(act)(u) * v
    else:
        h = act_fn(act)(jnp.einsum("...d,df->...f", x, p["wi"]))
    h = logical_constraint(h, rules, "batch", None, "act_ff")
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, n_heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    inv = jnp.asarray(rope_freqs(hd, theta))
    ang = positions[..., None].astype(F32) * inv          # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq_len: int, d_model: int, dtype=jnp.bfloat16):
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(0, d_model, 2)[None, :]
    ang = pos / np.power(10_000.0, dim / d_model)
    out = np.zeros((seq_len, d_model), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out, dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------
def _repeat_kv(k, hq: int):
    """(B, S, Hk, D) -> (B, S, Hq, D): GQA KV replication along heads.

    Repeat (not regroup) keeps every tensor head-major so a TP-sharded head
    dim never needs a partitioner-hostile (Hk, G) reshape; XLA fuses the
    broadcast into the score/value dots on TPU.
    """
    hk = k.shape[2]
    if hk == hq:
        return k
    return jnp.repeat(k, hq // hk, axis=2)


NEG_BIAS = -1e30          # finite: avoids (-inf) - (-inf) NaNs in the scan
PAD_POS = 2**30           # sentinel position for padded KV slots


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """Additive f32 bias (..., Sq, Sk): 0 keep / NEG_BIAS drop."""
    ok = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok &= kp < PAD_POS
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    return jnp.where(ok, 0.0, NEG_BIAS).astype(F32)


def attention_core(q, k, v, q_pos, k_pos, *, causal: bool,
                   window: Optional[int], chunk: int = 1024):
    """Online-softmax attention.

    q: (B, Sq, Hq, D); k/v: (B, Skv, Hk, D); positions (B, S*).
    Returns (B, Sq, Hq, D).  KV is consumed in ``chunk``-sized blocks with
    running (m, l, acc) statistics — O(Sq·chunk) live memory.
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]
    scale = 1.0 / np.sqrt(d)
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    qs = q * scale

    if skv <= chunk:
        s = jnp.einsum("bqhd,bkhd->bhqk", qs.astype(F32), k.astype(F32))
        s += _mask_bias(q_pos, k_pos, causal, window)[:, None]
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
        return o

    pad = (-skv) % chunk
    if pad:                                  # ragged tail: mask padded slots
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=PAD_POS)
        skv += pad
    n_chunks = skv // chunk
    ks = jnp.moveaxis(k.reshape(b, n_chunks, chunk, hq, d), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, n_chunks, chunk, hq, dv), 1, 0)
    kps = jnp.moveaxis(k_pos.reshape(b, n_chunks, chunk), 1, 0)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, kp = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", qs.astype(F32), kc.astype(F32))
        s += _mask_bias(q_pos, kp, causal, window)[:, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vc.astype(F32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, hq, sq), NEG_BIAS, F32)
    l0 = jnp.zeros((b, hq, sq), F32)
    a0 = jnp.zeros((b, hq, sq, dv), F32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(o, 1, 2).astype(q.dtype)


def decode_attention_core(q, k, v, k_pos, q_pos, *, window: Optional[int]):
    """Single-position decode: q (B,1,Hq,D) vs full cache k/v (B,S,Hk,D).

    ``k_pos`` holds the cache slot positions (-1 for unwritten slots); the
    softmax masks unwritten and out-of-window slots.  Sequence dim of the
    cache may be sharded (split-KV) — the reductions below then lower to the
    3-psum flash-decoding combine.
    """
    b, _, hq, d = q.shape
    scale = 1.0 / np.sqrt(d)
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    s = jnp.einsum("bqhd,bkhd->bhqk", (q * scale).astype(F32), k.astype(F32))
    valid = (k_pos >= 0) & (k_pos <= q_pos[:, None])
    if window is not None:
        valid &= k_pos > q_pos[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bkhd->bqhd", (p / l).astype(v.dtype), v)
    return o


# ---------------------------------------------------------------------------
# GQA attention layer (projections + rope + core), with KV cache support
# ---------------------------------------------------------------------------
def attn_decls(a: AttentionConfig, d_model: int) -> Dict[str, PDecl]:
    """Projections are declared flattened (d, H*hd): the fused dim is evenly
    TP-divisible for every assigned head count (40, 56, ... x 128), where a
    separate heads dim would not be; kernels reshape to (B,S,H,hd) after the
    matmul (the reshape of a sharded fused dim is a local view)."""
    if a.is_mla:
        r, rh = a.kv_lora_rank, a.rope_head_dim
        return {
            "wq": PDecl((d_model, a.n_heads * (a.head_dim + rh)),
                        ("embed", "heads")),
            "wdkv": PDecl((d_model, r + rh), ("embed", "latent")),
            "wuk": PDecl((a.kv_lora_rank, a.n_heads * a.head_dim),
                         ("latent", "heads")),
            "wuv": PDecl((a.kv_lora_rank, a.n_heads * a.head_dim),
                         ("latent", "heads")),
            "wo": PDecl((a.n_heads * a.head_dim, d_model),
                        ("heads", "embed")),
        }
    decls = {
        "wq": PDecl((d_model, a.q_dim), ("embed", "heads")),
        "wk": PDecl((d_model, a.kv_dim), ("embed", "kv_heads")),
        "wv": PDecl((d_model, a.kv_dim), ("embed", "kv_heads")),
        "wo": PDecl((a.q_dim, d_model), ("heads", "embed")),
    }
    if a.qkv_bias:
        decls["bq"] = PDecl((a.q_dim,), ("heads",), init="zeros")
        decls["bk"] = PDecl((a.kv_dim,), ("kv_heads",), init="zeros")
        decls["bv"] = PDecl((a.kv_dim,), ("kv_heads",), init="zeros")
    return decls


def _heads(t, n: int, hd: int):
    return t.reshape(*t.shape[:-1], n, hd)


def _qkv(p, a: AttentionConfig, x, positions, use_rope: bool):
    q = jnp.einsum("bsd,dk->bsk", x, p["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"])
    if a.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _heads(q, a.n_heads, a.head_dim)
    k = _heads(k, a.n_kv_heads, a.head_dim)
    v = _heads(v, a.n_kv_heads, a.head_dim)
    if use_rope:
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    return q, k, v


def attn_forward(p, a: AttentionConfig, x, positions, rules: LogicalRules,
                 *, use_rope: bool = True, chunk: int = 1024,
                 kv_override: Optional[Tuple] = None, causal: Optional[bool] = None):
    """Full-sequence attention (train / prefill).  Returns (out, kv) where kv
    is the (k, v) pair for cache seeding in prefill."""
    causal = a.causal if causal is None else causal
    if a.is_mla:
        return _mla_forward(p, a, x, positions, rules, chunk=chunk)
    if kv_override is None:
        q, k, v = _qkv(p, a, x, positions, use_rope)
        k_pos = positions
    else:  # cross-attention: kv comes from the encoder/vision tower
        kv_x, kv_pos = kv_override
        q = jnp.einsum("bsd,dk->bsk", x, p["wq"])
        k = jnp.einsum("bsd,dk->bsk", kv_x, p["wk"])
        v = jnp.einsum("bsd,dk->bsk", kv_x, p["wv"])
        if a.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = _heads(q, a.n_heads, a.head_dim)
        k = _heads(k, a.n_kv_heads, a.head_dim)
        v = _heads(v, a.n_kv_heads, a.head_dim)
        k_pos = kv_pos
        causal = False
    q = logical_constraint(q, rules, "batch", "seq", "act_heads", None)
    k = logical_constraint(k, rules, "batch", "seq", "act_heads", None)
    o = attention_core(q, k, v, positions, k_pos,
                       causal=causal, window=a.sliding_window, chunk=chunk)
    out = jnp.einsum("bsk,kd->bsd", o.reshape(*o.shape[:2], -1), p["wo"])
    return out, (k, v)


def attn_decode(p, a: AttentionConfig, x1, pos, slot_pos, cache,
                rules: LogicalRules, *, use_rope: bool = True,
                cross: bool = False):
    """One decode step.  x1: (B, 1, d); pos: (B,) int32 current position.

    ``slot_pos``: (B, S) int32 table slot->written position (-1 empty),
    shared across layers and already updated for this step by the caller.
    cache: {"k": (B, S, Hk, D), "v": ...}.  ``cross=True`` treats the cache
    as a static cross-attention KV (no write, all slots valid).
    Returns (out (B,1,d), new_cache).
    """
    if a.is_mla:
        return _mla_decode(p, a, x1, pos, slot_pos, cache, rules)
    positions = pos[:, None]
    q = jnp.einsum("bsd,dk->bsk", x1, p["wq"])
    if a.qkv_bias:
        q = q + p["bq"]
    q = _heads(q, a.n_heads, a.head_dim)
    if use_rope and not cross:
        q = apply_rope(q, positions, a.rope_theta)

    def out_proj(o):
        return jnp.einsum("bsk,kd->bsd", o.reshape(*o.shape[:2], -1), p["wo"])

    if cross:
        ck, cv = cache["k"], cache["v"]
        o = decode_attention_core(
            q, ck, cv,
            jnp.zeros(ck.shape[:2], jnp.int32), pos, window=None)
        return out_proj(o), cache
    k = jnp.einsum("bsd,dk->bsk", x1, p["wk"])
    v = jnp.einsum("bsd,dk->bsk", x1, p["wv"])
    if a.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = _heads(k, a.n_kv_heads, a.head_dim)
    v = _heads(v, a.n_kv_heads, a.head_dim)
    if use_rope:
        k = apply_rope(k, positions, a.rope_theta)
    S = cache["k"].shape[1]
    slot = pos % S                                        # ring buffer (SWA)
    bidx = jnp.arange(x1.shape[0])
    ck = cache["k"].at[bidx, slot].set(k[:, 0])
    cv = cache["v"].at[bidx, slot].set(v[:, 0])
    ck = logical_constraint(ck, rules, "batch", "kv_seq", None, None)
    cv = logical_constraint(cv, rules, "batch", "kv_seq", None, None)
    o = decode_attention_core(q, ck, cv, slot_pos, pos, window=a.sliding_window)
    return out_proj(o), {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent KV + decoupled rope; absorbed decode
# ---------------------------------------------------------------------------
def _mla_split_q(p, a, x, positions):
    qfull = _heads(jnp.einsum("bsd,dk->bsk", x, p["wq"]),
                   a.n_heads, a.head_dim + a.rope_head_dim)
    q_nope = qfull[..., : a.head_dim]
    q_rope = apply_rope(qfull[..., a.head_dim:], positions, a.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, a, x, positions):
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    c, k_rope = ckv[..., : a.kv_lora_rank], ckv[..., a.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, a.rope_theta)[:, :, 0]
    return c, k_rope


def _mla_forward(p, a: AttentionConfig, x, positions, rules, *, chunk: int):
    q_nope, q_rope = _mla_split_q(p, a, x, positions)
    c, k_rope = _mla_latent(p, a, x, positions)
    k_nope = _heads(jnp.einsum("bsr,rk->bsk", c, p["wuk"]),
                    a.n_heads, a.head_dim)
    v = _heads(jnp.einsum("bsr,rk->bsk", c, p["wuv"]),
               a.n_heads, a.head_dim)
    # Fold the decoupled-rope channel into the head dim so one core handles it.
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:-1] + (a.rope_head_dim,))],
        axis=-1)
    # core scales by 1/sqrt(dim(q)) — rescale to the paper's 1/sqrt(dh+rh): same dim, ok.
    o = attention_core(q, k, v, positions, positions,
                       causal=a.causal, window=None, chunk=chunk)
    out = jnp.einsum("bsk,kd->bsd", o.reshape(*o.shape[:2], -1), p["wo"])
    return out, (c, k_rope)


def _mla_decode(p, a: AttentionConfig, x1, pos, slot_pos, cache, rules):
    """Absorbed MLA decode: score/value computed against the latent cache —
    per-token cache is (r + rope_head_dim) floats, not 2·H·D."""
    positions = pos[:, None]
    q_nope, q_rope = _mla_split_q(p, a, x1, positions)   # (B,1,H,dh/rh)
    c1, kr1 = _mla_latent(p, a, x1, positions)           # (B,1,r), (B,1,rh)
    S = cache["c"].shape[1]
    slot = pos % S
    bidx = jnp.arange(x1.shape[0])
    cc = cache["c"].at[bidx, slot].set(c1[:, 0])
    ckr = cache["krope"].at[bidx, slot].set(kr1[:, 0])
    cc = logical_constraint(cc, rules, "batch", "kv_seq", None)

    wuk = p["wuk"].reshape(a.kv_lora_rank, a.n_heads, a.head_dim)
    wuv = p["wuv"].reshape(a.kv_lora_rank, a.n_heads, a.head_dim)
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, wuk)        # absorb W_uk
    scale = 1.0 / np.sqrt(a.head_dim + a.rope_head_dim)
    s = (jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(F32), cc.astype(F32))
         + jnp.einsum("bqhk,bsk->bhqs", q_rope.astype(F32), ckr.astype(F32))) * scale
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    pattn = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", pattn.astype(cc.dtype), cc)
    o = jnp.einsum("bqhr,rhk->bqhk", ctx, wuv)               # absorb W_uv
    out = jnp.einsum("bsk,kd->bsd", o.reshape(*o.shape[:2], -1), p["wo"])
    return out, {"c": cc, "krope": ckr}
