"""Recurrent blocks: Mamba-2 (SSD) and xLSTM (mLSTM / sLSTM).

The SSD chunked scan follows Dao & Gu (arXiv:2405.21060): within-chunk terms
are dense MXU matmuls, the inter-chunk recurrence carries an (N x P) state
per head.  The mLSTM maps onto the same machinery (decay = logsigmoid(f),
state driven by i*v k^T, normalizer = extra all-ones value channel); the
xLSTM log-space stabilizer is replaced by a soft-capped input gate in the
chunked path (DESIGN.md §5 notes the deviation).  sLSTM is inherently
sequential (nonlinear recurrence) and runs as a lax.scan over time; its
FLOPs are corrected analytically in the roofline (launch/roofline.py).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.param import PDecl
from repro.models.layers import rms_norm
from repro.runtime import maybe_scan
from repro.sharding.axes import LogicalRules

F32 = jnp.float32


# ---------------------------------------------------------------------------
# SSD chunked scan (shared by Mamba-2 and mLSTM)
# ---------------------------------------------------------------------------
def ssd_chunked(xbar, la, Bm, Cm, chunk: int):
    """y_t = C_t^T S_t,  S_t = exp(la_t) S_{t-1} + B_t xbar_t^T.

    xbar: (B,S,H,P) f32; la: (B,S,H) f32 log-decay (<=0);
    Bm, Cm: (B,S,N) f32 (shared across heads, n_groups=1).
    Returns y (B,S,H,P) f32 and final state (B,H,N,P).
    """
    b, s, h, pdim = xbar.shape
    n = Bm.shape[-1]
    s_true = s
    pad = (-s) % chunk
    if pad:   # zero inputs with zero log-decay leave the state untouched
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        s += pad
    nc = s // chunk

    def resh(t):
        return jnp.moveaxis(t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)

    xs, las, bs, cs = resh(xbar), resh(la), resh(Bm), resh(Cm)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(state, inp):
        xc, lac, bc, cc = inp                      # (B,Q,H,P) (B,Q,H) (B,Q,N)
        cl = jnp.cumsum(lac, axis=1)               # inclusive (B,Q,H)
        scores = jnp.einsum("bin,bjn->bij", cc, bc)
        lmat = jnp.exp(jnp.clip(cl[:, :, None, :] - cl[:, None, :, :], -60.0, 0.0))
        w = jnp.where(causal[None, :, :, None], scores[:, :, :, None] * lmat, 0.0)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xc)
        y_inter = jnp.einsum("bin,bhnp,bih->bihp", cc, state, jnp.exp(cl))
        tail = jnp.exp(cl[:, -1:, :] - cl)          # decay j -> chunk end
        s_new = jnp.einsum("bjn,bjhp,bjh->bhnp", bc, xc, tail) \
            + state * jnp.exp(cl[:, -1])[:, :, None, None]
        return s_new, y_intra + y_inter

    s0 = jnp.zeros((b, h, n, pdim), F32)
    s_fin, ys = maybe_scan(body, s0, (xs, las, bs, cs))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, pdim)[:, :s_true]
    return y, s_fin


def ssd_step(state, xbar1, la1, b1, c1):
    """One decode step. state (B,H,N,P); xbar1 (B,H,P); la1 (B,H); b1/c1 (B,N)."""
    s_new = state * jnp.exp(la1)[:, :, None, None] \
        + jnp.einsum("bn,bhp->bhnp", b1, xbar1)
    y = jnp.einsum("bn,bhnp->bhp", c1, s_new)
    return s_new, y


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (width cw) with streaming state
# ---------------------------------------------------------------------------
def causal_conv(x, w, bias):
    """x: (B,S,C); w: (cw,C) depthwise; left-pad causal."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(cw))
    return out + bias


def causal_conv_step(conv_state, x1, w, bias):
    """conv_state: (B, cw-1, C) previous inputs; x1: (B, C)."""
    window = jnp.concatenate([conv_state, x1[:, None, :]], axis=1)  # (B,cw,C)
    out = jnp.einsum("bkc,kc->bc", window, w) + bias
    return window[:, 1:], out


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------
def mamba2_decls(cfg: ArchConfig) -> Dict[str, PDecl]:
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    nh = di // s.head_dim
    n = s.state_dim
    cdim = di + 2 * n
    return {
        "norm": PDecl((d,), (None,), init="ones"),
        "in_proj": PDecl((d, 2 * di + 2 * n + nh), ("embed", "ff")),
        "conv_w": PDecl((s.conv_dim, cdim), ("conv", None), scale=0.3),
        "conv_b": PDecl((cdim,), (None,), init="zeros"),
        "a_log": PDecl((nh,), (None,), dtype=F32, init="zeros"),
        "dt_bias": PDecl((nh,), (None,), dtype=F32, init="zeros"),
        "d_skip": PDecl((nh,), (None,), dtype=F32, init="ones"),
        "gnorm": PDecl((di,), (None,), init="ones"),
        "out_proj": PDecl((di, d), ("ff", "embed")),
    }


def _mamba2_split(p, cfg, h):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    n = s.state_dim
    nh = di // s.head_dim
    z, xc, bm, cm, dt = jnp.split(
        h, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, xc, bm, cm, dt, di, n, nh


def mamba2_forward(p, cfg: ArchConfig, x, rules: LogicalRules,
                   return_state: bool = False):
    s = cfg.ssm
    hin = rms_norm(x, p["norm"], cfg.norm_eps)
    h = jnp.einsum("bsd,dk->bsk", hin, p["in_proj"])
    z, xc, bm, cm, dt, di, n, nh = _mamba2_split(p, cfg, h)
    conv_in = jnp.concatenate([xc, bm, cm], axis=-1)
    conv_out = jax.nn.silu(causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xc, bm, cm = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])            # (B,S,nh)
    la = -jnp.exp(p["a_log"]) * dt
    xh = xc.reshape(*xc.shape[:2], nh, s.head_dim).astype(F32)
    xbar = xh * dt[..., None]
    y, s_fin = ssd_chunked(xbar, la, bm.astype(F32), cm.astype(F32), s.chunk)
    y = y + p["d_skip"][:, None] * xh
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    out = x + jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    if return_state:
        cw = s.conv_dim
        conv_state = jnp.pad(conv_in, ((0, 0), (cw - 1, 0), (0, 0)))[:, -(cw - 1):]
        return out, (s_fin, conv_state)
    return out


def mamba2_init_state(cfg: ArchConfig, batch: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    return {
        "ssm": jnp.zeros((batch, nh, s.state_dim, s.head_dim), F32),
        "conv": jnp.zeros((batch, s.conv_dim - 1, di + 2 * s.state_dim),
                          jnp.bfloat16),
    }


def mamba2_decode(p, cfg: ArchConfig, x1, state, rules: LogicalRules):
    """x1: (B,1,d). state: {"ssm","conv"}. Returns (out (B,1,d), state)."""
    s = cfg.ssm
    hin = rms_norm(x1[:, 0], p["norm"], cfg.norm_eps)
    h = jnp.einsum("bd,dk->bk", hin, p["in_proj"])
    z, xc, bm, cm, dt, di, n, nh = _mamba2_split(p, cfg, h)
    conv_in = jnp.concatenate([xc, bm, cm], axis=-1)
    conv_state, conv_out = causal_conv_step(
        state["conv"], conv_in, p["conv_w"], p["conv_b"])
    conv_out = jax.nn.silu(conv_out)
    xc, bm, cm = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])            # (B,nh)
    la = -jnp.exp(p["a_log"]) * dt
    xh = xc.reshape(-1, nh, s.head_dim).astype(F32)
    ssm, y = ssd_step(state["ssm"], xh * dt[..., None], la,
                      bm.astype(F32), cm.astype(F32))
    y = y + p["d_skip"][:, None] * xh
    y = y.reshape(-1, di).astype(x1.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    out = x1 + jnp.einsum("bk,kd->bd", y, p["out_proj"])[:, None]
    return out, {"ssm": ssm, "conv": conv_state}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM block (chunk-parallel) and sLSTM block (sequential)
# ---------------------------------------------------------------------------
GATE_CAP = 4.0   # soft cap replacing the xLSTM stabilizer in the chunked path


def mlstm_decls(cfg: ArchConfig) -> Dict[str, PDecl]:
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    nh = cfg.attention.n_heads
    return {
        "norm": PDecl((d,), (None,), init="ones"),
        "up": PDecl((d, 2 * di), ("embed", "ff")),
        "conv_w": PDecl((s.conv_dim, di), ("conv", None), scale=0.3),
        "conv_b": PDecl((di,), (None,), init="zeros"),
        "wq": PDecl((di, di), ("ff", None)),
        "wk": PDecl((di, di), ("ff", None)),
        "wv": PDecl((di, di), ("ff", None)),
        "wgate": PDecl((d, 2 * nh), ("embed", None), dtype=F32),
        "bgate": PDecl((2 * nh,), (None,), dtype=F32, init="zeros"),
        "gnorm": PDecl((di,), (None,), init="ones"),
        "down": PDecl((di, d), ("ff", "embed")),
    }


def _mlstm_qkv(p, cfg, hin):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = cfg.attention.n_heads
    hd = di // nh
    up = jnp.einsum("...d,dk->...k", hin, p["up"])
    xb, z = jnp.split(up, 2, axis=-1)
    return xb, z, di, nh, hd


def mlstm_forward(p, cfg: ArchConfig, x, rules: LogicalRules,
                  return_state: bool = False):
    """mLSTM via SSD: decay=logsigmoid(f), input i=exp(min(i_raw, cap)),
    state driven by (i * v) k^T, queried by q; normalizer via an extra
    all-ones value channel."""
    hin = rms_norm(x, p["norm"], cfg.norm_eps)
    xb, z, di, nh, hd = _mlstm_qkv(p, cfg, hin)
    conv = jax.nn.silu(causal_conv(xb, p["conv_w"], p["conv_b"]))
    q = jnp.einsum("bsk,kj->bsj", conv, p["wq"]).reshape(*x.shape[:2], nh, hd)
    k = jnp.einsum("bsk,kj->bsj", conv, p["wk"]).reshape(*x.shape[:2], nh, hd)
    v = jnp.einsum("bsk,kj->bsj", xb, p["wv"]).reshape(*x.shape[:2], nh, hd)
    gates = jnp.einsum("bsd,dg->bsg", hin.astype(F32), p["wgate"]) + p["bgate"]
    ig, fg = jnp.split(gates, 2, axis=-1)                          # (B,S,nh)
    la = jax.nn.log_sigmoid(fg)
    i = jnp.exp(jnp.minimum(ig, GATE_CAP))
    scale = 1.0 / np.sqrt(hd)
    # one SSD per head: state dim = key dim. v' = [v, 1] for the normalizer.
    vn = jnp.concatenate([v.astype(F32), jnp.ones_like(v[..., :1], F32)], -1)
    xbar = vn * i[..., None]
    b, ssteps = x.shape[:2]
    # fold heads into batch so B/C can stay per-head (SSD shares B/C per head)
    def fold(t):  # (B,S,nh,*) -> (B*nh, S, 1, *)
        return jnp.moveaxis(t, 2, 1).reshape(b * nh, ssteps, 1, *t.shape[3:])
    y, s_fin = ssd_chunked(
        fold(xbar),
        fold(la[..., None])[..., 0],
        fold(k.astype(F32) * scale)[:, :, 0],
        fold(q.astype(F32))[:, :, 0],
        cfg.ssm.chunk)
    y = jnp.moveaxis(y.reshape(b, nh, ssteps, hd + 1), 1, 2)
    num, den = y[..., :hd], y[..., hd:]
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.reshape(b, ssteps, di).astype(x.dtype)
    h = rms_norm(h * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    out = x + jnp.einsum("bsk,kd->bsd", h, p["down"])
    if return_state:
        cw = cfg.ssm.conv_dim
        conv_state = jnp.pad(xb, ((0, 0), (cw - 1, 0), (0, 0)))[:, -(cw - 1):]
        return out, (s_fin.reshape(b, nh, hd, hd + 1), conv_state)
    return out


def mlstm_init_state(cfg: ArchConfig, batch: int):
    di = cfg.ssm.expand * cfg.d_model
    nh = cfg.attention.n_heads
    hd = di // nh
    return {
        "ssm": jnp.zeros((batch, nh, hd, hd + 1), F32),
        "conv": jnp.zeros((batch, cfg.ssm.conv_dim - 1, di), jnp.bfloat16),
    }


def mlstm_decode(p, cfg: ArchConfig, x1, state, rules: LogicalRules):
    hin = rms_norm(x1[:, 0], p["norm"], cfg.norm_eps)
    xb, z, di, nh, hd = _mlstm_qkv(p, cfg, hin)
    conv_state, conv = causal_conv_step(state["conv"], xb, p["conv_w"], p["conv_b"])
    conv = jax.nn.silu(conv)
    q = (conv @ p["wq"]).reshape(-1, nh, hd)
    k = (conv @ p["wk"]).reshape(-1, nh, hd)
    v = (xb @ p["wv"]).reshape(-1, nh, hd)
    gates = hin.astype(F32) @ p["wgate"] + p["bgate"]
    ig, fg = jnp.split(gates, 2, axis=-1)
    la = jax.nn.log_sigmoid(fg)                                   # (B,nh)
    i = jnp.exp(jnp.minimum(ig, GATE_CAP))
    scale = 1.0 / np.sqrt(hd)
    vn = jnp.concatenate([v.astype(F32), jnp.ones_like(v[..., :1], F32)], -1)
    s_new = state["ssm"] * jnp.exp(la)[..., None, None] + jnp.einsum(
        "bhk,bhp->bhkp", k.astype(F32) * scale, vn * i[..., None])
    y = jnp.einsum("bhk,bhkp->bhp", q.astype(F32), s_new)
    h = (y[..., :hd] / jnp.maximum(jnp.abs(y[..., hd:]), 1.0)).reshape(-1, di)
    h = rms_norm(h.astype(x1.dtype) * jax.nn.silu(z), p["gnorm"], cfg.norm_eps)
    out = x1 + (h @ p["down"])[:, None]
    return out, {"ssm": s_new, "conv": conv_state}


# --- sLSTM -----------------------------------------------------------------
def slstm_decls(cfg: ArchConfig) -> Dict[str, PDecl]:
    d = cfg.d_model
    nh = cfg.attention.n_heads
    hd = d // nh
    ffs = int(d * 4 / 3 / 64) * 64 or 64
    return {
        "norm": PDecl((d,), (None,), init="ones"),
        "w": PDecl((d, 4 * d), ("embed", "ff")),
        "r": PDecl((nh, hd, 4 * hd), (None, None, None), scale=0.05),
        "b": PDecl((4 * d,), (None,), dtype=F32, init="zeros"),
        "gnorm": PDecl((d,), (None,), init="ones"),
        "up": PDecl((d, 2 * ffs), ("embed", "ff")),
        "down": PDecl((ffs, d), ("ff", "embed")),
    }


def slstm_cell(params_r, b, nh, hd, carry, wx_t):
    """Stabilized sLSTM step.  carry: (c, n, h, m) each (B, nh, hd).

    wx_t: (B, 4d) laid out as [z|i|f|o] each d = nh*hd wide; the recurrent
    matrix R (nh, hd, 4*hd) produces the same four gates per head.
    """
    c, n, h, m = carry
    bsz = wx_t.shape[0]
    rh = jnp.einsum("bhk,hkg->bhg", h, params_r)                # (B,nh,4*hd)
    wx4 = wx_t.reshape(bsz, 4, nh, hd).transpose(0, 2, 1, 3)    # (B,nh,4,hd)
    rh4 = rh.reshape(bsz, nh, 4, hd)
    b4 = b.reshape(4, nh, hd).transpose(1, 0, 2)                # (nh,4,hd)
    pre = wx4 + rh4 + b4
    zt, it, ft, ot = (pre[:, :, i] for i in range(4))
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(log_f + m - m_new)
    c_new = fp * c + ip * zt
    n_new = fp * n + ip
    h_new = ot * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(p, cfg: ArchConfig, x, rules: LogicalRules,
                  return_state: bool = False):
    d = cfg.d_model
    nh = cfg.attention.n_heads
    hd = d // nh
    b, s, _ = x.shape
    hin = rms_norm(x, p["norm"], cfg.norm_eps)
    wx = jnp.einsum("bsd,dg->bsg", hin, p["w"]).astype(F32)      # (B,S,4d)
    carry0 = tuple(jnp.zeros((b, nh, hd), F32) for _ in range(4))
    cell = lambda carry, wx_t: slstm_cell(p["r"].astype(F32), p["b"], nh, hd,
                                          carry, wx_t)
    carry, hs = jax.lax.scan(cell, carry0, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    h = rms_norm(h, p["gnorm"], cfg.norm_eps)
    uv = jnp.einsum("bsd,dk->bsk", h, p["up"])
    u, v = jnp.split(uv, 2, axis=-1)
    out = x + jnp.einsum("bsk,kd->bsd", jax.nn.silu(u) * v, p["down"])
    if return_state:
        return out, carry
    return out


def slstm_init_state(cfg: ArchConfig, batch: int):
    nh = cfg.attention.n_heads
    hd = cfg.d_model // nh
    return tuple(jnp.zeros((batch, nh, hd), F32) for _ in range(4))


def slstm_decode(p, cfg: ArchConfig, x1, state, rules: LogicalRules):
    d = cfg.d_model
    nh = cfg.attention.n_heads
    hd = d // nh
    hin = rms_norm(x1[:, 0], p["norm"], cfg.norm_eps)
    wx = (hin @ p["w"]).astype(F32)
    state, h = slstm_cell(p["r"].astype(F32), p["b"], nh, hd, state, wx)
    h = h.reshape(-1, d).astype(x1.dtype)
    h = rms_norm(h, p["gnorm"], cfg.norm_eps)
    uv = h @ p["up"]
    u, v = jnp.split(uv, 2, axis=-1)
    out = x1 + ((jax.nn.silu(u) * v) @ p["down"])[:, None]
    return out, state
