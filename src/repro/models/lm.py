"""Model assembly: every assigned architecture becomes a ModelBundle with

* ``decls``            — PDecl tree (params; init/shape/sharding views)
* ``loss_fn(params, batch)``            -> (loss, metrics)  [train_4k]
* ``prefill_fn(params, batch)``         -> (logits_last, cache)  [prefill_32k]
* ``decode_fn(params, cache, batch)``   -> (logits, cache)  [decode_*]
* ``cache_decls(shape)``  — PDecl tree of the decode cache

Layers are scanned (jax.lax.scan) so the HLO stays compact at 100 layers;
heterogeneous stacks (hybrid/vlm/xlstm) scan over repeating groups.  Remat
wraps each scanned body.  Cross-entropy is computed in sequence chunks over
vocab-sharded logits (never materializes (B,S,V) at once).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import ssm as S
from repro.models.layers import (
    attn_decls, attn_decode, attn_forward, mlp_decls, mlp_forward, rms_norm,
    sinusoidal_pos,
)
from repro.models.moe import moe_decls, moe_forward
from repro.models.param import PDecl, is_decl
from repro.runtime import maybe_scan
from repro.sharding.axes import LogicalRules, logical_constraint

F32 = jnp.float32


def stack_decls(tree, n: int):
    return jax.tree.map(
        lambda p: PDecl((n,) + p.shape, ("layers",) + p.logical,
                        p.dtype, p.init, p.scale),
        tree, is_leaf=is_decl)


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    policy = {
        "full": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    }[mode]
    return jax.checkpoint(fn, policy=policy)


@dataclass
class ModelBundle:
    arch: ArchConfig
    decls: Any
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable
    cache_decls: Callable          # (ShapeConfig) -> PDecl tree
    input_specs: Callable          # (ShapeConfig) -> dict of PDecl
    rules: LogicalRules


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------
def _emb_decls(cfg: ArchConfig) -> Dict[str, PDecl]:
    d = {"emb": PDecl((cfg.vocab_size, cfg.d_model), ("vocab", "embed_tp"))}
    if not cfg.tie_embeddings:
        d["unemb"] = PDecl((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    d["lnf"] = PDecl((cfg.d_model,), (None,), init="ones")
    return d


def _unemb(params, cfg):
    return params["emb"].T if cfg.tie_embeddings else params["unemb"]


def _embed(params, tokens):
    return params["emb"][tokens]


def chunked_ce_loss(unemb, h, targets, rules: LogicalRules, chunk: int = 512):
    """Mean CE over (B,S) with seq-chunked vocab-sharded logits."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    nch = s // chunk
    hs = jnp.moveaxis(h.reshape(b, nch, chunk, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(b, nch, chunk), 1, 0)

    def body(acc, xs):
        hc, tc = xs
        logits = jnp.einsum("bsd,dv->bsv", hc, unemb).astype(F32)
        logits = logical_constraint(logits, rules, "batch", None, "vocab_logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    tot, _ = maybe_scan(body, jnp.zeros((), F32), (hs, ts))
    return tot / (b * s)


def _last_logits(unemb, h, rules):
    logits = jnp.einsum("bd,dv->bv", h[:, -1], unemb).astype(F32)
    return logical_constraint(logits, rules, "batch", "vocab_logits")


def _kv_cache_decls(cfg: ArchConfig, n_layers: int, batch: int, s_max: int,
                    prefix: Tuple[int, ...] = ()):
    a = cfg.attention
    cap = min(s_max, a.sliding_window) if a.sliding_window else s_max
    lead = prefix + (n_layers,) if n_layers else prefix
    lax_names = tuple(None for _ in lead)
    if a.is_mla:
        return {
            "c": PDecl(lead + (batch, cap, a.kv_lora_rank),
                       lax_names + ("batch", "kv_seq", None)),
            "krope": PDecl(lead + (batch, cap, a.rope_head_dim),
                           lax_names + ("batch", "kv_seq", None)),
        }, cap
    return {
        "k": PDecl(lead + (batch, cap, a.n_kv_heads, a.head_dim),
                   lax_names + ("batch", "kv_seq", None, None)),
        "v": PDecl(lead + (batch, cap, a.n_kv_heads, a.head_dim),
                   lax_names + ("batch", "kv_seq", None, None)),
    }, cap


def _pos_decls(batch: int, cap: int):
    return {
        "slot_pos": PDecl((batch, cap), ("batch", "kv_seq"),
                          dtype=jnp.int32, init="zeros"),
        "cur": PDecl((batch,), ("batch",), dtype=jnp.int32, init="zeros"),
    }


def _advance_pos(cache, cap: int):
    """Update the shared slot->position table for this step."""
    cur = cache["cur"]
    slot = cur % cap
    bidx = jnp.arange(cur.shape[0])
    slot_pos = cache["slot_pos"].at[bidx, slot].set(cur)
    return cur, slot_pos


def make_init_cache(cache_decls_fn):
    def init_cache(shape: ShapeConfig):
        from repro.models.param import struct_tree
        decls = cache_decls_fn(shape)

        def mk(d: PDecl):
            if d.dtype == jnp.int32:
                return jnp.full(d.shape, -1, jnp.int32) if d.shape[-1] != d.shape[0] or True else None
            return jnp.zeros(d.shape, d.dtype)

        out = jax.tree.map(mk, decls, is_leaf=is_decl)
        # "cur" starts at 0, slot tables at -1 (handled above: all int32 -> -1)
        def fix(path, arr):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            if name == "cur":
                return jnp.zeros_like(arr)
            return arr
        return jax.tree_util.tree_map_with_path(fix, out)
    return init_cache


# ---------------------------------------------------------------------------
# decoder-only (dense + MoE families) — also the text stack for VLM
# ---------------------------------------------------------------------------
def _dense_layer_decls(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln1": PDecl((cfg.d_model,), (None,), init="ones"),
        "attn": attn_decls(cfg.attention, cfg.d_model),
        "ln2": PDecl((cfg.d_model,), (None,), init="ones"),
        "mlp": mlp_decls(cfg.d_model, cfg.d_ff, cfg.glu),
    }


def _moe_layer_decls(cfg: ArchConfig, ep: int) -> Dict[str, Any]:
    return {
        "ln1": PDecl((cfg.d_model,), (None,), init="ones"),
        "attn": attn_decls(cfg.attention, cfg.d_model),
        "ln2": PDecl((cfg.d_model,), (None,), init="ones"),
        "moe": moe_decls(cfg, ep),
    }


def build_decoder(cfg: ArchConfig, rules: LogicalRules, mesh=None,
                  remat: str = "full", attn_chunk: int = 1024,
                  ep_axis: str = "model") -> ModelBundle:
    is_moe = cfg.family == "moe"
    ep = mesh.shape[ep_axis] if (is_moe and mesh is not None) else 1
    n_dense_head = cfg.moe.first_dense_layers if is_moe else 0
    n_scan = cfg.n_layers - n_dense_head

    decls = _emb_decls(cfg)
    if is_moe:
        decls["layers"] = stack_decls(_moe_layer_decls(cfg, ep), n_scan)
        if n_dense_head:
            dense_cfg = cfg
            head = {
                "ln1": PDecl((cfg.d_model,), (None,), init="ones"),
                "attn": attn_decls(cfg.attention, cfg.d_model),
                "ln2": PDecl((cfg.d_model,), (None,), init="ones"),
                "mlp": mlp_decls(cfg.d_model, cfg.moe.d_first_dense, cfg.glu),
            }
            decls["head_layers"] = stack_decls(head, n_dense_head)
    else:
        decls["layers"] = stack_decls(_dense_layer_decls(cfg), n_scan)

    def attn_block(lp, h, positions):
        a, kv = attn_forward(lp["attn"], cfg.attention,
                             rms_norm(h, lp["ln1"], cfg.norm_eps),
                             positions, rules, chunk=attn_chunk)
        h = h + a
        h = logical_constraint(h, rules, "batch", "seq_shard", "act_embed")
        return h, kv

    def dense_body(h, lp, positions, width=None):
        h, kv = attn_block(lp, h, positions)
        m = mlp_forward(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                        cfg.act, cfg.glu, rules)
        return h + m, kv

    def moe_body(h, lp, positions):
        h, kv = attn_block(lp, h, positions)
        m, aux = moe_forward(lp["moe"], cfg,
                             rms_norm(h, lp["ln2"], cfg.norm_eps),
                             rules, mesh=mesh, ep_axis=ep_axis)
        return h + m, kv, aux

    def backbone(params, tokens, collect_kv: bool = False):
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        h = _embed(params, tokens)
        h = logical_constraint(h, rules, "batch", "seq_shard", "act_embed")
        aux_total = jnp.zeros((), F32)

        if is_moe and n_dense_head:
            def hbody(h, lp):
                h, kv = dense_body(h, lp, positions)
                return h, kv if collect_kv else None
            h, head_kv = maybe_scan(
                _remat(hbody, remat), h, params["head_layers"])
        else:
            head_kv = None

        if is_moe:
            def body(carry, lp):
                h, aux = carry
                h, kv, a = moe_body(h, lp, positions)
                return (h, aux + a), kv if collect_kv else None
            (h, aux_total), kvs = maybe_scan(
                _remat(body, remat), (h, aux_total), params["layers"])
        else:
            def body(h, lp):
                h, kv = dense_body(h, lp, positions)
                return h, kv if collect_kv else None
            h, kvs = maybe_scan(_remat(body, remat), h, params["layers"])

        h = rms_norm(h, params["lnf"], cfg.norm_eps)
        return h, aux_total, (head_kv, kvs)

    def loss_fn(params, batch):
        h, aux, _ = backbone(params, batch["tokens"])
        ce = chunked_ce_loss(_unemb(params, cfg), h, batch["targets"], rules)
        return ce + aux, {"ce": ce, "aux": aux}

    def cache_decls(shape: ShapeConfig):
        kv, cap = _kv_cache_decls(cfg, n_scan, shape.global_batch, shape.seq_len)
        out = {"layers": kv}
        if is_moe and n_dense_head:
            hkv, _ = _kv_cache_decls(cfg, n_dense_head, shape.global_batch,
                                     shape.seq_len)
            out["head_layers"] = hkv
        out.update(_pos_decls(shape.global_batch, cap))
        return out

    def prefill_fn(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        h, _, (head_kv, kvs) = backbone(params, tokens, collect_kv=True)
        logits = _last_logits(_unemb(params, cfg), h, rules)

        def to_cache(kv):
            if cfg.attention.is_mla:
                c, krope = kv
                return {"c": c, "krope": krope}
            k, v = kv
            return {"k": k, "v": v}

        cache = {"layers": to_cache(kvs)}
        if head_kv is not None:
            cache["head_layers"] = to_cache(head_kv)
        cache["slot_pos"] = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        cache["cur"] = jnp.full((b,), s, jnp.int32)
        return logits, cache

    def decode_fn(params, cache, batch):
        tokens = batch["tokens"]                     # (B, 1)
        cap = cache["slot_pos"].shape[1]
        cur, slot_pos = _advance_pos(cache, cap)
        h = _embed(params, tokens)

        def step_layer(h, lp, lc):
            a, lc2 = attn_decode(lp["attn"], cfg.attention,
                                 rms_norm(h, lp["ln1"], cfg.norm_eps),
                                 cur, slot_pos, lc, rules)
            h = h + a
            if is_moe and "moe" in lp:
                m, _ = moe_forward(lp["moe"], cfg,
                                   rms_norm(h, lp["ln2"], cfg.norm_eps),
                                   rules, mesh=mesh, ep_axis=ep_axis)
            else:
                m = mlp_forward(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                                cfg.act, cfg.glu, rules)
            return h + m, lc2

        new_cache = dict(cache)
        if is_moe and n_dense_head:
            def hbody(h, xs):
                lp, lc = xs
                return step_layer(h, lp, lc)
            h, hkv = maybe_scan(hbody, h,
                                  (params["head_layers"], cache["head_layers"]))
            new_cache["head_layers"] = hkv

        def body(h, xs):
            lp, lc = xs
            return step_layer(h, lp, lc)

        h, kvs = maybe_scan(body, h, (params["layers"], cache["layers"]))
        new_cache["layers"] = kvs
        h = rms_norm(h, params["lnf"], cfg.norm_eps)
        logits = _last_logits(_unemb(params, cfg), h, rules)
        new_cache["slot_pos"] = slot_pos
        new_cache["cur"] = cur + 1
        return logits, new_cache

    def input_specs(shape: ShapeConfig):
        b = shape.global_batch
        s = shape.seq_len if shape.kind != "decode" else 1
        specs = {"tokens": PDecl((b, s), ("batch", None), dtype=jnp.int32)}
        if shape.kind == "train":
            specs["targets"] = PDecl((b, s), ("batch", None), dtype=jnp.int32)
        return specs

    return ModelBundle(cfg, decls, loss_fn, prefill_fn, decode_fn,
                       cache_decls, input_specs, rules)


# ---------------------------------------------------------------------------
# xLSTM (ssm family): groups of (slstm_every-1) mLSTM + 1 sLSTM
# ---------------------------------------------------------------------------
def build_xlstm(cfg: ArchConfig, rules: LogicalRules, mesh=None,
                remat: str = "full", attn_chunk: int = 1024) -> ModelBundle:
    per = cfg.ssm.slstm_every or cfg.n_layers
    n_groups = cfg.n_layers // per
    n_m = per - 1 if cfg.ssm.slstm_every else per

    decls = _emb_decls(cfg)
    m_decls = stack_decls(stack_decls(S.mlstm_decls(cfg), n_m), n_groups)
    decls["mlstm"] = m_decls
    if cfg.ssm.slstm_every:
        decls["slstm"] = stack_decls(S.slstm_decls(cfg), n_groups)

    def backbone(params, tokens, states=None, cur=None, collect_state=False):
        h = _embed(params, tokens)
        h = logical_constraint(h, rules, "batch", "seq_shard", "act_embed")

        def group(h, gp):
            def mbody(h, lp):
                out = S.mlstm_forward(lp, cfg, h, rules,
                                      return_state=collect_state)
                if collect_state:
                    return out[0], out[1]
                return out, None
            h, mstates = maybe_scan(_remat(mbody, remat), h, gp["m"])
            sstate = None
            if cfg.ssm.slstm_every:
                out = S.slstm_forward(gp["s"], cfg, h, rules,
                                      return_state=collect_state)
                if collect_state:
                    h, sstate = out
                else:
                    h = out
            return h, (mstates, sstate)

        gparams = {"m": params["mlstm"]}
        if cfg.ssm.slstm_every:
            gparams["s"] = params["slstm"]
        h, states_out = maybe_scan(group, h, gparams)
        h = rms_norm(h, params["lnf"], cfg.norm_eps)
        return h, states_out

    def loss_fn(params, batch):
        h, _ = backbone(params, batch["tokens"])
        ce = chunked_ce_loss(_unemb(params, cfg), h, batch["targets"], rules)
        return ce, {"ce": ce}

    def cache_decls(shape: ShapeConfig):
        b = shape.global_batch
        di = cfg.ssm.expand * cfg.d_model
        nh = cfg.attention.n_heads
        hd = di // nh
        out = {
            "m_ssm": PDecl((n_groups, n_m, b, nh, hd, hd + 1),
                           (None, None, "batch", None, None, None), dtype=F32,
                           init="zeros"),
            "m_conv": PDecl((n_groups, n_m, b, cfg.ssm.conv_dim - 1, di),
                            (None, None, "batch", None, None), init="zeros"),
            "cur": PDecl((b,), ("batch",), dtype=jnp.int32, init="zeros"),
        }
        if cfg.ssm.slstm_every:
            shd = cfg.d_model // nh
            out["s_state"] = PDecl((n_groups, 4, b, nh, shd),
                                   (None, None, "batch", None, None),
                                   dtype=F32, init="zeros")
        return out

    def prefill_fn(params, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        h, states = backbone(params, tokens, collect_state=True)
        logits = _last_logits(_unemb(params, cfg), h, rules)
        mstates, sstates = states
        cache = {
            "m_ssm": mstates[0], "m_conv": mstates[1],
            "cur": jnp.full((b,), tokens.shape[1], jnp.int32),
        }
        if cfg.ssm.slstm_every:
            cache["s_state"] = jnp.stack(sstates, axis=1) \
                if isinstance(sstates, tuple) else sstates
        return logits, cache

    def decode_fn(params, cache, batch):
        h = _embed(params, batch["tokens"])

        def group(h, xs):
            gp, gc = xs
            def mbody(h, xs2):
                lp, (ssm_st, conv_st) = xs2
                h, st = S.mlstm_decode(lp, cfg, h, {"ssm": ssm_st, "conv": conv_st},
                                       rules)
                return h, (st["ssm"], st["conv"])
            h, mst = maybe_scan(mbody, h, (gp["m"], (gc["ssm"], gc["conv"])))
            sst = None
            if cfg.ssm.slstm_every:
                h, sst = S.slstm_decode(gp["s"], cfg, h, tuple(gc["sst"]), rules)
                sst = jnp.stack(sst)
            return h, (mst, sst)

        gparams = {"m": params["mlstm"]}
        gcache = {"ssm": cache["m_ssm"], "conv": cache["m_conv"]}
        if cfg.ssm.slstm_every:
            gparams["s"] = params["slstm"]
            gcache["sst"] = cache["s_state"]
        h, (mst, sst) = maybe_scan(group, h, (gparams, gcache))
        h = rms_norm(h, params["lnf"], cfg.norm_eps)
        logits = _last_logits(_unemb(params, cfg), h, rules)
        out = {"m_ssm": mst[0], "m_conv": mst[1], "cur": cache["cur"] + 1}
        if cfg.ssm.slstm_every:
            out["s_state"] = sst
        return logits, out

    def input_specs(shape: ShapeConfig):
        b = shape.global_batch
        s = shape.seq_len if shape.kind != "decode" else 1
        specs = {"tokens": PDecl((b, s), ("batch", None), dtype=jnp.int32)}
        if shape.kind == "train":
            specs["targets"] = PDecl((b, s), ("batch", None), dtype=jnp.int32)
        return specs

    return ModelBundle(cfg, decls, loss_fn, prefill_fn, decode_fn,
                       cache_decls, input_specs, rules)


# ---------------------------------------------------------------------------
# zamba2 hybrid: scan groups of (shared_attn_every-1 mamba + shared block)
# ---------------------------------------------------------------------------
def build_hybrid(cfg: ArchConfig, rules: LogicalRules, mesh=None,
                 remat: str = "full", attn_chunk: int = 1024) -> ModelBundle:
    per = cfg.shared_attn_every
    n_groups = cfg.n_layers // per
    n_m = per - 1
    n_tail = cfg.n_layers - n_groups * per

    decls = _emb_decls(cfg)
    decls["mamba"] = stack_decls(stack_decls(S.mamba2_decls(cfg), n_m), n_groups)
    if n_tail:
        decls["tail"] = stack_decls(S.mamba2_decls(cfg), n_tail)
    decls["shared"] = _dense_layer_decls(cfg)   # ONE param set, 13 applications

    def shared_block(h, positions, params):
        lp = params["shared"]
        a, kv = attn_forward(lp["attn"], cfg.attention,
                             rms_norm(h, lp["ln1"], cfg.norm_eps),
                             positions, rules, chunk=attn_chunk)
        h = h + a
        m = mlp_forward(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                        cfg.act, cfg.glu, rules)
        return h + m, kv

    def backbone(params, tokens, collect=False):
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        h = _embed(params, tokens)
        h = logical_constraint(h, rules, "batch", "seq_shard", "act_embed")

        def group(h, gp):
            def mbody(h, lp):
                out = S.mamba2_forward(lp, cfg, h, rules, return_state=collect)
                return (out[0], out[1]) if collect else (out, None)
            h, mstates = maybe_scan(_remat(mbody, remat), h, gp)
            h, kv = shared_block(h, positions, params)
            return h, (mstates, kv if collect else None)

        h, (mstates, kvs) = maybe_scan(group, h, params["mamba"])
        tail_states = None
        if n_tail:
            def tbody(h, lp):
                out = S.mamba2_forward(lp, cfg, h, rules, return_state=collect)
                return (out[0], out[1]) if collect else (out, None)
            h, tail_states = maybe_scan(_remat(tbody, remat), h, params["tail"])
        h = rms_norm(h, params["lnf"], cfg.norm_eps)
        return h, (mstates, kvs, tail_states)

    def loss_fn(params, batch):
        h, _ = backbone(params, batch["tokens"])
        ce = chunked_ce_loss(_unemb(params, cfg), h, batch["targets"], rules)
        return ce, {"ce": ce}

    def cache_decls(shape: ShapeConfig):
        b = shape.global_batch
        s2 = cfg.ssm
        di = s2.expand * cfg.d_model
        nh = di // s2.head_dim
        kv, cap = _kv_cache_decls(cfg, 0, b, shape.seq_len, prefix=(n_groups,))
        out = {
            "m_ssm": PDecl((n_groups, n_m, b, nh, s2.state_dim, s2.head_dim),
                           (None, None, "batch", None, None, None),
                           dtype=F32, init="zeros"),
            "m_conv": PDecl((n_groups, n_m, b, s2.conv_dim - 1,
                             di + 2 * s2.state_dim),
                            (None, None, "batch", None, None), init="zeros"),
            "shared_kv": kv,
        }
        if n_tail:
            out["t_ssm"] = PDecl((n_tail, b, nh, s2.state_dim, s2.head_dim),
                                 (None, "batch", None, None, None),
                                 dtype=F32, init="zeros")
            out["t_conv"] = PDecl((n_tail, b, s2.conv_dim - 1,
                                   di + 2 * s2.state_dim),
                                  (None, "batch", None, None), init="zeros")
        out.update(_pos_decls(b, cap))
        return out

    def prefill_fn(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        h, (mstates, kvs, tail_states) = backbone(params, tokens, collect=True)
        logits = _last_logits(_unemb(params, cfg), h, rules)
        a = cfg.attention
        cap = min(s, a.sliding_window) if a.sliding_window else s
        k, v = kvs
        cache = {
            "m_ssm": mstates[0], "m_conv": mstates[1],
            "shared_kv": {"k": k[:, :, -cap:], "v": v[:, :, -cap:]},
            "slot_pos": jnp.broadcast_to(jnp.arange(s - cap, s)[None], (b, cap)),
            "cur": jnp.full((b,), s, jnp.int32),
        }
        if n_tail:
            cache["t_ssm"], cache["t_conv"] = tail_states
        return logits, cache

    def decode_fn(params, cache, batch):
        cap = cache["slot_pos"].shape[1]
        cur, slot_pos = _advance_pos(cache, cap)
        h = _embed(params, batch["tokens"])

        def group(h, xs):
            gp, (ssm_st, conv_st, kv) = xs
            def mbody(h, xs2):
                lp, (s1, c1) = xs2
                h, st = S.mamba2_decode(lp, cfg, h, {"ssm": s1, "conv": c1}, rules)
                return h, (st["ssm"], st["conv"])
            h, mst = maybe_scan(mbody, h, (gp, (ssm_st, conv_st)))
            lp = params["shared"]
            a, kv2 = attn_decode(lp["attn"], cfg.attention,
                                 rms_norm(h, lp["ln1"], cfg.norm_eps),
                                 cur, slot_pos, kv, rules)
            h = h + a
            m = mlp_forward(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                            cfg.act, cfg.glu, rules)
            return h + m, (mst, kv2)

        h, (mst, kv2) = maybe_scan(
            group, h,
            (params["mamba"], (cache["m_ssm"], cache["m_conv"],
                               cache["shared_kv"])))
        out = {"m_ssm": mst[0], "m_conv": mst[1], "shared_kv": kv2}
        if n_tail:
            def tbody(h, xs2):
                lp, (s1, c1) = xs2
                h, st = S.mamba2_decode(lp, cfg, h, {"ssm": s1, "conv": c1}, rules)
                return h, (st["ssm"], st["conv"])
            h, tst = maybe_scan(tbody, h,
                                  (params["tail"], (cache["t_ssm"], cache["t_conv"])))
            out["t_ssm"], out["t_conv"] = tst
        h = rms_norm(h, params["lnf"], cfg.norm_eps)
        logits = _last_logits(_unemb(params, cfg), h, rules)
        out["slot_pos"] = slot_pos
        out["cur"] = cur + 1
        return logits, out

    def input_specs(shape: ShapeConfig):
        b = shape.global_batch
        s = shape.seq_len if shape.kind != "decode" else 1
        specs = {"tokens": PDecl((b, s), ("batch", None), dtype=jnp.int32)}
        if shape.kind == "train":
            specs["targets"] = PDecl((b, s), ("batch", None), dtype=jnp.int32)
        return specs

    return ModelBundle(cfg, decls, loss_fn, prefill_fn, decode_fn,
                       cache_decls, input_specs, rules)


# ---------------------------------------------------------------------------
# whisper-style enc-dec (audio) — conv frontend stubbed
# ---------------------------------------------------------------------------
def build_encdec(cfg: ArchConfig, rules: LogicalRules, mesh=None,
                 remat: str = "full", attn_chunk: int = 1024) -> ModelBundle:
    decls = _emb_decls(cfg)
    decls["frontend_proj"] = PDecl((cfg.d_frontend, cfg.d_model),
                                   ("frontend", "embed"))
    enc_layer = {
        "ln1": PDecl((cfg.d_model,), (None,), init="ones"),
        "attn": attn_decls(cfg.attention, cfg.d_model),
        "ln2": PDecl((cfg.d_model,), (None,), init="ones"),
        "mlp": mlp_decls(cfg.d_model, cfg.d_ff, cfg.glu),
    }
    dec_layer = dict(enc_layer)
    dec_layer["lnx"] = PDecl((cfg.d_model,), (None,), init="ones")
    dec_layer["cross"] = attn_decls(cfg.attention, cfg.d_model)
    decls["encoder"] = stack_decls(enc_layer, cfg.n_encoder_layers)
    decls["decoder"] = stack_decls(dec_layer, cfg.n_layers)
    decls["enc_lnf"] = PDecl((cfg.d_model,), (None,), init="ones")

    def encode(params, frames):
        b, s, _ = frames.shape
        h = jnp.einsum("bsf,fd->bsd", frames, params["frontend_proj"])
        h = h + sinusoidal_pos(s, cfg.d_model, h.dtype)[None]
        h = logical_constraint(h, rules, "batch", "seq_shard", "act_embed")
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def body(h, lp):
            a, _ = attn_forward(lp["attn"], cfg.attention,
                                rms_norm(h, lp["ln1"], cfg.norm_eps),
                                positions, rules, use_rope=False,
                                chunk=attn_chunk, causal=False)
            h = h + a
            m = mlp_forward(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                            cfg.act, cfg.glu, rules)
            return h + m, None

        h, _ = maybe_scan(_remat(body, remat), h, params["encoder"])
        return rms_norm(h, params["enc_lnf"], cfg.norm_eps)

    def decode_stack(params, tokens, enc_out, collect_kv=False):
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1])[None], (b, enc_out.shape[1]))
        h = _embed(params, tokens) + sinusoidal_pos(s, cfg.d_model)[None]

        def body(h, lp):
            a, kv = attn_forward(lp["attn"], cfg.attention,
                                 rms_norm(h, lp["ln1"], cfg.norm_eps),
                                 positions, rules, use_rope=False,
                                 chunk=attn_chunk)
            h = h + a
            c, xkv = attn_forward(lp["cross"], cfg.attention,
                                  rms_norm(h, lp["lnx"], cfg.norm_eps),
                                  positions, rules, use_rope=False,
                                  chunk=attn_chunk,
                                  kv_override=(enc_out, enc_pos))
            h = h + c
            m = mlp_forward(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                            cfg.act, cfg.glu, rules)
            return h + m, (kv, xkv) if collect_kv else None

        h, kvs = maybe_scan(_remat(body, remat), h, params["decoder"])
        return rms_norm(h, params["lnf"], cfg.norm_eps), kvs

    def loss_fn(params, batch):
        enc = encode(params, batch["frames"])
        h, _ = decode_stack(params, batch["tokens"], enc)
        ce = chunked_ce_loss(_unemb(params, cfg), h, batch["targets"], rules)
        return ce, {"ce": ce}

    def cache_decls(shape: ShapeConfig):
        b = shape.global_batch
        kv, cap = _kv_cache_decls(cfg, cfg.n_layers, b, shape.seq_len)
        xkv, _ = _kv_cache_decls(cfg, cfg.n_layers, b, shape.seq_len)
        out = {"self_kv": kv, "cross_kv": xkv}
        out.update(_pos_decls(b, cap))
        return out

    def prefill_fn(params, batch):
        """Encode the audio + run the decoder over the prompt tokens."""
        enc = encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        h, kvs = decode_stack(params, tokens, enc, collect_kv=True)
        logits = _last_logits(_unemb(params, cfg), h, rules)
        (k, v), (xk, xv) = kvs
        cache = {
            "self_kv": {"k": k, "v": v},
            "cross_kv": {"k": xk, "v": xv},
            "slot_pos": jnp.broadcast_to(jnp.arange(s)[None], (b, s)),
            "cur": jnp.full((b,), s, jnp.int32),
        }
        return logits, cache

    def decode_fn(params, cache, batch):
        cap = cache["slot_pos"].shape[1]
        cur, slot_pos = _advance_pos(cache, cap)
        h = _embed(params, batch["tokens"]) \
            + sinusoidal_pos(1, cfg.d_model)[None]

        def body(h, xs):
            lp, (lc, xc) = xs
            a, lc2 = attn_decode(lp["attn"], cfg.attention,
                                 rms_norm(h, lp["ln1"], cfg.norm_eps),
                                 cur, slot_pos, lc, rules, use_rope=False)
            h = h + a
            c, _ = attn_decode(lp["cross"], cfg.attention,
                               rms_norm(h, lp["lnx"], cfg.norm_eps),
                               cur, slot_pos, xc, rules, use_rope=False,
                               cross=True)
            h = h + c
            m = mlp_forward(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                            cfg.act, cfg.glu, rules)
            return h + m, lc2

        h, kv2 = maybe_scan(
            body, h, (params["decoder"], (cache["self_kv"], cache["cross_kv"])))
        h = rms_norm(h, params["lnf"], cfg.norm_eps)
        logits = _last_logits(_unemb(params, cfg), h, rules)
        return logits, {"self_kv": kv2, "cross_kv": cache["cross_kv"],
                        "slot_pos": slot_pos, "cur": cur + 1}

    def input_specs(shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {
                "frames": PDecl((b, s, cfg.d_frontend), ("batch", "seq_shard", None)),
                "tokens": PDecl((b, s), ("batch", None), dtype=jnp.int32),
                "targets": PDecl((b, s), ("batch", None), dtype=jnp.int32),
            }
        if shape.kind == "prefill":
            return {
                "frames": PDecl((b, s, cfg.d_frontend), ("batch", "seq_shard", None)),
                "tokens": PDecl((b, s), ("batch", None), dtype=jnp.int32),
            }
        return {"tokens": PDecl((b, 1), ("batch", None), dtype=jnp.int32)}

    return ModelBundle(cfg, decls, loss_fn, prefill_fn, decode_fn,
                       cache_decls, input_specs, rules)


# ---------------------------------------------------------------------------
# VLM: decoder with a gated cross-attention layer every Nth layer
# ---------------------------------------------------------------------------
def build_vlm(cfg: ArchConfig, rules: LogicalRules, mesh=None,
              remat: str = "full", attn_chunk: int = 1024) -> ModelBundle:
    per = cfg.cross_attn_every
    n_groups = cfg.n_layers // per
    n_self = per - 1

    decls = _emb_decls(cfg)
    decls["img_proj"] = PDecl((cfg.d_frontend, cfg.d_model),
                              ("frontend", "embed"))
    decls["self_layers"] = stack_decls(
        stack_decls(_dense_layer_decls(cfg), n_self), n_groups)
    cross_layer = dict(_dense_layer_decls(cfg))
    cross_layer["gate"] = PDecl((1,), (None,), dtype=F32, init="zeros")
    decls["cross_layers"] = stack_decls(cross_layer, n_groups)

    def backbone(params, tokens, img, collect_kv=False):
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        img_h = jnp.einsum("bnf,fd->bnd", img, params["img_proj"])
        img_pos = jnp.broadcast_to(
            jnp.arange(img_h.shape[1])[None], (b, img_h.shape[1]))
        h = _embed(params, tokens)
        h = logical_constraint(h, rules, "batch", "seq_shard", "act_embed")

        def group(h, gp):
            sp, cp = gp
            def sbody(h, lp):
                a, kv = attn_forward(lp["attn"], cfg.attention,
                                     rms_norm(h, lp["ln1"], cfg.norm_eps),
                                     positions, rules, chunk=attn_chunk)
                h = h + a
                m = mlp_forward(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                                cfg.act, cfg.glu, rules)
                return h + m, kv if collect_kv else None
            h, kvs = maybe_scan(_remat(sbody, remat), h, sp)
            a, xkv = attn_forward(cp["attn"], cfg.attention,
                                  rms_norm(h, cp["ln1"], cfg.norm_eps),
                                  positions, rules, use_rope=False,
                                  chunk=attn_chunk,
                                  kv_override=(img_h, img_pos))
            h = h + jnp.tanh(cp["gate"]).astype(h.dtype) * a
            m = mlp_forward(cp["mlp"], rms_norm(h, cp["ln2"], cfg.norm_eps),
                            cfg.act, cfg.glu, rules)
            h = h + m
            return h, (kvs, xkv if collect_kv else None)

        h, kv_all = maybe_scan(group, h,
                                 (params["self_layers"], params["cross_layers"]))
        h = rms_norm(h, params["lnf"], cfg.norm_eps)
        return h, kv_all

    def loss_fn(params, batch):
        h, _ = backbone(params, batch["tokens"], batch["img"])
        ce = chunked_ce_loss(_unemb(params, cfg), h, batch["targets"], rules)
        return ce, {"ce": ce}

    def cache_decls(shape: ShapeConfig):
        b = shape.global_batch
        kv, cap = _kv_cache_decls(cfg, n_self, b, shape.seq_len,
                                  prefix=(n_groups,))
        a = cfg.attention
        xkv = {
            "k": PDecl((n_groups, b, cfg.n_frontend_tokens, a.n_kv_heads,
                        a.head_dim), (None, "batch", None, None, None)),
            "v": PDecl((n_groups, b, cfg.n_frontend_tokens, a.n_kv_heads,
                        a.head_dim), (None, "batch", None, None, None)),
        }
        out = {"self_kv": kv, "cross_kv": xkv}
        out.update(_pos_decls(b, cap))
        return out

    def prefill_fn(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        h, (kvs, xkvs) = backbone(params, tokens, batch["img"], collect_kv=True)
        logits = _last_logits(_unemb(params, cfg), h, rules)
        k, v = kvs
        xk, xv = xkvs
        cache = {
            "self_kv": {"k": k, "v": v},
            "cross_kv": {"k": xk, "v": xv},
            "slot_pos": jnp.broadcast_to(jnp.arange(s)[None], (b, s)),
            "cur": jnp.full((b,), s, jnp.int32),
        }
        return logits, cache

    def decode_fn(params, cache, batch):
        cap = cache["slot_pos"].shape[1]
        cur, slot_pos = _advance_pos(cache, cap)
        h = _embed(params, batch["tokens"])

        def group(h, xs):
            (sp, cp), (lc, xc) = xs
            def sbody(h, xs2):
                lp, c1 = xs2
                a, c2 = attn_decode(lp["attn"], cfg.attention,
                                    rms_norm(h, lp["ln1"], cfg.norm_eps),
                                    cur, slot_pos, c1, rules)
                h = h + a
                m = mlp_forward(lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps),
                                cfg.act, cfg.glu, rules)
                return h + m, c2
            h, lc2 = maybe_scan(sbody, h, (sp, lc))
            a, _ = attn_decode(cp["attn"], cfg.attention,
                               rms_norm(h, cp["ln1"], cfg.norm_eps),
                               cur, slot_pos, xc, rules, use_rope=False,
                               cross=True)
            h = h + jnp.tanh(cp["gate"]).astype(h.dtype) * a
            m = mlp_forward(cp["mlp"], rms_norm(h, cp["ln2"], cfg.norm_eps),
                            cfg.act, cfg.glu, rules)
            return h + m, lc2

        h, lc2 = maybe_scan(
            group, h,
            ((params["self_layers"], params["cross_layers"]),
             (cache["self_kv"], cache["cross_kv"])))
        h = rms_norm(h, params["lnf"], cfg.norm_eps)
        logits = _last_logits(_unemb(params, cfg), h, rules)
        return logits, {"self_kv": lc2, "cross_kv": cache["cross_kv"],
                        "slot_pos": slot_pos, "cur": cur + 1}

    def input_specs(shape: ShapeConfig):
        b = shape.global_batch
        s = shape.seq_len if shape.kind != "decode" else 1
        specs = {
            "tokens": PDecl((b, s), ("batch", None), dtype=jnp.int32),
            "img": PDecl((b, cfg.n_frontend_tokens, cfg.d_frontend),
                         ("batch", None, None)),
        }
        if shape.kind == "train":
            specs["targets"] = PDecl((b, s), ("batch", None), dtype=jnp.int32)
        if shape.kind == "decode":
            specs.pop("img")   # image context lives in the cross-KV cache
        return specs

    return ModelBundle(cfg, decls, loss_fn, prefill_fn, decode_fn,
                       cache_decls, input_specs, rules)


# ---------------------------------------------------------------------------
def build_model(cfg: ArchConfig, rules: Optional[LogicalRules] = None,
                mesh=None, remat: str = "full",
                attn_chunk: int = 1024) -> ModelBundle:
    if rules is None:
        from repro.sharding.axes import rules_for
        rules = rules_for(cfg.name, "train", cfg.d_model)
    # Pad Q-heads to the TP degree when heads are model-sharded (40 -> 48,
    # 56 -> 64): otherwise the (H, hd) reshape of the fused projection can't
    # be mapped by the partitioner and it falls back to replicate+reshard
    # ("involuntary full rematerialization").  DESIGN.md §4; the padding
    # overhead shows up honestly in the MODEL_FLOPS/HLO ratio.
    if mesh is not None and rules.to_dict().get("heads") is not None:
        tp = mesh.shape.get("model", 1)
        a = cfg.attention
        if tp > 1 and a.n_heads % tp:
            from dataclasses import replace as _rep
            pad = ((a.n_heads + tp - 1) // tp) * tp
            cfg = _rep(cfg, attention=_rep(a, n_heads=pad))
    builders = {
        "dense": build_decoder,
        "moe": build_decoder,
        "ssm": build_xlstm,
        "hybrid": build_hybrid,
        "audio": build_encdec,
        "vlm": build_vlm,
    }
    return builders[cfg.family](cfg, rules, mesh=mesh, remat=remat,
                                attn_chunk=attn_chunk)
