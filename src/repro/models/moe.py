"""Mixture-of-experts FFN: shared + routed experts, top-k routing.

Three dispatch paths, selected by ``ep_size`` (the physical size of the
``experts`` logical axis) and the token count:

* ``local``   — single-device / smoke tests: sort + capacity scatter, no
                collectives.
* ``a2a``     — expert parallelism: ``shard_map`` + ``lax.all_to_all``;
                tokens are sequence-sharded over the expert axis for the
                dispatch, experts live sharded (GShard/DeepSpeed-MoE style).
* ``dense_ep``— decode (few tokens): every expert shard computes its local
                experts' contribution for all tokens, combined with one psum
                (a2a would move less data than it costs in latency at T≈B).

Routed experts may be padded (qwen2-moe 60 -> 64 for EP=16); the router
masks padded experts to -inf so they are never selected.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.param import PDecl
from repro.models.layers import act_fn, mlp_decls, mlp_forward
from repro.sharding.axes import LogicalRules

from repro.sharding.compat import shard_map_compat as _shard_map

F32 = jnp.float32


def padded_experts(m: MoEConfig, ep_size: int) -> int:
    e = m.n_routed
    if ep_size > 1 and e % ep_size:
        e = ((e + ep_size - 1) // ep_size) * ep_size
    return e


def moe_decls(cfg: ArchConfig, ep_size: int = 16) -> Dict[str, PDecl]:
    m = cfg.moe
    d, f = cfg.d_model, m.d_expert
    e = padded_experts(m, ep_size)
    decls = {
        "router": PDecl((d, e), ("embed", None), dtype=jnp.float32),
        "wi": PDecl((e, d, 2, f), ("experts", "embed_tp", None, "expert_ff")),
        "wo": PDecl((e, f, d), ("experts", "expert_ff", "embed_tp")),
    }
    if m.d_shared:
        decls["shared"] = mlp_decls(d, m.d_shared, glu=True)
        if m.shared_gate:
            decls["shared_gate"] = PDecl((d, 1), ("embed", None), dtype=jnp.float32)
    return decls


def _route(p, m: MoEConfig, x_flat, e_pad: int):
    """Router: top-k probs over true experts; padded experts masked."""
    logits = jnp.einsum("td,de->te", x_flat.astype(F32), p["router"])
    if e_pad > m.n_routed:
        neg = jnp.full((x_flat.shape[0], e_pad - m.n_routed), -1e9, F32)
        logits = jnp.concatenate([logits[:, : m.n_routed], neg], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing aux loss.
    dispatch_frac = jnp.mean(
        jax.nn.one_hot(top_e, e_pad, dtype=F32).sum(1), axis=0)
    aux = m.n_routed * jnp.sum(dispatch_frac * jnp.mean(probs, axis=0))
    return top_w, top_e, aux


def _capacity(t: int, m: MoEConfig, e_pad: int) -> int:
    """Per-expert token capacity for a dispatch pool of ``t`` tokens.

    Serving-size pools (t <= 256) are dropless — every token can land on a
    single expert.  One rule shared by the local and EP paths: the a2a
    block used to apply the trained-capacity formula to its *local* shard
    pool, which dropped tokens the dropless oracle kept (the jax-0.4.x
    "a2a mismatch" was never the exchange, it was this).
    """
    if t <= 256:
        return t
    return max(int(np.ceil(t * m.top_k / e_pad * m.capacity_factor)),
               m.top_k)


def _expert_mlp(wi, wo, h, act: str):
    """h: (E, C, d) grouped tokens -> (E, C, d)."""
    uv = jnp.einsum("ecd,edgf->ecgf", h, wi)
    u, v = uv[..., 0, :], uv[..., 1, :]
    return jnp.einsum("ecf,efd->ecd", act_fn(act)(u) * v, wo)


def _capacity_dispatch(x_flat, top_w, top_e, e_pad: int, cap: int):
    """Sort+scatter tokens into an (E, cap, d) buffer.

    Returns (buf, se, pos, st, sw, keep) with the bookkeeping needed to
    gather results back to token order.
    """
    t, k = top_e.shape
    e_flat = top_e.reshape(-1)
    w_flat = top_w.reshape(-1)
    tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(e_flat)
    se, st, sw = e_flat[order], tok[order], w_flat[order]
    counts = jnp.bincount(e_flat, length=e_pad)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k) - starts[se]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)  # out-of-range rows -> dropped by mode
    buf = jnp.zeros((e_pad, cap + 1, x_flat.shape[-1]), x_flat.dtype)
    buf = buf.at[se, pos_c].set(x_flat[st], mode="drop")
    return buf[:, :cap], se, pos_c, st, sw, keep


def _combine(y_buf, se, pos_c, st, sw, keep, t: int, cap: int):
    pad = jnp.zeros((y_buf.shape[0], 1, y_buf.shape[-1]), y_buf.dtype)
    yb = jnp.concatenate([y_buf, pad], axis=1)
    rows = yb[se, pos_c] * (sw * keep)[:, None].astype(y_buf.dtype)
    out = jnp.zeros((t, y_buf.shape[-1]), y_buf.dtype).at[st].add(rows)
    return out


def _moe_local(p, cfg: ArchConfig, x, e_pad: int):
    """Single-shard routed path (also the oracle for the EP paths)."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    t = xf.shape[0]
    top_w, top_e, aux = _route(p, m, xf, e_pad)
    cap = _capacity(t, m, e_pad)
    buf, se, pos_c, st, sw, keep = _capacity_dispatch(xf, top_w, top_e, e_pad, cap)
    y_buf = _expert_mlp(p["wi"], p["wo"], buf, cfg.act)
    y = _combine(y_buf, se, pos_c, st, sw, keep, t, cap)
    return y.reshape(b, s, d), aux


def _moe_a2a(p, cfg: ArchConfig, x, e_pad: int, mesh, ep_axis: str,
             dp_axes=None) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel dispatch: sequence-shard tokens over the expert axis,
    all_to_all token groups to their expert shards, grouped GEMM, reverse."""
    m = cfg.moe
    b, s, d = x.shape
    ep = mesh.shape[ep_axis]
    e_loc = e_pad // ep

    def block(xb, router_w, wi_loc, wo_loc):
        bl, sl, _ = xb.shape
        xf = xb.reshape(-1, d)
        t = xf.shape[0]
        top_w, top_e, aux = _route({"router": router_w}, m, xf, e_pad)
        cap = _capacity(t, m, e_pad)
        buf, se, pos_c, st, sw, keep = _capacity_dispatch(
            xf, top_w, top_e, e_pad, cap)
        # (E, cap, d) -> exchange: every shard keeps rows for its local experts
        recv = jax.lax.all_to_all(
            buf.reshape(ep, e_loc, cap, d), ep_axis, 0, 0, tiled=False)
        # recv: (ep, e_loc, cap, d) — sender-major groups for local experts
        h = recv.transpose(1, 0, 2, 3).reshape(e_loc, ep * cap, d)
        y = _expert_mlp(wi_loc, wo_loc, h, cfg.act)
        y = y.reshape(e_loc, ep, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(y, ep_axis, 0, 0, tiled=False)
        y_buf = back.reshape(e_pad, cap, d)
        out = _combine(y_buf, se, pos_c, st, sw, keep, t, cap)
        aux = jax.lax.pmean(aux, ep_axis)
        return out.reshape(bl, sl, d), aux

    in_specs = (
        P(dp_axes, ep_axis, None),        # x: tokens seq-sharded over EP axis
        P(None, None),                    # router replicated
        P(ep_axis, None, None, None),     # wi sharded over experts
        P(ep_axis, None, None),           # wo
    )
    out_specs = (P(dp_axes, ep_axis, None), P())
    fn = _shard_map(block, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs)
    return fn(x, p["router"], p["wi"], p["wo"])


def _moe_dense_ep(p, cfg: ArchConfig, x, e_pad: int, mesh, ep_axis: str,
                  dp_axes=None) -> Tuple[jax.Array, jax.Array]:
    """Decode path: T is tiny — each expert shard computes its experts'
    contributions for all local tokens, one psum combines."""
    m = cfg.moe
    b, s, d = x.shape
    ep = mesh.shape[ep_axis]
    e_loc = e_pad // ep

    def block(xb, router_w, wi_loc, wo_loc):
        bl, sl, _ = xb.shape
        xf = xb.reshape(-1, d)
        top_w, top_e, aux = _route({"router": router_w}, m, xf, e_pad)
        shard = jax.lax.axis_index(ep_axis)
        e0 = shard * e_loc
        # weight of each local expert for each token (T, e_loc)
        w_local = jnp.zeros((xf.shape[0], e_loc), F32)
        for j in range(m.top_k):
            idx = top_e[:, j] - e0
            hit = (idx >= 0) & (idx < e_loc)
            w_local = w_local.at[jnp.arange(xf.shape[0]),
                                 jnp.clip(idx, 0, e_loc - 1)].add(
                jnp.where(hit, top_w[:, j], 0.0))
        h = jnp.broadcast_to(xf[None], (e_loc,) + xf.shape)
        y = _expert_mlp(wi_loc, wo_loc, h, cfg.act)       # (e_loc, T, d)
        out = jnp.einsum("etd,te->td", y.astype(F32), w_local)
        out = jax.lax.psum(out, ep_axis)
        aux = jax.lax.pmean(aux, ep_axis)
        return out.astype(xb.dtype).reshape(bl, sl, d), aux

    in_specs = (P(dp_axes, None, None), P(None, None),
                P(ep_axis, None, None, None), P(ep_axis, None, None))
    out_specs = (P(dp_axes, None, None), P())
    fn = _shard_map(block, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs)
    return fn(x, p["router"], p["wi"], p["wo"])


def moe_forward(p, cfg: ArchConfig, x, rules: LogicalRules,
                mesh=None, ep_axis: Optional[str] = None):
    """Routed + shared experts. Returns (y, aux_loss)."""
    m = cfg.moe
    ep = mesh.shape[ep_axis] if (mesh is not None and ep_axis) else 1
    e_pad = padded_experts(m, ep)
    b, s, d = x.shape
    if ep == 1:
        y, aux = _moe_local(p, cfg, x, e_pad)
    else:
        # batch must divide the data axes for shard_map; degrade to
        # replicated batch otherwise (long-context cells with batch 1)
        dp_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
        dp_size = 1
        for a in dp_axes:
            dp_size *= mesh.shape[a]
        if b % dp_size:
            dp_axes = None
        if s % ep == 0 and b * s >= 256:
            y, aux = _moe_a2a(p, cfg, x, e_pad, mesh, ep_axis, dp_axes)
        else:
            y, aux = _moe_dense_ep(p, cfg, x, e_pad, mesh, ep_axis, dp_axes)
    if m.d_shared:
        sh = mlp_forward(p["shared"], x, cfg.act, glu=True, rules=rules)
        if m.shared_gate:
            gate = jax.nn.sigmoid(
                jnp.einsum("bsd,dg->bsg", x.astype(F32), p["shared_gate"]))
            sh = sh * gate.astype(sh.dtype)
        y = y + sh
    return y, m.router_aux_coef * aux
