from repro.models.lm import ModelBundle, build_model
from repro.models.param import (
    PDecl, init_tree, struct_tree, spec_tree, sharding_tree, param_count,
)

__all__ = [
    "ModelBundle", "build_model", "PDecl", "init_tree", "struct_tree",
    "spec_tree", "sharding_tree", "param_count",
]
