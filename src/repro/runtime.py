"""Process-wide runtime flags.

``unroll_inner``: the dry-run cost pass sets this so inner lax.scan loops
(KV-chunk attention, SSD chunk scan, microbatch accumulation) are unrolled —
``compiled.cost_analysis()`` counts a while-loop body once, so rolled loops
would under-report FLOPs/bytes.  The memory-proof compile keeps loops rolled.

``force_pallas``: route kernel wrappers to the Pallas implementation even on
CPU (interpret mode) — used by kernel tests.
"""
from __future__ import annotations

import contextlib
import os

unroll_inner: bool = False
force_pallas: str = os.environ.get("REPRO_FORCE_PALLAS", "")
# Mesh axis names available for sharding constraints (None = no filtering);
# set by launch code so rule tables mentioning ("pod","data") degrade
# gracefully on a single-pod ("data","model") mesh.
mesh_axes = None


@contextlib.contextmanager
def flags(**kw):
    g = globals()
    old = {k: g[k] for k in kw}
    g.update(kw)
    try:
        yield
    finally:
        g.update(old)


def maybe_scan(body, init, xs, length=None):
    """lax.scan that honors the unroll flag (for cost-exact dry-runs)."""
    import jax
    n = length
    if n is None:
        n = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(body, init, xs, length=n,
                        unroll=n if unroll_inner else 1)


def bounded_put(cache: dict, key, value, max_entries: int) -> None:
    """Shared bounded-FIFO insert for the warm-path caches (key tables,
    index maps, block layouts): evict oldest entries past the cap.
    Lives here because the compile and serverless layers both use it and
    this module has no repro-internal imports (no cycle risk)."""
    while len(cache) >= max_entries:
        cache.pop(next(iter(cache)))
    cache[key] = value
