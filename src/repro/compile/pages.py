"""Device-resident feature-page pool (ISSUE 3 tentpole, compile layer).

The megabatch programs consume *feature pages*: one (N_pad, P_pad)
zero-padded copy of a request's X matrix per bucket shape.  Before this
module the pages were re-stacked on the host and re-transferred
host->device on every drain — for steady-state serving (the same datasets
estimated over and over) that round-trip is pure waste, and it is exactly
the transfer the paper's Lambda workers avoid by caching their S3 pull.

``PagePool`` keeps pages resident on device across drains:

  * pages are keyed by ``(data fingerprint, N_pad, P_pad)`` — pure value
    identity, like the ``ProgramCache``, so repeat traffic (same dataset
    content, any request object) hits without transfer;
  * per launch the pool assembles the (D, N_pad, P_pad) page stack by
    *lane assignment on device*: resident pages are gathered into lanes
    (a device-side copy, no host round-trip), newly admitted requests'
    pages transfer once and join in place, and the assembled stack —
    itself a materialized device array — is cached by its lane
    composition, so steady-state traffic re-presents the same composition
    and gets the **same array object** back: a warm drain performs zero
    transfers and zero copies;
  * an LRU byte budget bounds device residency of pages *and* cached
    stacks: stacks evict first (rebuildable without any host round-trip),
    then least-recently-used pages; a later request for an evicted page
    pays one re-transfer.

Keeping D equal to the launch's own page count (pow2-bucketed), rather
than the pool's total, keeps compiled program shapes independent of pool
history — part of the bitwise schedule-invariance contract.

``PageStats`` feeds the session telemetry and BENCH_asyncdrain.json
(hit rate, bytes transferred vs saved, evictions, stack reuse).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Sequence, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.crossfit import pow2_bucket

# page identity: (data fingerprint, n_pad, p_pad)
PageKey = Tuple[object, int, int]

DEFAULT_BYTE_BUDGET = 256 * 1024 * 1024
MAX_CACHED_STACKS = 128


@dataclass
class PageStats:
    """Hit/miss/transfer accounting across drains."""
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    stack_builds: int = 0
    stack_hits: int = 0
    bytes_h2d: int = 0                  # host->device page transfers
    bytes_saved: int = 0                # transfers avoided by residency

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def summary(self) -> Dict:
        return {"page_hits": self.hits, "page_misses": self.misses,
                "page_hit_rate": self.hit_rate,
                "page_evictions": self.evictions,
                "stack_builds": self.stack_builds,
                "stack_hits": self.stack_hits,
                "page_bytes_h2d": self.bytes_h2d,
                "page_bytes_saved": self.bytes_saved}

    def snapshot(self) -> "PageStats":
        return PageStats(self.hits, self.misses, self.evictions,
                         self.stack_builds, self.stack_hits,
                         self.bytes_h2d, self.bytes_saved)

    def delta(self, since: "PageStats") -> "PageStats":
        return PageStats(self.hits - since.hits, self.misses - since.misses,
                         self.evictions - since.evictions,
                         self.stack_builds - since.stack_builds,
                         self.stack_hits - since.stack_hits,
                         self.bytes_h2d - since.bytes_h2d,
                         self.bytes_saved - since.bytes_saved)


class PagePool:
    """LRU pool of device-resident padded feature pages.

    One instance per backend (it sits next to the backend's
    ``ProgramCache`` and persists across drains).  ``byte_budget`` counts
    the canonical page entries; assembled stacks are composition-keyed
    views capped at ``MAX_CACHED_STACKS`` entries.
    """

    def __init__(self, byte_budget: int = DEFAULT_BYTE_BUDGET):
        self.byte_budget = int(byte_budget)
        self.stats = PageStats()
        self._pages: "OrderedDict[PageKey, object]" = OrderedDict()
        self._nbytes: Dict[PageKey, int] = {}
        self._page_bytes = 0
        # (tuple of page keys, d_pad) -> stacked device array
        self._stacks: "OrderedDict[Tuple, object]" = OrderedDict()
        self._stacks_of: Dict[PageKey, Set[Tuple]] = {}
        self._stack_bytes = 0

    # ------------------------------------------------------------------
    @staticmethod
    def page_key(req, n_pad: int, p_pad: int) -> PageKey:
        return (req.data_key, n_pad, p_pad)

    @property
    def n_pages(self) -> int:
        return len(self._pages)

    @property
    def total_bytes(self) -> int:
        """Device bytes held: canonical pages + materialized stacks."""
        return self._page_bytes + self._stack_bytes

    # ------------------------------------------------------------------
    def _page(self, pkey: PageKey, req, n_pad: int, p_pad: int):
        """The request's device-resident padded page; transfers on miss."""
        page = self._pages.get(pkey)
        nbytes = n_pad * p_pad * 4
        if page is not None:
            self._pages.move_to_end(pkey)
            self.stats.hits += 1
            self.stats.bytes_saved += nbytes
            return page
        x = np.asarray(req.x, np.float32)
        host = np.zeros((n_pad, p_pad), np.float32)
        host[:x.shape[0], :x.shape[1]] = x
        page = jnp.asarray(host)                    # the one h2d copy
        self._pages[pkey] = page
        self._nbytes[pkey] = nbytes
        self._page_bytes += nbytes
        self.stats.misses += 1
        self.stats.bytes_h2d += nbytes
        return page

    def _drop_stack(self, skey: Tuple):
        stack = self._stacks.pop(skey, None)
        if stack is not None:
            self._stack_bytes -= int(stack.size) * 4
        for pk in skey[0]:
            self._stacks_of.get(pk, set()).discard(skey)

    def _evict_lru(self, keep: Set[PageKey], keep_stack: Tuple = None):
        """Shrink to the byte budget: drop LRU cached stacks first (they
        rebuild without any host round-trip), then evict LRU pages (never
        ones needed by the in-flight launch), dropping their stacks."""
        while self._stack_bytes + self._page_bytes > self.byte_budget:
            victim = next((sk for sk in self._stacks if sk != keep_stack),
                          None)
            if victim is None:
                break
            self._drop_stack(victim)
        for pkey in list(self._pages):
            if self.total_bytes <= self.byte_budget:
                return
            if pkey in keep:
                continue
            self._pages.pop(pkey)
            self._page_bytes -= self._nbytes.pop(pkey)
            self.stats.evictions += 1
            for skey in list(self._stacks_of.pop(pkey, ())):
                self._drop_stack(skey)

    # ------------------------------------------------------------------
    def stack(self, needs: Sequence[Tuple[PageKey, object]],
              n_pad: int, p_pad: int):
        """Assemble the (D, N_pad, P_pad) stack for one launch.

        ``needs`` is ``[(page_key, request), ...]`` in lane order (lane i
        = needs[i]); D is pow2 of the lane count.  The assembled stack is
        cached by composition, so steady traffic reuses the identical
        array object and pays neither transfer nor copy.
        """
        pkeys = tuple(pk for pk, _ in needs)
        d_pad = pow2_bucket(max(len(pkeys), 1), 1)
        skey = (pkeys, d_pad)
        cached = self._stacks.get(skey)
        if cached is not None and all(pk in self._pages for pk in pkeys):
            self._stacks.move_to_end(skey)
            self.stats.stack_hits += 1
            for pk, req in needs:                   # LRU touch + accounting
                self._pages.move_to_end(pk)
                self.stats.hits += 1
                self.stats.bytes_saved += n_pad * p_pad * 4
            return cached
        lanes = [self._page(pk, req, n_pad, p_pad) for pk, req in needs]
        zero = jnp.zeros((n_pad, p_pad), np.float32)
        stack = jnp.stack(lanes + [zero] * (d_pad - len(lanes)))
        self.stats.stack_builds += 1
        self._stacks[skey] = stack
        self._stack_bytes += d_pad * n_pad * p_pad * 4
        for pk in pkeys:
            self._stacks_of.setdefault(pk, set()).add(skey)
        while len(self._stacks) > MAX_CACHED_STACKS:
            self._drop_stack(next(iter(self._stacks)))
        self._evict_lru(keep=set(pkeys), keep_stack=skey)
        return stack
