"""Device-resident feature-page pool (ISSUE 3 tentpole, compile layer).

The megabatch programs consume *feature pages*: one (N_pad, P_pad)
zero-padded copy of a request's X matrix per bucket shape.  Before this
module the pages were re-stacked on the host and re-transferred
host->device on every drain — for steady-state serving (the same datasets
estimated over and over) that round-trip is pure waste, and it is exactly
the transfer the paper's Lambda workers avoid by caching their S3 pull.

``PagePool`` keeps pages resident on device across drains:

  * pages are keyed by ``(data fingerprint, N_pad, P_pad)`` — pure value
    identity, like the ``ProgramCache``, so repeat traffic (same dataset
    content, any request object) hits without transfer;
  * per launch the pool assembles the (D, N_pad, P_pad) page stack by
    *lane assignment on device*: resident pages are gathered into lanes
    (a device-side copy, no host round-trip), newly admitted requests'
    pages transfer once and join in place, and the assembled stack —
    itself a materialized device array — is cached by its lane
    composition, so steady-state traffic re-presents the same composition
    and gets the **same array object** back: a warm drain performs zero
    transfers and zero copies;
  * an LRU byte budget bounds device residency of pages *and* cached
    stacks: stacks evict first (rebuildable without any host round-trip),
    then least-recently-used pages; a later request for an evicted page
    pays one re-transfer.

Keeping D equal to the launch's own page count (pow2-bucketed), rather
than the pool's total, keeps compiled program shapes independent of pool
history — part of the bitwise schedule-invariance contract.

``PageStats`` feeds the session telemetry and BENCH_asyncdrain.json
(hit rate, bytes transferred vs saved, evictions, stack reuse).

Multi-host (ISSUE 4): one ``PagePool`` per host mesh, all sharing a
``PageDirectory`` — the cluster-wide fingerprint map of which hosts hold
which pages.  A host that misses locally but whose directory names a
peer holder fetches the page device-to-device (cheaper than the host
round-trip, and accounted separately as a *cross-host transfer*); the
topology layer's placement policy exists to make those fetches converge
to zero by routing each bucket to the host already holding its pages.
``resident`` / ``stack_cached`` are the residency probes that policy
scores hosts with.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import warm_cache
from repro.core.crossfit import pow2_bucket

# page identity: (data fingerprint, n_pad, p_pad)
PageKey = Tuple[object, int, int]

DEFAULT_BYTE_BUDGET = 256 * 1024 * 1024
MAX_CACHED_STACKS = 128


@dataclass
class PageStats:
    """Hit/miss/transfer accounting across drains.

    A *cross-host fetch* is a local miss served device-to-device from a
    peer pool instead of the host round-trip: it counts as a miss for
    this pool's hit rate, its bytes land in ``bytes_d2d`` (never
    ``bytes_h2d``), and steady-state topology traffic is gated on it
    reaching zero.
    """
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    stack_builds: int = 0
    stack_hits: int = 0
    bytes_h2d: int = 0                  # host->device page transfers
    bytes_saved: int = 0                # transfers avoided by residency
    cross_host_fetches: int = 0         # misses served from a peer pool
    bytes_d2d: int = 0                  # device->device cross-host bytes

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def summary(self) -> Dict:
        return {"page_hits": self.hits, "page_misses": self.misses,
                "page_hit_rate": self.hit_rate,
                "page_evictions": self.evictions,
                "stack_builds": self.stack_builds,
                "stack_hits": self.stack_hits,
                "page_bytes_h2d": self.bytes_h2d,
                "page_bytes_saved": self.bytes_saved,
                "cross_host_fetches": self.cross_host_fetches,
                "page_bytes_d2d": self.bytes_d2d}

    # snapshot/delta/merge iterate the dataclass fields so a counter
    # added above is automatically carried through all three
    def snapshot(self) -> "PageStats":
        return dataclasses.replace(self)

    def delta(self, since: "PageStats") -> "PageStats":
        return PageStats(*(getattr(self, f.name) - getattr(since, f.name)
                           for f in dataclasses.fields(self)))

    def merge(self, other: "PageStats") -> "PageStats":
        """Aggregate two pools' accounting (topology-wide telemetry)."""
        return PageStats(*(getattr(self, f.name) + getattr(other, f.name)
                           for f in dataclasses.fields(self)))


class PageDirectory:
    """Cluster-wide fingerprint directory over per-host ``PagePool``s.

    Maps every page key to the set of hosts currently holding it, and
    brokers device-to-device fetches between pools: a host that misses
    locally asks the directory, which hands back a peer's resident array
    (the caller places it on its own device).  Pure bookkeeping plus the
    fetch counters the topology acceptance gates read — placement policy
    is the caller's job (sharding/policy.py).
    """

    def __init__(self):
        self._holders: Dict[PageKey, Set[int]] = {}
        self._pools: Dict[int, "PagePool"] = {}
        self.fetches = 0                # cross-host page fetches brokered
        self.bytes_fetched = 0

    def attach(self, pool: "PagePool") -> None:
        self._pools[pool.host_id] = pool

    def detach(self, pool: "PagePool") -> None:
        """Withdraw a dead host: drop it from the pool map and purge it
        from every holder set so no d2d fetch is ever brokered against
        unreachable device memory (host-loss recovery)."""
        self._pools.pop(pool.host_id, None)
        for pkey in list(self._holders):
            self.unregister(pkey, pool.host_id)

    def register(self, pkey: PageKey, host_id: int) -> None:
        self._holders.setdefault(pkey, set()).add(host_id)

    def unregister(self, pkey: PageKey, host_id: int) -> None:
        holders = self._holders.get(pkey)
        if holders is not None:
            holders.discard(host_id)
            if not holders:
                del self._holders[pkey]

    def holders(self, pkey: PageKey) -> frozenset:
        return frozenset(self._holders.get(pkey, ()))

    def fetch(self, pkey: PageKey, requester: int):
        """A peer's resident page array, or None if no peer holds it.
        Deterministic source choice (lowest holder id); does not touch
        the source pool's LRU order."""
        for hid in sorted(self._holders.get(pkey, ())):
            if hid == requester:
                continue
            src = self._pools.get(hid)
            page = src._pages.get(pkey) if src is not None else None
            if page is not None:
                self.fetches += 1
                self.bytes_fetched += src._nbytes[pkey]
                return page
        return None


class PagePool:
    """LRU pool of device-resident padded feature pages.

    One instance per backend (it sits next to the backend's
    ``ProgramCache`` and persists across drains).  ``byte_budget`` counts
    the canonical page entries; assembled stacks are composition-keyed
    views capped at ``MAX_CACHED_STACKS`` entries.

    Topology mode: one pool per host mesh, identified by ``host_id``,
    pinned to that host's lead ``device``, and registered with the shared
    ``PageDirectory`` — local misses then try a device-to-device fetch
    from a peer holder before paying the host round-trip.
    """

    def __init__(self, byte_budget: int = DEFAULT_BYTE_BUDGET, *,
                 host_id: int = 0, directory: Optional[PageDirectory] = None,
                 device=None):
        self.byte_budget = int(byte_budget)
        self.host_id = host_id
        self.directory = directory
        self.device = device
        if directory is not None:
            directory.attach(self)
        self.stats = PageStats()
        self._pages: "OrderedDict[PageKey, object]" = OrderedDict()
        self._nbytes: Dict[PageKey, int] = {}
        self._page_bytes = 0
        # (tuple of page keys, d_pad) -> stacked device array
        self._stacks: "OrderedDict[Tuple, object]" = OrderedDict()
        self._stacks_of: Dict[PageKey, Set[Tuple]] = {}
        self._stack_bytes = 0

    # ------------------------------------------------------------------
    @staticmethod
    def page_key(req, n_pad: int, p_pad: int) -> PageKey:
        return (req.data_key, n_pad, p_pad)

    @property
    def n_pages(self) -> int:
        return len(self._pages)

    # ---- residency probes (placement policy, sharding/policy.py) -----
    def resident(self, pkey: PageKey) -> bool:
        """Membership test without touching LRU order or stats."""
        return pkey in self._pages

    def stack_cached(self, pkeys: Sequence[PageKey]) -> bool:
        """Whether the lane composition is launch-ready with zero
        copies: a singleton composition's launch array IS its resident
        page; multi-lane compositions need their assembled stack."""
        pkeys = tuple(pkeys)
        if len(pkeys) == 1:
            return pkeys[0] in self._pages
        return (pkeys, pow2_bucket(len(pkeys), 1)) in self._stacks

    @property
    def total_bytes(self) -> int:
        """Device bytes held: canonical pages + materialized stacks."""
        return self._page_bytes + self._stack_bytes

    # ------------------------------------------------------------------
    def _put(self, arr):
        """Place an array on this pool's host device (default placement
        when the pool is not device-pinned)."""
        if self.device is not None:
            return jax.device_put(arr, self.device)
        return jnp.asarray(arr)

    def _page(self, pkey: PageKey, req, n_pad: int, p_pad: int):
        """The request's device-resident padded page, shaped
        ``(1, n_pad, p_pad)`` so a singleton launch can consume it
        directly with zero copies; a local miss tries a device-to-device
        fetch from a peer pool (directory) before paying the
        host->device transfer."""
        page = self._pages.get(pkey)
        nbytes = n_pad * p_pad * 4
        if page is not None:
            self._pages.move_to_end(pkey)
            self.stats.hits += 1
            self.stats.bytes_saved += nbytes
            return page
        self.stats.misses += 1
        peer = self.directory.fetch(pkey, self.host_id) \
            if self.directory is not None else None
        if peer is not None:
            page = self._put(peer)                  # d2d cross-host copy
            self.stats.cross_host_fetches += 1
            self.stats.bytes_d2d += nbytes
        else:
            x = np.asarray(req.x, np.float32)
            host = np.zeros((1, n_pad, p_pad), np.float32)
            host[0, :x.shape[0], :x.shape[1]] = x
            page = self._put(host)                  # the one h2d copy
            self.stats.bytes_h2d += nbytes
        self._pages[pkey] = page
        self._nbytes[pkey] = nbytes
        self._page_bytes += nbytes
        if self.directory is not None:
            self.directory.register(pkey, self.host_id)
        return page

    def _drop_stack(self, skey: Tuple):
        stack = self._stacks.pop(skey, None)
        if stack is not None:
            self._stack_bytes -= int(stack.size) * 4
        for pk in skey[0]:
            self._stacks_of.get(pk, set()).discard(skey)

    def _evict_lru(self, keep: Set[PageKey], keep_stack: Tuple = None):
        """Shrink to the byte budget: drop LRU cached stacks first (they
        rebuild without any host round-trip), then evict LRU pages (never
        ones needed by the in-flight launch), dropping their stacks."""
        while self._stack_bytes + self._page_bytes > self.byte_budget:
            victim = next((sk for sk in self._stacks if sk != keep_stack),
                          None)
            if victim is None:
                break
            self._drop_stack(victim)
        for pkey in list(self._pages):
            if self.total_bytes <= self.byte_budget:
                return
            if pkey in keep:
                continue
            self._pages.pop(pkey)
            self._page_bytes -= self._nbytes.pop(pkey)
            self.stats.evictions += 1
            if self.directory is not None:
                self.directory.unregister(pkey, self.host_id)
            for skey in list(self._stacks_of.pop(pkey, ())):
                self._drop_stack(skey)

    def invalidate(self) -> None:
        """Host loss: drop every resident page and stack and withdraw
        from the cluster directory.  Surviving hosts re-materialize any
        page they need from host memory (``_page`` falls through to the
        h2d path once no peer holds the key) — the orphaned work itself
        is re-placed by the topology backend, not by the pool."""
        if self.directory is not None:
            self.directory.detach(self)
        self._pages.clear()
        self._nbytes.clear()
        self._page_bytes = 0
        self._stacks.clear()
        self._stacks_of.clear()
        self._stack_bytes = 0

    # ------------------------------------------------------------------
    # page contents are pinned by the PageKeys inside ``needs`` (a
    # page_key embeds the request's data_key); the composition cache
    # and residency maps live on this pool instance (ambient)
    @warm_cache(name="page_pool_stacks", key=("needs", "n_pad", "p_pad"),
                ambient=("self",))
    def stack(self, needs: Sequence[Tuple[PageKey, object]],
              n_pad: int, p_pad: int):
        """Assemble the (D, N_pad, P_pad) stack for one launch.

        ``needs`` is ``[(page_key, request), ...]`` in lane order (lane i
        = needs[i]); D is pow2 of the lane count.

        Singleton launches (per-block dispatch: one need per launch)
        consume the resident ``(1, N_pad, P_pad)`` page **directly** —
        no copy, no second device allocation, no cache entry beyond the
        page itself; a repeat composition is booked as a stack hit
        because the launch array was served with zero copies.  The
        multi-lane path below serves **fused launches** (ISSUE 5): a
        multi-request same-shape group hands its union composition here,
        pays one concatenation cold, and every warm repeat of the same
        composition gets the identical materialized stack back — the
        fused hot path is zero-copy exactly like the singleton one.
        """
        if len(needs) == 1:
            pk, req = needs[0]
            was_resident = pk in self._pages
            page = self._page(pk, req, n_pad, p_pad)
            if was_resident:
                self.stats.stack_hits += 1
            else:
                self.stats.stack_builds += 1
                self._evict_lru(keep={pk})
            return page
        pkeys = tuple(pk for pk, _ in needs)
        d_pad = pow2_bucket(max(len(pkeys), 1), 1)
        skey = (pkeys, d_pad)
        cached = self._stacks.get(skey)
        if cached is not None and all(pk in self._pages for pk in pkeys):
            self._stacks.move_to_end(skey)
            self.stats.stack_hits += 1
            for pk, req in needs:                   # LRU touch + accounting
                self._pages.move_to_end(pk)
                self.stats.hits += 1
                self.stats.bytes_saved += n_pad * p_pad * 4
            return cached
        lanes = [self._page(pk, req, n_pad, p_pad) for pk, req in needs]
        if d_pad > len(lanes):
            zero = self._put(jnp.zeros((1, n_pad, p_pad), np.float32))
            lanes = lanes + [zero] * (d_pad - len(lanes))
        stack = jnp.concatenate(lanes)
        self.stats.stack_builds += 1
        self._stacks[skey] = stack
        self._stack_bytes += d_pad * n_pad * p_pad * 4
        for pk in pkeys:
            self._stacks_of.setdefault(pk, set()).add(skey)
        while len(self._stacks) > MAX_CACHED_STACKS:
            self._drop_stack(next(iter(self._stacks)))
        self._evict_lru(keep=set(pkeys), keep_stack=skey)
        return stack
