"""Megabatch program build, cache, and execution.

A **program** is one jitted function per (bucket, padded batch shape):

    run(pages (D, N_pad, P_pad), data_idx (B,), y (B, N_pad),
        w (B, N_pad), valid (B, N_pad), key_data (B, ...)) -> (B, N_pad)

It gathers every task's feature page, rebuilds the per-task typed PRNG
keys, and calls the learner family's ``batched_fit_predict`` — on the
linear/ridge path that bottoms out in the fused Pallas kernels
(``batched_gram`` / ``batched_predict`` in kernels/ops.py).  The batch
axis B is wave-capacity-aligned (``aligned_bucket``: multiples of the
lane quantum, so steady traffic lands on the same few shapes with <1
quantum of waste) and the page axis D is pow2-bucketed, so repeat traffic
of *any* composition hits a previously-compiled program: the warm cache
is keyed by spec, never by object identity or request.  Feature pages
come from the device-resident ``PagePool`` (pages.py) when the backend
passes one — warm drains then perform zero host->device page transfer.

**Same-shape block fusion** (ISSUE 5 tentpole): equal-canonical-B blocks
from *different* requests pack into ONE device launch via a leading
block axis —

    run_fused(pages (D, N_pad, P_pad), data_idx (G, B), y (G, B, N_pad),
              ... ) -> (G, B, N_pad)

implemented as ``lax.map`` of the single-block body over axis 0, with
the G blocks sharing one union page stack (the ``PagePool`` multi-lane
composition cache, so warm fused launches are zero-copy).  ``lax.map``
— not ``vmap`` — is the float-pinning choice: the mapped body compiles
to exactly the single-block computation, so fused launches are
**bitwise-identical** to per-block launches for every learner family
(vmap's extra leading dim lets XLA retile reductions, ~1e-7 drift;
verified and CI-gated in tests/test_compile.py).  Each task's compiled
B stays pinned to its own request's canonical grid — fusion only
changes how many blocks ride per launch, never a block's shape.

**Non-blocking dispatch**: ``dispatch_bucket`` launches a bucket's
blocks and returns an in-flight ``BucketDispatch`` holding the raw
``jax.Array`` handles — no ``block_until_ready``.  The backends queue
these (serverless/dispatch.py) and harvest only when a ledger's buckets
must complete, so host-side booking, placement, stealing, admission,
and autoscaling overlap device execution.  ``run_bucket`` remains the
synchronous wrapper (dispatch + harvest in one call).

``ProgramCache`` owns the programs plus hit/miss/padding accounting; the
execution backends (serverless/backends.py) hold one instance each and
stay warm across ``run_requests`` calls.  An optional ``partition`` hook
wraps the program body before jit — ShardedBackend passes a shard_map
over the batch axis (sharding/policy.py::megabatch_specs); partitioned
programs never fuse (the specs map one block's operands).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.analysis.registry import warm_cache
from repro.core.crossfit import PaddingStats, aligned_bucket, pow2_bucket
from repro.compile.buckets import (BucketKey, Entry, MegabatchPlan,
                                   pack_tail_blocks)
from repro.compile.pages import PagePool
from repro.compile.persist import (PersistentProgramCache, backend_platform,
                                   default_persist, jax_build, pin_executable,
                                   program_avals, program_fingerprint)
from repro.learners import as_batched, get_batched_learner
from repro.runtime import bounded_put


@dataclass
class CompileStats:
    """Warm-cache and padding accounting across program launches.

    ``launches`` counts device dispatches; ``blocks`` counts the
    canonical blocks they carried — ``blocks > launches`` is same-shape
    fusion at work (``fused_launches`` of them carried 2+ launch
    blocks).  ``coalesced_blocks`` counts canonical tail blocks that
    rode a *combined* launch block (cross-shape coalescing);
    ``disk_hits``/``disk_misses`` track the persistent program cache —
    a disk hit deserializes an executable instead of compiling, so it
    does NOT count as a compile (``misses``)."""
    hits: int = 0
    misses: int = 0
    disk_hits: int = 0
    disk_misses: int = 0
    launches: int = 0
    blocks: int = 0
    fused_launches: int = 0
    coalesced_blocks: int = 0
    padding: PaddingStats = field(default_factory=PaddingStats)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def summary(self) -> Dict:
        return {"programs_compiled": self.misses,
                "cache_hits": self.hits,
                "cache_hit_rate": self.hit_rate,
                "disk_hits": self.disk_hits,
                "disk_misses": self.disk_misses,
                "launches": self.launches,
                "blocks": self.blocks,
                "fused_launches": self.fused_launches,
                "coalesced_blocks": self.coalesced_blocks,
                "padding_waste_frac": self.padding.waste_frac,
                "padding_waste_b_frac": self.padding.b_waste_frac,
                "padding_waste_b_morphed_frac":
                    self.padding.b_waste_frac_morphed,
                "padding_waste_n_frac": self.padding.n_waste_frac,
                "padding_waste_p_frac": self.padding.p_waste_frac,
                "tasks": self.padding.tasks,
                "padded_tasks": self.padding.padded_tasks}


def segment_batched_fn(seg) -> Callable:
    """Resolve a segment's megabatch implementation: registry learners get
    their native batched form, opaque callables the vmap adapter."""
    if seg.learner is not None:
        return get_batched_learner(seg.learner, dict(seg.params))
    return as_batched(seg.learner_fn)


class ProgramCache:
    """Spec-keyed cache of compiled megabatch programs.

    Keys are ``(BucketKey, B_pad, D_pad)`` — pure value identity, so two
    requests built from equal plans share programs, and a session's
    repeat traffic never re-traces.

    When a ``PersistentProgramCache`` is attached (default: the
    environment-configured one, see ``persist.ENV_CACHE_DIR``), an
    in-memory miss consults the disk before tracing: spec-identified,
    unpartitioned programs are AOT-compiled against their exact avals,
    serialized to disk on first compile, and deserialized (~14x cheaper
    than compiling here) by later processes — a disk-warm cold drain
    compiles zero programs.

    Donation: the megabatch output ``(…, B, N_pad) f32`` is shape- and
    dtype-identical to the ``y`` operand, so ``y`` (argnum 2) is donated
    and XLA writes the predictions in place.  The page stack is NEVER
    donated — the device-resident ``PagePool`` retains and reuses those
    buffers across launches.
    """

    def __init__(self, partition: Optional[Callable] = None,
                 persist: object = "auto",
                 partition_fused: Optional[Callable] = None,
                 partition_axes: Optional[Tuple] = None):
        self._programs: Dict[Tuple, Callable] = {}
        self.partition = partition
        # ISSUE 8: shard_map transform for the *fused* calling convention
        # (leading block axis G replicated, task axis sharded).  When
        # set, partitioned buckets fuse again — the per-shard body is the
        # unsharded lax.map program, so fused sharded launches stay
        # bitwise-equal to per-block unsharded ones.  partition_axes
        # names the mesh axes (and their sizes) the transform closes
        # over; it is part of the program cache key because two meshes
        # with different shard counts compile different programs.
        self.partition_fused = partition_fused
        self.partition_axes = tuple(partition_axes) if partition_axes \
            else None
        self.persist: Optional[PersistentProgramCache] = \
            default_persist() if persist == "auto" else persist
        self.stats = CompileStats()

    def _disk(self, key: BucketKey):
        """(persist, fingerprint-builder inputs) when this program may be
        persisted: spec-identified learners only, never partitioned
        programs (shard_map closes over mesh state the serialized
        executable would not carry)."""
        if self.persist is None or self.partition is not None \
                or self.partition_fused is not None:
            return None
        return self.persist

    def _disk_lookup(self, fp):
        prog = self.persist.lookup(jax_build(), backend_platform(), fp)
        if prog is not None:
            self.stats.disk_hits += 1
        else:
            self.stats.disk_misses += 1
        return prog

    def _compile_persistable(self, run, fp, key, b_pad, d_pad, g=None):
        """AOT-compile at exact avals and serialize to disk.  The
        returned executable is operand-pinned (``pin_executable``):
        unlike jit dispatch, a direct AOT call does not keep the
        caller's host operands alive while it reads them
        asynchronously."""
        compiled = jax.jit(run, donate_argnums=(2,)).lower(
            *program_avals(key, b_pad, d_pad, g)).compile()
        self.persist.store(jax_build(), backend_platform(), fp, compiled)
        return pin_executable(compiled)

    # BucketKey pins the segment's (learner, params) and padded shapes,
    # which fully determine the batched fn the thunk builds — hence
    # covers={"key": ("fn_thunk",)}; the cache dict lives on this
    # ProgramCache instance, so instance state is ambient.
    @warm_cache(name="program_cache", key=("key", "b_pad", "d_pad"),
                reads=("fn_thunk",), covers={"key": ("fn_thunk",)},
                ambient=("self",))
    def program(self, key: BucketKey, b_pad: int, d_pad: int,
                fn_thunk: Callable[[], Callable]) -> Callable:
        pkey = (key, b_pad, d_pad)
        prog = self._programs.get(pkey)
        if prog is not None:
            self.stats.hits += 1
            return prog
        fp = program_fingerprint(key, b_pad, d_pad) \
            if self._disk(key) is not None else None
        if fp is not None:
            prog = self._disk_lookup(fp)
            if prog is not None:
                self._programs[pkey] = prog
                return prog
        self.stats.misses += 1
        batched_fn = fn_thunk()

        def run(pages, data_idx, y, w, valid, key_data):
            xb = pages[data_idx]                       # (B, N_pad, P_pad)
            keys = jax.random.wrap_key_data(key_data)  # (B,) typed keys
            return batched_fn(xb, y, w, valid, keys)

        if self.partition is not None:
            prog = jax.jit(self.partition(run))
        elif fp is not None:
            prog = self._compile_persistable(run, fp, key, b_pad, d_pad)
        else:
            prog = jax.jit(run, donate_argnums=(2,))
        self._programs[pkey] = prog
        return prog

    @warm_cache(name="fused_program_cache",
                key=("key", "b_pad", "d_pad", "g"),
                reads=("fn_thunk",), covers={"key": ("fn_thunk",)},
                ambient=("self",))
    def fused_program(self, key: BucketKey, b_pad: int, d_pad: int,
                      g: int, fn_thunk: Callable[[], Callable]) -> Callable:
        """One launch carrying ``g`` same-shape blocks over a shared
        union page stack: ``lax.map`` of the single-block body over the
        leading block axis.  lax.map (not vmap) is the float pinning —
        the mapped body is compiled exactly as the single-block program,
        so fused results are bitwise-equal to per-block launches."""
        pkey = (key, b_pad, d_pad, g)
        prog = self._programs.get(pkey)
        if prog is not None:
            self.stats.hits += 1
            return prog
        fp = program_fingerprint(key, b_pad, d_pad, g) \
            if self._disk(key) is not None else None
        if fp is not None:
            prog = self._disk_lookup(fp)
            if prog is not None:
                self._programs[pkey] = prog
                return prog
        self.stats.misses += 1
        batched_fn = fn_thunk()

        def run_one(pages, data_idx, y, w, valid, key_data):
            xb = pages[data_idx]
            keys = jax.random.wrap_key_data(key_data)
            return batched_fn(xb, y, w, valid, keys)

        def run_fused(pages, data_idx, y, w, valid, key_data):
            return jax.lax.map(lambda t: run_one(pages, *t),
                               (data_idx, y, w, valid, key_data))

        if fp is not None:
            prog = self._compile_persistable(run_fused, fp, key, b_pad,
                                             d_pad, g)
        else:
            prog = jax.jit(run_fused, donate_argnums=(2,))
        self._programs[pkey] = prog
        return prog

    # The sharded-fused program closes over the mesh the partition_fused
    # transform was built with, so the mesh axes (names + sizes) join the
    # cache key — same bucket on a differently-sized mesh is a different
    # program.  Never persisted to disk (the serialized executable would
    # not carry the mesh), which _disk() enforces.
    @warm_cache(name="sharded_fused_program_cache",
                key=("key", "b_pad", "d_pad", "g", "self.partition_axes"),
                reads=("fn_thunk",), covers={"key": ("fn_thunk",)},
                ambient=("self",))
    def sharded_fused_program(self, key: BucketKey, b_pad: int, d_pad: int,
                              g: int,
                              fn_thunk: Callable[[], Callable]) -> Callable:
        """The fused launch shard_mapped over the host mesh (ISSUE 8):
        ``shard_map`` *around* the ``lax.map`` fused body, task axis
        sharded and the block axis G replicated
        (``megabatch_specs(fused=True)``), lifting the PR 5 "sharded
        caches never fuse" restriction.  Each shard compiles the SAME
        lax.map body as the unsharded fused program over its B/m lane
        slice — the structural contract audited by
        analysis/jaxpr_audit.py (sharded-fused-wraps-scan).  Parity vs
        the unsharded fused launch is bitwise on a 1-device mesh; on an
        m-way mesh XLA may retile reductions at the smaller compiled
        B/m (measured: B-invariance holds down to 16 lanes, not below),
        so multi-device results sit in the same ~1e-6 float tier as the
        unfused sharded path — verified per family by
        tests/test_compile.py::test_sharded_fused_launch_bitwise_parity.
        The win is launch count: partitioned drains now pack blocks
        into fused launches instead of one launch per block."""
        pkey = (key, b_pad, d_pad, g, ("mesh",) + self.partition_axes)
        prog = self._programs.get(pkey)
        if prog is not None:
            self.stats.hits += 1
            return prog
        self.stats.misses += 1
        batched_fn = fn_thunk()

        def run_one(pages, data_idx, y, w, valid, key_data):
            xb = pages[data_idx]
            keys = jax.random.wrap_key_data(key_data)
            return batched_fn(xb, y, w, valid, keys)

        def run_fused(pages, data_idx, y, w, valid, key_data):
            return jax.lax.map(lambda t: run_one(pages, *t),
                               (data_idx, y, w, valid, key_data))

        prog = jax.jit(self.partition_fused(run_fused))
        self._programs[pkey] = prog
        return prog


# A launch carries at most B_BLOCK task lanes.  The compiled B is part
# of the determinism contract: per-lane floats are independent of lane
# position and of the *other lanes' contents* (verified per family by
# tests/test_compile.py::test_tail_launch_b_invariance).  Whether they
# depend on the compiled B itself is a *per-family, per-platform*
# property (XLA reduction tiling CAN retile across B): families listed
# in MORPH_BITWISE_FAMILIES below are proven **compiled-B invariant** —
# the same lane content launched at B=16 and B=32 is bitwise-equal —
# by the parametrized morph gate in tests/test_compile.py and a
# structural check in analysis/jaxpr_audit.py.  For those families a
# task's launch B is a scheduling degree of freedom; for everything
# else (opaque callables, unproven families) it must stay a pure
# function of the task's own request.  Within each (request, segment),
# the segment's flat tasks in ascending order split into **canonical
# blocks** of B_BLOCK tasks, and a block's canonical size — full blocks
# at B_BLOCK, the tail at its sublane-aligned count — is what launches
# even when a capacity-limited wave executes only part of it (the
# missing lanes ride as padding; lane-content independence makes the
# result identical to the full-block launch).  Flat task ids are
# scaling-level-invariant, so per-split and per-fold scaling also
# compile identical launch shapes.
#
# **Cross-shape coalescing** (ISSUE 7 tentpole): for morph-proven
# families the scheduler goes one step further — canonical *tail*
# blocks (b_pad < B_BLOCK) from different requests pack
# lane-contiguously into one combined launch block
# (buckets.pack_tail_blocks), and when a bucket is still left with
# mixed shapes under fusion, the smaller blocks morph UP to the largest
# b_pad so the whole bucket rides one lax.map launch.  Packing is
# deterministic (first-fit in block order) and bitwise-neutral by the
# proven B-invariance + lane-content independence; families outside the
# bitwise set may only morph via the explicit opt-in tolerance tier
# (PoolConfig.morph_tolerance > 0 + MORPH_TOLERANCE_FAMILIES), which
# the jaxpr auditor knows about.
#
# This replaces the PR-3 rule that padded *every* launch up to B_BLOCK:
# constant-shape was sufficient for bitwise invariance but blew B-axis
# waste to ~65% on small-bucket traffic (BENCH_asyncdrain.json) — a
# 12-task bucket burned 20 padding lanes per launch.  Canonical tails
# launch at aligned size instead (12 tasks -> B=16), capping a bucket's
# B waste at the tail block's alignment.  16 for B_BLOCK would cut
# single-request waste further but doubles launch count and halves
# steady throughput on the session benches — 32 is the measured sweet
# spot.
#
# Caveat: partitioned paths agree with the unsharded schedulers to
# float tolerance (~1e-6) on multi-device meshes, bitwise only on a
# 1-device mesh.  For the *unfused* sharded path the cause is shard_map
# retiling the batched learner's B-axis reductions; the *sharded-fused*
# path (ISSUE 8) wraps the lax.map fused body so each shard runs the
# per-lane program unchanged (structurally audited), but it compiles
# that body at B/m lanes and compiled-B invariance only holds down to
# 16 lanes on this platform — below that XLA retiles and the same
# ~1e-6 tier applies.  Verified per family by the sharded-fused parity
# gate in tests/test_compile.py.
B_BLOCK = 32

# Families with a standing bitwise compiled-B invariance proof on this
# backend: the same lane content produces bit-identical floats at any
# aligned launch B.  Enforced empirically (per-family parametrized gate,
# tests/test_compile.py) and structurally (analysis/jaxpr_audit.py
# morph audit); the coalescing scheduler only morphs these.
MORPH_BITWISE_FAMILIES = frozenset(
    {"ols", "ridge", "lasso", "logistic", "kernel_ridge", "mlp"})

# Opt-in tolerance tier: families whose morphed launches are only
# float-tolerance-equal to canonical launches.  Morphing them requires
# PoolConfig.morph_tolerance > 0 — an explicit user opt-out of bitwise
# reproducibility, which the jaxpr auditor reports.  Empty today: every
# registered family passes the bitwise gate on this backend.
MORPH_TOLERANCE_FAMILIES = frozenset()


def bucket_family(key: BucketKey) -> Optional[str]:
    """Learner family name of a spec-identified bucket, else None."""
    ident = key.learner
    if isinstance(ident, tuple) and len(ident) == 2 \
            and isinstance(ident[0], str) and ident[0] != "opaque":
        return ident[0]
    return None


def morph_allowed(key: BucketKey, morph_tolerance: float = 0.0) -> bool:
    """May this bucket's tail blocks be coalesced/morphed?  Bitwise
    families always; tolerance-tier families only under an explicit
    ``morph_tolerance`` opt-in; opaque callables never."""
    fam = bucket_family(key)
    if fam is None:
        return False
    if fam in MORPH_BITWISE_FAMILIES:
        return True
    return morph_tolerance > 0.0 and fam in MORPH_TOLERANCE_FAMILIES


@dataclass
class _Block:
    """One canonical launch block, stacked and ready to launch."""
    ri: int
    si: int
    members: List[Tuple[int, int, int]]   # (flat task, inv, row-in-inv)
    b_pad: int
    k: int                                # real task lanes
    n: int                                # true N of the request
    p: int                                # true P of the request
    tpi: int                              # rows per invocation buffer


@dataclass
class _LaunchBlock:
    """One launch-shaped unit: one canonical block at its canonical
    shape (the common case), several tail blocks packed
    lane-contiguously (cross-shape coalescing), or a block morphed up
    to a neighbor's B.  ``offsets[i]`` is the first lane of
    ``parts[i]`` inside the combined (b_pad,) batch axis."""
    parts: List[_Block]
    offsets: List[int]
    b_pad: int
    k: int                                # total real lanes


def _coalesce(blocks: List[_Block], b_block: int, b_align: int,
              morph: bool, fuse: bool) -> List[_LaunchBlock]:
    """Lower canonical blocks to launch blocks.

    Without morphing this is the identity wrapping (every block at its
    own canonical shape).  With morphing: tails pack first-fit into
    combined blocks at one uniform padded size T chosen to minimize
    total padded lanes (buckets.pack_tail_blocks), then — if fusing
    would still face mixed shapes (full blocks vs packed tails) —
    remaining blocks morph up to the largest b_pad so the bucket fuses
    into a single lax.map launch.
    """
    out = [_LaunchBlock([b], [0], b.b_pad, b.k)
           for b in blocks if b.b_pad >= b_block]
    tails = [b for b in blocks if b.b_pad < b_block]
    if not morph or len(tails) <= 1:
        out += [_LaunchBlock([b], [0], b.b_pad, b.k) for b in tails]
    else:
        groups, target = pack_tail_blocks([b.k for b in tails], b_block,
                                          8, b_align)
        for idxs in groups:
            parts = [tails[i] for i in idxs]
            offs, tot = [], 0
            for p in parts:
                offs.append(tot)
                tot += p.k
            out.append(_LaunchBlock(parts, offs, target, tot))
    if morph and fuse and len(out) > 1:
        target = max(lb.b_pad for lb in out)
        out = [lb if lb.b_pad == target else
               _LaunchBlock(lb.parts, lb.offsets, target, lb.k)
               for lb in out]
    return out


@dataclass(eq=False)            # identity equality: comparing in-flight
class Launch:                   # jax arrays elementwise would raise
    """One device dispatch: ``out`` is the raw in-flight ``jax.Array``
    ((B, N_pad) single launch block, (G, B, N_pad) fused)."""
    out: object
    blocks: List[_LaunchBlock]
    fused: bool

    def is_ready(self) -> bool:
        return bool(self.out.is_ready()) if hasattr(self.out, "is_ready") \
            else True


@dataclass(eq=False)            # identity equality (holds Launches)
class BucketDispatch:
    """One bucket slice in flight: every launch its entries need.

    An invocation's rows can straddle two canonical blocks (and so two
    launches with different tail shapes), so booking is only legal once
    ALL launches have landed — ``harvest`` is therefore the bucket-level
    barrier, and the dispatch queue (serverless/dispatch.py) tracks
    these whole, never individual launches.
    """
    key: BucketKey
    launches: List[Launch]
    entries: List[Entry]
    n_tasks: int

    def ready(self) -> bool:
        """Non-blocking poll: have all launches landed on device?"""
        return all(l.is_ready() for l in self.launches)

    def harvest(self) -> Dict[Entry, np.ndarray]:
        """Block until every launch lands; scatter predictions back per
        invocation.  Returns {(req_idx, inv): preds (tpi, n_obs)}."""
        # function-level import: the compile layer must not load the
        # serverless package at module scope (core <-> serverless cycle)
        from repro.serverless.sanitize import check_harvest_once
        check_harvest_once(self)
        results: Dict[Entry, np.ndarray] = {}
        for launch in self.launches:
            out = np.asarray(jax.block_until_ready(launch.out), np.float32)
            outs = out if launch.fused else out[None]
            for g, lb in enumerate(launch.blocks):
                for blk, ofs in zip(lb.parts, lb.offsets):
                    for lane, (_, inv, row) in enumerate(blk.members):
                        buf = results.get((blk.ri, inv))
                        if buf is None:
                            buf = results[(blk.ri, inv)] = \
                                np.empty((blk.tpi, blk.n), np.float32)
                        buf[row] = outs[g, ofs + lane, :blk.n]
        return results

    def discard(self) -> None:
        """Retire a cancelled dispatch WITHOUT building results: block
        until the launches land (freeing the runtime's stream in order)
        and drop the handles.  Shares ``harvest``'s arm-once flag, so a
        discarded dispatch can never also be booked — and vice versa:
        the losing leg of a hedge race is structurally unbookable."""
        from repro.serverless.sanitize import check_harvest_once
        check_harvest_once(self)
        for launch in self.launches:
            jax.block_until_ready(launch.out)
        self.launches = []


# Structural cache of per-request block layouts: the canonical-block
# assignment is a pure function of (grid, scaling, segment l_ids,
# invocation subset, b_block, b_align) — steady serving re-lowers
# identical requests every round, and recomputing the rank arithmetic
# per drain was a dominant warm dispatch cost.  Value: a list of
# ((si, block, b_pad, canon_total), members) group descriptors.
_BLOCK_LAYOUT_CACHE: Dict[Tuple, List] = {}
_BLOCK_LAYOUT_CACHE_MAX = 1024


# segment_of_inv and _index_maps are pure functions of (grid, scaling,
# segment l_ids) — all key components — hence covers under req.segments
@warm_cache(name="block_layouts",
            key=("req.grid.n_rep", "req.grid.n_folds",
                 "req.grid.n_nuisance", "req.scaling", "req.segments",
                 "invs", "b_block", "b_align"),
            reads=("req.segment_of_inv", "req._index_maps"),
            covers={"req.segments": ("req.segment_of_inv",
                                     "req._index_maps")})
def _request_block_layout(req, invs: List[int], b_block: int,
                          b_align: int) -> List:
    layout_key = (req.grid.n_rep, req.grid.n_folds, req.grid.n_nuisance,
                  req.scaling,
                  tuple(tuple(sorted(s.l_ids)) for s in req.segments),
                  tuple(invs), b_block, b_align)
    hit = _BLOCK_LAYOUT_CACHE.get(layout_key)
    if hit is not None:
        return hit
    invs_arr = np.asarray(invs, np.int64)
    # exact segment per invocation, one vectorized lookup (robust to two
    # segments of a request collapsing onto one bucket after param
    # resolution)
    sis = req.segment_of_inv(invs_arr)
    tasks_mat = req._index_maps()[0][invs_arr]         # (m, tpi)
    L = req.grid.n_nuisance
    groups: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = {}
    for mi, (inv, si) in enumerate(zip(invs, sis)):
        si = int(si)
        l_ids = sorted(req.segments[si].l_ids)
        pos = {l: i for i, l in enumerate(l_ids)}
        for row, t in enumerate(tasks_mat[mi]):
            t = int(t)
            rank = (t // L) * len(l_ids) + pos[t % L]
            groups.setdefault((si, rank // b_block), []).append(
                (t, int(inv), row))
    out = []
    for (si, block), members in groups.items():
        n_l = len(req.segments[si].l_ids)
        seg_total = req.grid.n_rep * req.grid.n_folds * n_l
        canon = min(b_block, seg_total - block * b_block)
        out.append(((si, block, aligned_bucket(canon, 8, b_align)),
                    members))
    bounded_put(_BLOCK_LAYOUT_CACHE, layout_key, out,
                _BLOCK_LAYOUT_CACHE_MAX)
    return out


def _plan_blocks(plan: MegabatchPlan, key: BucketKey,
                 entries: Sequence[Entry], b_block: int,
                 b_align: int) -> List[_Block]:
    """Group a bucket slice's tasks into canonical launch blocks
    (order = first appearance); the per-request rank arithmetic is
    served from the structural layout cache on repeat traffic."""
    requests = plan.requests
    by_req: Dict[int, List[int]] = {}
    for ri, inv in entries:
        by_req.setdefault(ri, []).append(int(inv))

    blocks: List[_Block] = []
    for ri, invs in by_req.items():
        req = requests[ri]
        n = int(req.ledger.n_obs)
        p = int(req.x.shape[1])
        tpi = req.grid.tasks_per_invocation(req.scaling)
        for (si, block, b_pad), members in \
                _request_block_layout(req, invs, b_block, b_align):
            blocks.append(_Block(ri=ri, si=si, members=members,
                                 b_pad=b_pad, k=len(members),
                                 n=n, p=p, tpi=tpi))
    return blocks


# Content-keyed cache of stacked block tensors: a block's (y, w, valid,
# key_data) stack is a pure function of the request's ``work_key`` (set
# by the front-end when the tensors' provenance is fully pinned — the
# FULL data content, not just the feature page) and the block's lane
# content — steady serving re-lowers identical requests every round,
# and re-gathering/zero-padding the same tensors was a dominant warm
# dispatch cost.  Entries are marked read-only.  Unlike the small
# metadata caches this one holds real arrays, so it is bounded by
# BYTES (FIFO eviction), the same discipline as the PagePool.
_BLOCK_TENSOR_CACHE: Dict[Tuple, Tuple] = {}
_BLOCK_TENSOR_CACHE_BYTES = 256 * 1024 * 1024
_block_tensor_bytes = 0


# work_key pins the FULL data content plus plan structure (the PR 5
# staleness fix), which determines the wave arrays and key-data tables;
# a block's lane count k is determined by its member list
@warm_cache(name="block_tensors",
            key=("req.work_key", "seg_idx", "blk.members", "blk.b_pad",
                 "n_pad"),
            reads=("req.wave_arrays", "req.task_key_data", "blk.k",
                   "blk.n"),
            covers={"req.work_key": ("req.wave_arrays",
                                     "req.task_key_data", "blk.n"),
                    "blk.members": ("blk.k",)})
def _block_tensors(req, seg_idx: int, blk: _Block, n_pad: int):
    """Stack one block's task tensors at its canonical padded shape."""
    global _block_tensor_bytes
    tasks_t = tuple(t for t, _, _ in blk.members)
    ck = None
    if req.work_key is not None:
        ck = (req.work_key, seg_idx, tasks_t, blk.b_pad, n_pad)
        hit = _BLOCK_TENSOR_CACHE.get(ck)
        if hit is not None:
            return hit
    tasks = np.asarray(tasks_t, np.int64)
    ye, we = req.wave_arrays(tasks)
    kde = req.task_key_data(seg_idx, tasks)
    k, b_pad, n = blk.k, blk.b_pad, blk.n
    y = np.zeros((b_pad, n_pad), np.float32)
    w = np.zeros((b_pad, n_pad), np.float32)
    valid = np.zeros((b_pad, n_pad), np.float32)
    kd = np.zeros((b_pad,) + kde.shape[1:], kde.dtype)
    y[:k, :n] = ye
    w[:k, :n] = we
    valid[:k, :n] = 1.0
    kd[:k] = kde
    if ck is not None:
        nbytes = y.nbytes + w.nbytes + valid.nbytes + kd.nbytes
        if nbytes <= _BLOCK_TENSOR_CACHE_BYTES:
            for arr in (y, w, valid, kd):
                arr.flags.writeable = False
            while (_block_tensor_bytes + nbytes
                   > _BLOCK_TENSOR_CACHE_BYTES) and _BLOCK_TENSOR_CACHE:
                old = _BLOCK_TENSOR_CACHE.pop(
                    next(iter(_BLOCK_TENSOR_CACHE)))
                _block_tensor_bytes -= sum(a.nbytes for a in old)
            _BLOCK_TENSOR_CACHE[ck] = (y, w, valid, kd)
            _block_tensor_bytes += nbytes
    return y, w, valid, kd


class _PaddingAcc:
    """Plain-int padding accumulator: one ``PaddingStats`` merge per
    dispatch call instead of one dataclass round-trip per block (the
    per-block churn was measurable on the warm dispatch path)."""
    __slots__ = ("true_cells", "padded_cells", "tasks", "padded_tasks",
                 "lane_cells", "lane_cells_pow2", "true_feats",
                 "padded_feats")

    def __init__(self):
        for f in self.__slots__:
            setattr(self, f, 0)

    def book_part(self, key: BucketKey, blk: _Block, exact_shapes: bool):
        """Per-canonical-block terms: true work and N/P-axis lanes."""
        # opaque exact-shape buckets never padded N under either rule
        n_pow2 = blk.n if exact_shapes else pow2_bucket(blk.n, 8)
        self.true_cells += blk.k * blk.n
        self.tasks += blk.k
        self.lane_cells += blk.k * key.n_pad
        self.lane_cells_pow2 += blk.k * n_pow2
        self.true_feats += blk.k * blk.p
        self.padded_feats += blk.k * key.p_pad

    def book_launch(self, key: BucketKey, lb: _LaunchBlock):
        """Per-launch-block terms: what the device actually burned —
        a coalesced launch block bills its combined b_pad ONCE."""
        self.padded_cells += lb.b_pad * key.n_pad
        self.padded_tasks += lb.b_pad

    def stats(self, padded_tasks_pow2: int,
              padded_tasks_morphed: int) -> PaddingStats:
        return PaddingStats(
            true_cells=self.true_cells, padded_cells=self.padded_cells,
            tasks=self.tasks, padded_tasks=self.padded_tasks,
            padded_tasks_pow2=padded_tasks_pow2,
            padded_tasks_morphed=padded_tasks_morphed,
            lane_cells=self.lane_cells,
            lane_cells_pow2=self.lane_cells_pow2,
            true_feats=self.true_feats, padded_feats=self.padded_feats)


def _page_key_of(plan: MegabatchPlan, pages: Optional[PagePool],
                 blk: _Block, n_pad: int, p_pad: int):
    """Identity of a block's feature page: the PagePool content key when
    pooled, the request index on the host-stacked path."""
    if pages is not None:
        return PagePool.page_key(plan.requests[blk.ri], n_pad, p_pad)
    return blk.ri


def _launch_pages(plan: MegabatchPlan, pages: Optional[PagePool],
                  key: BucketKey, lbs: List[_LaunchBlock],
                  n_pad: int, p_pad: int):
    """Union page stack + page-key -> lane map across launch blocks."""
    lane_of: Dict[object, int] = {}
    needs = []
    for lb in lbs:
        for blk in lb.parts:
            pk = _page_key_of(plan, pages, blk, n_pad, p_pad)
            if pk not in lane_of:
                lane_of[pk] = len(lane_of)
                needs.append((pk, plan.requests[blk.ri]))
    if pages is not None:
        pages_arr = pages.stack(needs, n_pad, p_pad)
    else:
        stack = [plan.page(ri, key) for ri, _ in needs]
        d_pad = pow2_bucket(len(stack), 1)
        stack += [np.zeros((n_pad, p_pad), np.float32)] \
            * (d_pad - len(stack))
        pages_arr = np.stack(stack)
    return pages_arr, lane_of


def _launch_tensors(plan: MegabatchPlan, lb: _LaunchBlock, n_pad: int):
    """One launch block's (y, w, valid, kd) at its launch shape.

    Single canonical blocks at their own shape come straight from the
    content-keyed tensor cache (zero copy); packed or morphed launch
    blocks assemble their combined batch axis from the parts' cached
    tensors (padding lanes stay zero with valid=0)."""
    if len(lb.parts) == 1 and lb.b_pad == lb.parts[0].b_pad:
        blk = lb.parts[0]
        return _block_tensors(plan.requests[blk.ri], blk.si, blk, n_pad)
    y = np.zeros((lb.b_pad, n_pad), np.float32)
    w = np.zeros((lb.b_pad, n_pad), np.float32)
    valid = np.zeros((lb.b_pad, n_pad), np.float32)
    kd = None
    for blk, ofs in zip(lb.parts, lb.offsets):
        py, pw, pv, pkd = _block_tensors(plan.requests[blk.ri], blk.si,
                                         blk, n_pad)
        if kd is None:
            kd = np.zeros((lb.b_pad,) + pkd.shape[1:], pkd.dtype)
        k = blk.k
        y[ofs:ofs + k] = py[:k]
        w[ofs:ofs + k] = pw[:k]
        valid[ofs:ofs + k] = pv[:k]
        kd[ofs:ofs + k] = pkd[:k]
    return y, w, valid, kd


def _launch_didx(plan: MegabatchPlan, pages: Optional[PagePool],
                 lb: _LaunchBlock, lane_of: Dict[object, int],
                 n_pad: int, p_pad: int) -> np.ndarray:
    """Per-lane page index for one launch block.  Padding lanes point at
    page 0 — their gather is masked by valid=0, and a fixed index keeps
    the launch deterministic."""
    didx = np.zeros((lb.b_pad,), np.int32)
    for blk, ofs in zip(lb.parts, lb.offsets):
        didx[ofs:ofs + blk.k] = \
            lane_of[_page_key_of(plan, pages, blk, n_pad, p_pad)]
    return didx


def _axis_to_execute(key: BucketKey, axis_decision, mesh
                     ) -> Optional[Tuple[str, int]]:
    """(axis, shards) the drain can actually lower for this bucket, or
    None for the task path.  A data/feature ``AxisDecision`` executes
    only when the in-mesh executors apply: a Gram family, a mesh with a
    "data" device axis, and the sharded dimension divisible by the
    axis size (N_pad is 8-aligned, P_pad pow2 — so power-of-two meshes
    always divide; anything else falls back to task, which
    ``dispatch_bucket`` stamps on the decision)."""
    from repro.launch.roofline import GRAM_FAMILIES
    if axis_decision is None or mesh is None:
        return None
    axis = axis_decision.axis
    if axis not in ("data", "feature"):
        return None
    if bucket_family(key) not in GRAM_FAMILIES:
        return None
    if "data" not in mesh.axis_names:
        return None
    m = int(mesh.shape["data"])
    if axis == "data" and key.n_pad % m != 0:
        return None
    if axis == "feature" and key.p_pad % m != 0:
        return None
    return axis, m


def _dispatch_axis_bucket(plan: MegabatchPlan, cache: ProgramCache,
                          key: BucketKey, entries: Sequence[Entry],
                          blocks: List[_Block], axis: str, mesh,
                          *, b_align: int, pages: Optional[PagePool],
                          b_block: int, coalesce: bool,
                          morph_tolerance: float) -> BucketDispatch:
    """Lower a bucket slice through the planner's data@m/feature@m
    layout (ISSUE 9): every launch block dispatches through the in-mesh
    fit-predict program (sharding/gram.py::axis_fit_program) instead of
    the ProgramCache's task program — the data form streams each
    shard's N/m rows as chunks through the blocked Gram kernel with
    psum reassembly, the feature form shards P with the all-gather row
    term, and the solve epilogue runs replicated.  Page stacking, task
    tensors, coalescing, harvest booking, and DispatchStats/
    PaddingStats attribution are identical to the task path; results
    sit in the explicit tolerance tier (the task axis stays the bitwise
    reference), so axis launches never fuse across blocks or morph into
    foreign shapes beyond the same tail packing the task path does."""
    from repro.sharding.gram import (axis_fit_program,
                                     axis_fit_program_cached)
    requests = plan.requests
    n_pad, p_pad = key.n_pad, key.p_pad
    family = bucket_family(key)
    params = tuple(key.learner[1])
    can_morph = morph_allowed(key, morph_tolerance)
    morph = coalesce and can_morph
    lblocks = _coalesce(blocks, b_block, b_align, morph, False)
    morphed_tasks = sum(lb.b_pad for lb in lblocks) if morph == can_morph \
        else sum(lb.b_pad for lb in
                 _coalesce(blocks, b_block, b_align, can_morph, False))

    pad_acc = _PaddingAcc()
    launches: List[Launch] = []
    # operands may be committed to a single device (the host PagePool
    # pins pages to its lead device); re-place them replicated on the
    # mesh so the jitted shard_map accepts and partitions them
    from jax.sharding import NamedSharding, PartitionSpec
    repl = NamedSharding(mesh, PartitionSpec())
    for lb in lblocks:
        pages_arr, lane_of = _launch_pages(plan, pages, key, [lb],
                                           n_pad, p_pad)
        y, w, valid, kd = _launch_tensors(plan, lb, n_pad)
        didx = _launch_didx(plan, pages, lb, lane_of, n_pad, p_pad)
        pages_arr, didx, y, w, valid, kd = jax.device_put(
            (pages_arr, didx, y, w, valid, kd), repl)
        if axis_fit_program_cached(mesh, axis, family, params):
            cache.stats.hits += 1
        else:
            cache.stats.misses += 1
        prog = axis_fit_program(mesh, axis, family, params)
        out = prog(pages_arr, didx, y, w, valid, kd)
        launches.append(Launch(out=out, blocks=[lb], fused=False))
        cache.stats.launches += 1
        cache.stats.blocks += len(lb.parts)
        if len(lb.parts) > 1:
            cache.stats.coalesced_blocks += len(lb.parts)
            cache.stats.fused_launches += 1
        for blk in lb.parts:
            pad_acc.book_part(
                key, blk,
                requests[blk.ri].segments[blk.si].learner is None)
        pad_acc.book_launch(key, lb)

    total_tasks = sum(blk.k for blk in blocks)
    cache.stats.padding = cache.stats.padding.merge(
        pad_acc.stats(pow2_bucket(total_tasks, 8), morphed_tasks))
    return BucketDispatch(key=key, launches=launches,
                          entries=list(entries), n_tasks=total_tasks)


def dispatch_bucket(plan: MegabatchPlan, cache: ProgramCache,
                    key: BucketKey, entries: Sequence[Entry], *,
                    b_align: int = 1, pages: Optional[PagePool] = None,
                    b_block: int = B_BLOCK, fuse: bool = True,
                    coalesce: bool = True, morph_tolerance: float = 0.0,
                    axis_decision=None, mesh=None,
                    ) -> BucketDispatch:
    """Launch one bucket slice WITHOUT waiting for the device.

    Groups the entries' tasks into canonical launch blocks; for
    morph-proven families (``coalesce``, see MORPH_BITWISE_FAMILIES)
    tail blocks pack cross-request into combined launch blocks and
    residual mixed shapes morph up so the bucket fuses into one
    ``lax.map`` launch.  Equal-``b_pad`` launch blocks pack into fused
    launches (a leading block axis over one union page stack; per-block
    launches when ``fuse`` is off, the block is unique at its shape, or
    the cache is partitioned).  Returns the in-flight
    ``BucketDispatch``; call ``.harvest()`` (or go through
    ``run_bucket``) for the results.

    ``axis_decision``/``mesh`` (ISSUE 9): a planner ``AxisDecision``
    whose axis is data/feature lowers through the in-mesh Gram
    executors on ``mesh`` (``_dispatch_axis_bucket``) when the
    executability guards pass; the decision's ``executed`` field is
    stamped with the axis that actually ran either way.
    """
    requests = plan.requests
    n_pad, p_pad = key.n_pad, key.p_pad
    blocks = _plan_blocks(plan, key, entries, b_block, b_align)
    # execute the axis plan (ISSUE 9): a data/feature decision lowers
    # through the in-mesh Gram executors; anything else (including a
    # data/feature plan the guards reject) runs the task path, and the
    # decision records which axis actually ran
    axis_m = _axis_to_execute(key, axis_decision, mesh)
    if axis_m is not None:
        axis_decision.executed = axis_m[0]
        return _dispatch_axis_bucket(
            plan, cache, key, entries, blocks, axis_m[0], mesh,
            b_align=b_align, pages=pages, b_block=b_block,
            coalesce=coalesce, morph_tolerance=morph_tolerance)
    if axis_decision is not None:
        axis_decision.executed = "task"
    # a partitioned cache fuses again when it carries the sharded-fused
    # transform (ISSUE 8) — shard_map wraps the lax.map body, so the
    # PR 5 "sharded caches never fuse" restriction is lifted
    fuse = fuse and (cache.partition is None
                     or cache.partition_fused is not None)
    can_morph = morph_allowed(key, morph_tolerance)
    morph = coalesce and can_morph
    lblocks = _coalesce(blocks, b_block, b_align, morph, fuse)
    # the morphed-B comparator: what the coalescing scheduler burns (or
    # would burn, when coalesce is off) on this slice's B axis
    morphed_tasks = sum(lb.b_pad for lb in lblocks) if morph == can_morph \
        else sum(lb.b_pad for lb in
                 _coalesce(blocks, b_block, b_align, can_morph, fuse))

    by_shape: Dict[int, List[_LaunchBlock]] = {}
    for lb in lblocks:
        by_shape.setdefault(lb.b_pad, []).append(lb)

    pad_acc = _PaddingAcc()
    launches: List[Launch] = []
    for b_pad, group in by_shape.items():
        lead = group[0].parts[0]
        seg = requests[lead.ri].segments[lead.si]
        if not fuse or len(group) == 1:
            for lb in group:
                pages_arr, lane_of = _launch_pages(plan, pages, key, [lb],
                                                   n_pad, p_pad)
                y, w, valid, kd = _launch_tensors(plan, lb, n_pad)
                didx = _launch_didx(plan, pages, lb, lane_of, n_pad, p_pad)
                blk_seg = requests[lb.parts[0].ri].segments[lb.parts[0].si]
                prog = cache.program(
                    key, b_pad, int(pages_arr.shape[0]),
                    lambda: segment_batched_fn(blk_seg))
                out = prog(pages_arr, didx, y, w, valid, kd)
                launches.append(Launch(out=out, blocks=[lb], fused=False))
                cache.stats.launches += 1
                cache.stats.blocks += len(lb.parts)
                if len(lb.parts) > 1:
                    # a coalesced multi-part launch IS a fused launch:
                    # 2+ canonical blocks went up in one dispatch
                    cache.stats.coalesced_blocks += len(lb.parts)
                    cache.stats.fused_launches += 1
                for blk in lb.parts:
                    pad_acc.book_part(
                        key, blk,
                        requests[blk.ri].segments[blk.si].learner is None)
                pad_acc.book_launch(key, lb)
            continue

        # ---- fused launch: G same-shape launch blocks, one union stack
        pages_arr, lane_of = _launch_pages(plan, pages, key, group,
                                           n_pad, p_pad)
        g = len(group)
        ys = np.empty((g, b_pad, n_pad), np.float32)
        ws = np.empty((g, b_pad, n_pad), np.float32)
        valids = np.empty((g, b_pad, n_pad), np.float32)
        didx = np.empty((g, b_pad), np.int32)
        kds = None
        for gi, lb in enumerate(group):
            y, w, valid, kd = _launch_tensors(plan, lb, n_pad)
            if kds is None:
                kds = np.empty((g,) + kd.shape, kd.dtype)
            ys[gi], ws[gi], valids[gi], kds[gi] = y, w, valid, kd
            didx[gi] = _launch_didx(plan, pages, lb, lane_of, n_pad, p_pad)
            cache.stats.blocks += len(lb.parts)
            if len(lb.parts) > 1:
                cache.stats.coalesced_blocks += len(lb.parts)
            for blk in lb.parts:
                pad_acc.book_part(
                    key, blk,
                    requests[blk.ri].segments[blk.si].learner is None)
            pad_acc.book_launch(key, lb)
        if cache.partition_fused is not None:
            prog = cache.sharded_fused_program(
                key, b_pad, int(pages_arr.shape[0]), g,
                lambda: segment_batched_fn(seg))
        else:
            prog = cache.fused_program(
                key, b_pad, int(pages_arr.shape[0]), g,
                lambda: segment_batched_fn(seg))
        out = prog(pages_arr, didx, ys, ws, valids, kds)
        launches.append(Launch(out=out, blocks=list(group), fused=True))
        cache.stats.launches += 1
        cache.stats.fused_launches += 1

    total_tasks = sum(blk.k for blk in blocks)
    # one merge per dispatch; padded_tasks_pow2 records what the old rule
    # (one pow2 launch per bucket slice) would have cost, and
    # padded_tasks_morphed what the coalescing scheduler costs
    cache.stats.padding = cache.stats.padding.merge(
        pad_acc.stats(pow2_bucket(total_tasks, 8), morphed_tasks))
    return BucketDispatch(key=key, launches=launches,
                          entries=list(entries), n_tasks=total_tasks)


def run_bucket(plan: MegabatchPlan, cache: ProgramCache, key: BucketKey,
               entries: Sequence[Entry], *, b_align: int = 1,
               pages: Optional[PagePool] = None, b_block: int = B_BLOCK,
               fuse: bool = True, coalesce: bool = True,
               morph_tolerance: float = 0.0,
               axis_decision=None, mesh=None,
               ) -> Tuple[Dict[Entry, np.ndarray], float]:
    """Synchronous wrapper: dispatch one bucket slice and block for its
    results.  Returns ({(req_idx, inv): preds (tpi, n_obs)}, wall_s).

    When a ``PagePool`` is passed, feature pages come from the
    device-resident pool (zero host->device transfer on warm pages, and
    fused launches reuse the composition-cached union stack); otherwise
    pages are stacked on the host.
    """
    t0 = time.perf_counter()
    bd = dispatch_bucket(plan, cache, key, entries, b_align=b_align,
                         pages=pages, b_block=b_block, fuse=fuse,
                         coalesce=coalesce, morph_tolerance=morph_tolerance,
                         axis_decision=axis_decision, mesh=mesh)
    results = bd.harvest()
    return results, time.perf_counter() - t0
