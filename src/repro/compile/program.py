"""Megabatch program build, cache, and execution.

A **program** is one jitted function per (bucket, padded batch shape):

    run(pages (D, N_pad, P_pad), data_idx (B,), y (B, N_pad),
        w (B, N_pad), valid (B, N_pad), key_data (B, ...)) -> (B, N_pad)

It gathers every task's feature page, rebuilds the per-task typed PRNG
keys, and calls the learner family's ``batched_fit_predict`` — on the
linear/ridge path that bottoms out in the fused Pallas kernels
(``batched_gram`` / ``batched_predict`` in kernels/ops.py).  The batch
axis B is wave-capacity-aligned (``aligned_bucket``: multiples of the
lane quantum, so steady traffic lands on the same few shapes with <1
quantum of waste) and the page axis D is pow2-bucketed, so repeat traffic
of *any* composition hits a previously-compiled program: the warm cache
is keyed by spec, never by object identity or request.  Feature pages
come from the device-resident ``PagePool`` (pages.py) when the backend
passes one — warm drains then perform zero host->device page transfer.

``ProgramCache`` owns the programs plus hit/miss/padding accounting; the
execution backends (serverless/backends.py) hold one instance each and
stay warm across ``run_requests`` calls.  An optional ``partition`` hook
wraps the program body before jit — ShardedBackend passes a shard_map
over the batch axis (sharding/policy.py::megabatch_specs).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.crossfit import PaddingStats, aligned_bucket, pow2_bucket
from repro.compile.buckets import BucketKey, Entry, MegabatchPlan
from repro.compile.pages import PagePool
from repro.learners import as_batched, get_batched_learner


@dataclass
class CompileStats:
    """Warm-cache and padding accounting across program launches."""
    hits: int = 0
    misses: int = 0
    launches: int = 0
    padding: PaddingStats = field(default_factory=PaddingStats)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def summary(self) -> Dict:
        return {"programs_compiled": self.misses,
                "cache_hits": self.hits,
                "cache_hit_rate": self.hit_rate,
                "launches": self.launches,
                "padding_waste_frac": self.padding.waste_frac,
                "tasks": self.padding.tasks,
                "padded_tasks": self.padding.padded_tasks}


def segment_batched_fn(seg) -> Callable:
    """Resolve a segment's megabatch implementation: registry learners get
    their native batched form, opaque callables the vmap adapter."""
    if seg.learner is not None:
        return get_batched_learner(seg.learner, dict(seg.params))
    return as_batched(seg.learner_fn)


class ProgramCache:
    """Spec-keyed cache of compiled megabatch programs.

    Keys are ``(BucketKey, B_pad, D_pad)`` — pure value identity, so two
    requests built from equal plans share programs, and a session's
    repeat traffic never re-traces.
    """

    def __init__(self, partition: Optional[Callable] = None):
        self._programs: Dict[Tuple, Callable] = {}
        self.partition = partition
        self.stats = CompileStats()

    def program(self, key: BucketKey, b_pad: int, d_pad: int,
                fn_thunk: Callable[[], Callable]) -> Callable:
        pkey = (key, b_pad, d_pad)
        prog = self._programs.get(pkey)
        if prog is not None:
            self.stats.hits += 1
            return prog
        self.stats.misses += 1
        batched_fn = fn_thunk()

        def run(pages, data_idx, y, w, valid, key_data):
            xb = pages[data_idx]                       # (B, N_pad, P_pad)
            keys = jax.random.wrap_key_data(key_data)  # (B,) typed keys
            return batched_fn(xb, y, w, valid, keys)

        if self.partition is not None:
            run = self.partition(run)
        prog = jax.jit(run)
        self._programs[pkey] = prog
        return prog


# One launch carries exactly B_BLOCK task lanes (invocations are atomic
# within a launch; only a single invocation wider than the block raises
# the launch's B, to aligned_bucket(tpi)).  A *constant* launch shape is
# the bitwise schedule-invariance contract: per-lane results depend on
# the compiled B (XLA reduction tiling) but not on lane position or other
# lanes' contents, so fixing B makes every scheduler — inline whole-bucket
# drains, capacity-limited waves, out-of-order async slices — produce
# identical floats.  It also collapses the B axis onto one compiled
# program per bucket and caps B padding at the final partial block
# (vs pow2's up-to-2x on every drain).  16 would cut single-request
# B waste further but doubles launch count and halves steady throughput
# on the session benches — 32 is the measured sweet spot.
#
# Caveat: ShardedBackend aligns B up to its shard count, so bitwise
# parity with the other schedulers holds when the shard count divides
# B_BLOCK (1/2/4/8/16/32-way meshes; a 3-way mesh compiles B=33 and
# agrees only to float tolerance).
B_BLOCK = 32


def _chunk_rows(rows, b_block: int):
    """Split (ri, inv, tasks) rows into launches of <= b_block tasks,
    keeping invocations atomic."""
    chunks: List[List] = []
    cur, cur_tasks = [], 0
    for row in rows:
        k = len(row[2])
        if cur and cur_tasks + k > b_block:
            chunks.append(cur)
            cur, cur_tasks = [], 0
        cur.append(row)
        cur_tasks += k
    if cur:
        chunks.append(cur)
    return chunks


def run_bucket(plan: MegabatchPlan, cache: ProgramCache, key: BucketKey,
               entries: Sequence[Entry], *, b_align: int = 1,
               pages: Optional[PagePool] = None, b_block: int = B_BLOCK,
               ) -> Tuple[Dict[Entry, np.ndarray], float]:
    """Execute one bucket slice: stack the entries' tasks into padded
    megabatch tensors, launch the (cached) fixed-shape program once per
    ``B_BLOCK`` chunk, and scatter the predictions back per invocation.

    When a ``PagePool`` is passed, feature pages come from the
    device-resident pool (zero host->device transfer on warm pages, and
    the whole page stack is the cached array object on repeat
    compositions); otherwise pages are stacked on the host as before.

    Returns ({(req_idx, inv): preds (tpi, n_obs)}, wall_seconds).
    """
    requests = plan.requests
    n_pad, p_pad = key.n_pad, key.p_pad

    rows: List[Tuple[int, int, np.ndarray]] = []
    for ri, inv in entries:
        req = requests[ri]
        rows.append((ri, inv, req.invocation_tasks(inv)))

    def seg_of_entry(ri, inv):
        """Exact segment of one invocation (robust to two segments of a
        request collapsing onto one bucket after param resolution)."""
        return int(requests[ri].segment_of_inv(
            np.asarray([inv], np.int64))[0])

    results: Dict[Entry, np.ndarray] = {}
    wall = 0.0
    for chunk in _chunk_rows(rows, b_block):
        n_tasks = sum(len(t) for _, _, t in chunk)
        b_pad = aligned_bucket(max(n_tasks, b_block), 8, b_align)

        # ---- data pages (lane order = first appearance in the chunk) ----
        page_idx: Dict[int, int] = {}
        chunk_pages: List = []
        for ri, _, _ in chunk:
            if ri not in page_idx:
                page_idx[ri] = len(chunk_pages)
                chunk_pages.append(ri)
        if pages is not None:
            pages_arr = pages.stack(
                [(pages.page_key(requests[ri], n_pad, p_pad), requests[ri])
                 for ri in chunk_pages], n_pad, p_pad)
        else:
            host_pages = [plan.page(ri, key) for ri in chunk_pages]
            d_pad = pow2_bucket(len(host_pages), 1)
            while len(host_pages) < d_pad:
                host_pages.append(np.zeros((n_pad, p_pad), np.float32))
            pages_arr = np.stack(host_pages)

        # ---- stack task tensors -----------------------------------------
        first = requests[chunk[0][0]]
        kd_probe = first.task_key_data(
            seg_of_entry(chunk[0][0], chunk[0][1]), chunk[0][2][:1])
        y = np.zeros((b_pad, n_pad), np.float32)
        w = np.zeros((b_pad, n_pad), np.float32)
        valid = np.zeros((b_pad, n_pad), np.float32)
        kd = np.zeros((b_pad,) + kd_probe.shape[1:], kd_probe.dtype)
        didx = np.zeros((b_pad,), np.int32)
        slices: List[Tuple[int, int, int, int, int]] = []
        r0 = 0
        true_cells = 0
        for ri, inv, tasks in chunk:
            req = requests[ri]
            n = int(req.ledger.n_obs)
            ye, we = req.wave_arrays(tasks)
            k = len(tasks)
            y[r0:r0 + k, :n] = ye
            w[r0:r0 + k, :n] = we
            valid[r0:r0 + k, :n] = 1.0
            kd[r0:r0 + k] = req.task_key_data(seg_of_entry(ri, inv), tasks)
            didx[r0:r0 + k] = page_idx[ri]
            slices.append((ri, inv, r0, k, n))
            true_cells += k * n
            r0 += k

        # ---- launch -----------------------------------------------------
        d_pad = int(pages_arr.shape[0])
        seg = requests[chunk[0][0]].segments[plan.seg_of[(chunk[0][0], key)]]
        prog = cache.program(key, b_pad, d_pad,
                             lambda: segment_batched_fn(seg))
        t0 = time.perf_counter()
        out = prog(pages_arr, didx, y, w, valid, kd)
        out = np.asarray(jax.block_until_ready(out), np.float32)
        wall += time.perf_counter() - t0

        cache.stats.launches += 1
        cache.stats.padding = cache.stats.padding.merge(PaddingStats(
            true_cells=true_cells, padded_cells=b_pad * n_pad,
            tasks=n_tasks, padded_tasks=b_pad))
        for ri, inv, a, k, n in slices:
            results[(ri, inv)] = out[a:a + k, :n]
    # what the old rule (one pow2 launch per bucket slice) would have cost
    total_tasks = sum(len(t) for _, _, t in rows)
    cache.stats.padding = cache.stats.padding.merge(PaddingStats(
        padded_tasks_pow2=pow2_bucket(total_tasks, 8)))
    return results, wall
