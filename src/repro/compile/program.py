"""Megabatch program build, cache, and execution.

A **program** is one jitted function per (bucket, padded batch shape):

    run(pages (D, N_pad, P_pad), data_idx (B,), y (B, N_pad),
        w (B, N_pad), valid (B, N_pad), key_data (B, ...)) -> (B, N_pad)

It gathers every task's feature page, rebuilds the per-task typed PRNG
keys, and calls the learner family's ``batched_fit_predict`` — on the
linear/ridge path that bottoms out in the fused Pallas kernels
(``batched_gram`` / ``batched_predict`` in kernels/ops.py).  The batch
axis B is wave-capacity-aligned (``aligned_bucket``: multiples of the
lane quantum, so steady traffic lands on the same few shapes with <1
quantum of waste) and the page axis D is pow2-bucketed, so repeat traffic
of *any* composition hits a previously-compiled program: the warm cache
is keyed by spec, never by object identity or request.  Feature pages
come from the device-resident ``PagePool`` (pages.py) when the backend
passes one — warm drains then perform zero host->device page transfer.

``ProgramCache`` owns the programs plus hit/miss/padding accounting; the
execution backends (serverless/backends.py) hold one instance each and
stay warm across ``run_requests`` calls.  An optional ``partition`` hook
wraps the program body before jit — ShardedBackend passes a shard_map
over the batch axis (sharding/policy.py::megabatch_specs).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.crossfit import PaddingStats, aligned_bucket, pow2_bucket
from repro.compile.buckets import BucketKey, Entry, MegabatchPlan
from repro.compile.pages import PagePool
from repro.learners import as_batched, get_batched_learner


@dataclass
class CompileStats:
    """Warm-cache and padding accounting across program launches."""
    hits: int = 0
    misses: int = 0
    launches: int = 0
    padding: PaddingStats = field(default_factory=PaddingStats)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def summary(self) -> Dict:
        return {"programs_compiled": self.misses,
                "cache_hits": self.hits,
                "cache_hit_rate": self.hit_rate,
                "launches": self.launches,
                "padding_waste_frac": self.padding.waste_frac,
                "padding_waste_b_frac": self.padding.b_waste_frac,
                "padding_waste_n_frac": self.padding.n_waste_frac,
                "padding_waste_p_frac": self.padding.p_waste_frac,
                "tasks": self.padding.tasks,
                "padded_tasks": self.padding.padded_tasks}


def segment_batched_fn(seg) -> Callable:
    """Resolve a segment's megabatch implementation: registry learners get
    their native batched form, opaque callables the vmap adapter."""
    if seg.learner is not None:
        return get_batched_learner(seg.learner, dict(seg.params))
    return as_batched(seg.learner_fn)


class ProgramCache:
    """Spec-keyed cache of compiled megabatch programs.

    Keys are ``(BucketKey, B_pad, D_pad)`` — pure value identity, so two
    requests built from equal plans share programs, and a session's
    repeat traffic never re-traces.
    """

    def __init__(self, partition: Optional[Callable] = None):
        self._programs: Dict[Tuple, Callable] = {}
        self.partition = partition
        self.stats = CompileStats()

    def program(self, key: BucketKey, b_pad: int, d_pad: int,
                fn_thunk: Callable[[], Callable]) -> Callable:
        pkey = (key, b_pad, d_pad)
        prog = self._programs.get(pkey)
        if prog is not None:
            self.stats.hits += 1
            return prog
        self.stats.misses += 1
        batched_fn = fn_thunk()

        def run(pages, data_idx, y, w, valid, key_data):
            xb = pages[data_idx]                       # (B, N_pad, P_pad)
            keys = jax.random.wrap_key_data(key_data)  # (B,) typed keys
            return batched_fn(xb, y, w, valid, keys)

        if self.partition is not None:
            run = self.partition(run)
        prog = jax.jit(run)
        self._programs[pkey] = prog
        return prog


# A launch carries at most B_BLOCK task lanes.  The compiled B is part
# of the determinism contract: per-lane floats are independent of lane
# position and of the *other lanes' contents* (verified per family by
# tests/test_compile.py::test_tail_launch_b_invariance), but they DO
# depend on the compiled B itself (XLA reduction tiling — B=8 and B=16
# programs differ by ~1e-6).  So a task's launch B must be a pure
# function of its own request, never of what a scheduler happened to
# hand over in one call: within each (request, segment), the segment's
# flat tasks in ascending order split into **canonical blocks** of
# B_BLOCK tasks, and a block always compiles at its canonical aligned
# size — full blocks at B_BLOCK, the tail at its sublane-aligned count —
# even when a capacity-limited wave executes only part of it (the
# missing lanes ride as padding; lane-content independence makes the
# result identical to the full-block launch).  Flat task ids are
# scaling-level-invariant, so per-split and per-fold scaling also
# compile identical launch shapes.
#
# This replaces the PR-3 rule that padded *every* launch up to B_BLOCK:
# constant-shape was sufficient for bitwise invariance but blew B-axis
# waste to ~65% on small-bucket traffic (BENCH_asyncdrain.json) — a
# 12-task bucket burned 20 padding lanes per launch.  Canonical tails
# launch at aligned size instead (12 tasks -> B=16), capping a bucket's
# B waste at the tail block's alignment.  16 for B_BLOCK would cut
# single-request waste further but doubles launch count and halves
# steady throughput on the session benches — 32 is the measured sweet
# spot.
#
# Caveat: ShardedBackend aligns B up to its shard count and shard_map
# retiles the per-lane reductions, so the sharded scheduler agrees with
# the unsharded ones to float tolerance (~1e-6) on multi-device meshes,
# bitwise only on a 1-device mesh.
B_BLOCK = 32


def run_bucket(plan: MegabatchPlan, cache: ProgramCache, key: BucketKey,
               entries: Sequence[Entry], *, b_align: int = 1,
               pages: Optional[PagePool] = None, b_block: int = B_BLOCK,
               ) -> Tuple[Dict[Entry, np.ndarray], float]:
    """Execute one bucket slice: group the entries' tasks by their
    canonical launch block, stack each block's tasks into padded
    megabatch tensors, launch the (cached) canonical-shape program per
    block, and scatter the predictions back per invocation.

    When a ``PagePool`` is passed, feature pages come from the
    device-resident pool (zero host->device transfer on warm pages, and
    the whole page stack is the cached array object on repeat
    compositions); otherwise pages are stacked on the host as before.

    Returns ({(req_idx, inv): preds (tpi, n_obs)}, wall_seconds).
    """
    requests = plan.requests
    n_pad, p_pad = key.n_pad, key.p_pad

    # exact segment per invocation, one vectorized lookup per request
    # (robust to two segments of a request collapsing onto one bucket
    # after param resolution)
    by_req: Dict[int, List[int]] = {}
    for ri, inv in entries:
        by_req.setdefault(ri, []).append(inv)
    seg_of: Dict[Entry, int] = {}
    for ri, invs in by_req.items():
        sis = requests[ri].segment_of_inv(np.asarray(invs, np.int64))
        for inv, si in zip(invs, sis):
            seg_of[(ri, int(inv))] = int(si)

    # ---- canonical block assignment (order = first appearance) ----------
    # group key (ri, si, block) -> [(flat task, inv, row-in-invocation)]
    groups: Dict[Tuple[int, int, int], List[Tuple[int, int, int]]] = {}
    seg_meta: Dict[Tuple[int, int], Tuple[int, Dict[int, int]]] = {}
    total_tasks = 0
    for ri, inv in entries:
        req = requests[ri]
        tasks = req.invocation_tasks(inv)
        total_tasks += len(tasks)
        si = seg_of[(ri, inv)]
        meta = seg_meta.get((ri, si))
        if meta is None:
            l_ids = sorted(req.segments[si].l_ids)
            meta = seg_meta[(ri, si)] = \
                (len(l_ids), {l: i for i, l in enumerate(l_ids)})
        n_l, pos = meta
        L = req.grid.n_nuisance
        for row, t in enumerate(tasks):
            rank = (int(t) // L) * n_l + pos[int(t) % L]
            groups.setdefault((ri, si, rank // b_block), []).append(
                (int(t), int(inv), row))

    results: Dict[Entry, np.ndarray] = {}
    wall = 0.0
    for (ri, si, block), members in groups.items():
        req = requests[ri]
        n = int(req.ledger.n_obs)
        p = int(req.x.shape[1])
        n_l = len(req.segments[si].l_ids)
        seg_total = req.grid.n_rep * req.grid.n_folds * n_l
        canon = min(b_block, seg_total - block * b_block)
        b_pad = aligned_bucket(canon, 8, b_align)
        tasks = np.array([t for t, _, _ in members], np.int64)
        k = len(tasks)

        # ---- data page (one request per canonical block) ----------------
        if pages is not None:
            pages_arr = pages.stack(
                [(pages.page_key(req, n_pad, p_pad), req)], n_pad, p_pad)
        else:
            pages_arr = plan.page(ri, key)[None]

        # ---- stack task tensors -----------------------------------------
        ye, we = req.wave_arrays(tasks)
        kde = req.task_key_data(si, tasks)
        y = np.zeros((b_pad, n_pad), np.float32)
        w = np.zeros((b_pad, n_pad), np.float32)
        valid = np.zeros((b_pad, n_pad), np.float32)
        kd = np.zeros((b_pad,) + kde.shape[1:], kde.dtype)
        didx = np.zeros((b_pad,), np.int32)
        y[:k, :n] = ye
        w[:k, :n] = we
        valid[:k, :n] = 1.0
        kd[:k] = kde

        # ---- launch -----------------------------------------------------
        d_pad = int(pages_arr.shape[0])
        seg = req.segments[si]
        prog = cache.program(key, b_pad, d_pad,
                             lambda: segment_batched_fn(seg))
        t0 = time.perf_counter()
        out = prog(pages_arr, didx, y, w, valid, kd)
        out = np.asarray(jax.block_until_ready(out), np.float32)
        wall += time.perf_counter() - t0

        cache.stats.launches += 1
        cache.stats.padding = cache.stats.padding.merge(PaddingStats(
            true_cells=k * n, padded_cells=b_pad * n_pad,
            tasks=k, padded_tasks=b_pad,
            lane_cells=k * n_pad, true_feats=k * p,
            padded_feats=k * p_pad))
        tpi = req.grid.tasks_per_invocation(req.scaling)
        for lane, (_, inv, row) in enumerate(members):
            buf = results.get((ri, inv))
            if buf is None:
                buf = results[(ri, inv)] = np.empty((tpi, n), np.float32)
            buf[row] = out[lane, :n]
    # what the old rule (one pow2 launch per bucket slice) would have cost
    cache.stats.padding = cache.stats.padding.merge(PaddingStats(
        padded_tasks_pow2=pow2_bucket(total_tasks, 8)))
    return results, wall
