"""The megabatch task compiler (ISSUE 2 tentpole).

Lowers the union of all pending WorkRequests into a small set of bucketed,
cached, fused programs:

    plan (DMLPlan, DMLData)
      -> task grid (core/crossfit.TaskGrid, M x K x L per request)
      -> buckets (buckets.plan_buckets: learner x N-bucket x P-bucket)
      -> programs (program.ProgramCache: jitted batched_fit_predict,
                   Pallas batched_gram / batched_predict on the hot path)
      -> waves (serverless/backends.py schedules bucket slices)

Every execution backend is a thin scheduler over this layer.
"""
from repro.compile.buckets import (
    BucketKey, Entry, MegabatchPlan, plan_buckets,
)
from repro.compile.pages import PageDirectory, PagePool, PageStats
from repro.compile.program import (
    BucketDispatch, CompileStats, ProgramCache, dispatch_bucket,
    run_bucket, segment_batched_fn,
)

__all__ = [
    "BucketKey", "Entry", "MegabatchPlan", "plan_buckets",
    "PageDirectory", "PagePool", "PageStats",
    "BucketDispatch", "CompileStats", "ProgramCache", "dispatch_bucket",
    "run_bucket", "segment_batched_fn",
]
