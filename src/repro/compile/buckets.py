"""Megabatch bucket planning: the whole cross-fitting grid -> few shapes.

The planner takes the union of all pending ``WorkRequest``s — across
sessions, repetitions, folds, nuisances, and mixed learner families — and
groups every task into a **bucket** keyed by

    (learner identity, padded N bucket, padded P bucket)

Tasks inside a bucket are shape-compatible after padding, so one jitted
program (see program.py) serves all of them regardless of which request
they came from: the serverless-ML lesson (pack many small homogeneous
work items into few large compiled invocations) applied to the paper's
M x K x L task grid.

Padding rules per learner family:

  * registry learners: N rounded up to the sublane quantum
    (``aligned_bucket``, multiples of 8 — mirroring the B tail rule),
    so N-axis waste is bounded at < 8 rows per lane instead of pow2's
    <2x; P stays pow2-bucketed for the feature-pad-safe families (the
    long tail of widths collapses onto a handful of programs);
  * mlp (init scale depends on the true P): N aligned, P exact;
  * opaque callables (the legacy raw-array path): exact shapes — we
    cannot prove padding is inert for arbitrary user code.

The aligned N rule trades program variety for waste: distinct N values
8 apart no longer share a program, but steady serving re-presents the
same N values and the N-axis was the dominant waste term once B was
fixed (35.9% on BENCH_asyncdrain vs 25% B; now gated <= 30% in CI).

The planner is pure bookkeeping (numpy only); execution and the warm
program cache live in program.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.registry import warm_cache
from repro.core.crossfit import aligned_bucket, pow2_bucket
from repro.learners import FEATURE_PAD_SAFE

Entry = Tuple[int, int]                 # (request index, invocation id)


@dataclass(frozen=True)
class BucketKey:
    """Identity of one megabatch program family."""
    learner: object                     # Segment.bucket_id (spec or opaque)
    n_pad: int
    p_pad: int


@dataclass
class MegabatchPlan:
    """The lowered view of a stream of requests: every (request, segment)
    mapped to its bucket, plus lazily-built padded data pages.

    The plan is **incremental**: ``admit()`` lowers one request at a time,
    so the continuous-admission drain engine can extend a live plan while
    earlier requests are already executing.  ``plan_buckets`` stays as the
    batch convenience (admit everything up front).
    """
    requests: List = field(default_factory=list)
    bucket_of: Dict[Tuple[int, int], BucketKey] = field(default_factory=dict)
    seg_of: Dict[Tuple[int, BucketKey], int] = field(default_factory=dict)
    min_n: int = 8
    min_p: int = 8
    _pages: Dict[Tuple[int, int, int], np.ndarray] = field(
        default_factory=dict)

    # ---- continuous admission -------------------------------------------
    def admit(self, req) -> int:
        """Lower one request into the plan; returns its request index."""
        ri = len(self.requests)
        self.requests.append(req)
        n = int(req.ledger.n_obs)
        p = int(req.x.shape[1])
        for si, seg in enumerate(req.segments):
            if seg.learner is None:            # opaque callable: exact shapes
                n_pad, p_pad = n, p
            elif seg.learner in FEATURE_PAD_SAFE:
                n_pad = aligned_bucket(n, self.min_n)
                p_pad = pow2_bucket(p, self.min_p)
            else:                              # e.g. mlp: P must stay exact
                n_pad, p_pad = aligned_bucket(n, self.min_n), p
            key = BucketKey(seg.bucket_id, n_pad, p_pad)
            self.bucket_of[(ri, si)] = key
            # first-wins: if two segments of one request collapse onto one
            # bucket (their *resolved* params are equal), either resolves
            # the same batched fn — per-task PRNG streams are looked up
            # via segment_of_inv in run_bucket, never through this map
            self.seg_of.setdefault((ri, key), si)
        return ri

    # ---- planning shapes -------------------------------------------------
    @property
    def buckets(self) -> List[BucketKey]:
        out: List[BucketKey] = []
        for key in self.bucket_of.values():
            if key not in out:
                out.append(key)
        return out

    # the plan owns its requests, so req_idx names one fixed request for
    # this plan's lifetime (the cache dict dies with the plan: ambient)
    @warm_cache(name="plan_pages", key=("req_idx", "key.n_pad",
                                        "key.p_pad"),
                ambient=("self",))
    def page(self, req_idx: int, key: BucketKey) -> np.ndarray:
        """The request's feature page padded to the bucket shape."""
        pkey = (req_idx, key.n_pad, key.p_pad)
        page = self._pages.get(pkey)
        if page is None:
            x = np.asarray(self.requests[req_idx].x, np.float32)
            page = np.zeros((key.n_pad, key.p_pad), np.float32)
            page[:x.shape[0], :x.shape[1]] = x
            self._pages[pkey] = page
        return page

    # ---- entry grouping --------------------------------------------------
    def group_entries(self, entries: Sequence[Entry]) \
            -> Dict[BucketKey, List[Entry]]:
        """Group (request, invocation) pairs by their bucket, preserving
        order (deterministic program launch order)."""
        groups: Dict[BucketKey, List[Entry]] = {}
        by_req: Dict[int, List[int]] = {}
        for ri, inv in entries:
            by_req.setdefault(ri, []).append(inv)
        for ri, invs in by_req.items():
            req = self.requests[ri]
            seg_idx = req.segment_of_inv(np.asarray(invs, np.int64))
            for inv, si in zip(invs, seg_idx):
                key = self.bucket_of[(ri, int(si))]
                groups.setdefault(key, []).append((ri, int(inv)))
        return groups

    def pending_by_bucket(self, exclude=None) -> Dict[BucketKey, List[Entry]]:
        """Every not-yet-DONE invocation of every request, bucketed.

        ``exclude`` is the dispatched-but-unharvested entry set of the
        caller's in-flight queue: those invocations are on device already
        and must not be re-dispatched while their launch is pending."""
        entries: List[Entry] = []
        for ri, req in enumerate(self.requests):
            entries.extend(e for inv in req.ledger.pending()
                           if (e := (ri, int(inv))) not in (exclude or ()))
        return self.group_entries(entries)

def pack_tail_blocks(lane_counts: Sequence[int], b_block: int,
                     quantum: int = 8, b_align: int = 1,
                     ) -> Tuple[List[List[int]], int]:
    """Pack tail-block lane counts into combined launch blocks sharing
    ONE uniform lane count ``T`` (ISSUE 7 cross-shape coalescing).
    Returns ``(groups, T)``: index groups plus the shared padded size.

    A uniform T is what lets every packed group fuse into a single
    ``lax.map`` launch without a second morph-up pass (morphing smaller
    groups up to the largest one is where naive packing bleeds padding).
    T is chosen by sweeping every aligned candidate up to ``b_block``
    and greedily first-fit packing against it, keeping the T that
    minimizes total padded lanes (ties: fewer groups, then smaller T).

    Deterministic: inputs are visited in order and placed into the
    first group with room, so a bucket's packing is a pure function of
    its tail sizes — the same traffic packs the same way on every
    drain.  Pure bookkeeping; the bitwise-safety of launching packed
    lanes at a different compiled B is the compiled-B invariance proven
    per family in tests/test_compile.py and audited by
    analysis/jaxpr_audit.py.
    """
    counts = [int(k) for k in lane_counts]
    lo = max(aligned_bucket(k, quantum, b_align) for k in counts)
    cands = sorted({aligned_bucket(v, quantum, b_align)
                    for v in range(lo, max(b_block, lo) + 1)})

    def pack(cap: int) -> Tuple[List[List[int]], List[int]]:
        groups: List[List[int]] = []
        totals: List[int] = []
        for i, k in enumerate(counts):
            for gi, tot in enumerate(totals):
                if aligned_bucket(tot + k, quantum, b_align) <= cap:
                    groups[gi].append(i)
                    totals[gi] = tot + k
                    break
            else:
                groups.append([i])
                totals.append(k)
        return groups, totals

    best = None
    for cap in cands:
        groups, _ = pack(cap)
        score = (len(groups) * cap, len(groups), cap)
        if best is None or score < best[0]:
            best = (score, groups, cap)
    return best[1], best[2]


def plan_buckets(requests: Sequence, *, min_n: int = 8,
                 min_p: int = 8) -> MegabatchPlan:
    """Assign every (request, segment) to a megabatch bucket (batch form
    of ``MegabatchPlan.admit``)."""
    plan = MegabatchPlan(min_n=min_n, min_p=min_p)
    for req in requests:
        plan.admit(req)
    return plan


# ---------------------------------------------------------------------------
# Per-bucket parallelization-axis planning (ISSUE 8)
# ---------------------------------------------------------------------------
@dataclass
class AxisDecision:
    """One bucket's parallelization-axis choice plus the full roofline
    candidate table it was picked from — logged on
    ``BackendRunInfo.axis_plans`` exactly like autoscale decisions, so a
    drain's layout choices are auditable after the fact.  The planner
    fields are written once; ``executed`` is the one mutable slot —
    ``dispatch_bucket`` stamps the axis the drain actually lowered
    (ISSUE 9), so decision-vs-executed mixes are auditable too."""
    bucket: BucketKey
    axis: str                           # task | data | feature
    shards: int                         # mesh devices the layout spans
    n_tasks: int                        # pending tasks priced
    n_pad: int
    p_pad: int
    mesh_devices: int                   # devices the planner could use
    priced_by: str = "roofline"
    # (axis, shards, est_s, executable) per candidate, planner input
    candidate_costs: Tuple[Tuple[str, int, float, bool], ...] = ()
    # axis dispatch_bucket actually executed: None until the bucket's
    # first dispatch; "task" when a data/feature plan fell back (e.g.
    # no mesh, a non-Gram family, or a non-divisible shard count)
    executed: Optional[str] = None

    @property
    def est_s(self) -> float:
        """The chosen candidate's priced wall-clock."""
        for axis, shards, est, _ in self.candidate_costs:
            if axis == self.axis and shards == self.shards:
                return est
        return float("nan")


def plan_bucket_axis(key: BucketKey, *, n_tasks: int, n_devices: int,
                     ) -> "AxisDecision | None":
    """Pick the parallelization axis for one bucket on an
    ``n_devices`` mesh: roofline-price the task-parallel, data-parallel
    (blocked Gram) and feature-parallel candidates
    (``launch/roofline.py::axis_candidate_costs``) and take the cheapest
    *executable* one.  Returns None for opaque-callable buckets (no
    analytic model — they always run task-parallel unsharded).

    Pure pricing: deterministic in (bucket, n_tasks, n_devices), no
    device access — so the decision is unit-testable and the bench gate
    "the planner never picks a candidate priced strictly worse than
    another executable one" holds by construction.
    """
    ident = key.learner
    if not (isinstance(ident, tuple) and len(ident) == 2
            and isinstance(ident[0], str)) or ident[0] == "opaque":
        return None
    from repro.launch.roofline import axis_candidate_costs
    learner, ptuple = ident
    cands = axis_candidate_costs(learner, dict(ptuple), n_tasks,
                                 key.n_pad, key.p_pad, n_devices)
    runnable = [c for c in cands if c[3]]
    if not runnable:                      # e.g. tall-N non-Gram family
        runnable = [c for c in cands if c[0] == "task"]
    axis, shards, _, _ = min(runnable, key=lambda c: (c[2], c[1], c[0]))
    return AxisDecision(bucket=key, axis=axis, shards=shards,
                        n_tasks=int(n_tasks), n_pad=key.n_pad,
                        p_pad=key.p_pad, mesh_devices=int(n_devices),
                        candidate_costs=tuple(cands))
