"""Persistent on-disk megabatch program cache (ISSUE 7 tentpole).

Cold drains used to pay a full trace+compile per (bucket, B, D[, G])
shape even when an identical session ran seconds earlier in another
process — the in-memory ``ProgramCache`` dies with the process.  This
module persists the *compiled executables* across processes:

  * programs are lowered ahead-of-time against their exact argument
    avals (the megabatch calling convention is shape-total: every
    operand shape is a pure function of the bucket key and the padded
    batch shape), serialized via ``jax.experimental.serialize_executable``
    and written to ``REPRO_PROGRAM_CACHE_DIR``;
  * JAX's own XLA compilation cache (``jax_compilation_cache_dir``) is
    pointed at a subdirectory as belt-and-braces for any residual
    tracing path (partitioned programs, probe traces).

Deserializing an executable is ~14x cheaper than compiling it on this
backend, which is what flips the BENCH_fusion cold gate: a disk-warm
cold drain re-traces **zero** programs.

**Custom-call portability (measured, this jaxlib/CPU build):** an
executable serialized via ``serialize_executable`` embeds raw host
function pointers for its custom-call targets (LAPACK/BLAS kernels),
even the name-registered ``_ffi`` variants — deserializing one in a
fresh process and calling it segfaults under ASLR.  JAX's own XLA
compilation cache does NOT have this problem (it re-links targets at
load), so the split is: custom-call-bearing programs (ols, ridge,
logistic, kernel_ridge solvers) rely on the XLA cache for cross-process
cold-compile relief, while custom-call-free programs (lasso, mlp — pure
XLA iterative solvers) additionally skip tracing entirely through the
AOT store.  ``store()`` enforces this by scanning the optimized HLO and
refusing to persist non-portable executables (``skipped_unportable``).

A third tier covers the recycled-container case (same process, fresh
backend): ``_process_programs`` is a process-wide map over the same
``(build, platform, fingerprint)`` key, safe for ALL programs —
including custom-call ones — because host pointers stay valid within
the process.  A warm container's "cold" drain therefore compiles zero
programs regardless of portability.

Key discipline (the ninth ``@warm_cache`` contract, audited by
``analysis/cache_keys.py``): a serialized executable is only valid for
the exact jax build, backend platform, and program shape that produced
it, so the lookup key is ``(jax_build, platform, fingerprint)`` — the
fingerprint pins the resolved learner spec (never an object identity),
the padded shapes, the PRNG key-data layout, and the x64 mode.  Opaque
callables have process-local identity and are never persisted.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.registry import warm_cache

# Environment switch: set to a directory path to enable cross-process
# program persistence.  Unset (the default) keeps the compile layer
# purely in-memory — zero behavior change for existing callers.
ENV_CACHE_DIR = "REPRO_PROGRAM_CACHE_DIR"


def _key_tail() -> Tuple[int, ...]:
    """Trailing shape of one task's PRNG key data under the process's
    configured key implementation (threefry: (2,))."""
    return tuple(jax.random.key_data(jax.random.key(0)).shape)


class _PinnedExecutable:
    """Operand-lifetime guard for direct AOT executable calls.

    ``jit`` dispatch retains the caller's host operands while the
    asynchronous transfer/execution reads them; a ``lower().compile()``
    executable — fresh or deserialized — does NOT.  The dispatch path
    hands these executables temporary numpy operands (morphed batch
    tensors, per-launch ``didx`` lane maps) and drops every reference
    the moment the call returns, so the async read races Python's
    allocator: a freed-and-reused buffer reaches the device as garbage
    inputs and books garbage predictions (observed as nondeterministic
    thetas on disk-warm resumed drains).

    The wrapper pins each call's operand tuple until that call's
    outputs land, releasing landed calls lazily on the next dispatch —
    steady state holds at most the pipeline depth.  Calls happen on
    one drain thread, so no locking.
    """

    __slots__ = ("_prog", "_inflight")

    def __init__(self, prog):
        self._prog = prog
        self._inflight: list = []

    def _release_landed(self) -> None:
        self._inflight[:] = [
            (out, args) for out, args in self._inflight
            if not all(getattr(o, "is_ready", lambda: True)()
                       for o in jax.tree_util.tree_leaves(out))]
        # backstop: a caller that never drains still can't pin
        # unbounded host memory behind un-landed launches
        while len(self._inflight) > 64:
            out, _ = self._inflight.pop(0)
            jax.block_until_ready(out)

    def __call__(self, *args):
        self._release_landed()
        out = self._prog(*args)
        self._inflight.append((out, args))
        return out


def pin_executable(prog) -> _PinnedExecutable:
    """Wrap an AOT executable so every call keeps its host operands
    alive until the outputs land (see ``_PinnedExecutable``)."""
    return _PinnedExecutable(prog)


def jax_build() -> str:
    """The jax build a serialized executable is valid for."""
    try:
        import jaxlib
        lib = getattr(jaxlib, "__version__", "?")
    except Exception:                              # pragma: no cover
        lib = "?"
    return f"jax-{jax.__version__}+jaxlib-{lib}"


def backend_platform() -> str:
    """The backend platform (and device kind) executables target."""
    try:
        kind = jax.devices()[0].device_kind
    except Exception:                              # pragma: no cover
        kind = "?"
    return f"{jax.default_backend()}:{kind}"


def program_fingerprint(key, b_pad: int, d_pad: int,
                        g: Optional[int] = None) -> Optional[Tuple]:
    """Value identity of one compiled megabatch program, stable across
    processes — or None when the program must not be persisted.

    The learner identity must be a resolved spec tuple
    ``(family, params)``: opaque callables key by ``id()`` which is
    process-local, so persisting them would alias unrelated programs.
    """
    ident = key.learner
    if not (isinstance(ident, tuple) and len(ident) == 2
            and isinstance(ident[0], str) and ident[0] != "opaque"):
        return None
    return ("megabatch-v1", repr(ident), int(key.n_pad), int(key.p_pad),
            int(b_pad), int(d_pad), None if g is None else int(g),
            _key_tail(), bool(jax.config.jax_enable_x64))


def program_avals(key, b_pad: int, d_pad: int,
                  g: Optional[int] = None) -> Tuple:
    """Exact argument avals of the megabatch calling convention
    ``run(pages, data_idx, y, w, valid, key_data)`` — single-block when
    ``g`` is None, fused (leading block axis) otherwise."""
    n_pad, p_pad = int(key.n_pad), int(key.p_pad)
    kt = _key_tail()
    lead = () if g is None else (int(g),)
    shapes = ((int(d_pad), n_pad, p_pad),          # pages
              lead + (int(b_pad),),                # data_idx
              lead + (int(b_pad), n_pad),          # y
              lead + (int(b_pad), n_pad),          # w
              lead + (int(b_pad), n_pad),          # valid
              lead + (int(b_pad),) + kt)           # key_data
    dtypes = (jnp.float32, jnp.int32, jnp.float32, jnp.float32,
              jnp.float32, jnp.uint32)
    return tuple(jax.ShapeDtypeStruct(s, d) for s, d in zip(shapes, dtypes))


def _configure_jax_cache(cache_dir: str):
    """Point JAX's own persistent compilation cache at a subdirectory —
    covers any tracing path that bypasses the AOT store (partitioned
    programs, audit probes).  Best-effort: unsupported backends fall
    back to the AOT store alone."""
    try:
        jax.config.update("jax_compilation_cache_dir",
                          os.path.join(cache_dir, "xla"))
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:                              # pragma: no cover
        pass


class PersistentProgramCache:
    """Directory of AOT-serialized megabatch executables.

    One file per ``(jax_build, platform, fingerprint)``; writes are
    atomic (tmp + rename) so concurrent processes sharing a cache
    directory never observe torn blobs, and unreadable/stale entries
    are treated as misses and evicted.
    """

    #: process-wide L1 over the disk tier, shared by every instance:
    #: a recycled container (same process, fresh backend/ProgramCache)
    #: reuses already-compiled executables without re-tracing — and
    #: unlike the disk tier this is safe for custom-call programs too,
    #: because the baked host pointers are valid within the process.
    #: Keyed by the SAME (build, platform, fingerprint) triple as disk.
    _process_programs: dict = {}
    _PROCESS_CAP = 256

    def __init__(self, cache_dir: str):
        self.cache_dir = cache_dir
        self.loads = 0                  # executables deserialized from disk
        self.process_hits = 0           # served from the in-process tier
        self.stores = 0                 # executables serialized to disk
        self.errors = 0                 # unreadable / unserializable entries
        self.skipped_unportable = 0     # custom-call programs not persisted
        os.makedirs(cache_dir, exist_ok=True)
        _configure_jax_cache(cache_dir)

    def _path(self, build: str, platform: str, fingerprint: Tuple) -> str:
        h = hashlib.sha1(
            repr((build, platform, fingerprint)).encode()).hexdigest()
        return os.path.join(self.cache_dir, f"{h}.prog")

    # Both tiers cache under the SAME full triple: the jax build and
    # platform pin the executable format, the fingerprint pins the
    # program (resolved spec + padded shapes + key layout + x64 mode).
    # This is the only insert site of the process-wide tier — lookup's
    # disk path and store both remember through it.
    @warm_cache(name="persistent_program_cache_process_tier",
                key=("build", "platform", "fingerprint"),
                reads=("prog",),
                covers={"fingerprint": ("prog",)},
                ambient=("self",))
    def _process_put(self, build: str, platform: str, fingerprint: Tuple,
                     prog) -> None:
        from repro.runtime import bounded_put
        bounded_put(self._process_programs,
                    (build, platform, fingerprint), prog,
                    self._PROCESS_CAP)

    # The on-disk entry is a pure function of the full lookup key (same
    # triple as the process tier).  The directory handle is instance
    # state (ambient).
    @warm_cache(name="persistent_program_cache",
                key=("build", "platform", "fingerprint"),
                ambient=("self",))
    def lookup(self, build: str, platform: str, fingerprint: Tuple):
        """Serve from the in-process tier, else deserialize a
        previously-stored executable from disk, else None."""
        prog = self._process_programs.get((build, platform, fingerprint))
        if prog is not None:
            self.process_hits += 1
            return prog
        path = self._path(build, platform, fingerprint)
        if not os.path.exists(path):
            return None
        try:
            with open(path, "rb") as f:
                payload, in_tree, out_tree = pickle.loads(f.read())
            from jax.experimental import serialize_executable as se
            prog = pin_executable(
                se.deserialize_and_load(payload, in_tree, out_tree))
        except Exception:
            # stale jax build, torn write, foreign blob: evict and miss
            self.errors += 1
            try:
                os.remove(path)
            except OSError:
                pass
            return None
        self.loads += 1
        self._process_put(build, platform, fingerprint, prog)
        return prog

    @staticmethod
    def portable(compiled) -> bool:
        """A serialized executable only survives a process boundary when
        it contains NO custom calls: XLA:CPU bakes custom-call targets
        in by host address (segfault under ASLR in the next process).
        Conservative on inspection failure: not portable."""
        try:
            return "custom-call" not in compiled.as_text()
        except Exception:                          # pragma: no cover
            return False

    def store(self, build: str, platform: str, fingerprint: Tuple,
              compiled) -> bool:
        """Record one AOT-compiled executable: always into the
        in-process tier; onto disk (atomic write) only when portable —
        custom-call-bearing programs (see ``portable``) lean on the XLA
        compilation cache for cross-process relief instead.  Returns
        whether a disk entry was written."""
        self._process_put(build, platform, fingerprint,
                          pin_executable(compiled))
        if not self.portable(compiled):
            self.skipped_unportable += 1
            return False
        try:
            from jax.experimental import serialize_executable as se
            blob = pickle.dumps(se.serialize(compiled))
            fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    f.write(blob)
                os.replace(tmp, self._path(build, platform, fingerprint))
            finally:
                if os.path.exists(tmp):
                    os.remove(tmp)
        except Exception:                          # pragma: no cover
            self.errors += 1
            return False
        self.stores += 1
        return True

    def summary(self) -> dict:
        return {"cache_dir": self.cache_dir, "disk_loads": self.loads,
                "process_hits": self.process_hits,
                "disk_stores": self.stores, "disk_errors": self.errors,
                "skipped_unportable": self.skipped_unportable}


def default_persist() -> Optional[PersistentProgramCache]:
    """The environment-configured persistent cache, or None."""
    d = os.environ.get(ENV_CACHE_DIR)
    return PersistentProgramCache(d) if d else None
