"""Pallas TPU kernels for megabatch (bucketed) cross-fit programs.

The megabatch compiler (repro/compile) stacks tasks from *different*
requests — hence different datasets — into one ``(B, N_pad, P_pad)``
tensor, so unlike ``crossfit_gram`` (one shared X, many masks) each task
here carries its own feature page.  Two kernels cover the hot linear
path:

``batched_gram_pallas``     per-task masked normal equations
                            G_b = X_b' diag(w_b) X_b,  b_b = X_b'(w_b*y_b)
                            accumulated tile-by-tile over the padded N
                            axis; padded rows carry w == 0 so they are
                            arithmetically inert.
``batched_predict_pallas``  the masked GEMV epilogue
                            preds_b = valid_b * (X_b @ beta_b)
                            that scatters fitted coefficients back to
                            per-row predictions, zeroing padding lanes.
``batched_gram_blocked_pallas``
                            the streaming variant (ISSUE 8): the N axis
                            arrives pre-chunked as (B, C, Nc, P) and the
                            kernel accumulates across a compile-time
                            (chunk, n_block) grid, so one task's N never
                            has to fit a single device page.  The (c, j)
                            accumulation order equals the unblocked
                            kernel's j order over the merged N axis, so
                            results are bitwise-identical when the
                            chunks tile N exactly; ragged tails carry
                            w == 0 rows whose FMA terms are exact zeros.

Tiling mirrors crossfit_gram.py: grid (task_blocks, n_blocks); per-task X
tiles (bb, bn, P) live in VMEM; the (bb, P, P) f32 accumulator persists in
the output block across the inner n-block loop.  P is padded to a
multiple of 128 (lanes) by the ops.py wrapper; bn is a multiple of 8
(sublanes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _gram_kernel(x_ref, w_ref, y_ref, g_ref, b_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        b_ref[...] = jnp.zeros_like(b_ref)

    x = x_ref[...].astype(F32)                     # (bb, bn, P)
    w = w_ref[...].astype(F32)                     # (bb, bn)
    y = y_ref[...].astype(F32)                     # (bb, bn)
    wx = w[:, :, None] * x                         # (bb, bn, P)
    # batched MXU contraction over the bn axis, one matmul per task lane
    g_ref[...] += jax.lax.dot_general(
        wx, x, dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=F32)
    b_ref[...] += jnp.einsum("bn,bnp->bp", w * y, x,
                             preferred_element_type=F32)


def batched_gram_pallas(xs, w, y, *, block_b: int = 8, block_n: int = 256,
                        interpret: bool = False):
    """xs: (B, N, P); w, y: (B, N) -> (G (B,P,P) f32, b (B,P) f32).

    N must be a multiple of block_n and B of block_b (wrapper pads).
    """
    b_dim, n, p = xs.shape
    assert n % block_n == 0 and b_dim % block_b == 0, \
        (b_dim, n, block_b, block_n)
    grid = (b_dim // block_b, n // block_n)
    g, bv = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, p, p), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_b, p), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_dim, p, p), F32),
            jax.ShapeDtypeStruct((b_dim, p), F32),
        ],
        interpret=interpret,
    )(xs, w, y)
    return g, bv


def _gram_blocked_kernel(x_ref, w_ref, y_ref, g_ref, b_ref):
    c = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((c == 0) & (j == 0))
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        b_ref[...] = jnp.zeros_like(b_ref)

    x = x_ref[...].astype(F32)[:, 0]               # (bb, 1, bn, P) -> 3D
    w = w_ref[...].astype(F32)[:, 0]               # (bb, bn)
    y = y_ref[...].astype(F32)[:, 0]               # (bb, bn)
    wx = w[:, :, None] * x
    g_ref[...] += jax.lax.dot_general(
        wx, x, dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=F32)
    b_ref[...] += jnp.einsum("bn,bnp->bp", w * y, x,
                             preferred_element_type=F32)


def batched_gram_blocked_pallas(xc, w, y, *, block_b: int = 8,
                                block_n: int = 256,
                                interpret: bool = False):
    """Streaming blocked Gram over N-chunks.

    xc: (B, C, Nc, P) — the N axis pre-chunked into C streamed pieces of
    Nc rows each; w, y: (B, C, Nc).  Returns (G (B,P,P) f32, b (B,P) f32).

    The accumulator persists in the output block across the (c, j) grid,
    so partial sums land in the same order as the unblocked kernel's
    n-block loop over the merged (B, C*Nc, P) tensor — bitwise-equal by
    construction when Nc is a multiple of block_n.  Nc must be a
    multiple of block_n and B of block_b (wrapper pads).
    """
    b_dim, c_dim, nc, p = xc.shape
    assert nc % block_n == 0 and b_dim % block_b == 0, \
        (b_dim, c_dim, nc, block_b, block_n)
    grid = (b_dim // block_b, c_dim, nc // block_n)
    g, bv = pl.pallas_call(
        _gram_blocked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 1, block_n, p),
                         lambda i, c, j: (i, c, j, 0)),
            pl.BlockSpec((block_b, 1, block_n), lambda i, c, j: (i, c, j)),
            pl.BlockSpec((block_b, 1, block_n), lambda i, c, j: (i, c, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, p, p), lambda i, c, j: (i, 0, 0)),
            pl.BlockSpec((block_b, p), lambda i, c, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_dim, p, p), F32),
            jax.ShapeDtypeStruct((b_dim, p), F32),
        ],
        interpret=interpret,
    )(xc, w, y)
    return g, bv


def _predict_kernel(x_ref, beta_ref, v_ref, o_ref):
    x = x_ref[...].astype(F32)                     # (bb, bn, P)
    beta = beta_ref[...].astype(F32)               # (bb, P)
    v = v_ref[...].astype(F32)                     # (bb, bn)
    # per-task GEMV on the MXU: (bb, bn, P) x (bb, P) -> (bb, bn)
    pred = jax.lax.dot_general(
        x, beta, dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=F32)
    o_ref[...] = pred * v                          # mask padding lanes


def batched_predict_pallas(xs, beta, valid, *, block_b: int = 8,
                           block_n: int = 256, interpret: bool = False):
    """xs: (B, N, P); beta: (B, P); valid: (B, N) -> preds (B, N) f32.

    The masked GEMM/predict epilogue: rows with valid == 0 (padding)
    output exactly 0.  N must be a multiple of block_n, B of block_b.
    """
    b_dim, n, p = xs.shape
    assert n % block_n == 0 and b_dim % block_b == 0, \
        (b_dim, n, block_b, block_n)
    grid = (b_dim // block_b, n // block_n)
    return pl.pallas_call(
        _predict_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_b, p), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b_dim, n), F32),
        interpret=interpret,
    )(xs, beta, valid)
