"""Pallas TPU kernel: blockwise online-softmax (flash) attention.

Forward-only fused attention for the 32k prefill path: causal and
sliding-window masking, GQA handled by the wrapper (q grouped into the
batch*kv_head axis).  Tiling: grid (BH, q_blocks, kv_blocks) with the
kv-block loop innermost; running (m, l, acc) statistics live in VMEM
scratch across kv blocks.  Out-of-range blocks (fully masked by causality
or the window) are skipped with pl.when, so the sliding-window cell does
O(S*W) work, not O(S^2).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, offset: int, n_kb: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = qi * block_q + offset          # absolute position of first query
    k0 = kj * block_k
    # Block-level skip: no key in this block can be visible to any query.
    visible = True
    if causal:
        visible = k0 <= q0 + block_q - 1
    if window is not None:
        visible = visible & (k0 + block_k - 1 > q0 - window)

    @pl.when(visible)
    def _work():
        q = q_ref[...].astype(F32) * scale                  # (bq, D)
        k = k_ref[...].astype(F32)                          # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)  # (bq, bk)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = jnp.ones_like(s, bool)
        if causal:
            ok &= kpos <= qpos
        if window is not None:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_ref[...].astype(F32), (((1,), (0,)), ((), ())),
            preferred_element_type=F32)
        m_ref[...] = m_new

    @pl.when(kj == n_kb - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 256, block_k: int = 256,
                           interpret: bool = False):
    """q: (BH, Sq, D); k/v: (BH, Skv, D).  Query i has absolute position
    (Skv - Sq + i), i.e. suffix alignment (standard prefill)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    grid = (bh, sq // block_q, skv // block_k)
    kern = functools.partial(
        _kernel, scale=1.0 / np.sqrt(d), causal=causal, window=window,
        block_q=block_q, block_k=block_k, offset=skv - sq,
        n_kb=skv // block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), F32),       # running max
            pltpu.VMEM((block_q, 1), F32),       # running sum
            pltpu.VMEM((block_q, d), F32),       # running output
        ],
        interpret=interpret,
    )(q, k, v)
