"""Pallas TPU kernel: fused per-task masked Gram accumulation.

This is the paper's technique reduced to compute: all T = M*K*L cross-fit
estimation problems share one X, differing only in 0/1 fold masks, so the
per-task normal equations  G_t = X' diag(w_t) X,  b_t = X'(w_t*y_t)  are
accumulated for a *block of tasks at once* in a single tiled pass over X.
One HBM read of X serves bt tasks (vs. T reads in the per-task loop a
serverless worker pool implies) — the arithmetic-intensity win that makes
the TPU adaptation structural rather than concurrency-based (DESIGN.md §2).

Tiling: grid (task_blocks, n_blocks); X tile (bn, P), mask/target tiles
(bt, bn) live in VMEM; the (bt, P, P) f32 accumulator persists in the output
block across the inner n-block loop.  P is padded to a multiple of 128
(lane width) by the wrapper; bn is a multiple of 8 (sublanes).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(x_ref, w_ref, y_ref, g_ref, b_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        b_ref[...] = jnp.zeros_like(b_ref)

    x = x_ref[...].astype(F32)                     # (bn, P)
    w = w_ref[...].astype(F32)                     # (bt, bn)
    y = y_ref[...].astype(F32)                     # (bt, bn)
    wx = w[:, :, None] * x[None, :, :]             # (bt, bn, P)
    # batched MXU contraction over the bn axis
    g_ref[...] += jnp.einsum("tnp,nq->tpq", wx, x,
                             preferred_element_type=F32)
    b_ref[...] += jnp.einsum("tn,np->tp", w * y, x,
                             preferred_element_type=F32)


def crossfit_gram_pallas(x, w, y, *, block_t: int = 8, block_n: int = 512,
                         interpret: bool = False):
    """x: (N, P); w, y: (T, N) -> (G (T,P,P) f32, b (T,P) f32).

    N must be a multiple of block_n and T of block_t (wrapper pads).
    """
    n, p = x.shape
    t = w.shape[0]
    assert n % block_n == 0 and t % block_t == 0, (n, t, block_n, block_t)
    grid = (t // block_t, n // block_n)
    g, b = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i, j: (j, 0)),
            pl.BlockSpec((block_t, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((block_t, block_n), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, p, p), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_t, p), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, p, p), F32),
            jax.ShapeDtypeStruct((t, p), F32),
        ],
        interpret=interpret,
    )(x, w, y)
    return g, b
