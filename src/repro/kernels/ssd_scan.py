"""Pallas TPU kernel: Mamba-2 SSD chunked scan.

Grid (BH, n_chunks) with the chunk loop innermost; the (N, P) recurrent
state per (batch*head) lane persists in VMEM scratch across chunks.  The
within-chunk terms are dense MXU matmuls of shape (Q,N)x(N,Q) and
(Q,Q)x(Q,P); the inter-chunk term is a rank-N update — exactly the
decomposition of Dao & Gu (2024) restructured so the state never leaves
VMEM (HBM traffic is only the chunk inputs/outputs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(x_ref, la_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(F32)          # (Q, P)
    la = la_ref[...].astype(F32)        # (Q, 1)
    bm = b_ref[...].astype(F32)         # (Q, N)
    cm = c_ref[...].astype(F32)         # (Q, N)

    cl = jnp.cumsum(la, axis=0)                               # (Q,1) inclusive
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)  # (Q,Q)
    diff = jnp.clip(cl - cl.T, -60.0, 0.0)                    # (Q,Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    w = jnp.where(ii >= jj, scores * jnp.exp(diff), 0.0)
    y_intra = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=F32)
    state = state_ref[...]                                    # (N, P)
    y_inter = jnp.exp(cl) * jax.lax.dot_general(
        cm, state, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    y_ref[...] = (y_intra + y_inter).astype(y_ref.dtype)
    tail = jnp.exp(cl[-1:] - cl)                              # (Q,1)
    state_ref[...] = jnp.exp(cl[-1]) * state + jax.lax.dot_general(
        bm * tail, x, (((0,), (0,)), ((), ())), preferred_element_type=F32)


def ssd_scan_pallas(xbar, la, bm, cm, *, chunk: int = 256,
                    interpret: bool = False):
    """xbar: (BH, S, P); la: (BH, S); bm/cm: (BH, S, N) -> y (BH, S, P) f32.

    S must be a multiple of ``chunk``.
    """
    bh, s, p = xbar.shape
    n = bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    grid = (bh, s // chunk)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, p), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, chunk, 1), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, p), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), F32),
        scratch_shapes=[pltpu.VMEM((n, p), F32)],
        interpret=interpret,
    )(xbar, la[..., None], bm, cm)
