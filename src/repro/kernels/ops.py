"""Public jit'd wrappers for the Pallas kernels.

Routing: on TPU backends the Pallas kernel runs natively; on CPU (this
container) the wrappers route to the jnp oracle so XLA HLO (and hence the
dry-run roofline) reflects real math, unless ``repro.runtime.force_pallas``
is set ("interpret") — used by the kernel test-suite.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import runtime
from repro.kernels import ref
from repro.kernels.crossfit_gram import crossfit_gram_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _backend() -> str:
    return jax.default_backend()


def _use_pallas() -> bool:
    return _backend() == "tpu" or bool(runtime.force_pallas)


def _interpret() -> bool:
    return _backend() != "tpu"


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("reg",))
def crossfit_gram(x, w, y, reg: float = 0.0):
    """Batched masked normal equations (see crossfit_gram.py).

    x: (N, P); w/y: (T, N).  Returns G (T,P,P) f32, b (T,P) f32 — sliced
    back to the true P after lane padding.
    """
    if not _use_pallas():
        return ref.crossfit_gram_ref(x, w, y, reg)
    n, p = x.shape
    block_n = 512 if n >= 512 else 8
    xp, p0 = _pad_to(x, 1, 128)          # lane-align features
    xp, _ = _pad_to(xp, 0, block_n)      # N to a block multiple
    padn = xp.shape[0] - n
    if padn:                              # padded rows get zero weight
        w = jnp.pad(w, ((0, 0), (0, padn)))
        y = jnp.pad(y, ((0, 0), (0, padn)))
    w, t0 = _pad_to(w, 0, 8)
    y, _ = _pad_to(y, 0, 8)
    g, b = crossfit_gram_pallas(xp, w, y, block_t=8, block_n=block_n,
                                interpret=_interpret())
    g = g[:t0, :p0, :p0]
    b = b[:t0, :p0]
    if reg:
        g = g + reg * jnp.eye(p0, dtype=g.dtype)
    return g, b


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 256, block_k: int = 256):
    """q: (BH, Sq, D); k/v: (BH, Skv, D)."""
    if not _use_pallas():
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interpret())


def ssd_scan(xbar, la, bm, cm, *, chunk: int = 256):
    """xbar: (BH,S,P); la: (BH,S); bm/cm: (BH,S,N) -> (y, final_state)."""
    if not _use_pallas():
        return ref.ssd_scan_ref(xbar, la, bm, cm)
    y = ssd_scan_pallas(xbar, la, bm, cm, chunk=chunk,
                        interpret=_interpret())
    # final state from the oracle recurrence on the last chunk only would
    # need the carried state; recompute cheaply via the reference when needed
    _, state = ref.ssd_scan_ref(xbar, la, bm, cm)
    return y, state
