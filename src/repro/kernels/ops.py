"""Public jit'd wrappers for the Pallas kernels.

Routing: on TPU backends the Pallas kernel runs natively; on CPU (this
container) the wrappers route to the jnp oracle so XLA HLO (and hence the
dry-run roofline) reflects real math, unless ``repro.runtime.force_pallas``
is set ("interpret") — used by the kernel test-suite.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import runtime
from repro.kernels import ref
from repro.kernels.crossfit_gram import crossfit_gram_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.megabatch import (
    batched_gram_blocked_pallas, batched_gram_pallas, batched_predict_pallas,
)
from repro.kernels.ssd_scan import ssd_scan_pallas


def _backend() -> str:
    return jax.default_backend()


def _use_pallas() -> bool:
    return _backend() == "tpu" or bool(runtime.force_pallas)


def _interpret() -> bool:
    return _backend() != "tpu"


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


@functools.partial(jax.jit, static_argnames=("reg",))
def crossfit_gram(x, w, y, reg: float = 0.0):
    """Batched masked normal equations (see crossfit_gram.py).

    x: (N, P); w/y: (T, N).  Returns G (T,P,P) f32, b (T,P) f32 — sliced
    back to the true P after lane padding.
    """
    if not _use_pallas():
        return ref.crossfit_gram_ref(x, w, y, reg)
    n, p = x.shape
    block_n = 512 if n >= 512 else 8
    xp, p0 = _pad_to(x, 1, 128)          # lane-align features
    xp, _ = _pad_to(xp, 0, block_n)      # N to a block multiple
    padn = xp.shape[0] - n
    if padn:                              # padded rows get zero weight
        w = jnp.pad(w, ((0, 0), (0, padn)))
        y = jnp.pad(y, ((0, 0), (0, padn)))
    w, t0 = _pad_to(w, 0, 8)
    y, _ = _pad_to(y, 0, 8)
    g, b = crossfit_gram_pallas(xp, w, y, block_t=8, block_n=block_n,
                                interpret=_interpret())
    g = g[:t0, :p0, :p0]
    b = b[:t0, :p0]
    if reg:
        g = g + reg * jnp.eye(p0, dtype=g.dtype)
    return g, b


@functools.partial(jax.jit, static_argnames=("reg",))
def batched_gram(xs, w, y, reg: float = 0.0):
    """Per-task masked normal equations with per-task features.

    xs: (B, N, P); w/y: (B, N).  Returns G (B,P,P) f32, b (B,P) f32 —
    sliced back to the true P after lane padding.  The megabatch analogue
    of ``crossfit_gram`` for buckets that mix datasets.
    """
    if not _use_pallas():
        return ref.batched_gram_ref(xs, w, y, reg)
    b_dim, n, p = xs.shape
    block_n = 256 if n >= 256 else 8
    xp, _ = _pad_to(xs, 2, 128)          # lane-align features
    p0 = p
    xp, _ = _pad_to(xp, 1, block_n)      # N to a block multiple
    padn = xp.shape[1] - n
    if padn:                              # padded rows get zero weight
        w = jnp.pad(w, ((0, 0), (0, padn)))
        y = jnp.pad(y, ((0, 0), (0, padn)))
    xp, b0 = _pad_to(xp, 0, 8)           # task-batch to sublane multiple
    w, _ = _pad_to(w, 0, 8)
    y, _ = _pad_to(y, 0, 8)
    g, bv = batched_gram_pallas(xp, w, y, block_b=8, block_n=block_n,
                                interpret=_interpret())
    g = g[:b0, :p0, :p0]
    bv = bv[:b0, :p0]
    if reg:
        g = g + reg * jnp.eye(p0, dtype=g.dtype)
    return g, bv


# Blocked-Gram parity tiers (ISSUE 8).  For families whose fit is a
# pure function of the Gram statistics (X'X, X'y), streaming the N axis
# chunk-by-chunk adds partial sums in the same order as the unblocked
# kernel's n-block loop, so results are bitwise-equal.  Families whose
# iterations re-reduce per-row activations (logistic's sigmoid pass,
# kernel_ridge's kernel matrix, mlp's backprop) genuinely reorder float
# accumulation when N is re-chunked — they get an explicit tolerance
# tier instead of a false bitwise promise.
BLOCKED_GRAM_BITWISE_FAMILIES = frozenset({"ols", "ridge", "lasso"})
BLOCKED_GRAM_TOLERANCE_FAMILIES = frozenset(
    {"logistic", "kernel_ridge", "mlp"})


def chunk_tall_n(xs, w, y, chunk_rows: int):
    """Split a tall (B, N, P) task batch into (B, C, Nc, P) N-chunks for
    the streaming blocked Gram path.

    A ragged tail (N % chunk_rows != 0) is padded with w == 0 rows, which
    the kernel's masked accumulation treats as exact no-ops.  Pure
    relayout otherwise — no float arithmetic.
    """
    b_dim, n, p = xs.shape
    nc = int(chunk_rows)
    pad = (-n) % nc
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad)))
        y = jnp.pad(y, ((0, 0), (0, pad)))
    c = (n + pad) // nc
    return (xs.reshape(b_dim, c, nc, p), w.reshape(b_dim, c, nc),
            y.reshape(b_dim, c, nc))


@functools.partial(jax.jit, static_argnames=("reg",))
def batched_gram_blocked(xc, w, y, reg: float = 0.0):
    """Streaming blocked Gram: per-task normal equations accumulated
    over pre-chunked N.

    xc: (B, C, Nc, P); w/y: (B, C, Nc).  Returns G (B,P,P) f32,
    b (B,P) f32 — the same contract as ``batched_gram`` on the merged
    (B, C*Nc, P) tensor, but each chunk is streamed through the device
    separately so a task's N never has to fit one page.
    """
    if not _use_pallas():
        return ref.batched_gram_blocked_ref(xc, w, y, reg)
    b_dim, c_dim, nc, p = xc.shape
    # prefer the 256-row MXU block only when it tiles Nc exactly: an
    # exactly-tiled chunk grid keeps partial-sum order identical to the
    # unblocked kernel (bitwise); a ragged Nc falls back to 8-row blocks
    # plus zero-weight padding (tolerance tier)
    block_n = 256 if nc % 256 == 0 and nc >= 256 else 8
    xp, _ = _pad_to(xc, 3, 128)          # lane-align features
    p0 = p
    xp, _ = _pad_to(xp, 2, block_n)      # Nc to a block multiple
    padn = xp.shape[2] - nc
    if padn:                              # padded rows get zero weight
        w = jnp.pad(w, ((0, 0), (0, 0), (0, padn)))
        y = jnp.pad(y, ((0, 0), (0, 0), (0, padn)))
    xp, b0 = _pad_to(xp, 0, 8)           # task-batch to sublane multiple
    w, _ = _pad_to(w, 0, 8)
    y, _ = _pad_to(y, 0, 8)
    g, bv = batched_gram_blocked_pallas(xp, w, y, block_b=8,
                                        block_n=block_n,
                                        interpret=_interpret())
    g = g[:b0, :p0, :p0]
    bv = bv[:b0, :p0]
    if reg:
        g = g + reg * jnp.eye(p0, dtype=g.dtype)
    return g, bv


@jax.jit
def batched_predict(xs, beta, valid):
    """Masked per-task GEMV epilogue: valid_b * (X_b @ beta_b).

    xs: (B, N, P); beta: (B, P); valid: (B, N) -> (B, N) f32 with padding
    rows exactly 0.
    """
    if not _use_pallas():
        return ref.batched_predict_ref(xs, beta, valid)
    b_dim, n, p = xs.shape
    block_n = 256 if n >= 256 else 8
    xp, _ = _pad_to(xs, 2, 128)
    bp, _ = _pad_to(beta, 1, 128)
    xp, n0 = _pad_to(xp, 1, block_n)
    padn = xp.shape[1] - n
    if padn:
        valid = jnp.pad(valid, ((0, 0), (0, padn)))
    xp, b0 = _pad_to(xp, 0, 8)
    bp, _ = _pad_to(bp, 0, 8)
    valid, _ = _pad_to(valid, 0, 8)
    out = batched_predict_pallas(xp, bp, valid, block_b=8, block_n=block_n,
                                 interpret=_interpret())
    return out[:b0, :n0]


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 256, block_k: int = 256):
    """q: (BH, Sq, D); k/v: (BH, Skv, D)."""
    if not _use_pallas():
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interpret())


def ssd_scan(xbar, la, bm, cm, *, chunk: int = 256):
    """xbar: (BH,S,P); la: (BH,S); bm/cm: (BH,S,N) -> (y, final_state)."""
    if not _use_pallas():
        return ref.ssd_scan_ref(xbar, la, bm, cm)
    y = ssd_scan_pallas(xbar, la, bm, cm, chunk=chunk,
                        interpret=_interpret())
    # final state from the oracle recurrence on the last chunk only would
    # need the carried state; recompute cheaply via the reference when needed
    _, state = ref.ssd_scan_ref(xbar, la, bm, cm)
    return y, state
