"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


def crossfit_gram_ref(x, w, y, reg: float = 0.0):
    """Per-task masked Gram matrices and moment vectors.

    x: (N, P) features; w: (T, N) per-task training weights (0/1 fold masks,
    possibly fractional for weighted fits); y: (T, N) per-task targets.
    Returns (G, b): G (T, P, P) = X' diag(w_t) X + reg*I;  b (T, P) =
    X' (w_t * y_t).  f32 accumulation.
    """
    xf = x.astype(F32)
    wf = w.astype(F32)
    yf = y.astype(F32)
    g = jnp.einsum("np,tn,nq->tpq", xf, wf, xf)
    if reg:
        g = g + reg * jnp.eye(x.shape[1], dtype=F32)
    b = jnp.einsum("tn,np->tp", wf * yf, xf)
    return g, b


def batched_gram_ref(xs, w, y, reg: float = 0.0):
    """Per-task masked Gram with per-task features (megabatch buckets).

    xs: (B, N, P) per-task feature pages; w/y: (B, N).  Returns
    (G (B,P,P), b (B,P)) with G_b = X_b' diag(w_b) X_b + reg*I and
    b_b = X_b'(w_b*y_b).  Padded rows must carry w == 0.
    """
    xf = xs.astype(F32)
    wf = w.astype(F32)
    yf = y.astype(F32)
    g = jnp.einsum("bnp,bn,bnq->bpq", xf, wf, xf)
    if reg:
        g = g + reg * jnp.eye(xs.shape[-1], dtype=F32)
    b = jnp.einsum("bn,bnp->bp", wf * yf, xf)
    return g, b


def batched_gram_blocked_ref(xc, w, y, reg: float = 0.0):
    """Oracle for the streaming blocked Gram kernel.

    xc: (B, C, Nc, P) N-chunked feature pages; w/y: (B, C, Nc).  Merging
    the chunk axis back into N is a pure relayout (no float ops), so the
    oracle IS ``batched_gram_ref`` on the merged tensor — the blocked
    kernel's contract is to match it despite streaming the chunks.
    """
    b, c, nc, p = xc.shape
    return batched_gram_ref(xc.reshape(b, c * nc, p),
                            w.reshape(b, c * nc),
                            y.reshape(b, c * nc), reg)


def batched_predict_ref(xs, beta, valid):
    """Masked per-task GEMV: preds_b = valid_b * (X_b @ beta_b)."""
    pred = jnp.einsum("bnp,bp->bn", xs.astype(F32), beta.astype(F32))
    return pred * valid.astype(F32)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """Masked softmax attention oracle.

    q: (B, Sq, D); k/v: (B, Skv, D) — head dim folded into B by the wrapper.
    Query i attends to keys with absolute position <= (Skv - Sq + i).
    """
    b, sq, d = q.shape
    skv = k.shape[1]
    off = skv - sq
    s = jnp.einsum("bqd,bkd->bqk", q.astype(F32), k.astype(F32)) / np.sqrt(d)
    qpos = jnp.arange(sq)[:, None] + off
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(F32)).astype(q.dtype)


def ssd_scan_ref(xbar, la, bm, cm):
    """Sequential SSD oracle: S_t = exp(la_t) S_{t-1} + bm_t xbar_t^T;
    y_t = cm_t . S_t.

    xbar: (B, S, P); la: (B, S); bm/cm: (B, S, N).  (head folded into B.)
    Returns y (B, S, P) f32 and final state (B, N, P).
    """
    def step(state, inp):
        xb, a, b_, c_ = inp
        state = state * jnp.exp(a)[:, None, None] \
            + jnp.einsum("bn,bp->bnp", b_, xb)
        return state, jnp.einsum("bn,bnp->bp", c_, state)

    b, s, p = xbar.shape
    n = bm.shape[-1]
    s0 = jnp.zeros((b, n, p), F32)
    mov = lambda t: jnp.moveaxis(t.astype(F32), 1, 0)
    state, ys = jax.lax.scan(step, s0, (mov(xbar), mov(la), mov(bm), mov(cm)))
    return jnp.moveaxis(ys, 0, 1), state
