"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434; hf].

27L d_model=2048 16H (GQA kv=16) d_ff=1408 (expert width) vocab=102400.
MoE: 64 routed experts, top-6, 2 shared experts; first layer dense
(width 10944).  MLA: kv_lora_rank=512, decoupled rope head dim 64.

NOTE (DESIGN.md §5): the assignment line says both "MoE 64e top-6" and
"2 shared+160 routed"; we implement 64 routed + 2 shared top-6, matching the
published hf config for DeepSeek-V2-Lite.

subquadratic=True for long_500k: the MLA latent cache stores only
(kv_lora_rank + rope_head_dim) = 576 floats/token, ~18x smaller than a full
KV cache, making the 500k decode cell feasible (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    d_ff=1408,
    vocab_size=102400,
    attention=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                              kv_lora_rank=512, rope_head_dim=64),
    moe=MoEConfig(n_routed=64, top_k=6, d_expert=1408,
                  n_shared=2, d_shared=2 * 1408,
                  first_dense_layers=1, d_first_dense=10944),
    subquadratic=True,
    source="arXiv:2405.04434; hf",
)
