"""llama-3.2-vision-90b — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  A cross-attention
layer is interleaved every 5th layer (20 cross-attn layers).  The vision
frontend is a STUB: ``input_specs()`` provides precomputed patch embeddings
(batch, n_img_tokens, d_frontend) which a linear projector maps to d_model.
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    d_ff=28672,
    vocab_size=128256,
    attention=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128,
                              rope_theta=500_000.0),
    cross_attn_every=5,
    d_frontend=1280,
    n_frontend_tokens=1601,    # (448/14)^2 + 1 per tile, one tile
    subquadratic=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
