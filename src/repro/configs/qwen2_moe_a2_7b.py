"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (GQA kv=16) d_ff=1408 (expert width) vocab=151936.
Shared experts are fused into one 5632-wide MLP with a sigmoid gate
(Qwen-MoE design).  Routed experts are padded 60 -> 64 for EP=16
(router never selects pads; DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, AttentionConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    d_ff=1408,
    vocab_size=151936,
    attention=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                              qkv_bias=True),
    moe=MoEConfig(n_routed=60, top_k=4, d_expert=1408,
                  n_shared=4, d_shared=4 * 1408, shared_gate=True),
    subquadratic=False,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)
