"""Config dataclasses for architectures, input shapes and runtime policy.

Every assigned architecture gets one module in this package defining a
``CONFIG`` (full size, dry-run only) and implicitly a reduced smoke variant
via :meth:`ArchConfig.reduced`.  Configs are plain frozen dataclasses so they
are hashable (usable as static args) and trivially serializable.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttentionConfig:
    """Multi-head attention settings (GQA / SWA / MLA)."""

    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    # Sliding-window attention (Mistral-style). None = full attention.
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    # Multi-head latent attention (DeepSeek-V2). When kv_lora_rank is set the
    # KV path goes through a shared latent of this rank plus a decoupled
    # rope key of ``rope_head_dim``.
    kv_lora_rank: Optional[int] = None
    rope_head_dim: int = 64
    causal: bool = True

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank is not None

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN settings (shared + routed experts)."""

    n_routed: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    d_shared: int = 0          # total width of the fused shared expert
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    shared_gate: bool = False  # qwen2-moe applies a sigmoid gate on shared out
    first_dense_layers: int = 0
    d_first_dense: int = 0     # FFN width of the leading dense layers


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 / xLSTM recurrent block settings."""

    state_dim: int = 64
    conv_dim: int = 4
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256           # SSD chunk length
    # xLSTM: every ``slstm_every``-th block is an sLSTM block (0 = none).
    slstm_every: int = 0


@dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture."""

    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: AttentionConfig
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Hybrid (zamba2): one shared attention block applied every N slots.
    shared_attn_every: int = 0
    # VLM: a cross-attention layer every N layers; audio: encoder-decoder.
    cross_attn_every: int = 0
    n_encoder_layers: int = 0
    d_frontend: int = 0         # stubbed modality frontend embedding width
    n_frontend_tokens: int = 0  # image/audio token count fed by the stub
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"
    glu: bool = True
    # Whether full attention makes long_500k infeasible (skip + note).
    subquadratic: bool = False
    source: str = ""

    # ---- derived ----
    @property
    def is_enc_dec(self) -> bool:
        return self.n_encoder_layers > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        a = self.attention
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention
        if a.is_mla:
            kvr = a.kv_lora_rank
            per_layer += d * (a.q_dim + a.n_heads * a.rope_head_dim)      # q (+rope part)
            per_layer += d * (kvr + a.rope_head_dim)                      # latent down
            per_layer += kvr * (a.q_dim + a.kv_dim)                       # k/v up
            per_layer += a.q_dim * d                                      # o
        else:
            per_layer += d * (a.q_dim + 2 * a.kv_dim) + a.q_dim * d
            if a.qkv_bias:
                per_layer += a.q_dim + 2 * a.kv_dim
        per_layer += 2 * d  # norms
        attn_params = per_layer

        def mlp_params(width: int) -> int:
            return d * width * (3 if self.glu else 2)

        total = emb
        if self.family == "moe":
            m = self.moe
            moe_layer = attn_params + m.n_routed * mlp_params(m.d_expert) \
                + (mlp_params(m.d_shared) if m.d_shared else 0) + d * m.n_routed
            dense_layer = attn_params + mlp_params(m.d_first_dense or self.d_ff)
            total += m.first_dense_layers * dense_layer \
                + (L - m.first_dense_layers) * moe_layer
        elif self.family == "ssm":
            s = self.ssm
            di = s.expand * d
            nh = di // s.head_dim
            block = d * 2 * di + di * d + di * s.conv_dim + 2 * di * s.state_dim \
                + 2 * nh + 2 * d
            total += L * block
        elif self.family == "hybrid":
            s = self.ssm
            di = s.expand * d
            n_shared_apps = L // max(self.shared_attn_every, 1)
            n_mamba = L - n_shared_apps
            mamba_block = d * 2 * di + di * d + di * s.conv_dim \
                + 2 * di * s.state_dim + 2 * (di // s.head_dim) + 2 * d
            shared_block = attn_params + mlp_params(self.d_ff)
            total += n_mamba * mamba_block + shared_block
        elif self.family == "vlm":
            n_cross = L // max(self.cross_attn_every, 1)
            cross_layer = attn_params + mlp_params(self.d_ff)
            total += L * (attn_params + mlp_params(self.d_ff)) + n_cross * cross_layer
            total += self.d_frontend * d  # projector
        elif self.family == "audio":
            enc = self.n_encoder_layers * (attn_params + mlp_params(self.d_ff))
            dec = L * (attn_params * 2 + mlp_params(self.d_ff))  # self + cross
            total += enc + dec + self.d_frontend * d
        else:
            total += L * (attn_params + mlp_params(self.d_ff))
        return total

    def active_param_count(self) -> int:
        """Per-token active params (= param_count for non-MoE)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        d = self.d_model

        def mlp_params(width: int) -> int:
            return d * width * (3 if self.glu else 2)

        full = self.param_count()
        routed_total = (self.n_layers - m.first_dense_layers) * m.n_routed \
            * mlp_params(m.d_expert)
        routed_active = (self.n_layers - m.first_dense_layers) * m.top_k \
            * mlp_params(m.d_expert)
        return full - routed_total + routed_active

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        a = self.attention
        small_attn = replace(
            a,
            n_heads=min(a.n_heads, 4),
            n_kv_heads=min(a.n_kv_heads, min(a.n_heads, 4)),
            head_dim=32,
            sliding_window=min(a.sliding_window, 64) if a.sliding_window else None,
            kv_lora_rank=32 if a.is_mla else None,
            rope_head_dim=16 if a.is_mla else a.rope_head_dim,
        )
        kw = dict(
            n_layers=min(self.n_layers, 4),
            d_model=64,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            attention=small_attn,
        )
        if self.moe is not None:
            kw["moe"] = replace(
                self.moe, n_routed=8, top_k=2, d_expert=32,
                d_shared=64 if self.moe.d_shared else 0,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
                d_first_dense=128 if self.moe.d_first_dense else 0,
            )
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, state_dim=16, head_dim=16, chunk=16)
        if self.shared_attn_every:
            kw["shared_attn_every"] = 3
            kw["n_layers"] = 6
        if self.cross_attn_every:
            kw["cross_attn_every"] = 2
            kw["n_layers"] = 4
        if self.n_encoder_layers:
            kw["n_encoder_layers"] = 2
            kw["n_layers"] = 2
        if self.d_frontend:
            kw["d_frontend"] = 32
            kw["n_frontend_tokens"] = 16
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The assigned LM shape set (identical across the 10 archs).
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, with the reason when skipped.

    ``long_500k`` needs a sub-quadratic attention path (SSM / hybrid /
    sliding-window / latent-compressed KV); pure full-attention archs skip it
    (see DESIGN.md §5).
    """
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "pure full-attention arch: 500k KV cache infeasible (DESIGN.md §5)"
    if arch.is_enc_dec and shape.name == "long_500k":
        return False, "enc-dec audio backbone: 500k decode inapplicable (DESIGN.md §5)"
    return True, ""
