"""Architecture/shape/config registry.

``get_arch("qwen2.5-32b")`` returns the full assigned config;
``get_arch("qwen2.5-32b", reduced=True)`` the CPU smoke variant.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    ArchConfig,
    AttentionConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    SHAPES,
    SHAPE_BY_NAME,
    shape_applicable,
)

_ARCH_MODULES = {
    "xlstm-350m": "xlstm_350m",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "qwen2.5-32b": "qwen2_5_32b",
    "yi-34b": "yi_34b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "zamba2-7b": "zamba2_7b",
    "whisper-base": "whisper_base",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
}

ARCH_NAMES: List[str] = list(_ARCH_MODULES)


def get_arch(name: str, reduced: bool = False) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    cfg: ArchConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def all_archs(reduced: bool = False) -> Dict[str, ArchConfig]:
    return {n: get_arch(n, reduced) for n in ARCH_NAMES}


def get_dml_config():
    from repro.configs.dml_plr_bonus import CONFIG
    return CONFIG


__all__ = [
    "ArchConfig", "AttentionConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
    "SHAPES", "SHAPE_BY_NAME", "shape_applicable", "ARCH_NAMES",
    "get_arch", "all_archs", "get_dml_config",
]
