"""codeqwen1.5-7b — qwen1.5 architecture [hf:Qwen/CodeQwen1.5-7B; hf].

32L d_model=4096 32H (GQA kv=32, i.e. full MHA) d_ff=13440 vocab=92416.
Qwen1.5 uses QKV bias.
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    d_ff=13440,
    vocab_size=92416,
    attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=128,
                              qkv_bias=True, rope_theta=1_000_000.0),
    subquadratic=False,
    source="hf:Qwen/CodeQwen1.5-7B; hf",
)
