"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.  SWA window 4096
makes the arch sub-quadratic => long_500k runs with a window-capped KV.
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    d_ff=10240,
    vocab_size=32000,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=120,
                              sliding_window=4096, rope_theta=100_000.0),
    subquadratic=True,   # via SWA: KV cache capped at the window
    source="arXiv:2401.16818; unverified",
)
