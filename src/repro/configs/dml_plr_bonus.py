"""The paper's own experiment config (§5): PLR model on the Pennsylvania
Reemployment Bonus experiment, K=5 folds, M=100 repetitions, L=2 nuisance
functions => 1000 ML fits.

The bonus dataset itself is not bundled (offline container); ``repro.data.bonus``
generates a schema-faithful synthetic replica (N=5099 rows, 17 regressors as
in the Chernozhukov et al. 2018 / DoubleML preprocessing).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class DMLConfig:
    model: str = "plr"            # plr | pliv | irm | iivm
    n_folds: int = 5              # K
    n_rep: int = 100              # M
    learner: str = "ridge"        # ridge | ols | lasso | kernel_ridge | mlp
    learner_params: tuple = (("reg", 1.0),)
    scaling: str = "n_rep"        # 'n_rep' | 'n_folds*n_rep'  (paper §4.2)
    score: str = "partialling out"
    # serverless-analogue executor knobs (paper §5.2 sweep)
    worker_memory_mb: int = 1024  # Lambda memory knob (drives the cost model)
    n_workers: int = 0            # 0 = elastic (all available devices)
    seed: int = 42


CONFIG = DMLConfig()

# The paper's Figure 3 sweep grid.
FIG3_MEMORY_GRID = (256, 512, 1024, 2048)
FIG3_SCALING_GRID = ("n_rep", "n_folds*n_rep")

# Table 1 reference numbers (1024 MB, per-sample-split scaling, 100 runs).
PAPER_TABLE1 = {
    "fit_time_s": {"mean": 19.82, "min": 19.53, "max": 21.49},
    "billed_gb_s": {"mean": 3515.36, "min": 3492.01, "max": 3571.42},
    "avg_duration_per_invocation_s": {"mean": 17.16, "min": 17.05, "max": 17.44},
    "total_response_time_s": {"mean": 19.09, "min": 18.81, "max": 20.76},
}
USD_PER_GB_S = 0.0000166667   # AWS eu-central-1 at paper time [5]
