"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.  d_ff=0: xLSTM blocks
carry their own up-projection (expand=2); there is no separate FFN.
Every 8th block is an sLSTM block (scalar memory, recurrent), the rest are
mLSTM (matrix memory, chunked-parallel).
"""
from repro.configs.base import ArchConfig, AttentionConfig, SSMConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    d_ff=0,
    vocab_size=50304,
    attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=256),
    ssm=SSMConfig(state_dim=256, conv_dim=4, head_dim=512, expand=2,
                  chunk=256, slstm_every=8),
    glu=False,
    tie_embeddings=True,
    subquadratic=True,
    source="arXiv:2405.04517; unverified",
)
