"""whisper-base — enc-dec audio backbone, conv frontend STUB
[arXiv:2212.04356; unverified].

6L(enc)+6L(dec) d_model=512 8H d_ff=2048 vocab=51865.  The conv frontend is
a stub: ``input_specs()`` provides precomputed frame embeddings
(batch, seq, d_frontend).  ``n_layers`` counts DECODER layers per the
assignment ("6L"); the encoder mirrors it.
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    d_ff=2048,
    vocab_size=51865,
    attention=AttentionConfig(n_heads=8, n_kv_heads=8, head_dim=64),
    n_encoder_layers=6,
    d_frontend=512,
    n_frontend_tokens=0,   # encoder seq comes from the shape cell
    act="gelu",
    glu=False,
    subquadratic=False,
    source="arXiv:2212.04356; unverified",
)
