"""zamba2-7b — Mamba2 backbone + shared attention block [arXiv:2411.15242;
unverified].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Every 6th layer slot applies the single SHARED attention+MLP block (params
shared across applications, per the Zamba2 design); the remaining slots are
Mamba2 blocks.  81 slots => 13 shared applications + 68 Mamba2 blocks.

Hybrid => subquadratic: the Mamba state is O(1) and the shared-attention KV
is window-capped at 32k for the long_500k cell (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, AttentionConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab_size=32000,
    attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=112,
                              sliding_window=32768),
    ssm=SSMConfig(state_dim=64, conv_dim=4, head_dim=64, expand=2, chunk=256),
    shared_attn_every=6,
    subquadratic=True,
    source="arXiv:2411.15242; unverified",
)
