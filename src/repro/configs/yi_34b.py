"""yi-34b — llama-arch GQA [arXiv:2403.04652; hf].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
56 heads padded to 64 for TP=16 (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    d_ff=20480,
    vocab_size=64000,
    attention=AttentionConfig(n_heads=56, n_kv_heads=8, head_dim=128,
                              rope_theta=5_000_000.0),
    subquadratic=False,
    source="arXiv:2403.04652; hf",
)
