"""qwen2.5-32b — GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B; hf].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
40 heads are padded to 48 for TP=16 (DESIGN.md §4) — padding happens in the
model build, the config records the true head count.
"""
from repro.configs.base import ArchConfig, AttentionConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    d_ff=27648,
    vocab_size=152064,
    attention=AttentionConfig(n_heads=40, n_kv_heads=8, head_dim=128,
                              qkv_bias=True, rope_theta=1_000_000.0),
    subquadratic=False,
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)
