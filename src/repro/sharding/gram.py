"""In-mesh executors for the non-task parallelization axes (ISSUE 8).

The axis planner (compile/buckets.py::plan_bucket_axis) prices three
layouts per bucket; this module supplies the two that split *inside* a
task, for the Gram-based families whose fit is a pure function of the
(X'X, X'y) statistics:

``data_parallel_gram``     shards the N axis over the mesh: every
                           device accumulates a partial Gram over its
                           N/m rows (the same masked-moment math as the
                           streaming blocked kernel) and a psum
                           reassembles the exact statistics.  The only
                           layout that can run a bucket whose N exceeds
                           one device page — pair with
                           ``kernels/ops.py::chunk_tall_n`` +
                           ``batched_gram_blocked`` to stream arbitrarily
                           tall N through fixed-size chunks.
``feature_parallel_gram``  shards the P axis (LightGBM's
                           feature-parallel analogue): each device owns
                           P/m columns, gathers the row dimension it
                           needs, and emits its column block of the
                           Gram; the blocks concatenate into the full
                           (P, P) statistics.

Both agree with the single-device statistics to float tolerance, never
bitwise: the data split changes the N-axis reduction tree, and the
feature split's narrower column blocks let XLA retile the N
contraction — the same explicit tolerance tier as the blocked kernel's
ragged-tail case (kernels/ops.py::BLOCKED_GRAM_TOLERANCE_FAMILIES
documents the bitwise/tolerance split).  The unsharded task-parallel
axis remains the bitwise reference path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sharding.compat import shard_map_compat

F32 = jnp.float32


@functools.lru_cache(maxsize=None)
def _data_gram_fn(mesh, axis: str):
    """Jitted N-sharded Gram executor, cached per (mesh, axis) so a
    drain's repeated calls hit the warm compiled program instead of
    re-tracing a fresh shard_map closure every launch."""
    from jax.sharding import PartitionSpec as P

    def body(xs, w, y):
        xf, wf, yf = xs.astype(F32), w.astype(F32), y.astype(F32)
        g = jnp.einsum("bnp,bn,bnq->bpq", xf, wf, xf)
        b = jnp.einsum("bn,bnp->bp", wf * yf, xf)
        g = jax.lax.psum(g, axis)
        b = jax.lax.psum(b, axis)
        return g, b

    return jax.jit(shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis), P(None, axis)),
        out_specs=(P(), P())))


def data_parallel_gram(mesh, xs, w, y, reg: float = 0.0,
                       axis: str = "data"):
    """Per-task normal equations with the N axis sharded over ``mesh``.

    xs: (B, N, P); w/y: (B, N).  N must be a multiple of the axis size
    (callers pad with w == 0 rows, which are arithmetically inert).
    Each device reduces its local rows — exactly one chunk of the
    streaming blocked Gram — and a psum sums the partials into the full
    (G (B,P,P), b (B,P)) on every device.
    """
    g, b = _data_gram_fn(mesh, axis)(xs, w, y)
    if reg:
        g = g + reg * jnp.eye(xs.shape[-1], dtype=g.dtype)
    return g, b


@functools.lru_cache(maxsize=None)
def _feature_gram_fn(mesh, axis: str):
    """Jitted P-sharded Gram executor, cached per (mesh, axis) — same
    warm-call economics as ``_data_gram_fn``."""
    from jax.sharding import PartitionSpec as P

    def body(xs, w, y):
        xf, wf, yf = xs.astype(F32), w.astype(F32), y.astype(F32)
        # full row matrix on every device: the priced all-gather
        x_full = jax.lax.all_gather(xf, axis, axis=2, tiled=True)
        g_blk = jnp.einsum("bnp,bn,bnq->bpq", x_full, wf, xf)
        b_blk = jnp.einsum("bn,bnp->bp", wf * yf, xf)
        return g_blk, b_blk

    return jax.jit(shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(None, None, axis), P(None, None), P(None, None)),
        out_specs=(P(None, None, axis), P(None, axis))))


def feature_parallel_gram(mesh, xs, w, y, reg: float = 0.0,
                          axis: str = "data"):
    """Per-task normal equations with the P axis sharded over ``mesh``.

    xs: (B, N, P); w/y: (B, N).  P must be a multiple of the axis size.
    Each device holds its P/m columns, all-gathers the full row matrix
    (the wire term the planner prices), computes its (P, P/m) column
    block of the Gram and its slice of X'(w*y), and the blocks
    concatenate back into the full statistics.
    """
    g, b = _feature_gram_fn(mesh, axis)(xs, w, y)
    if reg:
        g = g + reg * jnp.eye(xs.shape[-1], dtype=g.dtype)
    return g, b


def gram_solve(g, b):
    """The shared ridge/OLS epilogue on reassembled statistics: solve
    G beta = b per task.  Runs replicated — the planner prices the
    solve as unsplittable (launch/roofline.py::_solve_flops)."""
    return jnp.linalg.solve(g, b[..., None])[..., 0]
