"""In-mesh executors for the non-task parallelization axes (ISSUE 8/9).

The axis planner (compile/buckets.py::plan_bucket_axis) prices three
layouts per bucket; this module supplies the two that split *inside* a
task, for the Gram-based families whose fit is a pure function of the
(X'X, X'y) statistics:

``data_parallel_gram``     shards the N axis over the mesh: every
                           device accumulates a partial Gram over its
                           N/m rows (the same masked-moment math as the
                           streaming blocked kernel) and a psum
                           reassembles the exact statistics.  The only
                           layout that can run a bucket whose N exceeds
                           one device page — pair with
                           ``kernels/ops.py::chunk_tall_n`` +
                           ``batched_gram_blocked`` to stream arbitrarily
                           tall N through fixed-size chunks.
``feature_parallel_gram``  shards the P axis (LightGBM's
                           feature-parallel analogue): each device owns
                           P/m columns, gathers the row dimension it
                           needs, and emits its column block of the
                           Gram; the blocks concatenate into the full
                           (P, P) statistics.

ISSUE 9 adds the *drain* forms: ``axis_fit_program`` lowers a whole
bucket launch — the same ``run(pages, data_idx, y, w, valid, key_data)``
signature the ProgramCache programs compile — through these layouts, so
``dispatch_bucket`` (compile/program.py) can execute a data@m/feature@m
``AxisDecision`` instead of ignoring it.  The data form streams each
shard's rows as N-chunks through ``chunk_tall_n`` +
``batched_gram_blocked`` and psums the (G, b) moments; the feature form
shards P with the all-gather row term; the solve epilogue runs
replicated on the reassembled statistics (``gram_solve`` for ridge/OLS,
the FISTA moments form for lasso).

Both agree with the single-device statistics to float tolerance, never
bitwise: the data split changes the N-axis reduction tree, and the
feature split's narrower column blocks let XLA retile the N
contraction — the same explicit tolerance tier as the blocked kernel's
ragged-tail case (kernels/ops.py::BLOCKED_GRAM_TOLERANCE_FAMILIES
documents the bitwise/tolerance split).  The unsharded task-parallel
axis remains the bitwise reference path.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.registry import warm_cache
from repro.runtime import bounded_put
from repro.sharding.compat import shard_map_compat

F32 = jnp.float32

#: jitted shard_map programs, one per (mesh, mesh_axis, family, params)
#: — the in-mesh analogue of the ProgramCache, bounded because meshes
#: and hyperparameter bindings churn across sessions (sim-host meshes
#: are rebuilt per Topology) while a drain's repeated calls must hit
#: the warm compiled program instead of re-tracing a fresh shard_map
#: closure every launch
_DATA_GRAM_PROGRAMS: Dict[Tuple, object] = {}
_FEATURE_GRAM_PROGRAMS: Dict[Tuple, object] = {}
_GRAM_PROGRAM_CACHE_MAX = 64


def _chunk_rows(n_local: int, page_rows: int) -> int:
    """Chunk size for streaming ``n_local`` rows through fixed device
    pages: one chunk when the rows fit, else the balanced chunk size
    rounded up to the 8-row sublane multiple (minimizing the ragged
    tail the blocked kernel pads with w == 0 rows)."""
    if n_local <= page_rows:
        return n_local
    n_chunks = -(-n_local // page_rows)
    return min((-(-n_local // n_chunks) + 7) // 8 * 8, page_rows)


def _fit_epilogue(family: str, params: Dict, g, b, nw):
    """The replicated solve epilogue on fully-reassembled raw moments.

    g (B,Pa,Pa), b (B,Pa) are the *unregularized* statistics (augmented
    with the intercept column when the learner asks for one); nw (B,)
    is the global training-weight sum (psummed on the data axis).
    Mirrors learners/linear.py: ridge adds reg to the diagonal and
    un-penalizes the intercept, OLS is ridge at 1e-8, lasso runs the
    FISTA moments form.
    """
    from repro.learners.linear import _fista_beta_moments
    intercept = bool(params.get("intercept", True))
    if family == "lasso":
        return _fista_beta_moments(
            g, b, nw, reg=float(params.get("reg", 0.01)),
            intercept=intercept, n_iter=int(params.get("n_iter", 200)))
    reg = 1e-8 if family == "ols" else float(params.get("reg", 1.0))
    pa = g.shape[-1]
    g = g + reg * jnp.eye(pa, dtype=g.dtype)
    if intercept and reg:
        g = g.at[:, pa - 1, pa - 1].add(-reg + 1e-8)
    return gram_solve(g, b)


def _data_fit_body(mesh_axis: str, family: str, params: Tuple):
    """Per-shard body of the data@m bucket program: the shard sees its
    N/m slice of the pages and task tensors, streams those rows as
    N-chunks through the blocked Gram kernel, psums the (G, b, nw)
    moments into the exact full-N statistics, solves replicated, and
    predicts its local rows (the out_spec reassembles the N axis)."""
    from repro.kernels import ops
    from repro.learners.linear import _augment_b
    p = dict(params)
    p.pop("classify", None)     # linear families fit propensities as
    intercept = bool(p.get("intercept", True))   # regression (base.py)

    def body(pages, data_idx, y, w, valid, key_data):
        del key_data                       # gram families draw no keys
        from repro.launch import roofline
        xb = pages[data_idx].astype(F32)             # (B, Nloc, P)
        yf, wf = y.astype(F32), w.astype(F32)
        xa = _augment_b(xb) if intercept else xb
        chunk = _chunk_rows(int(xa.shape[1]), roofline.DEVICE_PAGE_ROWS)
        xc, wc, yc = ops.chunk_tall_n(xa, wf, yf, chunk)
        g, b = ops.batched_gram_blocked(xc, wc, yc)
        g = jax.lax.psum(g, mesh_axis)
        b = jax.lax.psum(b, mesh_axis)
        nw = jnp.maximum(
            jax.lax.psum(jnp.sum(wf, axis=1), mesh_axis), 1.0)
        beta = _fit_epilogue(family, p, g, b, nw)
        return ops.batched_predict(xa, beta, valid.astype(F32))

    return body


def _feature_fit_body(mesh_axis: str, family: str, params: Tuple):
    """Per-shard body of the feature@m bucket program: the shard owns
    P/m feature columns, all-gathers the full row matrix (the wire term
    the planner prices), computes its (P, P/m) column block of the raw
    Gram, gathers the blocks into the full statistics, assembles the
    intercept row/column from cheap O(NP) moments, and solves/predicts
    replicated."""
    from repro.kernels import ops
    from repro.learners.linear import _augment_b
    p = dict(params)
    p.pop("classify", None)
    intercept = bool(p.get("intercept", True))

    def body(pages, data_idx, y, w, valid, key_data):
        del key_data
        xb = pages[data_idx].astype(F32)             # (B, N, Ploc)
        yf, wf = y.astype(F32), w.astype(F32)
        x_full = jax.lax.all_gather(xb, mesh_axis, axis=2, tiled=True)
        g_blk = jnp.einsum("bnp,bn,bnq->bpq", x_full, wf, xb)
        b_blk = jnp.einsum("bn,bnp->bp", wf * yf, xb)
        g = jax.lax.all_gather(g_blk, mesh_axis, axis=2, tiled=True)
        b = jax.lax.all_gather(b_blk, mesh_axis, axis=1, tiled=True)
        nw = jnp.maximum(jnp.sum(wf, axis=1), 1.0)
        if intercept:
            xw1 = jnp.einsum("bn,bnp->bp", wf, x_full)       # (B, P)
            sw = jnp.sum(wf, axis=1)
            swy = jnp.sum(wf * yf, axis=1)
            g = jnp.concatenate([
                jnp.concatenate([g, xw1[:, :, None]], axis=2),
                jnp.concatenate([xw1[:, None, :],
                                 sw[:, None, None]], axis=2)], axis=1)
            b = jnp.concatenate([b, swy[:, None]], axis=1)
            xa = _augment_b(x_full)
        else:
            xa = x_full
        beta = _fit_epilogue(family, p, g, b, nw)
        return ops.batched_predict(xa, beta, valid.astype(F32))

    return body


# family/params select a pure body-builder closure; the jitted program
# is otherwise a function of (mesh, mesh_axis) only
@warm_cache(name="data_gram_programs",
            key=("mesh", "mesh_axis", "family", "params"))
def _data_gram_fn(mesh, mesh_axis: str, family: Optional[str] = None,
                  params: Tuple = ()):
    """Jitted N-sharded executor, cached per (mesh, mesh_axis, family,
    params) so a drain's repeated calls hit the warm compiled program
    instead of re-tracing a fresh shard_map closure every launch.
    ``family=None`` is the standalone Gram form ((xs, w, y) -> (G, b));
    a Gram family name selects the full bucket fit-predict program
    (ISSUE 9 drain path) at the ProgramCache launch signature."""
    from jax.sharding import PartitionSpec as P

    ck = (mesh, mesh_axis, family, params)
    prog = _DATA_GRAM_PROGRAMS.get(ck)
    if prog is not None:
        return prog

    if family is None:
        def body(xs, w, y):
            xf, wf, yf = xs.astype(F32), w.astype(F32), y.astype(F32)
            g = jnp.einsum("bnp,bn,bnq->bpq", xf, wf, xf)
            b = jnp.einsum("bn,bnp->bp", wf * yf, xf)
            g = jax.lax.psum(g, mesh_axis)
            b = jax.lax.psum(b, mesh_axis)
            return g, b

        prog = jax.jit(shard_map_compat(
            body, mesh=mesh,
            in_specs=(P(None, mesh_axis), P(None, mesh_axis),
                      P(None, mesh_axis)),
            out_specs=(P(), P())))
    else:
        prog = jax.jit(shard_map_compat(
            _data_fit_body(mesh_axis, family, params), mesh=mesh,
            in_specs=(P(None, mesh_axis, None), P(None),
                      P(None, mesh_axis), P(None, mesh_axis),
                      P(None, mesh_axis), P(None, None)),
            out_specs=P(None, mesh_axis)))
    bounded_put(_DATA_GRAM_PROGRAMS, ck, prog, _GRAM_PROGRAM_CACHE_MAX)
    return prog


def data_parallel_gram(mesh, xs, w, y, reg: float = 0.0,
                       mesh_axis: str = "data"):
    """Per-task normal equations with the N axis sharded over ``mesh``.

    xs: (B, N, P); w/y: (B, N).  N must be a multiple of the axis size
    (callers pad with w == 0 rows, which are arithmetically inert).
    ``mesh_axis`` names the *mesh axis* the N dimension shards over
    (the parallelization axis is always N here — the planner's "data"
    layout).  Each device reduces its local rows — exactly one chunk of
    the streaming blocked Gram — and a psum sums the partials into the
    full (G (B,P,P), b (B,P)) on every device.
    """
    g, b = _data_gram_fn(mesh, mesh_axis)(xs, w, y)
    if reg:
        g = g + reg * jnp.eye(xs.shape[-1], dtype=g.dtype)
    return g, b


@warm_cache(name="feature_gram_programs",
            key=("mesh", "mesh_axis", "family", "params"))
def _feature_gram_fn(mesh, mesh_axis: str, family: Optional[str] = None,
                     params: Tuple = ()):
    """Jitted P-sharded executor — same cache economics and
    ``family=None``/fit-program split as ``_data_gram_fn``."""
    from jax.sharding import PartitionSpec as P

    ck = (mesh, mesh_axis, family, params)
    prog = _FEATURE_GRAM_PROGRAMS.get(ck)
    if prog is not None:
        return prog

    if family is None:
        def body(xs, w, y):
            xf, wf, yf = xs.astype(F32), w.astype(F32), y.astype(F32)
            # full row matrix on every device: the priced all-gather
            x_full = jax.lax.all_gather(xf, mesh_axis, axis=2,
                                        tiled=True)
            g_blk = jnp.einsum("bnp,bn,bnq->bpq", x_full, wf, xf)
            b_blk = jnp.einsum("bn,bnp->bp", wf * yf, xf)
            return g_blk, b_blk

        prog = jax.jit(shard_map_compat(
            body, mesh=mesh,
            in_specs=(P(None, None, mesh_axis), P(None, None),
                      P(None, None)),
            out_specs=(P(None, None, mesh_axis), P(None, mesh_axis))))
    else:
        prog = jax.jit(shard_map_compat(
            _feature_fit_body(mesh_axis, family, params), mesh=mesh,
            in_specs=(P(None, None, mesh_axis), P(None),
                      P(None, None), P(None, None), P(None, None),
                      P(None, None)),
            out_specs=P(None, None)))
    bounded_put(_FEATURE_GRAM_PROGRAMS, ck, prog,
                _GRAM_PROGRAM_CACHE_MAX)
    return prog


def feature_parallel_gram(mesh, xs, w, y, reg: float = 0.0,
                          mesh_axis: str = "data"):
    """Per-task normal equations with the P axis sharded over ``mesh``.

    xs: (B, N, P); w/y: (B, N).  P must be a multiple of the axis size.
    ``mesh_axis`` names the *mesh axis* the P dimension shards over —
    the default host meshes keep their device axis named "data" even
    when this executor splits features across it (the planner's
    "feature" layout).  Each device holds its P/m columns, all-gathers
    the full row matrix (the wire term the planner prices), computes
    its (P, P/m) column block of the Gram and its slice of X'(w*y), and
    the blocks concatenate back into the full statistics.
    """
    g, b = _feature_gram_fn(mesh, mesh_axis)(xs, w, y)
    if reg:
        g = g + reg * jnp.eye(xs.shape[-1], dtype=g.dtype)
    return g, b


def axis_fit_program(mesh, axis: str, family: str, params: Tuple,
                     mesh_axis: str = "data"):
    """The drain entry point (ISSUE 9): the jitted in-mesh bucket
    program executing a data@m/feature@m ``AxisDecision`` at the
    ProgramCache launch signature ``run(pages, data_idx, y, w, valid,
    key_data) -> preds (B, N_pad)``.  ``params`` is the bucket ident's
    sorted hyperparameter tuple (``BucketKey.learner[1]``)."""
    if axis == "data":
        return _data_gram_fn(mesh, mesh_axis, family, tuple(params))
    if axis == "feature":
        return _feature_gram_fn(mesh, mesh_axis, family, tuple(params))
    raise ValueError(f"no in-mesh executor for axis {axis!r}")


def axis_fit_program_cached(mesh, axis: str, family: str, params: Tuple,
                            mesh_axis: str = "data") -> bool:
    """Whether ``axis_fit_program`` would be a warm hit (compile-stats
    attribution in dispatch_bucket, mirroring ProgramCache hit/compile
    counting)."""
    ck = (mesh, mesh_axis, family, tuple(params))
    cache = _DATA_GRAM_PROGRAMS if axis == "data" \
        else _FEATURE_GRAM_PROGRAMS
    return ck in cache


def gram_solve(g, b):
    """The shared ridge/OLS epilogue on reassembled statistics: solve
    G beta = b per task.  Runs replicated — the planner prices the
    solve as unsplittable (launch/roofline.py::_solve_flops)."""
    return jnp.linalg.solve(g, b[..., None])[..., 0]
