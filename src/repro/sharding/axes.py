"""Logical-axis sharding (MaxText-style).

Model code annotates parameters and activations with *logical* axis names
("embed", "heads", "ff", "experts", "batch", "seq", ...).  A
:class:`LogicalRules` table maps logical names to physical mesh axes; the
per-arch policy (``repro.sharding.policy``) picks the table.  Hillclimbing a
sharding scheme = swapping one rules table, no model edits.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Physical = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class LogicalRules:
    """logical axis name -> physical mesh axis (or tuple, or None)."""

    rules: Tuple[Tuple[str, Physical], ...]

    def to_dict(self) -> Dict[str, Physical]:
        return dict(self.rules)

    def resolve(self, logical: Tuple[Optional[str], ...],
                shape: Optional[Tuple[int, ...]] = None,
                mesh_sizes: Optional[Dict[str, int]] = None) -> P:
        """Map logical dims to mesh axes.  With ``shape``+``mesh_sizes`` the
        resolution is divisibility-aware: axes a dim cannot evenly use are
        dropped *before* being marked used, so later dims can claim them
        (e.g. batch 128 cannot take ("data","model") -> "model" stays free
        for the kv_seq dim)."""
        from repro import runtime
        table = self.to_dict()
        avail = runtime.mesh_axes          # None = no filtering
        phys = []
        used: set = set()

        def _flat(p):
            if p is None:
                return ()
            out = (p,) if isinstance(p, str) else tuple(p)
            if avail is not None:
                out = tuple(a for a in out if a in avail)
            return out

        for i, name in enumerate(logical):
            if name is None:
                phys.append(None)
                continue
            p = table.get(name)
            # Never map two tensor dims to the same mesh axis.
            fp = tuple(a for a in _flat(p) if a not in used)
            if shape is not None and mesh_sizes is not None:
                # greedily drop from the right until the dim divides
                while fp:
                    total = 1
                    for a in fp:
                        total *= mesh_sizes.get(a, 1)
                    if shape[i] % total == 0:
                        break
                    fp = fp[:-1]
            used.update(fp)
            if not fp:
                phys.append(None)
            elif len(fp) == 1:
                phys.append(fp[0])
            else:
                phys.append(fp)
        return P(*phys)

    def sharding(self, mesh: Mesh, logical: Tuple[Optional[str], ...]) -> NamedSharding:
        return NamedSharding(mesh, self.resolve(logical))

    def replace(self, **updates: Physical) -> "LogicalRules":
        d = self.to_dict()
        d.update(updates)
        return LogicalRules(tuple(sorted(d.items())))


def logical_constraint(x, rules: LogicalRules, *logical: Optional[str]):
    """``with_sharding_constraint`` via logical names (no-op off-mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.resolve(tuple(logical)))
    except (ValueError, RuntimeError):
        # No mesh in scope (single-device smoke tests) — constraints vanish.
        return x


# ---------------------------------------------------------------------------
# Rule tables.  Mesh axes: ("pod",) "data", "model".
# DP := ("pod","data") for batch / task-grid / FSDP sharding.
# ---------------------------------------------------------------------------
DP = ("pod", "data")

# Big dense/MoE models: FSDP over data, tensor-parallel over model.
MEGATRON_FSDP = LogicalRules((
    # activations
    ("batch", DP),
    ("seq", None),
    ("seq_shard", "model"),       # sequence-parallel segments between blocks
    ("act_embed", None),
    ("act_heads", "model"),
    ("act_ff", "model"),
    ("vocab_logits", "model"),
    ("kv_seq", "model"),          # decode: split-KV over the model axis
    # params: (fsdp dim, tp dim)
    ("embed", "data"),
    ("embed_tp", None),
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("ff", "model"),
    ("experts", "model"),
    ("expert_ff", None),
    ("layers", None),
    ("latent", None),
    ("frontend", None),
    ("conv", None),
    ("state", None),
))

# Small models (xlstm-350m, whisper-base): batch over every axis it divides
# (the divisibility guard in param.py degrades gracefully), FFN width over
# model where divisible; no sequence sharding (time-recurrent scans over a
# sharded seq dim explode the SPMD partitioner and buy little at these
# sizes — the roofline honestly reports the low pod utilization).
SMALL_DP = LogicalRules((
    ("batch", ("pod", "data", "model")),
    ("seq", None),
    ("seq_shard", None),
    ("act_embed", None),
    ("act_heads", None),
    ("act_ff", "model"),
    ("vocab_logits", "model"),
    ("kv_seq", "model"),
    ("embed", "data"),
    ("embed_tp", None),
    ("vocab", "model"),
    ("heads", None),
    ("kv_heads", None),
    ("ff", "model"),
    ("experts", None),
    ("expert_ff", None),
    ("layers", None),
    ("latent", None),
    ("frontend", None),
    ("conv", None),
    ("state", None),
))

# Kept for experiments: sequence sharding variant (context parallel).
SMALL_SEQ = SMALL_DP.replace(seq="model", seq_shard="model")


def rules_for(arch_name: str, shape_kind: str, d_model: int,
              global_batch: int = 0) -> LogicalRules:
    """Default policy table per (arch, shape-kind) — see sharding/policy.py."""
    small = d_model <= 1024
    base = SMALL_DP if small else MEGATRON_FSDP
    if shape_kind == "decode" and 0 < global_batch < 8:
        # long-context cells (batch 1): parallelism must come from the KV
        # sequence, not the batch
        return base.replace(batch=None, kv_seq=("data", "model"))
    return base
