"""Named sharding-policy variants for §Perf hillclimbing, plus the
topology layer's bucket→host placement policy (ISSUE 4).

A variant = (rules transform, model-build overrides).  The dry-run CLI takes
``--variant NAME`` so a hypothesis is one flag away from its measurement; the
baseline tables always use ``default``.

Placement: ``place_bucket`` scores one megabatch bucket against every
host's page-pool residency — stack-cached beats pages-resident beats
cold — and ``steal_choice`` picks what an idle host takes from the most
loaded one.  Both are pure functions of the observed pools/queues, so a
drain's routing is reproducible; and because per-task PRNG is fixed at
compile time, no placement they produce can move an estimate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.sharding.axes import rules_for


@dataclass(frozen=True)
class Variant:
    name: str
    description: str
    rules_update: Dict[str, object] = field(default_factory=dict)
    attn_chunk: Optional[int] = None
    remat: Optional[str] = None
    n_microbatch: Optional[int] = None


VARIANTS: Dict[str, Variant] = {v.name: v for v in [
    Variant("default", "paper-faithful baseline policy"),
    Variant("no_seqpar",
            "hypothesis: sequence-parallel residual constraint is causing "
            "extra reshard traffic — drop it",
            rules_update={"seq_shard": None}),
    Variant("no_seqpar_m16",
            "no_seqpar trades wire for replicated activation checkpoints; "
            "recover HBM with 16 microbatches",
            rules_update={"seq_shard": None}, n_microbatch=16),
    Variant("dp_heavy",
            "hypothesis: TP all-reduces dominate — shard FFN/heads over "
            "(data,model) jointly and keep activations DP-only",
            rules_update={"act_heads": None, "act_ff": None,
                          "seq_shard": None}),
    Variant("remat_dots",
            "hypothesis: full remat recompute inflates the compute term — "
            "save matmul outputs instead",
            remat="dots"),
    Variant("chunk512", "smaller attention KV chunks (less transient traffic)",
            attn_chunk=512),
    Variant("chunk2048", "larger attention KV chunks (fewer softmax passes)",
            attn_chunk=2048),
]}


def megabatch_specs(batch_axis: str = "data",
                    pages_axis: Optional[str] = None, *,
                    fused: bool = False):
    """PartitionSpecs for a megabatch bucket program (repro/compile).

    The program signature is (pages, data_idx, y, w, valid, key_data) ->
    preds; every per-task tensor is sharded along the task-batch axis —
    the compiler pads B to a multiple of the shard count.

    ``pages_axis=None`` (the single-host default) replicates the
    device-resident page stack so every shard can gather any task's
    dataset.  Passing an axis name instead shards the page D axis — the
    multi-host megabatch layout where each host pool holds only its
    buckets' pages; callers must then also route each bucket's task
    slices to the shard holding its pages (ROADMAP "multi-host
    megabatch").

    ``fused=True`` (ISSUE 8) returns specs for the *fused* calling
    convention, where every per-task operand carries a leading canonical
    block axis G: the G axis is replicated (each shard runs all blocks)
    and the task-batch axis — now axis 1 — is sharded.  A PartitionSpec
    shorter than the operand rank leaves the trailing dims (N_pad, key
    tail) unsharded, so one spec covers all fused operand ranks.
    """
    from jax.sharding import PartitionSpec as P
    pages = P(pages_axis) if pages_axis else P()
    task = P(None, batch_axis) if fused else P(batch_axis)
    in_specs = (pages, task, task, task, task, task)
    out_specs = task
    return in_specs, out_specs


# ---------------------------------------------------------------------------
# Bucket -> host placement (topology layer)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BucketPlacement:
    """One routing decision plus the residency evidence it came from."""
    host: int
    score: float                        # mean page points in [0, 2]
    resident: int                       # pages of this bucket already held
    total: int                          # pages the bucket needs
    stacked: int                        # pages whose launch stack is cached


def _page_points(pool, pk) -> float:
    """Locality value of one page on one host: 2 if it is launch-ready
    with zero copies (for canonical singleton launches the resident page
    IS the launch array, so this fires for every resident page), 1 if
    only the raw page is held (zero transfers but a copy pending — the
    multi-lane fusion case), 0 cold."""
    if pool.stack_cached((pk,)):
        return 2.0
    if pool.resident(pk):
        return 1.0
    return 0.0


def place_bucket(pkeys: Sequence, pools: Sequence,
                 loads: Sequence[int]) -> BucketPlacement:
    """Route one bucket to the host best positioned to run it.

    ``pkeys`` are the bucket's page keys (one per request in it),
    ``pools`` the per-host PagePools, ``loads`` each host's currently
    queued invocation count.  Score = mean per-page locality points
    (stack-cached > resident > cold); ties break to the least-loaded
    host, then the lowest host id — fully deterministic.
    """
    lane_keys = tuple(dict.fromkeys(pkeys))       # dedup, keep order
    total = max(len(lane_keys), 1)
    best = None
    for hid, pool in enumerate(pools):
        resident = sum(1 for pk in lane_keys if pool.resident(pk))
        stacked = sum(1 for pk in lane_keys if pool.stack_cached((pk,)))
        score = sum(_page_points(pool, pk) for pk in lane_keys) / total
        rank = (-score, loads[hid], hid)
        cand = BucketPlacement(host=hid, score=score, resident=resident,
                               total=total, stacked=stacked)
        if best is None or rank < best[0]:
            best = (rank, cand)
    return best[1]


def steal_choice(queues: Dict[int, List], pools: Sequence,
                 pkeys_of: Callable[[object], Sequence]) \
        -> Optional[Tuple[int, object]]:
    """What an idle host steals: from the donor with the most queued
    buckets (only if it has more than one — never strand a host's last
    bucket mid-flight), take the bucket *least* local to the donor, so
    the migrated residency costs the donor the least.  Returns
    ``(donor_host, bucket_key)`` or None when no steal is worthwhile.
    """
    donor = None
    for hid, keys in sorted(queues.items()):
        if len(keys) > 1 and (donor is None
                              or len(keys) > len(queues[donor])):
            donor = hid
    if donor is None:
        return None
    pool = pools[donor]

    def locality(key):
        lane_keys = tuple(dict.fromkeys(pkeys_of(key)))
        return sum(_page_points(pool, pk) for pk in lane_keys) \
            / max(len(lane_keys), 1)

    # min() is stable: the first enqueued among equally-cold buckets wins
    victim = min(queues[donor], key=locality)
    return donor, victim


def apply_variant(arch_name: str, shape_kind: str, d_model: int,
                  variant: str):
    v = VARIANTS[variant]
    rules = rules_for(arch_name, shape_kind, d_model)
    if v.rules_update:
        rules = rules.replace(**v.rules_update)
    return rules, v
