"""Named sharding-policy variants for §Perf hillclimbing.

A variant = (rules transform, model-build overrides).  The dry-run CLI takes
``--variant NAME`` so a hypothesis is one flag away from its measurement; the
baseline tables always use ``default``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from repro.sharding.axes import LogicalRules, rules_for


@dataclass(frozen=True)
class Variant:
    name: str
    description: str
    rules_update: Dict[str, object] = field(default_factory=dict)
    attn_chunk: Optional[int] = None
    remat: Optional[str] = None
    n_microbatch: Optional[int] = None


VARIANTS: Dict[str, Variant] = {v.name: v for v in [
    Variant("default", "paper-faithful baseline policy"),
    Variant("no_seqpar",
            "hypothesis: sequence-parallel residual constraint is causing "
            "extra reshard traffic — drop it",
            rules_update={"seq_shard": None}),
    Variant("no_seqpar_m16",
            "no_seqpar trades wire for replicated activation checkpoints; "
            "recover HBM with 16 microbatches",
            rules_update={"seq_shard": None}, n_microbatch=16),
    Variant("dp_heavy",
            "hypothesis: TP all-reduces dominate — shard FFN/heads over "
            "(data,model) jointly and keep activations DP-only",
            rules_update={"act_heads": None, "act_ff": None,
                          "seq_shard": None}),
    Variant("remat_dots",
            "hypothesis: full remat recompute inflates the compute term — "
            "save matmul outputs instead",
            remat="dots"),
    Variant("chunk512", "smaller attention KV chunks (less transient traffic)",
            attn_chunk=512),
    Variant("chunk2048", "larger attention KV chunks (fewer softmax passes)",
            attn_chunk=2048),
]}


def megabatch_specs(batch_axis: str = "data",
                    pages_axis: Optional[str] = None):
    """PartitionSpecs for a megabatch bucket program (repro/compile).

    The program signature is (pages, data_idx, y, w, valid, key_data) ->
    preds; every per-task tensor is sharded along the task-batch axis —
    the compiler pads B to a multiple of the shard count.

    ``pages_axis=None`` (the single-host default) replicates the
    device-resident page stack so every shard can gather any task's
    dataset.  Passing an axis name instead shards the page D axis — the
    multi-host megabatch layout where each host pool holds only its
    buckets' pages; callers must then also route each bucket's task
    slices to the shard holding its pages (ROADMAP "multi-host
    megabatch").
    """
    from jax.sharding import PartitionSpec as P
    in_specs = (P(pages_axis) if pages_axis else P(), P(batch_axis),
                P(batch_axis), P(batch_axis), P(batch_axis), P(batch_axis))
    out_specs = P(batch_axis)
    return in_specs, out_specs


def apply_variant(arch_name: str, shape_kind: str, d_model: int,
                  variant: str):
    v = VARIANTS[variant]
    rules = rules_for(arch_name, shape_kind, d_model)
    if v.rules_update:
        rules = rules.replace(**v.rules_update)
    return rules, v
