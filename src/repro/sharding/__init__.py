from repro.sharding.axes import (
    LogicalRules, logical_constraint, rules_for, MEGATRON_FSDP, SMALL_DP,
    SMALL_SEQ,
)

__all__ = [
    "LogicalRules", "logical_constraint", "rules_for", "MEGATRON_FSDP",
    "SMALL_DP", "SMALL_SEQ",
]
