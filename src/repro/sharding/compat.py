"""Cross-version jax compat shims for SPMD primitives.

jax moved ``shard_map`` out of ``jax.experimental`` (and renamed
``check_rep`` to ``check_vma``) around 0.6; everything in this repo goes
through this helper so one module tracks the API drift.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` (jax >= 0.4.35), with Auto axis_types only on
    versions that have them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def shard_map_compat(fn, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)
