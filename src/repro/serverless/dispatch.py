"""Non-blocking dispatch queues (ISSUE 5 tentpole, serverless layer).

``compile/program.py::dispatch_bucket`` launches a bucket slice and
returns in-flight ``jax.Array`` handles instead of blocking per block.
This module is the layer the backends manage those handles with: one
``DispatchQueue`` per drain stream — per *host mesh* on the topology
backend, matching PR 4's per-host streams as the dispatch unit — holding
``PendingBucket``s until their ledgers must complete.

The queue is what turns the drain engine's event loop into real
host/device overlap: ``step()`` dispatches work and returns without
waiting, so placement, stealing, admission, autoscaling, and result
assembly all run while the device executes.  Booking happens at
*harvest*: non-blocking for buckets whose launches report ready
(``harvest_ready``), blocking only when a drain has nothing left to
dispatch (``harvest_next``).

Accounting (``DispatchStats``) feeds BENCH_fusion.json: ``wait_s`` is
host time spent blocked on the device, ``host_overlap_s`` is host work
performed while launches were in flight — their ratio is the measured
overlap of host booking with device execution.

Fault tolerance (ISSUE 10) lives at this layer too.  An in-flight
bucket carries an optional **deadline** (roofline-derived, capped by
``PoolConfig.timeout_s``); once overdue, the backend dispatches a
**hedged duplicate** — on a different host under the topology backend —
and the two legs race.  First to land wins and is booked; ``HedgePair.
settle`` (the protocol's SOLE cancel performer) cancels the loser, whose
dispatch is discarded without booking and whose wall-clock span is
charged to ``hedge_waste_s`` instead of the request bill, so the
GB-second ``Bill`` and the autoscaler EMAs see exactly one span per
completed bucket.  A host death abandons its whole queue
(``abandon()``): the orphans transition to LOST and their invocations
resurface in the ledger-driven pending view for re-dispatch elsewhere.
Every transition is checked against ``analysis/protocol.py``'s
``BUCKET_TRANSITIONS`` table when ``REPRO_SANITIZE`` is armed.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.serverless import sanitize

# (request index, invocation id) — compile/buckets.py::Entry, redeclared
# here because repro.compile must load lazily (core <-> serverless cycle)
Entry = Tuple[int, int]


@dataclass
class DispatchStats:
    """In-flight accounting for one drain's dispatch queues."""
    dispatched: int = 0                 # buckets pushed
    harvested: int = 0                  # buckets booked
    ready_harvests: int = 0             # booked without blocking
    wait_s: float = 0.0                 # host blocked on the device
    host_overlap_s: float = 0.0         # host work while work in flight
    in_flight_peak: int = 0             # max concurrent pending buckets
    hedges: int = 0                     # duplicate dispatches launched
    hedge_wins: int = 0                 # races won by the duplicate
    cancelled: int = 0                  # losing legs discarded unbooked
    lost: int = 0                       # buckets abandoned to host loss
    hedge_waste_s: float = 0.0          # wall attributed to losing legs

    @property
    def overlap_ratio(self) -> float:
        """Fraction of device execution hidden behind host booking:
        overlapped host seconds vs total (overlapped + blocked) seconds
        spanning the in-flight windows."""
        total = self.host_overlap_s + self.wait_s
        return self.host_overlap_s / total if total > 0 else 0.0

    def merge(self, other: "DispatchStats") -> "DispatchStats":
        return DispatchStats(
            self.dispatched + other.dispatched,
            self.harvested + other.harvested,
            self.ready_harvests + other.ready_harvests,
            self.wait_s + other.wait_s,
            self.host_overlap_s + other.host_overlap_s,
            max(self.in_flight_peak, other.in_flight_peak),
            self.hedges + other.hedges,
            self.hedge_wins + other.hedge_wins,
            self.cancelled + other.cancelled,
            self.lost + other.lost,
            self.hedge_waste_s + other.hedge_waste_s)

    def summary(self) -> Dict:
        return {"buckets_dispatched": self.dispatched,
                "buckets_harvested": self.harvested,
                "ready_harvests": self.ready_harvests,
                "harvest_wait_s": self.wait_s,
                "host_overlap_s": self.host_overlap_s,
                "overlap_ratio": self.overlap_ratio,
                "in_flight_peak": self.in_flight_peak,
                "hedges": self.hedges,
                "hedge_wins": self.hedge_wins,
                "cancelled": self.cancelled,
                "lost": self.lost,
                "hedge_waste_s": self.hedge_waste_s}


@dataclass(eq=False)
class PendingBucket:
    """One dispatched bucket slice awaiting harvest.

    Identity equality (``eq=False``) is load-bearing: the queue removes
    pending buckets with ``list.remove``, and a generated ``__eq__``
    would compare the wrapped in-flight ``jax.Array`` handles
    elementwise — raising whenever two in-flight buckets share a key.

    Wraps the compiler's ``BucketDispatch`` with the scheduling context
    the booking callback needs (which host stream launched it, when).
    An invocation's rows can straddle launches, so the *bucket* is the
    booking unit — ``ready()`` only when every launch has landed.

    ``book`` is the **booking continuation**, attached at push
    (book-at-push, ISSUE 7): under pipelined dispatch a bucket may land
    several waves after it was pushed, so its booking context must ride
    with the bucket instead of being supplied by whichever harvest call
    happens to drain it.

    Lifecycle (``state``): DISPATCHED -> HARVESTED on the happy path;
    an overdue bucket becomes HEDGED when its duplicate launches, the
    race's loser becomes CANCELLED (discarded, never booked), and a
    bucket orphaned by a host death becomes LOST.  ``deadline_s`` arms
    the hedge check; ``not_ready_before`` models a synthetic straggler's
    long tail (``ready()`` stays False until it matures, which is what
    an armed deadline cuts short).
    """
    dispatch: object                    # compile/program.py::BucketDispatch
    host: int = -1                      # host stream (-1: single-stream)
    t_dispatch: float = field(default_factory=time.perf_counter)
    book: Optional["BookFn"] = None     # attached by DispatchQueue.push
    state: str = "DISPATCHED"           # protocol.BUCKET_TRANSITIONS
    deadline_s: Optional[float] = None  # hedge when overdue (None: never)
    not_ready_before: float = 0.0       # straggler hold (perf_counter)
    is_hedge: bool = False              # this leg IS the duplicate
    pair: Optional["HedgePair"] = None  # set on both legs of a race

    @property
    def key(self):
        return self.dispatch.key

    @property
    def entries(self) -> List[Entry]:
        return self.dispatch.entries

    def ready(self) -> bool:
        if self.not_ready_before and time.perf_counter() < self.not_ready_before:
            return False
        return self.dispatch.ready()


# booking callback: (pending_bucket, results, elapsed_s_since_dispatch)
BookFn = Callable[[PendingBucket, Dict[Entry, object], float], None]


@dataclass(eq=False)
class HedgePair:
    """The two legs of a hedged re-dispatch race.

    Both legs run the SAME compiled program over the SAME entries with
    the SAME per-task fold_in PRNG keys, so whichever lands first books
    bitwise-identical results — the race only decides latency, never
    values.  ``settle`` is the protocol's **sole cancel performer**
    (``analysis/protocol.py::CANCEL_PERFORMERS``): the winning leg's
    harvest calls it exactly once, and it cancels every other live leg,
    guaranteeing single-performer booking — a cancelled leg's dispatch
    is discarded via the same harvest-once flag, so it can never also be
    booked.
    """
    legs: List[Tuple[PendingBucket, "DispatchQueue"]] = field(
        default_factory=list)
    winner: Optional[PendingBucket] = None

    def settle(self, winner: PendingBucket) -> None:
        """Declare ``winner`` booked; cancel the remaining live legs.
        Idempotent: a leg that lost to an already-settled race was
        cancelled before it could harvest, so only the first call acts."""
        if self.winner is not None:
            return
        self.winner = winner
        for pb, q in self.legs:
            if pb is winner or pb.state == "LOST":
                continue
            q.cancel(pb)


class DispatchQueue:
    """FIFO of in-flight buckets for one drain stream.

    ``push`` marks the start of an in-flight window; host work done
    between a push and the next harvest is credited to
    ``host_overlap_s`` (the device was executing meanwhile), while time
    spent inside a blocking ``harvest`` is ``wait_s``.  ``max_inflight``
    bounds device-side liveness: a push beyond it first force-harvests
    the oldest bucket.
    """

    def __init__(self, max_inflight: int = 8,
                 stats: Optional[DispatchStats] = None):
        self.max_inflight = max(1, int(max_inflight))
        self.stats = stats if stats is not None else DispatchStats()
        self._pending: List[PendingBucket] = []
        self._mark: Optional[float] = None   # start of host-overlap window
        self._t_attr = 0.0                   # duration-attribution frontier

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def empty(self) -> bool:
        return not self._pending

    def in_flight_entries(self) -> Set[Entry]:
        """Dispatched-but-unharvested (request, invocation) pairs — the
        set schedulers must exclude from their pending view, and the
        autoscalers must count as occupancy rather than queue depth."""
        out: Set[Entry] = set()
        for pb in self._pending:
            out.update(pb.entries)
        return out

    @property
    def in_flight(self) -> int:
        """Dispatched-but-unharvested invocation count."""
        return sum(len(pb.entries) for pb in self._pending)

    # ------------------------------------------------------------------
    def _note_overlap(self):
        """Credit host time since the last dispatch/harvest event as
        overlapped work (only meaningful while something is in flight)."""
        now = time.perf_counter()
        if self._mark is not None and self._pending:
            self.stats.host_overlap_s += now - self._mark
        self._mark = now

    def push(self, pb: PendingBucket, book: Optional[BookFn] = None) -> None:
        """Enqueue one dispatched bucket; force-harvests the oldest
        first when the in-flight bound is reached.  ``book`` becomes the
        bucket's booking continuation (book-at-push) unless the caller
        already attached one to ``pb``."""
        if book is not None:
            pb.book = book
        sanitize.check_book_at_push(pb)
        self._note_overlap()
        while len(self._pending) >= self.max_inflight:
            self.harvest_next()
        self._pending.append(pb)
        self.stats.dispatched += 1
        self.stats.in_flight_peak = max(self.stats.in_flight_peak,
                                        len(self._pending))
        self._mark = time.perf_counter()

    def _harvest(self, pb: PendingBucket, book: Optional[BookFn],
                 blocked: bool):
        if pb.state == "CANCELLED":
            # The losing leg of a hedge race: discard without booking.
            # Its wall-clock span (beyond the attribution frontier) is
            # charged to hedge_waste_s, NOT to the request bill — the
            # winner already carried the bucket's one billable span, so
            # billing the loser too would double-charge GB-seconds and
            # skew the autoscaler EMA.
            t0 = time.perf_counter()
            pb.dispatch.discard()
            t1 = time.perf_counter()
            if blocked:
                self.stats.wait_s += t1 - t0
            self._mark = t1
            sanitize.check_attribution(t1, self._t_attr)
            waste = t1 - max(pb.t_dispatch, self._t_attr)
            self._t_attr = t1
            self.stats.hedge_waste_s += max(waste, 0.0)
            self.stats.cancelled += 1
            return
        t0 = time.perf_counter()
        if blocked and pb.not_ready_before:
            # blocking harvest of a held (synthetic-straggler) bucket:
            # the long tail is part of the wall we are waiting out
            hold = pb.not_ready_before - t0
            if hold > 0:
                time.sleep(hold)
        results = pb.dispatch.harvest()
        t1 = time.perf_counter()
        if blocked:
            self.stats.wait_s += t1 - t0
        self.stats.harvested += 1
        self._mark = t1
        # NON-OVERLAPPING duration attribution: concurrent in-flight
        # buckets share one wall-clock span, so billing each of them
        # (dispatch -> harvest) would charge the span k times over —
        # inflating GB-seconds, the autoscaler EMA, and the timeout
        # check.  Each bucket is billed only the span beyond the
        # frontier already attributed to earlier harvests; summed
        # durations then equal the true elapsed wall, matching the old
        # synchronous per-bucket accounting.
        sanitize.check_attribution(t1, self._t_attr)
        elapsed = t1 - max(pb.t_dispatch, self._t_attr)
        self._t_attr = t1
        sanitize.check_bucket_bookable(pb)
        pb.state = "HARVESTED"
        fn = pb.book if pb.book is not None else book
        fn(pb, results, max(elapsed, 0.0))
        if pb.pair is not None:
            # this leg won the race: record the outcome and cancel the
            # loser (HedgePair.settle — the sole cancel performer)
            if pb.is_hedge:
                self.stats.hedge_wins += 1
            pb.pair.settle(pb)

    def harvest_ready(self, book: Optional[BookFn] = None) -> int:
        """Book every bucket whose launches all report ready — the
        non-blocking poll the event loop runs each step.  Harvests in
        FIFO order but stops at the first not-ready bucket only for
        ordering of *blocking* waits; ready buckets behind a slow one
        are still booked (out-of-order harvest)."""
        self._note_overlap()
        done = [pb for pb in self._pending if pb.ready()]
        for pb in done:
            if pb not in self._pending:
                # removed mid-loop: an earlier harvest settled a hedge
                # race and cancelled-and-discarded this leg already
                continue
            self._pending.remove(pb)
            self._harvest(pb, book, blocked=False)
            self.stats.ready_harvests += 1
        return len(done)

    # ---- fault-tolerance lifecycle (ISSUE 10) -------------------------
    def overdue(self, now: Optional[float] = None) -> List[PendingBucket]:
        """In-flight buckets past their deadline and still not landed —
        the hedge candidates.  Already-hedged legs and hedge duplicates
        themselves are excluded (one duplicate per bucket, ever)."""
        now = time.perf_counter() if now is None else now
        return [pb for pb in self._pending
                if pb.state == "DISPATCHED" and not pb.is_hedge
                and pb.deadline_s is not None
                and now - pb.t_dispatch > pb.deadline_s
                and not pb.ready()]

    def cancel(self, pb: PendingBucket) -> None:
        """Transition a losing hedge leg to CANCELLED and discard it as
        soon as its launches land.  Only ``HedgePair.settle`` may call
        this (enforced statically by analysis/protocol.py)."""
        sanitize.check_cancel(pb)
        pb.state = "CANCELLED"
        pb.not_ready_before = 0.0    # no point holding a discard
        if pb in self._pending and pb.dispatch.ready():
            self._pending.remove(pb)
            self._harvest(pb, None, blocked=False)

    def abandon(self) -> List[PendingBucket]:
        """A host died: every in-flight bucket on its queue transitions
        to LOST and is returned for ledger-driven re-dispatch.  The
        dispatches are never harvested — their results lived on the dead
        host.  Only ``TopologyBackend.kill_host`` may call this."""
        pending, self._pending = self._pending, []
        orphans: List[PendingBucket] = []
        for pb in pending:
            if pb.state == "CANCELLED":
                # a hedge loser awaiting discard: its winner already
                # booked the entries, so the host taking it down loses
                # nothing — count the discard and drop the handles
                self.stats.cancelled += 1
                continue
            sanitize.check_abandon(pb)
            pb.state = "LOST"
            pb.not_ready_before = 0.0
            orphans.append(pb)
        self.stats.lost += len(orphans)
        self._mark = None
        return orphans

    def harvest_next(self, book: Optional[BookFn] = None) -> bool:
        """Block for the oldest in-flight bucket (the drain has nothing
        left to dispatch); False if the queue is empty."""
        if not self._pending:
            return False
        self._note_overlap()
        self._harvest(self._pending.pop(0), book, blocked=True)
        return True

    def harvest_all(self, book: Optional[BookFn] = None) -> None:
        while self.harvest_next(book):
            pass
