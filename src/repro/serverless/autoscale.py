"""Occupancy-driven, cost-aware worker autoscaling (ISSUE 3, backend layer).

Replaces the static ``PoolConfig.worker_schedule`` with a policy that
sizes each wave from live signals the compiler already reports:

  * **queue depth** — pending invocations across every admitted request,
  * **bucket occupancy** — how full the next wave's B buckets would be
    (capacity beyond the queue burns padded lanes),
  * **padding waste** — the compiler's running B/N padding fraction,
    which inflates the effective per-lane work.

Each candidate worker count is priced through the paper's Lambda cost
model (serverless/cost.py): more workers drain the queue in fewer waves
(latency down) but bill more padded lane-seconds (cost up).  The policy
minimizes ``latency + cost_weight * GB-seconds`` — the same latency/cost
frontier as the paper's Figure 3 memory study, applied to pool width.
The decision is a pure function of the observed state, so a drain's
schedule is reproducible; and because per-task PRNG is fixed at compile
time, no schedule the autoscaler picks can move an estimate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, List

from repro.serverless.cost import speedup_of

if TYPE_CHECKING:                        # avoid backends <-> autoscale cycle
    from repro.serverless.backends import PoolConfig


@dataclass(frozen=True)
class AutoscaleDecision:
    """One wave-sizing decision plus the signals it was derived from."""
    n_workers: int
    capacity: int                       # n_workers * lanes_per_worker
    queue_depth: int                    # pending invocations observed
    est_waves: int
    est_occupancy: float                # depth / (waves * capacity)
    est_time_s: float                   # modeled drain latency
    est_gb_s: float                     # modeled billed GB-seconds
    padding_waste: float                # compiler signal used for pricing


class OccupancyAutoscaler:
    """Sizes the next wave of a continuous drain.

    Stateless apart from an EMA of measured invocation durations (used to
    price candidates when the pool is not in simulate mode).
    """

    def __init__(self, pool: "PoolConfig", *, cost_weight: float = None,
                 candidates: List[int] = None):
        self.pool = pool
        self.cost_weight = (pool.autoscale_cost_weight
                            if cost_weight is None else cost_weight)
        self._cands = candidates
        self._ema_inv_s = None          # measured per-invocation seconds
        self.decisions: List[AutoscaleDecision] = []

    # ------------------------------------------------------------------
    def observe(self, duration_s: float):
        """Feed a measured per-invocation duration (EMA, alpha=0.3)."""
        if duration_s <= 0:
            return
        if self._ema_inv_s is None:
            self._ema_inv_s = duration_s
        else:
            self._ema_inv_s = 0.7 * self._ema_inv_s + 0.3 * duration_s

    def _per_invocation_s(self, tasks_per_invocation: int) -> float:
        """Modeled duration of one invocation at the pool's memory."""
        pool = self.pool
        if pool.simulate and pool.base_work_s > 0:
            return pool.base_work_s * tasks_per_invocation \
                / speedup_of(pool.memory_mb)
        if self._ema_inv_s is not None:
            return self._ema_inv_s
        # no signal yet: a unit work model still ranks candidates correctly
        return 1.0 / speedup_of(pool.memory_mb)

    def _candidates(self) -> List[int]:
        if self._cands is not None:
            return self._cands
        pool = self.pool
        out, w = [], max(1, pool.min_workers)
        while w < pool.max_workers:
            out.append(w)
            w *= 2
        out.append(pool.max_workers)
        return out

    # ------------------------------------------------------------------
    def decide(self, queue_depth: int, *, tasks_per_invocation: int = 1,
               padding_waste: float = 0.0) -> AutoscaleDecision:
        """Pick the worker count for the next wave given the live queue."""
        pool = self.pool
        lanes = pool.lanes_per_worker()
        depth = max(int(queue_depth), 1)
        per_inv = self._per_invocation_s(tasks_per_invocation)
        # padded lanes do real work under wave-capacity-aligned B buckets
        per_lane = per_inv * (1.0 + max(0.0, min(padding_waste, 1.0)))

        best = None
        for w in self._candidates():
            cap = max(1, w * lanes)
            waves = -(-depth // cap)                    # ceil
            occupancy = depth / (waves * cap)
            time_s = waves * (per_inv + pool.dispatch_overhead_s)
            # real invocations bill their (padding-inflated) lane-seconds;
            # idle lanes in the final partial wave still hold worker slots
            # for half a wave on average — the over-provisioning cost
            idle_lanes = waves * cap - depth
            gb_s = (depth * per_lane + idle_lanes * per_inv * 0.5) \
                * pool.memory_mb / 1024.0
            score = time_s + self.cost_weight * gb_s
            cand = AutoscaleDecision(
                n_workers=w, capacity=cap, queue_depth=depth,
                est_waves=waves, est_occupancy=occupancy,
                est_time_s=time_s, est_gb_s=gb_s,
                padding_waste=padding_waste)
            if best is None or score < best[0] - 1e-12 or \
                    (abs(score - best[0]) <= 1e-12
                     and w < best[1].n_workers):
                best = (score, cand)
        self.decisions.append(best[1])
        return best[1]
