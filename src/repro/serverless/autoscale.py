"""Occupancy-driven, cost-aware worker autoscaling (ISSUE 3 backend
layer; topology-aware + roofline-priced since ISSUE 4).

Replaces the static ``PoolConfig.worker_schedule`` with a policy that
sizes each wave from live signals the compiler already reports:

  * **queue depth** — pending invocations across every admitted request,
  * **bucket occupancy** — how full the next wave's B buckets would be
    (capacity beyond the queue burns padded lanes),
  * **padding waste** — the compiler's running B/N padding fraction,
    which inflates the effective per-lane work.

Each candidate worker count is priced through the paper's Lambda cost
model (serverless/cost.py): more workers drain the queue in fewer waves
(latency down) but bill more padded lane-seconds (cost up).  The policy
minimizes ``latency + cost_weight * GB-seconds`` — the same latency/cost
frontier as the paper's Figure 3 memory study, applied to pool width.
The decision is a pure function of the observed state, so a drain's
schedule is reproducible; and because per-task PRNG is fixed at compile
time, no schedule the autoscaler picks can move an estimate.

Candidate pricing resolves in order of signal quality: the simulate-mode
work model, then the EMA of *measured* invocation durations, then — new
in ISSUE 4 — the compiler's **roofline estimate** for the pending
buckets (``launch/roofline.py::invocation_roofline_s``, derived from
each bucket's per-task FLOP count), and only then the unit-work
fallback.  Every decision records which source priced it and the full
per-candidate cost table, so the first wave of a cold drain is already
cost-reasoned instead of unit-guessed (ROADMAP "autoscaler signals").

``TopologyAutoscaler`` sizes each host mesh's wave independently — one
``OccupancyAutoscaler`` per host stream, each fed only its host's queue.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, List, Tuple

from repro.serverless.cost import speedup_of

if TYPE_CHECKING:                        # avoid backends <-> autoscale cycle
    from repro.serverless.backends import PoolConfig


@dataclass(frozen=True)
class AutoscaleDecision:
    """One wave-sizing decision plus the signals it was derived from."""
    n_workers: int
    capacity: int                       # n_workers * lanes_per_worker
    queue_depth: int                    # pending invocations observed
    est_waves: int
    est_occupancy: float                # (depth + in_flight)/(waves * cap)
    est_time_s: float                   # modeled drain latency
    est_gb_s: float                     # modeled billed GB-seconds
    padding_waste: float                # compiler signal used for pricing
    priced_by: str = "unit"             # simulate | ema | roofline | unit
    host: int = -1                      # host stream (-1: single-stream)
    # the full candidate table this decision was picked from:
    # (n_workers, est_time_s, est_gb_s, score) per candidate
    candidate_costs: Tuple[Tuple[int, float, float, float], ...] = ()
    # dispatched-but-unharvested invocations at decision time: occupancy,
    # NOT queue depth — in-flight work is already placed on a device, so
    # sizing for it again would double-provision the pool
    in_flight: int = 0


class OccupancyAutoscaler:
    """Sizes the next wave of a continuous drain.

    Stateless apart from an EMA of measured invocation durations (used to
    price candidates when the pool is not in simulate mode).
    """

    def __init__(self, pool: "PoolConfig", *, cost_weight: float = None,
                 candidates: List[int] = None, host: int = -1):
        self.pool = pool
        self.host = host
        self.cost_weight = (pool.autoscale_cost_weight
                            if cost_weight is None else cost_weight)
        self._cands = candidates
        self._ema_inv_s = None          # measured per-invocation seconds
        self.decisions: List[AutoscaleDecision] = []

    # ------------------------------------------------------------------
    def observe(self, duration_s: float):
        """Feed a measured per-invocation duration (EMA, alpha=0.3)."""
        if duration_s <= 0:
            return
        if self._ema_inv_s is None:
            self._ema_inv_s = duration_s
        else:
            self._ema_inv_s = 0.7 * self._ema_inv_s + 0.3 * duration_s

    def _per_invocation_s(self, tasks_per_invocation: int,
                          roofline_inv_s) -> Tuple[float, str]:
        """Modeled duration of one invocation and the signal that priced
        it: simulate-mode work model > measured EMA > roofline > unit.
        ``roofline_inv_s`` may be a float or a zero-argument thunk — the
        thunk is only invoked when the higher-priority signals are
        absent, so callers can pass it unconditionally and the pricing
        priority lives in exactly one place."""
        pool = self.pool
        if pool.simulate and pool.base_work_s > 0:
            return (pool.base_work_s * tasks_per_invocation
                    / speedup_of(pool.memory_mb), "simulate")
        if self._ema_inv_s is not None:
            return self._ema_inv_s, "ema"
        if callable(roofline_inv_s):
            roofline_inv_s = roofline_inv_s()
        if roofline_inv_s is not None and roofline_inv_s > 0:
            return roofline_inv_s, "roofline"
        # no signal at all: a unit work model still ranks candidates
        return 1.0 / speedup_of(pool.memory_mb), "unit"

    def _candidates(self) -> List[int]:
        if self._cands is not None:
            return self._cands
        pool = self.pool
        out, w = [], max(1, pool.min_workers)
        while w < pool.max_workers:
            out.append(w)
            w *= 2
        out.append(pool.max_workers)
        return out

    # ------------------------------------------------------------------
    def decide(self, queue_depth: int, *, tasks_per_invocation: int = 1,
               padding_waste: float = 0.0, in_flight: int = 0,
               roofline_inv_s=None) -> AutoscaleDecision:
        """Pick the worker count for the next wave given the live queue.

        ``queue_depth`` must count only dispatchable work; ``in_flight``
        is the dispatched-but-unharvested invocation count of the
        caller's queue (non-blocking dispatch).  In-flight work raises
        the recorded occupancy but never the worker count — it already
        holds device capacity, and sizing for it again would
        double-provision the pool.  ``roofline_inv_s``: float or lazy
        thunk (see _per_invocation_s)."""
        pool = self.pool
        lanes = pool.lanes_per_worker()
        depth = max(int(queue_depth), 1)
        in_flight = max(int(in_flight), 0)
        per_inv, priced_by = self._per_invocation_s(tasks_per_invocation,
                                                    roofline_inv_s)
        # padded lanes do real work under wave-capacity-aligned B buckets
        per_lane = per_inv * (1.0 + max(0.0, min(padding_waste, 1.0)))

        best = None
        table: List[Tuple[int, float, float, float]] = []
        for w in self._candidates():
            cap = max(1, w * lanes)
            waves = -(-depth // cap)                    # ceil
            occupancy = (depth + in_flight) / (waves * cap)
            time_s = waves * (per_inv + pool.dispatch_overhead_s)
            # real invocations bill their (padding-inflated) lane-seconds;
            # idle lanes in the final partial wave still hold worker slots
            # for half a wave on average — the over-provisioning cost
            idle_lanes = waves * cap - depth
            gb_s = (depth * per_lane + idle_lanes * per_inv * 0.5) \
                * pool.memory_mb / 1024.0
            score = time_s + self.cost_weight * gb_s
            table.append((w, time_s, gb_s, score))
            cand = (w, cap, waves, occupancy, time_s, gb_s)
            if best is None or score < best[0] - 1e-12 or \
                    (abs(score - best[0]) <= 1e-12 and w < best[1][0]):
                best = (score, cand)
        w, cap, waves, occupancy, time_s, gb_s = best[1]
        decision = AutoscaleDecision(
            n_workers=w, capacity=cap, queue_depth=depth,
            est_waves=waves, est_occupancy=occupancy,
            est_time_s=time_s, est_gb_s=gb_s,
            padding_waste=padding_waste, priced_by=priced_by,
            host=self.host, candidate_costs=tuple(table),
            in_flight=in_flight)
        self.decisions.append(decision)
        return decision


class TopologyAutoscaler:
    """Per-mesh wave sizing: one ``OccupancyAutoscaler`` per host stream,
    each deciding from its own queue depth and feeding its own measured
    EMA — host meshes scale independently (a hot host widens its waves
    while an idle one stays narrow), exactly the elasticity-per-worker
    lever the paper's serverless pool has per lambda."""

    def __init__(self, pool: "PoolConfig", n_hosts: int):
        self.scalers: Dict[int, OccupancyAutoscaler] = {
            h: OccupancyAutoscaler(pool, host=h) for h in range(n_hosts)}

    def decide(self, host: int, queue_depth: int, *,
               tasks_per_invocation: int = 1, padding_waste: float = 0.0,
               in_flight: int = 0, roofline_inv_s=None) -> AutoscaleDecision:
        return self.scalers[host].decide(
            queue_depth, tasks_per_invocation=tasks_per_invocation,
            padding_waste=padding_waste, in_flight=in_flight,
            roofline_inv_s=roofline_inv_s)

    def observe(self, host: int, duration_s: float):
        self.scalers[host].observe(duration_s)

    @property
    def decisions(self) -> List[AutoscaleDecision]:
        out: List[AutoscaleDecision] = []
        for h in sorted(self.scalers):
            out.extend(self.scalers[h].decisions)
        return out
