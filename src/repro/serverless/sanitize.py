"""Opt-in runtime sanitizer for the async drain protocol.

``REPRO_SANITIZE=1`` arms cheap invariant checks at the protocol's
choke points — the live counterpart of the static checker
(``repro/analysis/protocol.py``), driven by the SAME transition table so
the two cannot drift apart:

  * a bucket dispatch is harvested exactly once (a second harvest would
    re-book rows and double-bill the wave);
  * booking only lands on rows in a legal source state per
    ``LEDGER_TRANSITIONS`` (a DONE row being re-booked means a
    lost race or a double-harvest);
  * the duration-attribution frontier only moves forward (overlapping
    attribution double-charges GB-seconds and skews the autoscaler EMA);
  * every pushed bucket carries its booking continuation (book-at-push:
    under pipelined dispatch a bucket may land waves after it was
    pushed, so a missing continuation is work that would harvest into
    the void);
  * a drain never retires with buckets still in flight OR a pipelined
    wave still unsettled (a lost bucket/wave is work billed but never
    booked);
  * bucket lifecycle transitions (hedge, cancel, abandon, book) only
    leave legal source states per ``BUCKET_TRANSITIONS`` — a
    double-hedge, a cancel of an already-cancelled leg, or a booking of
    a CANCELLED/LOST bucket each raise at the transition site.

Checks are no-ops unless the environment variable is set — it is read
per call so a test can flip it with ``monkeypatch.setenv``.  CI runs the
tier-1 async/topology suites with the sanitizer armed (job ``sanitize``
in .github/workflows/ci.yml).
"""
from __future__ import annotations

import os

import numpy as np

from repro.analysis.protocol import (BUCKET_TRANSITIONS, INVOCATION_STATES,
                                     LEDGER_TRANSITIONS)


class ProtocolError(AssertionError):
    """An async-protocol invariant was violated at runtime."""


def enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


_STATE_NAME = {v: k for k, v in INVOCATION_STATES.items()}


def check_harvest_once(dispatch) -> None:
    """Arm-once flag on a BucketDispatch: a second ``harvest()`` of the
    same in-flight bucket raises (it would re-book every entry)."""
    if not enabled():
        return
    if getattr(dispatch, "_sanitize_harvested", False):
        raise ProtocolError(
            f"bucket {dispatch.key} harvested twice — a dispatch is "
            "booked exactly once; a second harvest re-books its rows")
    dispatch._sanitize_harvested = True


def check_booking(ledger, invs, method: str) -> None:
    """Rows being booked must be in a legal source state for ``method``
    per the protocol table (RUNNING, or PENDING on the resume path)."""
    if not enabled():
        return
    legal = {INVOCATION_STATES[s] for s in LEDGER_TRANSITIONS[method][0]}
    invs_arr = np.atleast_1d(np.asarray(invs, np.int64))
    status = np.asarray(ledger.status)[invs_arr]
    bad = invs_arr[~np.isin(status, list(legal))]
    if bad.size:
        states = sorted({_STATE_NAME[int(s)]
                         for s in np.asarray(ledger.status)[bad]})
        raise ProtocolError(
            f"{method} on invocations {bad.tolist()} in state(s) "
            f"{states} — legal sources are "
            f"{list(LEDGER_TRANSITIONS[method][0])}; a DONE row being "
            "re-booked means a double-harvest or lost race")


def check_attribution(t_harvest: float, t_frontier: float) -> None:
    """The non-overlapping duration-attribution frontier is monotone:
    booking a harvest behind the frontier would double-charge the span
    already attributed to an earlier harvest."""
    if not enabled():
        return
    if t_harvest < t_frontier:
        raise ProtocolError(
            f"harvest attribution frontier moved backwards "
            f"({t_harvest:.6f} < {t_frontier:.6f}) — concurrent buckets "
            "would be billed overlapping wall-clock spans")


def check_book_at_push(pb) -> None:
    """Every bucket entering a dispatch queue must carry its booking
    continuation (``PendingBucket.book``) — under pipelined dispatch the
    harvest may happen waves later, with no caller left to supply one."""
    if not enabled():
        return
    if pb.book is None:
        raise ProtocolError(
            f"bucket {pb.key} pushed without a booking continuation — "
            "book-at-push is required: a deferred harvest has no caller "
            "context to book against")


def _check_bucket_transition(pb, action: str) -> None:
    """Shared driver: ``pb.state`` must be a legal source of ``action``
    per the protocol's BUCKET_TRANSITIONS table."""
    legal = BUCKET_TRANSITIONS[action][0]
    if pb.state not in legal:
        raise ProtocolError(
            f"{action} on bucket {pb.key} in state {pb.state} — legal "
            f"sources are {list(legal)}")


def check_hedge(pb) -> None:
    """A bucket is hedged at most once, and only while plainly
    DISPATCHED — hedging a HEDGED bucket would launch a third leg the
    settle logic doesn't know about; hedging a CANCELLED/LOST one
    duplicates work that is already accounted elsewhere."""
    if not enabled():
        return
    _check_bucket_transition(pb, "hedge")


def check_cancel(pb) -> None:
    """Only a live racing leg (DISPATCHED duplicate or HEDGED original)
    may be cancelled.  Cancelling a CANCELLED leg means two settle
    sites fired; cancelling a HARVESTED one means the race was settled
    after its loser already booked — both are double-performer bugs."""
    if not enabled():
        return
    _check_bucket_transition(pb, "cancel")


def check_abandon(pb) -> None:
    """Host-loss recovery may only orphan in-flight (DISPATCHED/HEDGED)
    buckets — a HARVESTED or CANCELLED bucket reaching abandon means the
    queue's bookkeeping already retired it once."""
    if not enabled():
        return
    _check_bucket_transition(pb, "abandon")


def check_bucket_bookable(pb) -> None:
    """A bucket being harvested-for-booking must be a live leg
    (DISPATCHED or HEDGED).  Booking a CANCELLED bucket means a losing
    hedge leg's results are entering the ledger alongside the winner's —
    double-booking; booking a LOST one means a dead host's handles were
    harvested."""
    if not enabled():
        return
    _check_bucket_transition(pb, "harvest")


def check_drained(state, where: str) -> None:
    """A drain may only retire with every dispatch queue empty and every
    pipelined wave settled — an in-flight bucket or unsettled wave left
    behind is work billed but never booked."""
    if not enabled():
        return
    n = 0
    q = getattr(state, "queue", None)
    if q is not None:
        n += len(q)
    for hq in getattr(state, "queues", {}).values():
        n += len(hq)
    if n:
        raise ProtocolError(
            f"{where}: drain retiring with {n} bucket(s) still in "
            "flight — every dispatched bucket must be harvested and "
            "booked before the state is dropped")
    waves = getattr(state, "waves_inflight", None)
    if waves:
        raise ProtocolError(
            f"{where}: drain retiring with {len(waves)} pipelined "
            "wave(s) unsettled — every dispatched wave must settle "
            "(book + bill) before the state is dropped")
