"""The pluggable execution layer: one task grid, three substrates.

A ``WorkRequest`` is the compiled form of one estimation request: the task
grid, the fused arrays (targets, training weights), one or more
``Segment``s (contiguous learner groups — mixed-learner grids such as IRM
carry one segment per distinct learner), and a durable ``TaskLedger``.

An ``ExecutionBackend`` consumes a *batch* of WorkRequests and fills their
ledgers.  All backends emit the same ``RunReport``/``TaskLedger``
artifacts, so fault tolerance, billing, and resume behave identically at
the API layer regardless of substrate:

  WaveBackend     the serverless-analogue wave scheduler (paper §4):
                  capacity-limited waves, fault injection + retries,
                  straggler speculation, elastic worker schedules, Lambda
                  billing.  Waves are SHARED across requests — many
                  concurrent estimations ride the same dispatch cycles
                  (the batch-processing cost lever).
  ShardedBackend  one SPMD program per segment: the task grid laid over a
                  jax Mesh via shard_map (launch/mesh.py), tasks sharded
                  over the "data" axis, x replicated.
  InlineBackend   single fused vmap call per segment — the pure reference
                  implementation tests compare against.

Determinism contract: a task's prediction depends only on (x, target,
weights, learner) for deterministic learners, so every backend — and every
wave composition, fault pattern, or shard count — produces identical
predictions.  Key-consuming learners (mlp) are reproducible per backend
but not bit-identical across backends.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import (
    TYPE_CHECKING, Callable, Dict, List, Optional, Protocol, Sequence, Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.serverless.cost import Bill, BillingRecord, speedup_of
from repro.serverless.ledger import DONE, TaskLedger

if TYPE_CHECKING:       # avoid the core <-> serverless import cycle
    from repro.core.crossfit import TaskGrid


# ---------------------------------------------------------------------------
# substrate configuration (immutable — plans/sessions share PoolConfigs)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PoolConfig:
    """The knobs the paper's user controls (§4.2, §5.2).

    Frozen: reusing one PoolConfig across estimators/sessions must never
    let one caller's settings leak into another's (use
    ``dataclasses.replace`` to derive variants).
    """
    n_workers: int = 8                  # concurrent lambda-analogue workers
    memory_mb: int = 1024               # Lambda memory knob
    scaling: str = "n_rep"              # paper's scaling parameter
    timeout_s: float = 900.0            # Lambda 15-min cap
    max_retries: int = 3
    failure_rate: float = 0.0           # fault injection (per invocation)
    straggler_rate: float = 0.0         # P(invocation is a straggler)
    straggler_slowdown: float = 4.0
    speculative_after: float = 2.0      # duplicate if > x median duration
    simulate: bool = False              # model durations via the speed curve
    base_work_s: float = 0.0            # simulated seconds per task @1 vCPU
    dispatch_overhead_s: float = 0.005  # per-wave dispatch latency
    seed: int = 0
    checkpoint_path: Optional[str] = None
    # elasticity: optional schedule of worker counts per wave (grow/shrink)
    worker_schedule: Optional[Sequence[int]] = None

    def lanes_per_worker(self) -> int:
        """Worker 'memory' buys lane width (DESIGN.md §2 mapping)."""
        return max(1, self.memory_mb // 256)


@dataclass
class RunReport:
    fit_time_s: float = 0.0
    response_time_s: float = 0.0
    waves: int = 0
    bill: Bill = field(default_factory=Bill)
    wave_sizes: List[int] = field(default_factory=list)
    failures: int = 0
    stragglers: int = 0

    def summary(self) -> Dict:
        out = {"fit_time_s": self.fit_time_s,
               "response_time_s": self.response_time_s,
               "waves": self.waves, "failures": self.failures,
               "stragglers": self.stragglers}
        out.update(self.bill.summary())
        return out


# ---------------------------------------------------------------------------
# the unit of execution
# ---------------------------------------------------------------------------
@dataclass
class Segment:
    """A learner-uniform slice of a request's grid.

    ``l_ids`` are the nuisance indices this segment owns; its invocations
    are exactly those with ``inv % L in l_ids`` (both scaling levels place
    l in the low digit of the invocation id).  ``cache_key`` is a hashable
    identity of (learner, params) — requests built from equal specs share
    warm compiled programs; when absent, backends fall back to object
    identity.
    """
    learner_fn: Callable
    l_ids: Tuple[int, ...]
    key: jax.Array
    cache_key: Optional[Tuple] = None


@dataclass
class WorkRequest:
    """One estimation request, compiled to arrays + a durable ledger."""
    grid: TaskGrid
    scaling: str                        # invocation granularity (§4.2)
    x: jnp.ndarray                      # (N, P)
    targets: np.ndarray                 # (L, N)
    train_w: np.ndarray                 # (M, K, L, N)
    segments: List[Segment]
    ledger: TaskLedger
    report: RunReport
    tag: object = None                  # caller's request id
    fold_masks: Optional[np.ndarray] = None   # (M,K,N), set by the compiler

    @classmethod
    def create(cls, grid: TaskGrid, scaling: str, x, targets, train_w,
               segments: List[Segment],
               ledger: Optional[TaskLedger] = None,
               report: Optional[RunReport] = None,
               tag: object = None) -> "WorkRequest":
        n_obs = int(np.asarray(targets).shape[-1])
        n_inv = grid.n_invocations(scaling)
        tpi = grid.tasks_per_invocation(scaling)
        if ledger is None:
            ledger = TaskLedger.create(n_inv, n_obs, tpi)
        elif (ledger.n_invocations, ledger.tasks_per_invocation,
              ledger.n_obs) != (n_inv, tpi, n_obs):
            raise ValueError(
                f"ledger shape ({ledger.n_invocations}, "
                f"{ledger.tasks_per_invocation}, {ledger.n_obs}) does not "
                f"match grid/scaling/data ({n_inv}, {tpi}, {n_obs}) — was it "
                "saved under a different plan?")
        return cls(grid=grid, scaling=scaling, x=jnp.asarray(x),
                   targets=np.asarray(targets), train_w=np.asarray(train_w),
                   segments=segments, ledger=ledger,
                   report=report or RunReport(), tag=tag)

    # ---- derived index maps (cached) ------------------------------------
    def _index_maps(self):
        if not hasattr(self, "_maps"):
            g = self.grid
            task_mat = g.invocation_task_ids(
                np.arange(g.n_invocations(self.scaling)), self.scaling)
            tm, tk, tl = g.task_coords()
            seg_of_l = np.zeros(g.n_nuisance, np.int64)
            for si, seg in enumerate(self.segments):
                for l in seg.l_ids:
                    seg_of_l[l] = si
            self._maps = (task_mat, tm, tk, tl, seg_of_l)
        return self._maps

    def segment_of_inv(self, inv: np.ndarray) -> np.ndarray:
        _, _, _, _, seg_of_l = self._index_maps()
        return seg_of_l[np.asarray(inv) % self.grid.n_nuisance]

    def wave_arrays(self, flat_tasks: np.ndarray):
        """Gather (targets, weights) rows for flat task ids."""
        _, tm, tk, tl = self._index_maps()[:4]
        y = self.targets[tl[flat_tasks]]
        w = self.train_w[tm[flat_tasks], tk[flat_tasks], tl[flat_tasks]]
        return y, w

    def gathered_preds(self) -> np.ndarray:
        """Scatter ledger rows back to the (M, K, L, N) tensor."""
        g = self.grid
        task_mat, tm, tk, tl, _ = self._index_maps()
        flat = task_mat.reshape(-1)
        n_obs = self.ledger.n_obs
        out = np.zeros((g.n_rep, g.n_folds, g.n_nuisance, n_obs), np.float32)
        out[tm[flat], tk[flat], tl[flat]] = \
            self.ledger.preds.reshape(-1, n_obs)
        return out


class ExecutionBackend(Protocol):
    """Anything that can drain a batch of WorkRequests.

    Contract: after ``run_requests`` returns, every request's ledger is
    complete (or an exception was raised), its report reflects the work
    performed in this call (appending to any prior state), and
    ``req.gathered_preds()`` yields the (M, K, L, N) prediction tensor.
    Pre-completed ledger rows (resume) must not be re-executed.
    """
    name: str

    def run_requests(self, requests: Sequence[WorkRequest]) -> "BackendRunInfo":
        ...


@dataclass
class BackendRunInfo:
    """Cross-request accounting for one backend drain (session telemetry)."""
    backend: str
    waves: int = 0
    wave_members: List[List[object]] = field(default_factory=list)

    @property
    def shared_waves(self) -> int:
        """Waves that carried invocations from 2+ requests — the fusion
        the multi-request session exists to create.  (Members lists are
        deduplicated at construction.)"""
        return sum(1 for m in self.wave_members if len(m) > 1)


# ---------------------------------------------------------------------------
# helpers shared by backends
# ---------------------------------------------------------------------------
def _fill_rows(req: WorkRequest, inv_ids: np.ndarray, wall: float,
               pool: PoolConfig):
    """Record successful rows with measured billing (non-wave backends)."""
    per = wall / max(len(inv_ids), 1)
    for inv in inv_ids:
        req.report.bill.add(BillingRecord(
            invocation=int(inv), duration_s=per, memory_mb=pool.memory_mb))


def _run_segment_pending(req: WorkRequest, call, pool: PoolConfig):
    """Drive every pending invocation of ``req`` through ``call`` — one
    fused evaluation per segment.  ``call(req, seg, y, w, key) ->
    (B*tpi, N)``.  Shared by Inline and Sharded backends (they differ only
    in how the fused call executes)."""
    pending = req.ledger.pending()
    if not len(pending):
        return
    task_mat = req._index_maps()[0]
    tpi = req.grid.tasks_per_invocation(req.scaling)
    n_obs = req.ledger.n_obs
    seg_idx = req.segment_of_inv(pending)
    t_all = time.perf_counter()
    for si, seg in enumerate(req.segments):
        inv_ids = pending[seg_idx == si]
        if not len(inv_ids):
            continue
        flat = task_mat[inv_ids].reshape(-1)
        y, w = req.wave_arrays(flat)
        seg.key, sub = jax.random.split(seg.key)
        t0 = time.perf_counter()
        preds = call(req, seg, jnp.asarray(y), jnp.asarray(w), sub)
        preds = np.asarray(jax.block_until_ready(preds), np.float32)
        wall = time.perf_counter() - t0
        preds = preds.reshape(len(inv_ids), tpi, n_obs)
        for i, inv in enumerate(inv_ids):
            req.ledger.record_success(int(inv), preds[i])
        _fill_rows(req, inv_ids, wall, pool)
        req.report.waves += 1
        req.report.wave_sizes.append(len(inv_ids))
    total = time.perf_counter() - t_all
    req.report.fit_time_s += total
    req.report.response_time_s += total
    if pool.checkpoint_path:
        req.ledger.save(pool.checkpoint_path)


# ---------------------------------------------------------------------------
# InlineBackend — pure fused-vmap reference
# ---------------------------------------------------------------------------
class InlineBackend:
    """The whole pending grid in one fused call per segment.  No faults,
    no waves, no capacity limit: the oracle the other backends must
    agree with."""
    name = "inline"

    def __init__(self, pool: Optional[PoolConfig] = None):
        self.pool = pool or PoolConfig()

    def run_requests(self, requests: Sequence[WorkRequest]) -> BackendRunInfo:
        info = BackendRunInfo(backend=self.name)
        for req in requests:
            _run_segment_pending(
                req,
                lambda r, seg, y, w, key: seg.learner_fn(r.x, y, w, key),
                self.pool)
            info.waves += req.report.waves
        return info


# ---------------------------------------------------------------------------
# ShardedBackend — SPMD over a device mesh
# ---------------------------------------------------------------------------
class ShardedBackend:
    """The task grid as one SPMD program: tasks sharded over the mesh's
    "data" axis via shard_map, x replicated on every device.  Reuses
    launch/mesh.py meshes; stays warm across requests (jitted programs are
    cached per learner)."""
    name = "sharded"

    def __init__(self, pool: Optional[PoolConfig] = None, mesh=None):
        self.pool = pool or PoolConfig()
        self._mesh = mesh
        self._programs: Dict[object, Callable] = {}

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_host_mesh
            self._mesh = make_host_mesh()
        return self._mesh

    def _n_shards(self) -> int:
        return int(self.mesh.shape["data"])

    def _program(self, seg: Segment) -> Callable:
        key = seg.cache_key if seg.cache_key is not None \
            else id(seg.learner_fn)
        prog = self._programs.get(key)
        if prog is None:
            from jax.sharding import PartitionSpec as P
            from repro.sharding.compat import shard_map_compat
            fn = seg.learner_fn

            def shard_fn(x, y, w, key_data):
                return fn(x, y, w, jax.random.wrap_key_data(key_data))

            prog = jax.jit(shard_map_compat(
                shard_fn, mesh=self.mesh,
                in_specs=(P(), P("data"), P("data"), P()),
                out_specs=P("data")))
            self._programs[key] = prog
        return prog

    def run_requests(self, requests: Sequence[WorkRequest]) -> BackendRunInfo:
        info = BackendRunInfo(backend=self.name)
        n_shards = self._n_shards()

        def call(req, seg, y, w, key):
            # pad the task axis to the shard count (zero-weight rows are
            # inert: the learners reduce them to the regularizer solution)
            t = y.shape[0]
            t_pad = ((t + n_shards - 1) // n_shards) * n_shards
            if t_pad != t:
                y = jnp.pad(y, ((0, t_pad - t), (0, 0)))
                w = jnp.pad(w, ((0, t_pad - t), (0, 0)))
            out = self._program(seg)(req.x, y, w, jax.random.key_data(key))
            return out[:t]

        for req in requests:
            _run_segment_pending(req, call, self.pool)
            info.waves += req.report.waves
        return info


# ---------------------------------------------------------------------------
# WaveBackend — the serverless-analogue scheduler, multi-request
# ---------------------------------------------------------------------------
@dataclass
class _Entry:
    """One dispatched lane: (request, invocation, speculative?)."""
    req_idx: int
    inv: int
    speculative: bool = False


class WaveBackend:
    """The paper's wave scheduler (§4) generalized to many requests.

    One *invocation* = the paper's lambda call; each wave dispatches up to
    ``n_workers * lanes_per_worker`` invocations drawn round-robin from
    every request's pending set, so concurrent estimations share dispatch
    cycles (fused waves).  Per wave the scheduler:

      * injects faults (per-request Philox streams) and re-queues failures
        (Lambda retry, first-attempt only so retries converge),
      * duplicates straggler suspects when capacity is spare (speculative
        execution, first-result-wins),
      * re-reads the worker count (elastic shrink/grow),
      * checkpoints every participating ledger.

    Billing: measured (wall time of a request's fused call divided over its
    lanes) or modeled via the Lambda memory/vCPU curve (simulate=True).
    """
    name = "wave"

    def __init__(self, pool: Optional[PoolConfig] = None):
        self.pool = pool or PoolConfig()

    def run_requests(self, requests: Sequence[WorkRequest]) -> BackendRunInfo:
        pool = self.pool
        info = BackendRunInfo(backend=self.name)
        # per-request fault streams: request 0 reproduces the single-request
        # executor draw-for-draw
        rngs = [np.random.Generator(np.random.Philox(key=pool.seed + i))
                for i in range(len(requests))]
        t_start = time.perf_counter()
        wave = 0
        while True:
            pendings = [req.ledger.pending() for req in requests]
            if all(len(p) == 0 for p in pendings):
                break
            n_workers = pool.n_workers
            if pool.worker_schedule is not None:
                n_workers = pool.worker_schedule[
                    min(wave, len(pool.worker_schedule) - 1)]
            capacity = max(1, n_workers * pool.lanes_per_worker())

            # ---- fill the wave: round-robin across requests -------------
            batch: List[_Entry] = []
            cursors = [0] * len(requests)
            while len(batch) < capacity:
                progressed = False
                for ri, p in enumerate(pendings):
                    if cursors[ri] < len(p) and len(batch) < capacity:
                        batch.append(_Entry(ri, int(p[cursors[ri]])))
                        cursors[ri] += 1
                        progressed = True
                if not progressed:
                    break
            spare = capacity - len(batch)
            dispatch = list(batch)
            if spare > 0 and pool.straggler_rate > 0 and batch:
                dispatch += [_Entry(e.req_idx, e.inv, True)
                             for e in batch[:min(spare, len(batch))]]

            # ---- execute: one fused call per (request, segment) ---------
            members: List[object] = []
            for e in dispatch:
                tag = requests[e.req_idx].tag
                tag = e.req_idx if tag is None else tag
                if tag not in members:
                    members.append(tag)
            info.wave_members.append(members)
            for ri, req in enumerate(requests):
                entries = [e for e in dispatch if e.req_idx == ri]
                if not entries:
                    continue
                self._run_request_wave(req, entries, rngs[ri], pool, wave)
            wave += 1
            info.waves = wave
            if pool.checkpoint_path:
                for i, req in enumerate(requests):
                    path = pool.checkpoint_path if len(requests) == 1 \
                        else f"{pool.checkpoint_path}.r{i}"
                    req.ledger.save(path)

        total_wall = time.perf_counter() - t_start
        for req in requests:
            if not pool.simulate:
                # accumulate (like the other backends) so an abort-and-
                # resume report covers every drain that fed its bill
                req.report.response_time_s += total_wall
                req.report.fit_time_s += total_wall
            else:
                req.report.fit_time_s = (req.report.response_time_s
                                         + pool.dispatch_overhead_s)
        return info

    # ------------------------------------------------------------------
    def _run_request_wave(self, req: WorkRequest, entries: List[_Entry],
                          rng, pool: PoolConfig, wave: int):
        """Dispatch one request's share of a wave and book the results."""
        task_mat = req._index_maps()[0]
        tpi = req.grid.tasks_per_invocation(req.scaling)
        n_obs = req.ledger.n_obs
        ledger, report = req.ledger, req.report
        inv_arr = np.array([e.inv for e in entries], np.int64)
        seg_idx = req.segment_of_inv(inv_arr)

        preds_rows = np.empty((len(entries), tpi, n_obs), np.float32)
        wall = 0.0
        for si, seg in enumerate(req.segments):
            sel = np.where(seg_idx == si)[0]
            if not len(sel):
                continue
            flat = task_mat[inv_arr[sel]].reshape(-1)
            y, w = req.wave_arrays(flat)
            seg.key, sub = jax.random.split(seg.key)
            t0 = time.perf_counter()
            preds = seg.learner_fn(req.x, jnp.asarray(y), jnp.asarray(w), sub)
            preds = np.asarray(jax.block_until_ready(preds), np.float32)
            wall += time.perf_counter() - t0
            preds_rows[sel] = preds.reshape(len(sel), tpi, n_obs)

        # --- per-invocation durations (measured or simulated) ------------
        if pool.simulate:
            base = pool.base_work_s * tpi / speedup_of(pool.memory_mb)
            noise = rng.lognormal(0.0, 0.08, len(entries))
            durs = base * noise
        else:
            durs = np.full(len(entries), wall / max(len(entries), 1))
        is_strag = rng.random(len(entries)) < pool.straggler_rate
        durs = np.where(is_strag, durs * pool.straggler_slowdown, durs)
        report.stragglers += int(is_strag.sum())
        # fault injection (first-attempt only so retries converge)
        first_try = ledger.attempts[inv_arr] == 0
        failed = (rng.random(len(entries)) < pool.failure_rate) & first_try
        failed |= durs > pool.timeout_s                   # lambda timeout cap

        for i, e in enumerate(entries):
            if ledger.status[e.inv] == DONE:   # speculative lost the race
                continue
            if failed[i]:
                if ledger.attempts[e.inv] >= pool.max_retries:
                    raise RuntimeError(
                        f"invocation {e.inv} exceeded retry budget")
                ledger.record_failure(e.inv)
                report.failures += 1
                continue
            ledger.record_success(int(e.inv), preds_rows[i])
            report.bill.add(BillingRecord(
                invocation=int(e.inv), duration_s=float(durs[i]),
                memory_mb=pool.memory_mb,
                retry=int(ledger.attempts[e.inv]),
                speculative=e.speculative))
        report.wave_sizes.append(len(entries))
        report.waves += 1
        if pool.simulate:
            # response time = slowest invocation in flight this wave
            report.response_time_s += float(np.max(durs)) \
                + pool.dispatch_overhead_s


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
BACKENDS = {"wave": WaveBackend, "inline": InlineBackend,
            "sharded": ShardedBackend}
BACKEND_NAMES = tuple(BACKENDS)


def make_backend(backend, pool: Optional[PoolConfig] = None):
    """Resolve a backend name (or pass through an instance)."""
    if isinstance(backend, str):
        if backend not in BACKENDS:
            raise KeyError(f"unknown backend {backend!r}; known: "
                           f"{BACKEND_NAMES}")
        return BACKENDS[backend](pool)
    return backend
