"""The pluggable execution layer: one task grid, three thin schedulers.

A ``WorkRequest`` is the compiled form of one estimation request: the task
grid, the fused arrays (targets, training weights), one or more
``Segment``s (contiguous learner groups — mixed-learner grids such as IRM
carry one segment per distinct learner), and a durable ``TaskLedger``.

Execution goes through the **megabatch compiler** (repro/compile): the
union of every pending request's tasks is bucketed by (learner family,
padded N, padded P), stacked into ``(B, N_pad, P_pad)`` tensors with
validity masks, and run by one jitted program per bucket (Pallas
batched_gram / batched_predict on the hot linear path).  Equal-shape
canonical blocks — even from different requests — **fuse into one
launch** (compile/program.py, bitwise-equal to per-block launches), and
launches are **dispatched non-blocking**: the compiler hands back
in-flight ``jax.Array`` handles which each drain stream queues
(serverless/dispatch.py) and harvests only when a ledger's buckets must
complete, so host-side booking overlaps device execution.  Each backend
is a thin scheduler over those compiled buckets — and every backend is a
**stream scheduler**: the unit of work is one ``step()`` over a live
``DrainState`` whose request set can grow between steps (continuous
admission from the session layer), with ``run_requests`` kept as the
batch wrapper (admit everything, step until idle):

  WaveBackend     the serverless-analogue wave scheduler (paper §4):
                  capacity-limited waves, identity-keyed fault injection
                  + backoff retries (serverless/chaos.py), deadline-based
                  hedged re-dispatch, elastic worker schedules or the
                  occupancy autoscaler (serverless/autoscale.py), Lambda
                  billing.  Waves are SHARED across requests — a wave's
                  lanes map onto bucket slices, so one warm program
                  serves every task of a bucket regardless of which
                  request it came from.
  ShardedBackend  the same bucket programs shard_map'd over the mesh's
                  "data" axis (sharding/policy.py::megabatch_specs),
                  pages replicated, the task-batch axis sharded.
  InlineBackend   each bucket drained in one direct program call — the
                  reference scheduler tests compare against.
  TopologyBackend (serverless/topology.py) per-host-mesh drain streams:
                  buckets routed to the host whose PagePool already
                  holds their pages, idle hosts steal, each mesh's wave
                  sized by its own roofline-priced autoscaler lane.

All backends emit the same ``RunReport``/``TaskLedger`` artifacts, so
fault tolerance, billing, and resume behave identically at the API layer;
each holds a persistent spec-keyed ``ProgramCache`` so repeat traffic
through a ``DMLSession`` never re-traces, and a device-resident
``PagePool`` so steady-state serving re-transfers no feature pages.

Determinism contract: every task draws its PRNG stream as
fold_in(segment seed, flat task id) at *compile* time, so predictions are
independent of backend, bucket composition, wave schedule, admission
order, fault pattern, and shard count — bitwise, for every learner family
including the key-consuming ones (mlp, kernel_ridge).
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import (
    TYPE_CHECKING, Callable, Dict, List, Optional, Protocol, Sequence, Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.registry import warm_cache
from repro.runtime import bounded_put
from repro.serverless import sanitize
from repro.serverless.autoscale import AutoscaleDecision, OccupancyAutoscaler
from repro.serverless.chaos import chaos_plan
from repro.serverless.cost import Bill, BillingRecord, speedup_of
from repro.serverless.dispatch import (
    DispatchQueue, DispatchStats, HedgePair, PendingBucket,
)
from repro.serverless.ledger import DONE, TaskLedger

if TYPE_CHECKING:       # avoid the core <-> serverless import cycle
    from repro.compile import (
        CompileStats, MegabatchPlan, PagePool, PageStats, ProgramCache,
    )
    from repro.core.crossfit import TaskGrid


def _compile():
    """Deferred import of the megabatch compiler.

    repro.compile reaches into repro.core.crossfit whose package __init__
    imports this module (spec.py needs BACKEND_NAMES), so the compiler
    must load lazily — at which point the cycle is already resolved.
    """
    import repro.compile as compile_mod
    return compile_mod


@jax.jit
def _fold_key_table(base_key, ids):
    """(n,) task ids -> (n, key_width) key data via per-id fold_in."""
    return jax.vmap(
        lambda i: jax.random.key_data(jax.random.fold_in(base_key, i)))(ids)


# Content-keyed cache of computed key tables: steady serving re-compiles
# the same (plan, data) into fresh WorkRequests every drain, and the
# fold_in table is a pure function of (segment key contents, n_tasks) —
# without this cache every warm drain pays one device round-trip per
# request segment just to rebuild identical tables (the dominant
# host-side term once launches were fused).  Bounded FIFO: serving mixes
# cycle a small set of segment keys.
_KEY_TABLE_CACHE: Dict[Tuple[bytes, Tuple[int, ...], int], np.ndarray] = {}
_KEY_TABLE_CACHE_MAX = 512
# structural cache of WorkRequest index maps (see _index_maps)
_INDEX_MAP_CACHE: Dict[Tuple, Tuple] = {}


@warm_cache(name="fold_in_key_tables",
            key=("base_key", "n_tasks", "key_ref"))
def _segment_key_table(base_key, n_tasks: int,
                       key_ref: Optional[Tuple] = None) -> np.ndarray:
    if key_ref is not None:
        ck = ("ref", key_ref, int(n_tasks))
    else:
        kd = np.asarray(jax.random.key_data(base_key))
        ck = (kd.tobytes(), kd.shape, int(n_tasks))
    table = _KEY_TABLE_CACHE.get(ck)
    if table is None:
        table = np.asarray(_fold_key_table(base_key, np.arange(n_tasks)))
        bounded_put(_KEY_TABLE_CACHE, ck, table, _KEY_TABLE_CACHE_MAX)
    return table


# ---------------------------------------------------------------------------
# substrate configuration (immutable — plans/sessions share PoolConfigs)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PoolConfig:
    """The knobs the paper's user controls (§4.2, §5.2).

    Frozen: reusing one PoolConfig across estimators/sessions must never
    let one caller's settings leak into another's (use
    ``dataclasses.replace`` to derive variants).
    """
    n_workers: int = 8                  # concurrent lambda-analogue workers
    memory_mb: int = 1024               # Lambda memory knob
    scaling: str = "n_rep"              # paper's scaling parameter
    timeout_s: float = 900.0            # Lambda 15-min cap
    max_retries: int = 3
    failure_rate: float = 0.0           # fault injection (per invocation)
    straggler_rate: float = 0.0         # P(invocation is a straggler)
    straggler_slowdown: float = 4.0
    simulate: bool = False              # model durations via the speed curve
    base_work_s: float = 0.0            # simulated seconds per task @1 vCPU
    dispatch_overhead_s: float = 0.005  # per-wave dispatch latency
    seed: int = 0
    checkpoint_path: Optional[str] = None
    # elasticity: optional static schedule of worker counts per wave
    # (grow/shrink); superseded by the occupancy autoscaler below
    worker_schedule: Optional[Sequence[int]] = None
    # occupancy-driven autoscaling (serverless/autoscale.py): derive the
    # per-wave worker count from queue depth / bucket occupancy / padding
    # waste, priced through the Lambda cost model
    autoscale: bool = False
    min_workers: int = 1
    max_workers: int = 64
    autoscale_cost_weight: float = 1.0
    # device-resident feature-page pool budget (compile/pages.py); 0 turns
    # the pool off and falls back to host page stacking per launch
    page_pool_bytes: int = 256 * 1024 * 1024
    # topology backend (serverless/topology.py): number of simulated host
    # meshes when none is passed explicitly, and whether an idle host may
    # steal queued buckets from a loaded one
    n_hosts: int = 2
    steal: bool = True
    # same-shape block fusion (compile/program.py): pack equal-canonical-B
    # blocks of different requests into one launch (bitwise-equal to
    # per-block launches).  Since ISSUE 8 partitioned program caches fuse
    # too: the sharded backends carry a partition_fused transform that
    # wraps the lax.map fused body in shard_map over the host mesh
    fuse: bool = True
    # non-blocking dispatch: buckets a drain stream may hold in flight
    # before a push force-harvests the oldest (device-liveness bound)
    max_inflight: int = 8
    # cross-shape coalescing (compile/program.py, ISSUE 7): pack/morph
    # tail blocks of morph-proven families into combined launches.
    # Bitwise families coalesce whenever this is on; families in the
    # tolerance tier additionally need morph_tolerance > 0 — an explicit
    # opt-out of bitwise reproducibility the jaxpr auditor reports
    coalesce: bool = True
    morph_tolerance: float = 0.0
    # double-buffered dispatch (ISSUE 7): waves a drain may hold
    # unsettled while filling/stacking the next one (wave k+1's host
    # work overlaps wave k's device execution).  Since ISSUE 10 chaos
    # pools pipeline too: fault verdicts are drawn per invocation
    # identity (serverless/chaos.py), not from an order-pinned stream
    pipeline_depth: int = 2
    # fault-tolerant drain (ISSUE 10): capped exponential backoff before
    # a failed invocation is re-dispatched (0 retries immediately — the
    # in-process default, where re-dispatch is the recovery)
    retry_backoff_s: float = 0.0
    retry_backoff_cap_s: float = 0.25
    # synthetic straggler long tail: a bucket carrying a straggler
    # verdict reports not-ready for this long after its launches land,
    # so the deadline/hedge machinery has a real tail to cut (0: off)
    straggler_hold_s: float = 0.0
    # hedged re-dispatch: None arms hedging exactly when a fault plan is
    # active (chaos pools / REPRO_CHAOS); True/False force it.  An
    # overdue in-flight bucket gets a duplicate dispatch — on another
    # host under the topology backend — and first-landing wins
    hedge: Optional[bool] = None
    # fixed overdue threshold override; None derives the deadline from
    # the bucket's roofline (launch/roofline.py::bucket_deadline_s),
    # capped by timeout_s
    hedge_after_s: Optional[float] = None

    def lanes_per_worker(self) -> int:
        """Worker 'memory' buys lane width (DESIGN.md §2 mapping)."""
        return max(1, self.memory_mb // 256)


@dataclass
class RunReport:
    fit_time_s: float = 0.0
    response_time_s: float = 0.0
    waves: int = 0
    bill: Bill = field(default_factory=Bill)
    wave_sizes: List[int] = field(default_factory=list)
    failures: int = 0
    stragglers: int = 0

    def summary(self) -> Dict:
        out = {"fit_time_s": self.fit_time_s,
               "response_time_s": self.response_time_s,
               "waves": self.waves, "failures": self.failures,
               "stragglers": self.stragglers}
        out.update(self.bill.summary())
        return out


# ---------------------------------------------------------------------------
# the unit of execution
# ---------------------------------------------------------------------------
@dataclass
class Segment:
    """A learner-uniform slice of a request's grid.

    ``l_ids`` are the nuisance indices this segment owns; its invocations
    are exactly those with ``inv % L in l_ids`` (both scaling levels place
    l in the low digit of the invocation id).

    ``learner``/``params`` name a registry learner with compile-time
    resolved hyperparameters — the megabatch compiler buckets on them and
    resolves the family's ``batched_fit_predict``.  ``learner_fn`` is the
    legacy opaque-callable path (ServerlessExecutor): such segments run
    through the vmap adapter at exact shapes.  ``cache_key`` is the hashable
    spec identity — requests built from equal specs share warm compiled
    programs; when absent, buckets fall back to object identity.

    ``key`` seeds the segment's PRNG: task t draws fold_in(key, t), fixed
    at compile time so no schedule can perturb the estimate.
    ``key_ref`` is an optional hashable identity of ``key`` (e.g. the
    seed it was built from): when present, the fold_in key-table cache
    is looked up without materializing the key's data — the warm path
    then performs zero device round-trips per segment.
    """
    learner_fn: Optional[Callable] = None
    l_ids: Tuple[int, ...] = ()
    key: Optional[jax.Array] = None
    cache_key: Optional[Tuple] = None
    learner: Optional[str] = None
    params: Tuple = ()
    key_ref: Optional[Tuple] = None

    @property
    def bucket_id(self):
        """Value identity when the spec is known, object identity else."""
        if self.cache_key is not None:
            return self.cache_key
        return ("opaque", id(self.learner_fn))


def fingerprint_array(x) -> Tuple[str, Tuple[int, ...]]:
    """Content identity of a feature matrix — the ``PagePool`` key, so two
    requests over equal data share one device-resident page."""
    arr = np.ascontiguousarray(np.asarray(x, np.float32))
    return (hashlib.sha1(arr.tobytes()).hexdigest(), arr.shape)


@dataclass
class WorkRequest:
    """One estimation request, compiled to arrays + a durable ledger."""
    grid: TaskGrid
    scaling: str                        # invocation granularity (§4.2)
    x: jnp.ndarray                      # (N, P)
    targets: np.ndarray                 # (L, N)
    train_w: np.ndarray                 # (M, K, L, N)
    segments: List[Segment]
    ledger: TaskLedger
    report: RunReport
    tag: object = None                  # caller's request id
    fold_masks: Optional[np.ndarray] = None   # (M,K,N), set by the compiler
    data_key: object = None             # content identity of x (page pool)
    # content identity of (targets, train_w, segment keys): when set by
    # the front-end (compile_request), the compiler may cache this
    # request's stacked block tensors across drains — steady serving
    # re-lowers identical (plan, data) pairs every round.  None (raw
    # requests) disables the cache.
    work_key: object = None

    @classmethod
    def create(cls, grid: TaskGrid, scaling: str, x, targets, train_w,
               segments: List[Segment],
               ledger: Optional[TaskLedger] = None,
               report: Optional[RunReport] = None,
               tag: object = None, data_key: object = None,
               work_key: object = None) -> "WorkRequest":
        n_obs = int(np.asarray(targets).shape[-1])
        n_inv = grid.n_invocations(scaling)
        tpi = grid.tasks_per_invocation(scaling)
        if ledger is None:
            ledger = TaskLedger.create(n_inv, n_obs, tpi)
        elif (ledger.n_invocations, ledger.tasks_per_invocation,
              ledger.n_obs) != (n_inv, tpi, n_obs):
            raise ValueError(
                f"ledger shape ({ledger.n_invocations}, "
                f"{ledger.tasks_per_invocation}, {ledger.n_obs}) does not "
                f"match grid/scaling/data ({n_inv}, {tpi}, {n_obs}) — was it "
                "saved under a different plan?")
        if data_key is None:
            data_key = fingerprint_array(x)
        return cls(grid=grid, scaling=scaling, x=jnp.asarray(x),
                   targets=np.asarray(targets), train_w=np.asarray(train_w),
                   segments=segments, ledger=ledger,
                   report=report or RunReport(), tag=tag, data_key=data_key,
                   work_key=work_key)

    # ---- derived index maps (cached) ------------------------------------
    # the grid's coordinate methods are pure functions of its scalar
    # shape fields (all keyed) — hence covers under grid.n_rep; the
    # per-instance memo self._maps is ambient
    @warm_cache(name="work_request_index_maps",
                key=("self.grid.n_rep", "self.grid.n_folds",
                     "self.grid.n_nuisance", "self.scaling",
                     "self.segments"),
                reads=("self.grid.invocation_task_ids",
                       "self.grid.task_coords",
                       "self.grid.n_invocations"),
                covers={"self.grid.n_rep": (
                    "self.grid.invocation_task_ids",
                    "self.grid.task_coords",
                    "self.grid.n_invocations")},
                ambient=("self._maps",))
    def _index_maps(self):
        if not hasattr(self, "_maps"):
            g = self.grid
            # structural cache: the maps depend only on (grid, scaling,
            # segment l_ids) — steady serving re-creates equal-structure
            # requests every drain and shares one entry
            ck = (g.n_rep, g.n_folds, g.n_nuisance, self.scaling,
                  tuple(s.l_ids for s in self.segments))
            maps = _INDEX_MAP_CACHE.get(ck)
            if maps is None:
                task_mat = g.invocation_task_ids(
                    np.arange(g.n_invocations(self.scaling)), self.scaling)
                tm, tk, tl = g.task_coords()
                seg_of_l = np.zeros(g.n_nuisance, np.int64)
                for si, seg in enumerate(self.segments):
                    for l in seg.l_ids:
                        seg_of_l[l] = si
                maps = (task_mat, tm, tk, tl, seg_of_l)
                bounded_put(_INDEX_MAP_CACHE, ck, maps, 512)
            self._maps = maps
        return self._maps

    def segment_of_inv(self, inv: np.ndarray) -> np.ndarray:
        _, _, _, _, seg_of_l = self._index_maps()
        return seg_of_l[np.asarray(inv) % self.grid.n_nuisance]

    def invocation_tasks(self, inv: int) -> np.ndarray:
        """Flat task ids of one invocation (tpi,)."""
        return self._index_maps()[0][int(inv)]

    def task_key_data(self, seg_idx: int, flat_tasks: np.ndarray) -> np.ndarray:
        """Per-task PRNG key data: fold_in(segment key, flat task id).

        Fixed at compile time and cached per segment, so a task's stream
        is identical however buckets, waves, retries, or shards slice the
        grid — the determinism contract for key-consuming learners.
        """
        if not hasattr(self, "_key_tables"):
            self._key_tables: Dict[int, np.ndarray] = {}
        table = self._key_tables.get(seg_idx)
        if table is None:
            seg = self.segments[seg_idx]
            table = _segment_key_table(seg.key, self.grid.n_tasks,
                                       key_ref=seg.key_ref)
            self._key_tables[seg_idx] = table
        return table[np.asarray(flat_tasks, np.int64)]

    def wave_arrays(self, flat_tasks: np.ndarray):
        """Gather (targets, weights) rows for flat task ids."""
        _, tm, tk, tl = self._index_maps()[:4]
        y = self.targets[tl[flat_tasks]]
        w = self.train_w[tm[flat_tasks], tk[flat_tasks], tl[flat_tasks]]
        return y, w

    def gathered_preds(self) -> np.ndarray:
        """Scatter ledger rows back to the (M, K, L, N) tensor."""
        g = self.grid
        task_mat, tm, tk, tl, _ = self._index_maps()
        flat = task_mat.reshape(-1)
        n_obs = self.ledger.n_obs
        out = np.zeros((g.n_rep, g.n_folds, g.n_nuisance, n_obs), np.float32)
        out[tm[flat], tk[flat], tl[flat]] = \
            self.ledger.preds.reshape(-1, n_obs)
        return out


class ExecutionBackend(Protocol):
    """Anything that can drain a stream of WorkRequests.

    The streaming contract is three primitives: ``begin_drain()`` opens a
    ``DrainState``; ``admit(state, req)`` lowers one request into the live
    bucket plan (legal at any point, including mid-drain); ``step(state)``
    performs one scheduling quantum — a wave (WaveBackend) or one bucket
    slice (Inline/Sharded) — books ledgers/billing, and returns False once
    nothing is pending.  ``run_requests`` is the batch wrapper: after it
    returns, every request's ledger is complete (or an exception was
    raised), its report reflects the work performed in this call
    (appending to any prior state), and ``req.gathered_preds()`` yields
    the (M, K, L, N) prediction tensor.  Pre-completed ledger rows
    (resume) must not be re-executed.
    """
    name: str

    def begin_drain(self) -> "DrainState":
        ...

    def admit(self, state: "DrainState", req: WorkRequest) -> int:
        ...

    def step(self, state: "DrainState") -> bool:
        ...

    def run_requests(self, requests: Sequence[WorkRequest]) -> "BackendRunInfo":
        ...


@dataclass
class BackendRunInfo:
    """Cross-request accounting for one backend drain (session telemetry)."""
    backend: str
    waves: int = 0
    wave_members: List[List[object]] = field(default_factory=list)
    buckets: int = 0                    # distinct megabatch buckets drained
    compile: Optional[CompileStats] = None   # backend's warm-cache stats
    pages: Optional[PageStats] = None        # device page-pool accounting
    autoscale: List[AutoscaleDecision] = field(default_factory=list)
    topology: Optional[object] = None   # per-host streams (TopologyInfo)
    dispatch: Optional[DispatchStats] = None  # in-flight queue accounting
    # per-bucket parallelization-axis decisions (ISSUE 8): one
    # compile.buckets.AxisDecision per (bucket, mesh) the drain priced,
    # logged like autoscale decisions
    axis_plans: List[object] = field(default_factory=list)

    @property
    def shared_waves(self) -> int:
        """Waves that carried invocations from 2+ requests — the fusion
        the multi-request session exists to create.  (Members lists are
        deduplicated at construction.)"""
        return sum(1 for m in self.wave_members if len(m) > 1)


@dataclass
class DrainState:
    """Mutable state of one continuous drain.

    Owns the incremental ``MegabatchPlan`` (its request list is the
    admission order), the pool's fault plan (``chaos``,
    serverless/chaos.py — None for fault-free pools, whose hot path
    then pays nothing), the retry-backoff gates, the in-flight dispatch
    ``queue`` (non-blocking dispatch), and the cross-request
    ``BackendRunInfo``.  The session layer holds one of these per live
    drain and interleaves ``admit`` with ``step``.
    """
    plan: "MegabatchPlan"
    info: BackendRunInfo
    chaos: Optional[object] = None      # serverless/chaos.py::ChaosPlan
    # (req slot, invocation) -> perf_counter time before which a failed
    # row may not be re-dispatched (capped exponential backoff)
    retry_at: Dict[Tuple[int, int], float] = field(default_factory=dict)
    wave: int = 0
    seen_buckets: set = field(default_factory=set)
    finalized: set = field(default_factory=set)
    queue: Optional[DispatchQueue] = None    # in-flight buckets (one stream)
    # pipelined waves dispatched but not yet settled (WaveBackend): each
    # settles — books ledgers, bills, finalizes — when its last bucket
    # lands; the sanitizer requires this empty at drain retirement
    waves_inflight: List = field(default_factory=list)
    # (bucket key, n_devices) -> AxisDecision memo: each bucket's
    # parallelization axis is priced once per drain per mesh size
    # (ISSUE 8); the decisions are also appended to info.axis_plans
    axis_planned: Dict = field(default_factory=dict)

    @property
    def requests(self) -> List[WorkRequest]:
        return self.plan.requests


# ---------------------------------------------------------------------------
# helpers shared by backends
# ---------------------------------------------------------------------------
def roofline_pending_inv_s(requests, groups) -> Optional[float]:
    """Mean roofline-modeled invocation duration over bucketed pending
    entries (launch/roofline.py) — the autoscaler's cold-start pricing
    signal, replacing the unit-work model before any duration has been
    observed.  Opaque-callable buckets carry no analytic model and are
    skipped; returns None when nothing could be priced."""
    from repro.launch.roofline import invocation_roofline_s
    total, n = 0.0, 0
    for key, entries in groups.items():
        ident = key.learner
        if not (isinstance(ident, tuple) and len(ident) == 2
                and isinstance(ident[0], str)) or ident[0] == "opaque":
            continue
        learner, ptuple = ident
        for ri, _ in entries:
            req = requests[ri]
            total += invocation_roofline_s(
                learner, dict(ptuple),
                req.grid.tasks_per_invocation(req.scaling),
                key.n_pad, key.p_pad,
                # the whole bucket typically rides one fused launch, so
                # each invocation carries an amortized share of its
                # dispatch overhead (launch/roofline.launch_overhead_s —
                # session-measured, constant fallback)
                amortized_launches=1.0 / len(entries))
            n += 1
    return total / n if n else None


def _fill_rows(req: WorkRequest, inv_ids: np.ndarray, wall: float,
               pool: PoolConfig):
    """Record successful rows with measured billing (non-wave backends)."""
    per = wall / max(len(inv_ids), 1)
    for inv in inv_ids:
        req.report.bill.add(BillingRecord(
            invocation=int(inv), duration_s=per, memory_mb=pool.memory_mb))


class _StreamBackend:
    """Shared streaming machinery: drain-state lifecycle, admission,
    completion finalization, checkpoints, and the batch wrapper."""

    def begin_drain(self) -> DrainState:
        info = BackendRunInfo(backend=self.name)
        info.compile = self.compiler.stats
        if self.pages is not None:
            info.pages = self.pages.stats
        state = DrainState(plan=_compile().MegabatchPlan(), info=info)
        state.chaos = chaos_plan(self.pool)
        state.queue = DispatchQueue(self.pool.max_inflight)
        info.dispatch = state.queue.stats
        return state

    def _fuse(self) -> bool:
        """Same-shape block fusion for this stream's program cache.
        Partitioned caches fuse only when they carry the sharded-fused
        transform (ISSUE 8: shard_map around the lax.map fused body);
        a partition-only cache still maps single-block operands."""
        return self.pool.fuse and (
            self.compiler.partition is None
            or self.compiler.partition_fused is not None)

    def _dispatch_opts(self) -> Dict:
        """The launch-scheduling knobs every dispatch_bucket call takes:
        fusion plus the cross-shape coalescing pair (coalesce gates the
        scheduler, morph_tolerance opts tolerance-tier families in)."""
        return {"fuse": self._fuse(), "coalesce": self.pool.coalesce,
                "morph_tolerance": self.pool.morph_tolerance}

    def admit(self, state: DrainState, req: WorkRequest) -> int:
        """Lower one request into the live plan.  The admission slot is
        the request's identity in the drain's fault plan
        (serverless/chaos.py): verdicts are drawn per
        (slot, invocation, attempt), so no schedule — bucket-coherent
        fill, pipelining, hedges, host loss, resume — can perturb the
        fault pattern."""
        ri = state.plan.admit(req)
        self._finalize_request(state, ri)   # resumed-complete ledgers
        return ri

    def run_requests(self, requests: Sequence[WorkRequest]) -> BackendRunInfo:
        state = self.begin_drain()
        for req in requests:
            self.admit(state, req)
        while self.step(state):
            pass
        self._finish(state)
        return state.info

    # ------------------------------------------------------------------
    def _finish(self, state: DrainState):
        sanitize.check_drained(state, "backend finish")
        for ri in range(len(state.requests)):
            self._finalize_request(state, ri)

    def _finalize_request(self, state: DrainState, ri: int):
        """Close out one request's report the moment its ledger completes
        (the early-result hook the session's event loop polls)."""
        if ri in state.finalized:
            return
        req = state.requests[ri]
        if not req.ledger.complete:
            return
        state.finalized.add(ri)
        if self.pool.simulate:
            req.report.fit_time_s = (req.report.response_time_s
                                     + self.pool.dispatch_overhead_s)

    def _checkpoint(self, state: DrainState):
        for req in state.requests:
            req.ledger.checkpoint()      # durable sessions bind a path
        if not self.pool.checkpoint_path:
            return
        for i, req in enumerate(state.requests):
            path = self.pool.checkpoint_path if len(state.requests) == 1 \
                else f"{self.pool.checkpoint_path}.r{i}"
            req.ledger.save(path)

    def _book_direct(self, state: DrainState, entries, results, wall: float):
        """Record one bucket launch: ledger bookings, billing, retries.

        Fault-free pools (``state.chaos is None``) batch-book everything
        with zero per-invocation work — the hot path is unchanged.
        Chaos pools consult the fault plan per entry: a failed verdict
        books a failure (retry-budget checked) and arms a backoff gate
        in ``state.retry_at`` so the row re-enters the pending view only
        once its gate matures; survivors book normally.  Verdicts are
        identity-keyed, so this booking is legal in ANY order — the
        bucket-coherent fill and pipeline stay on under chaos."""
        n_launch = max(len(entries), 1)
        plan = state.chaos
        exhausted: Optional[int] = None
        if plan is not None:
            now = time.perf_counter()
            ok: List[Tuple[int, int]] = []
            for ri, inv in entries:
                req = state.requests[ri]
                ledger = req.ledger
                if ledger.status[inv] == DONE:
                    continue             # lost a re-dispatch race (resume)
                att = int(ledger.attempts[inv])
                v = plan.verdict(ri, inv, att)
                if v.straggler:
                    req.report.stragglers += 1
                if v.failed:
                    if att >= self.pool.max_retries:
                        # defer the abort: sibling successes in this
                        # slice still book (the ledger philosophy —
                        # completed work is durable, an abort never
                        # discards it)
                        exhausted = inv
                        continue
                    sanitize.check_booking(ledger, inv, "record_failure")
                    ledger.record_failure(inv)
                    req.report.failures += 1
                    state.retry_at[(ri, inv)] = \
                        now + plan.backoff_s(int(ledger.attempts[inv]))
                    continue
                ok.append((ri, inv))
            entries = ok
        per_req: Dict[int, List[int]] = {}
        for ri, inv in entries:
            per_req.setdefault(ri, []).append(inv)
        for ri, invs in per_req.items():
            req = state.requests[ri]
            sanitize.check_booking(req.ledger, invs, "record_successes")
            req.ledger.record_successes(
                invs, np.stack([results[(ri, inv)] for inv in invs]))
            _fill_rows(req, np.asarray(invs),
                       wall * len(invs) / n_launch, self.pool)
            req.report.waves += 1
            req.report.wave_sizes.append(len(invs))
        if exhausted is not None:
            raise RuntimeError(
                f"invocation {exhausted} exceeded retry budget")
        return per_req

    def _note_wave(self, state: DrainState, ris, step_wall: float):
        """Close out one direct-scheduler wave: the tag-deduped member
        list, per-request wall-time accounting, and early finalization
        (shared by the bucket-stream and topology schedulers; the wave
        backend has its own fault-aware variant)."""
        members = []
        for ri in ris:
            tag = state.requests[ri].tag
            tag = ri if tag is None else tag
            if tag not in members:
                members.append(tag)
        state.info.wave_members.append(members)
        for ri in ris:
            state.requests[ri].report.fit_time_s += step_wall
            state.requests[ri].report.response_time_s += step_wall
            self._finalize_request(state, ri)

    # ---- fault-tolerant dispatch (ISSUE 10) --------------------------
    def _hedge_armed(self, state: DrainState) -> bool:
        """Hedged re-dispatch is on when the pool says so, else exactly
        when a fault plan is active (chaos is what makes tails long)."""
        if self.pool.hedge is not None:
            return self.pool.hedge
        return state.chaos is not None

    def _deadline_for(self, state: DrainState, bkey,
                      entries) -> Optional[float]:
        """Overdue threshold for one dispatched bucket slice: the pool's
        fixed override, else the roofline-derived deadline capped by
        timeout_s.  None disarms hedging for this bucket."""
        if not self._hedge_armed(state) or not entries:
            return None
        pool = self.pool
        if pool.hedge_after_s is not None:
            return pool.hedge_after_s
        ident = bkey.learner
        if not (isinstance(ident, tuple) and len(ident) == 2
                and isinstance(ident[0], str)) or ident[0] == "opaque":
            # no analytic model: the Lambda cap is the only deadline
            return pool.timeout_s
        from repro.launch.roofline import bucket_deadline_s
        learner, ptuple = ident
        ri = entries[0][0]
        req = state.requests[ri]
        d = bucket_deadline_s(learner, dict(ptuple),
                              req.grid.tasks_per_invocation(req.scaling),
                              bkey.n_pad, bkey.p_pad, len(entries),
                              n_workers=len(entries))
        return min(d, pool.timeout_s)

    def _hold_for(self, state: DrainState, entries) -> float:
        """Synthetic straggler tail: when the pool opts in
        (straggler_hold_s > 0) and any entry of the slice draws a
        straggler verdict, the bucket reports not-ready for the hold —
        the long tail a hedged duplicate then beats."""
        plan = state.chaos
        hold = self.pool.straggler_hold_s
        if plan is None or hold <= 0:
            return 0.0
        for ri, inv in entries:
            att = int(state.requests[ri].ledger.attempts[inv])
            if plan.verdict(ri, inv, att).straggler:
                return hold
        return 0.0

    def _push_bucket(self, state: DrainState, q: DispatchQueue, bd,
                     book, host: int = -1) -> PendingBucket:
        """Wrap one dispatched bucket with its fault-tolerance context
        (deadline, straggler hold) and enqueue it."""
        hold = self._hold_for(state, bd.entries)
        pb = PendingBucket(
            dispatch=bd, host=host,
            deadline_s=self._deadline_for(state, bd.key, bd.entries),
            not_ready_before=(time.perf_counter() + hold) if hold else 0.0)
        q.push(pb, book)
        return pb

    def _hedge_dispatch_kwargs(self, state: DrainState, bkey,
                               entries) -> Dict:
        """Extra dispatch_bucket kwargs a hedge must replicate so both
        legs run the identical compiled program (bitwise race)."""
        return {}

    def _maybe_hedge(self, state: DrainState) -> int:
        """Duplicate-dispatch every overdue in-flight bucket (single
        stream: the duplicate lands on the same queue — the topology
        backend overrides placement to a different host)."""
        q = state.queue
        if q is None or not self._hedge_armed(state):
            return 0
        n = 0
        for pb in q.overdue():
            self._hedge_bucket(state, pb, q, q,
                               compiler=self.compiler, pages=self.pages,
                               host=pb.host)
            n += 1
        return n

    def _hedge_bucket(self, state: DrainState, pb: PendingBucket,
                      owner_q: DispatchQueue, push_q: DispatchQueue, *,
                      compiler, pages, host: int = -1) -> PendingBucket:
        """Launch the duplicate leg of an overdue bucket and wire the
        race: same key, same entries, same per-task fold_in keys — so
        whichever leg lands first books bitwise-identical results.  The
        winner's harvest settles the pair (HedgePair.settle, the sole
        cancel performer) and the loser is discarded unbooked."""
        sanitize.check_hedge(pb)
        running: Dict[int, List[int]] = {}
        for ri, inv in pb.entries:
            running.setdefault(ri, []).append(inv)
        for ri, invs in running.items():
            # RUNNING -> RUNNING (legal re-mark): a checkpoint taken
            # mid-race must still re-queue these rows on restart
            state.requests[ri].ledger.mark_running(invs)
        bd = _compile().dispatch_bucket(
            state.plan, compiler, pb.key, list(pb.entries), pages=pages,
            **self._hedge_dispatch_kwargs(state, pb.key, pb.entries),
            **self._dispatch_opts())
        pair = HedgePair()
        hpb = PendingBucket(dispatch=bd, host=host, book=pb.book,
                            is_hedge=True, pair=pair)
        pair.legs = [(pb, owner_q), (hpb, push_q)]
        pb.state = "HEDGED"
        pb.pair = pair
        push_q.stats.hedges += 1
        push_q.push(hpb)
        return hpb

    def _backoff_filter(self, state: DrainState,
                        entries) -> Tuple[List, Optional[float]]:
        """Drop entries whose retry gate has not matured; purge matured
        gates.  Returns (dispatchable entries, seconds until the
        earliest still-armed gate — None when nothing is gated)."""
        if not state.retry_at:
            return list(entries), None
        now = time.perf_counter()
        for e, t in list(state.retry_at.items()):
            if t <= now:
                del state.retry_at[e]
        if not state.retry_at:
            return list(entries), None
        out = [e for e in entries if (e[0], int(e[1])) not in state.retry_at]
        wait = min(state.retry_at.values()) - now
        return out, max(wait, 0.0)


class _BucketStreamBackend(_StreamBackend):
    """Inline/Sharded stepping: one pending bucket slice dispatched per
    step, harvested on a later step (non-blocking dispatch) — the step
    that dispatches bucket k+1 books bucket k's results while the device
    executes, so host booking overlaps device execution."""

    def _b_align(self) -> int:
        return 1

    def _plan_axis(self, state: DrainState, bkey, entries):
        """Parallelization-axis planning hook (ISSUE 8): single-device
        streams have nothing to shard, so the default plans nothing; the
        mesh-owning backends price candidates, log AxisDecisions, and
        return the (memoized) decision so ``step`` can hand it to
        ``dispatch_bucket`` for in-mesh execution (ISSUE 9)."""
        return None

    def _axis_mesh(self):
        """The mesh ``dispatch_bucket`` lowers data/feature AxisDecisions
        onto.  None (the default) keeps every bucket on the task axis —
        the bitwise reference path."""
        return None

    def _book_harvest(self, state: DrainState, pb: PendingBucket,
                      results: Dict, elapsed: float):
        """Booking callback the queue fires at harvest: ledgers, bills,
        wave accounting, early finalization, checkpoint."""
        per_req = self._book_direct(state, pb.entries, results, elapsed)
        if per_req:     # chaos can fail a whole slice — nothing to book
            self._note_wave(state, list(per_req), elapsed)
        self._checkpoint(state)

    def _hedge_dispatch_kwargs(self, state: DrainState, bkey,
                               entries) -> Dict:
        return {"b_align": self._b_align(),
                "axis_decision": self._plan_axis(state, bkey, entries),
                "mesh": self._axis_mesh()}

    def step(self, state: DrainState) -> bool:
        q = state.queue
        book = lambda pb, res, el: self._book_harvest(state, pb, res, el)
        q.harvest_ready(book)               # opportunistic booking
        self._maybe_hedge(state)
        groups = state.plan.pending_by_bucket(
            exclude=q.in_flight_entries())
        gate_wait: Optional[float] = None
        if groups and state.retry_at:
            filtered = {}
            for bkey, entries in groups.items():
                ents, gate_wait = self._backoff_filter(state, entries)
                if ents:
                    filtered[bkey] = ents
            groups = filtered
        if not groups:
            if not q.empty and self._hedge_armed(state):
                # poll instead of blocking: a held straggler leg must
                # not stall the tail drain while its hedged duplicate
                # can land first and win the race
                self._maybe_hedge(state)
                if q.harvest_ready(book) == 0:
                    time.sleep(0.001)
                return True
            if q.harvest_next(book):        # drain the in-flight tail
                return True
            if gate_wait is not None:
                # every pending row is backoff-gated: wait the earliest
                # gate out instead of spinning (or stalling the drain)
                time.sleep(min(gate_wait, 0.05))
                return True
            return False
        bkey, entries = next(iter(groups.items()))
        decision = self._plan_axis(state, bkey, entries)
        running: Dict[int, List[int]] = {}
        for ri, inv in entries:
            running.setdefault(ri, []).append(inv)
        for ri, invs in running.items():
            state.requests[ri].ledger.mark_running(invs)
        bd = _compile().dispatch_bucket(
            state.plan, self.compiler, bkey, entries,
            b_align=self._b_align(), pages=self.pages,
            axis_decision=decision, mesh=self._axis_mesh(),
            **self._dispatch_opts())
        self._push_bucket(state, q, bd, book)
        state.seen_buckets.add(bkey)
        state.info.buckets = len(state.seen_buckets)
        state.info.waves += 1
        return True


# ---------------------------------------------------------------------------
# InlineBackend — direct bucket drain, the reference scheduler
# ---------------------------------------------------------------------------
class InlineBackend(_BucketStreamBackend):
    """Every pending bucket in one direct program call.  No faults, no
    capacity limit: the oracle the other schedulers must agree with."""
    name = "inline"

    def __init__(self, pool: Optional[PoolConfig] = None):
        self.pool = pool or PoolConfig()
        self.compiler = _compile().ProgramCache()
        self.pages = _compile().PagePool(self.pool.page_pool_bytes) \
            if self.pool.page_pool_bytes else None

    @property
    def _programs(self) -> Dict:
        return self.compiler._programs


# ---------------------------------------------------------------------------
# ShardedBackend — the bucket programs SPMD over a device mesh
# ---------------------------------------------------------------------------
def make_sharded_compiler(mesh) -> "ProgramCache":
    """A ProgramCache whose programs SPMD over ``mesh``'s "data" axis.

    Unfused programs shard the single-block specs (the PR 1 path);
    fused launches go through the shard_map-wrapped ``lax.map`` form
    (ISSUE 8, ``megabatch_specs(fused=True)``), so a partitioned cache
    participates in same-shape fusion like an unpartitioned one.  The
    mesh axes (names + sizes) become the cache's ``partition_axes`` —
    part of every sharded-fused program's cache key.
    """
    from repro.sharding.compat import shard_map_compat
    from repro.sharding.policy import megabatch_specs
    in_specs, out_specs = megabatch_specs("data")
    fin_specs, fout_specs = megabatch_specs("data", fused=True)

    def partition(fn):
        return shard_map_compat(fn, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs)

    def partition_fused(fn):
        return shard_map_compat(fn, mesh=mesh, in_specs=fin_specs,
                                out_specs=fout_specs)

    axes = tuple((str(a), int(mesh.shape[a])) for a in mesh.axis_names)
    return _compile().ProgramCache(partition=partition,
                                   partition_fused=partition_fused,
                                   partition_axes=axes)


class ShardedBackend(_BucketStreamBackend):
    """The same megabatch programs with the task-batch axis shard_map'd
    over the mesh's "data" axis (pages replicated on every device;
    sharding/policy.py::megabatch_specs).  Fused launches shard too
    (ISSUE 8): shard_map wraps the lax.map fused body, so same-shape
    fusion survives partitioning.  Every bucket's parallelization axis
    is roofline-priced (compile/buckets.py::plan_bucket_axis) and the
    decision logged on BackendRunInfo.axis_plans.  Reuses launch/mesh.py
    meshes; stays warm across requests via the spec-keyed ProgramCache."""
    name = "sharded"

    def __init__(self, pool: Optional[PoolConfig] = None, mesh=None):
        self.pool = pool or PoolConfig()
        self._mesh = mesh
        self._compiler: Optional[ProgramCache] = None
        self.pages = _compile().PagePool(self.pool.page_pool_bytes) \
            if self.pool.page_pool_bytes else None

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_host_mesh
            self._mesh = make_host_mesh()
        return self._mesh

    def _n_shards(self) -> int:
        return int(self.mesh.shape["data"])

    def _b_align(self) -> int:
        return self._n_shards()

    def _plan_axis(self, state: DrainState, bkey, entries):
        """Price the bucket's parallelization-axis candidates on this
        mesh, log the decision (once per bucket per drain), and return
        it so the drain executes the planned layout (ISSUE 9)."""
        memo_key = (bkey, self._n_shards())
        if memo_key not in state.axis_planned:
            from repro.compile.buckets import plan_bucket_axis
            decision = plan_bucket_axis(
                bkey, n_tasks=len(entries), n_devices=self._n_shards())
            state.axis_planned[memo_key] = decision
            if decision is not None:
                state.info.axis_plans.append(decision)
        return state.axis_planned[memo_key]

    def _axis_mesh(self):
        """Data/feature AxisDecisions lower onto this backend's mesh."""
        return self.mesh

    @property
    def compiler(self) -> ProgramCache:
        if self._compiler is None:
            self._compiler = make_sharded_compiler(self.mesh)
        return self._compiler

    @property
    def _programs(self) -> Dict:
        return self.compiler._programs


# ---------------------------------------------------------------------------
# WaveBackend — the serverless-analogue scheduler, multi-request
# ---------------------------------------------------------------------------
@dataclass
class _Entry:
    """One dispatched lane: (request, invocation, speculative?)."""
    req_idx: int
    inv: int
    speculative: bool = False


@dataclass(eq=False)            # identity equality: removed by list.remove
class _WaveLatch:
    """One pipelined wave awaiting settlement (ISSUE 7 double-buffered
    dispatch).

    A fault-free wave no longer barriers at the end of its step — its
    buckets stay in flight while the next wave is filled and stacked.
    The latch accumulates the wave's results and frontier-attributed
    wall shares as each bucket's booking continuation fires, and the
    wave **settles** (ledgers booked, bills recorded, requests
    finalized, checkpoint written) the moment its last bucket lands.
    """
    dispatch: List[_Entry]
    outstanding: int                    # buckets still in flight
    results: Dict = field(default_factory=dict)
    wall_of_req: Dict = field(default_factory=dict)


class WaveBackend(_StreamBackend):
    """The paper's wave scheduler (§4) generalized to a request stream.

    One *invocation* = the paper's lambda call; each ``step`` dispatches
    one wave of up to ``n_workers * lanes_per_worker`` invocations drawn
    round-robin from every admitted request's pending set, so concurrent
    estimations share dispatch cycles (fused waves).  A wave's lanes are
    then grouped by megabatch bucket and executed as one compiled program
    launch per bucket — one warm "worker program" serves every task of a
    bucket regardless of which request it came from.  Per wave the
    scheduler:

      * books fault verdicts from the drain's identity-keyed fault plan
        (serverless/chaos.py) and re-queues failures with capped
        exponential backoff (Lambda retry, injected failures
        first-attempt-only so retries converge),
      * hedges overdue in-flight buckets with a duplicate dispatch
        (deadline from launch/roofline.py::bucket_deadline_s, capped by
        timeout_s) — first-landing wins, the losing leg is cancelled
        and never booked nor billed,
      * re-sizes the pool — static ``worker_schedule`` if given, else the
        occupancy autoscaler (queue depth x padding waste priced through
        the Lambda cost model) when ``pool.autoscale`` is set,
      * checkpoints every participating ledger.

    Billing: measured (a request's share of its buckets' program wall
    time divided over its lanes) or modeled via the Lambda memory/vCPU
    curve (simulate=True).
    """
    name = "wave"

    def __init__(self, pool: Optional[PoolConfig] = None):
        self.pool = pool or PoolConfig()
        self.compiler = _compile().ProgramCache()
        self.pages = _compile().PagePool(self.pool.page_pool_bytes) \
            if self.pool.page_pool_bytes else None
        self.autoscaler = OccupancyAutoscaler(self.pool) \
            if self.pool.autoscale else None

    @property
    def _programs(self) -> Dict:
        return self.compiler._programs

    # ------------------------------------------------------------------
    def _wave_workers(self, state: DrainState,
                      pendings: List[np.ndarray]) -> int:
        pool = self.pool
        if pool.worker_schedule is not None:           # legacy static ramp
            return pool.worker_schedule[
                min(state.wave, len(pool.worker_schedule) - 1)]
        if self.autoscaler is not None:
            depth = sum(len(p) for p in pendings)
            tasks = sum(
                len(p) * req.grid.tasks_per_invocation(req.scaling)
                for p, req in zip(pendings, state.requests))
            # lazy thunk: the autoscaler invokes it only when no
            # higher-priority pricing signal (simulate model, EMA) exists
            decision = self.autoscaler.decide(
                depth,
                tasks_per_invocation=max(1, tasks // max(depth, 1)),
                padding_waste=self.compiler.stats.padding.waste_frac,
                in_flight=state.queue.in_flight if state.queue else 0,
                # pipelined waves can leave the queue non-empty here, so
                # the pricing view excludes in-flight entries — they are
                # occupancy, not dispatchable depth
                roofline_inv_s=lambda: roofline_pending_inv_s(
                    state.requests, state.plan.pending_by_bucket(
                        exclude=state.queue.in_flight_entries()
                        if state.queue else None)))
            state.info.autoscale.append(decision)
            return decision.n_workers
        return pool.n_workers

    def _fill_bucket_coherent(self, state: DrainState,
                              pendings: List[np.ndarray],
                              capacity: int) -> List[_Entry]:
        """Fill a pipelined wave in whole-bucket units.

        Round-robin admission is fair but fragments a bucket's canonical
        tail blocks across waves: a 24-lane bucket cut 6/18 by the
        capacity limit pads to 8 + 24 lanes instead of one 24-lane
        launch — the steady-state padding waste the asyncdrain bench
        gates on.  So buckets small enough to ever travel whole are
        taken whole (in round-robin first-appearance order) or deferred
        to the next wave; only buckets larger than a full wave are
        split, and those split round-robin across each other so
        concurrent oversize requests still share dispatch cycles."""
        rr: List[_Entry] = []
        cursors = [0] * len(pendings)
        while True:
            progressed = False
            for ri, p in enumerate(pendings):
                if cursors[ri] < len(p):
                    rr.append(_Entry(ri, int(p[cursors[ri]])))
                    cursors[ri] += 1
                    progressed = True
            if not progressed:
                break
        groups = state.plan.group_entries([(e.req_idx, e.inv) for e in rr])
        batch: List[_Entry] = []
        oversized: List[List[Tuple[int, int]]] = []
        for ents in groups.values():           # first-appearance order
            if len(ents) > capacity:
                oversized.append(ents)         # can never travel whole
            elif len(ents) <= capacity - len(batch):
                batch.extend(_Entry(ri, inv) for ri, inv in ents)
            # else: whole-bucket sized but no room left — defer intact
        cur = [0] * len(oversized)
        while len(batch) < capacity:
            progressed = False
            for gi, ents in enumerate(oversized):
                if cur[gi] < len(ents) and len(batch) < capacity:
                    ri, inv = ents[cur[gi]]
                    batch.append(_Entry(ri, inv))
                    cur[gi] += 1
                    progressed = True
            if not progressed:
                break
        return batch

    def step(self, state: DrainState) -> bool:
        """Dispatch one wave and pipeline it: the wave's buckets stay in
        flight while the next step fills and stacks wave k+1, up to
        ``pool.pipeline_depth`` unsettled waves — under chaos too, since
        fault verdicts are identity-keyed (serverless/chaos.py) and so
        immune to dispatch order.  Books via per-wave latches
        (book-at-push); False once nothing is pending and the pipeline
        has drained."""
        pool = self.pool
        requests = state.requests
        q = state.queue
        # opportunistic booking: settle any wave whose buckets all
        # landed while the host was filling the previous wave; then
        # duplicate-dispatch anything overdue
        q.harvest_ready()
        self._maybe_hedge(state)
        # ledger.pending() includes RUNNING rows, so the wave fill
        # must exclude every entry still in flight: on the queue OR
        # in an unsettled wave latch — a harvested bucket leaves the
        # queue before its wave settles (and books), and re-dispatching
        # its rows would double-book them.  Failed rows under backoff
        # stay out until their retry gate matures.
        inflight = q.in_flight_entries()
        for latch in state.waves_inflight:
            inflight.update((e.req_idx, e.inv) for e in latch.dispatch)
        gate_wait: Optional[float] = None
        gated: set = set()
        if state.retry_at:
            now = time.perf_counter()
            for e, t in list(state.retry_at.items()):
                if t <= now:
                    del state.retry_at[e]
            if state.retry_at:
                gated = set(state.retry_at)
                gate_wait = max(min(state.retry_at.values()) - now, 0.0)
        pendings = [np.asarray([i for i in req.ledger.pending()
                                if (ri, int(i)) not in inflight
                                and (ri, int(i)) not in gated],
                               np.int64)
                    for ri, req in enumerate(requests)]
        if all(len(p) == 0 for p in pendings):
            if not q.empty and self._hedge_armed(state):
                # poll instead of blocking: a held straggler leg must
                # not stall the tail drain while its hedged duplicate
                # can land first and win the race
                self._maybe_hedge(state)
                if q.harvest_ready() == 0:
                    time.sleep(0.001)
                return True
            if q.harvest_next():
                return True         # drain the in-flight pipeline tail
            if gate_wait is not None:
                # everything pending is backoff-gated: wait the
                # earliest gate out instead of stalling the drain
                time.sleep(min(gate_wait, 0.05))
                return True
            return False
        n_workers = self._wave_workers(state, pendings)
        capacity = max(1, n_workers * pool.lanes_per_worker())

        # ---- fill the wave (whole-bucket units, ISSUE 8) ----------------
        batch = self._fill_bucket_coherent(state, pendings, capacity)
        dispatch = list(batch)

        # ---- execute: one compiled launch per bucket in the wave --------
        members: List[object] = []
        for e in dispatch:
            tag = requests[e.req_idx].tag
            tag = e.req_idx if tag is None else tag
            if tag not in members:
                members.append(tag)
        state.info.wave_members.append(members)
        unique: Dict[Tuple[int, int], None] = {}
        for e in dispatch:
            unique.setdefault((e.req_idx, e.inv))
        running: Dict[int, List[int]] = {}
        for ri, inv in unique:
            running.setdefault(ri, []).append(inv)
        for ri, invs in running.items():
            requests[ri].ledger.mark_running(invs)
        # dispatch every bucket of the wave without blocking — all of a
        # wave's launches execute concurrently on device while the host
        # stacks the next bucket's tensors.  The wave's buckets carry a
        # latch that settles (books + bills) when its last bucket lands
        # — possibly steps later, while wave k+1 is already filling
        groups = state.plan.group_entries(list(unique))
        ctx = _WaveLatch(dispatch=dispatch, outstanding=len(groups))
        state.waves_inflight.append(ctx)

        def book(pb, res, elapsed):
            ctx.results.update(res)
            per = elapsed / max(len(pb.entries), 1)
            for ri, _ in pb.entries:
                ctx.wall_of_req[ri] = ctx.wall_of_req.get(ri, 0.0) + per
            ctx.outstanding -= 1
            if ctx.outstanding == 0:
                self._settle_wave(state, ctx)

        for bkey, ents in groups.items():
            state.seen_buckets.add(bkey)
            bd = _compile().dispatch_bucket(state.plan, self.compiler,
                                            bkey, ents, pages=self.pages,
                                            **self._dispatch_opts())
            self._push_bucket(state, q, bd, book)
        state.wave += 1
        state.info.buckets = len(state.seen_buckets)
        state.info.waves = state.wave
        # bound the pipeline: block-harvest oldest buckets until at
        # most pipeline_depth waves remain unsettled
        depth = max(1, pool.pipeline_depth)
        if self._hedge_armed(state):
            # poll, don't block: a blocked harvest picks the held
            # straggler and sleeps out the very hold the hedged
            # duplicate exists to beat — every race would settle for
            # the original
            while len(state.waves_inflight) > depth and not q.empty:
                self._maybe_hedge(state)
                if q.harvest_ready() == 0:
                    time.sleep(0.001)
        else:
            while len(state.waves_inflight) > depth and q.harvest_next():
                pass
        return True

    def _settle_wave(self, state: DrainState, ctx: _WaveLatch):
        """Book one pipelined wave the moment its last bucket lands:
        ledgers, bills, per-request wall attribution, finalization,
        checkpoint.  Wall time uses the queue's NON-overlapping
        attribution frontier, so concurrent waves' billed spans sum to
        the true elapsed wall instead of double-charging overlap."""
        pool = self.pool
        requests = state.requests
        state.waves_inflight.remove(ctx)
        touched = []
        for ri, req in enumerate(requests):
            entries = [e for e in ctx.dispatch if e.req_idx == ri]
            if not entries:
                continue
            self._book_request_wave(state, req, ri, entries, ctx.results,
                                    pool, ctx.wall_of_req.get(ri, 0.0))
            touched.append(ri)
        if self.autoscaler is not None and ctx.dispatch:
            total = sum(ctx.wall_of_req.values())
            if total > 0:
                self.autoscaler.observe(total / len(ctx.dispatch))
        for ri in touched:
            wall = ctx.wall_of_req.get(ri, 0.0)
            requests[ri].report.response_time_s += wall
            requests[ri].report.fit_time_s += wall
            self._finalize_request(state, ri)
        self._checkpoint(state)

    # ------------------------------------------------------------------
    def _book_request_wave(self, state: DrainState, req: WorkRequest,
                           ri: int, entries: List[_Entry], results: Dict,
                           pool: PoolConfig, wall: float):
        """Book one request's share of a wave: billing, fault verdicts,
        retries.  Predictions were already computed by the wave's bucket
        launches (``results``) — chaos can only reorder or repeat work,
        never change an estimate.

        Fault verdicts come from the drain's identity-keyed fault plan
        (serverless/chaos.py).  A fault-free pool consults nothing and
        batch-books (no draws, no per-invocation loop), keeping the warm
        serving path free of per-wave RNG cost; a chaos pool sees the
        same fault schedule whatever order waves, hedges, retries, or
        resumes book in — which is what lets chaos pools ride the
        pipelined bucket-coherent fill at all."""
        tpi = req.grid.tasks_per_invocation(req.scaling)
        n_obs = req.ledger.n_obs
        ledger, report = req.ledger, req.report
        inv_arr = np.array([e.inv for e in entries], np.int64)

        preds_rows = np.empty((len(entries), tpi, n_obs), np.float32)
        for i, e in enumerate(entries):
            preds_rows[i] = results[(ri, e.inv)]

        plan = state.chaos
        if plan is None:
            # fault-free fast path: batch-book everything (no draws, no
            # per-invocation loop) unless the measured wall tripped the
            # timeout cap — then fall through to the general machinery
            per = wall / max(len(entries), 1)
            if per <= pool.timeout_s:
                sanitize.check_booking(ledger, inv_arr,
                                       "record_successes")
                ledger.record_successes(inv_arr, preds_rows)
                for i, e in enumerate(entries):
                    report.bill.add(BillingRecord(
                        invocation=int(e.inv), duration_s=per,
                        memory_mb=pool.memory_mb))
                report.wave_sizes.append(len(entries))
                report.waves += 1
                return
            durs = np.full(len(entries), per)
            failed = durs > pool.timeout_s                # lambda cap
        else:
            # --- per-invocation verdicts and durations -------------------
            atts = ledger.attempts[inv_arr]
            verdicts = [plan.verdict(ri, int(e.inv), int(atts[i]))
                        for i, e in enumerate(entries)]
            if pool.simulate:
                base = pool.base_work_s * tpi / speedup_of(pool.memory_mb)
                durs = base * np.array([v.noise for v in verdicts])
            else:
                durs = np.full(len(entries), wall / max(len(entries), 1))
            is_strag = np.array([v.straggler for v in verdicts], bool)
            durs = np.where(is_strag, durs * pool.straggler_slowdown, durs)
            report.stragglers += int(is_strag.sum())
            # injected failures fire on attempt 0 only (retries converge)
            failed = np.array([v.failed for v in verdicts], bool)
            failed |= durs > pool.timeout_s               # lambda cap

        now = time.perf_counter()
        exhausted = None
        for i, e in enumerate(entries):
            if ledger.status[e.inv] == DONE:   # duplicate lost the race
                continue
            if failed[i]:
                if ledger.attempts[e.inv] >= pool.max_retries:
                    # defer the abort until the wave's sibling
                    # successes are booked (completed work is durable)
                    exhausted = int(e.inv)
                    continue
                sanitize.check_booking(ledger, e.inv, "record_failure")
                ledger.record_failure(e.inv)
                report.failures += 1
                if plan is not None:
                    state.retry_at[(ri, int(e.inv))] = \
                        now + plan.backoff_s(int(ledger.attempts[e.inv]))
                continue
            sanitize.check_booking(ledger, e.inv, "record_success")
            ledger.record_success(int(e.inv), preds_rows[i])
            report.bill.add(BillingRecord(
                invocation=int(e.inv), duration_s=float(durs[i]),
                memory_mb=pool.memory_mb,
                retry=int(ledger.attempts[e.inv]),
                speculative=e.speculative))
        report.wave_sizes.append(len(entries))
        report.waves += 1
        if pool.simulate:
            # response time = slowest invocation in flight this wave
            report.response_time_s += float(np.max(durs)) \
                + pool.dispatch_overhead_s
        if exhausted is not None:
            raise RuntimeError(
                f"invocation {exhausted} exceeded retry budget")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
BACKENDS = {"wave": WaveBackend, "inline": InlineBackend,
            "sharded": ShardedBackend}
# "topology" resolves lazily in make_backend: serverless/topology.py
# imports this module, so eager registration would be a cycle
BACKEND_NAMES = tuple(BACKENDS) + ("topology",)


def make_backend(backend, pool: Optional[PoolConfig] = None):
    """Resolve a backend name (or pass through an instance)."""
    if isinstance(backend, str):
        if backend == "topology":
            from repro.serverless.topology import TopologyBackend
            return TopologyBackend(pool)
        if backend not in BACKENDS:
            raise KeyError(f"unknown backend {backend!r}; known: "
                           f"{BACKEND_NAMES}")
        return BACKENDS[backend](pool)
    return backend
