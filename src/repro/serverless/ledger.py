"""Durable task-state ledger — checkpoint/restart for the estimation run.

The ledger *is* the fault-tolerance mechanism (DESIGN.md §4): completed
invocations' predictions are durable; a restart re-dispatches only the
missing ones; worker loss mid-wave just leaves PENDING entries behind.
Serialization is msgpack (no pickle: restart may happen on another host).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Optional

import msgpack
import numpy as np

PENDING, RUNNING, DONE, FAILED = 0, 1, 2, 3


@dataclass
class TaskLedger:
    n_invocations: int
    n_obs: int
    tasks_per_invocation: int            # K for 'n_rep' scaling, else 1
    status: np.ndarray                   # (n_inv,) int8
    preds: np.ndarray                    # (n_inv, tasks_per_inv, N) f32
    attempts: np.ndarray                 # (n_inv,) int16
    path: Optional[str] = None           # bound by durable sessions

    @classmethod
    def create(cls, n_invocations: int, n_obs: int,
               tasks_per_invocation: int) -> "TaskLedger":
        return cls(
            n_invocations=n_invocations,
            n_obs=n_obs,
            tasks_per_invocation=tasks_per_invocation,
            status=np.zeros(n_invocations, np.int8),
            preds=np.zeros((n_invocations, tasks_per_invocation, n_obs),
                           np.float32),
            attempts=np.zeros(n_invocations, np.int16),
        )

    # ---- state transitions ----
    def pending(self) -> np.ndarray:
        """Invocations still owed results (PENDING, FAILED-awaiting-retry,
        or RUNNING rows orphaned by a crashed drain)."""
        return np.where(self.status != DONE)[0]

    def mark_running(self, invs) -> None:
        """Flag dispatched rows so a checkpoint taken mid-wave re-queues
        exactly the in-flight work on restart (load() resets RUNNING)."""
        invs = np.asarray(invs, np.int64)
        self.status[invs[self.status[invs] != DONE]] = RUNNING

    def record_success(self, inv: int, preds: np.ndarray):
        self.preds[inv] = preds
        self.status[inv] = DONE

    def record_successes(self, invs, preds_rows: np.ndarray):
        """Batch form: one bucket launch landing many invocations."""
        invs = np.asarray(invs, np.int64)
        self.preds[invs] = preds_rows
        self.status[invs] = DONE

    def record_failure(self, inv: int):
        self.status[inv] = FAILED
        self.attempts[inv] += 1

    @property
    def complete(self) -> bool:
        return bool((self.status == DONE).all())

    @property
    def n_done(self) -> int:
        return int((self.status == DONE).sum())

    # ---- durability ----
    def save(self, path: str):
        payload = {
            "n_invocations": self.n_invocations,
            "n_obs": self.n_obs,
            "tasks_per_invocation": self.tasks_per_invocation,
            "status": self.status.tobytes(),
            "attempts": self.attempts.tobytes(),
            # only DONE rows are worth persisting
            "done_idx": np.where(self.status == DONE)[0].astype(np.int64)
                          .tobytes(),
            "done_preds": self.preds[self.status == DONE].tobytes(),
        }
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        os.replace(tmp, path)            # atomic — a crash never corrupts

    def checkpoint(self) -> None:
        """Persist to the bound ``path`` (no-op for in-memory ledgers).
        Durable sessions bind the path at admission; backends call this
        after every booking wave, so a crash loses at most one wave of
        re-executable work and never a booked result."""
        if self.path is not None:
            self.save(self.path)

    @classmethod
    def load(cls, path: str) -> "TaskLedger":
        with open(path, "rb") as f:
            p = msgpack.unpackb(f.read(), raw=False)
        led = cls.create(p["n_invocations"], p["n_obs"],
                         p["tasks_per_invocation"])
        led.status = np.frombuffer(p["status"], np.int8).copy()
        led.attempts = np.frombuffer(p["attempts"], np.int16).copy()
        done_idx = np.frombuffer(p["done_idx"], np.int64)
        done = np.frombuffer(p["done_preds"], np.float32).reshape(
            len(done_idx), p["tasks_per_invocation"], p["n_obs"])
        led.preds[done_idx] = done
        # anything that was RUNNING when we died is re-dispatched
        led.status[led.status == RUNNING] = PENDING
        return led
