"""The topology layer (ISSUE 4 tentpole): per-mesh drain streams with
locality-aware bucket placement.

The drain engine so far ran one stream over one host mesh.  This module
models the *cluster*: a ``Topology`` of host meshes — real pods split out
of ``launch/mesh.py::make_production_mesh`` ("pod", "data", "model"), or
N simulated hosts over this process's devices — each owning a per-host
device-resident ``PagePool`` (all pools sharing one ``PageDirectory``)
and one drain stream.  ``TopologyBackend`` is the scheduler over them:

  * **placement** — every megabatch bucket is routed to a host by
    ``sharding/policy.py::place_bucket``, scored against each host's
    page residency (stack-cached > pages-resident > cold, ties to the
    least-loaded host).  Steady-state traffic therefore re-lands on the
    host already holding its pages: zero transfers of any kind.
  * **per-mesh streams** — one ``step()`` advances ONE host's stream by
    one wave (round-robin cursor), so the session's event loop
    interleaves all hosts exactly as it interleaves waves today;
    ledgers complete out of order across hosts as they do within one.
    Since ISSUE 5 each host stream owns an in-flight **dispatch queue**
    (serverless/dispatch.py): a wave launches its buckets without
    blocking, and results are booked by later steps' non-blocking
    harvest — so one mesh's device execution overlaps every other
    host's placement, stealing, and booking.
  * **work-stealing** — a host whose queue drained steals the
    least-local bucket from the most-loaded host
    (``policy.steal_choice``); the stolen bucket's pages arrive
    device-to-device from the holder (a *cross-host transfer*, counted
    by the directory) and stay resident, so a re-stolen bucket is free.
  * **autoscaling** — a ``TopologyAutoscaler`` sizes each host's wave
    independently, pricing cold candidates with the compiler's
    per-bucket roofline FLOP estimates
    (``launch/roofline.py::invocation_roofline_s``) until measured
    durations take over.
  * **axis planning** (ISSUE 8) — every bucket's parallelization axis
    is roofline-priced on its host's own mesh
    (``compile/buckets.py::plan_bucket_axis``): compute-heavy buckets
    dispatch as sharded-fused launches (shard_map around the lax.map
    fused body) through a per-host program cache built on that host's
    mesh, small serving buckets stay single-device, and data/feature
    decisions are *executed* in-mesh (ISSUE 9): ``dispatch_bucket``
    lowers them through the sharded Gram executors
    (sharding/gram.py), chunk-paging tall N, and stamps the
    ``executed`` axis back on the decision.  Tall-N Gram buckets
    (``n_pad > DEVICE_PAGE_ROWS``) are routed — and stolen — only by
    hosts whose data axis can stream them.  Decisions land on
    ``BackendRunInfo.axis_plans`` like autoscale decisions.
  * **fault tolerance** (ISSUE 10) — chaos pools draw identity-keyed
    failure/straggler verdicts at booking (serverless/chaos.py) exactly
    as the single-stream backends do; overdue buckets are hedged onto
    the least-loaded *other* live host through the shared
    bitwise-reference cache; and ``kill_host`` simulates losing a mesh
    mid-drain — its page pool is invalidated (directory detach), its
    in-flight buckets are abandoned (LOST), and their still-RUNNING
    ledger rows resurface through the pending view to be re-routed
    onto the survivors, whose pools re-materialize any orphaned pages
    on first touch.

Determinism: placement and stealing only decide *where* a bucket's
fixed-shape program runs; per-task PRNG streams are fixed at compile
time, so buckets the planner keeps on the task@1 layout (the whole
serving mix) are bitwise-identical to the single-host inline path
(tests/test_topology.py, gated in CI by BENCH_topology.json).  Buckets
routed to a host's sharded-fused cache inherit that path's parity
tier: bitwise on 1-device hosts, ~1e-6 float tolerance on multi-device
hosts (see the B_BLOCK caveat in compile/program.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.compile.pages import PageDirectory, PagePool, PageStats
from repro.serverless.autoscale import TopologyAutoscaler
from repro.serverless.backends import (
    BackendRunInfo, DrainState, PoolConfig, _compile, _StreamBackend,
    make_sharded_compiler, roofline_pending_inv_s,
)
from repro.serverless.chaos import chaos_plan
from repro.serverless.dispatch import (
    DispatchQueue, DispatchStats, PendingBucket,
)
from repro.sharding.policy import place_bucket, steal_choice


# ---------------------------------------------------------------------------
# the cluster model
# ---------------------------------------------------------------------------
@dataclass
class HostMesh:
    """One host: its device mesh, the lead device its page pool pins
    pages to, and the pool itself (directory-shared)."""
    host_id: int
    mesh: object                        # jax.sharding.Mesh of this host
    device: object                      # lead device (page residency)
    pool: PagePool

    @property
    def n_devices(self) -> int:
        return int(np.asarray(self.mesh.devices).size)


class Topology:
    """The set of host meshes one ``TopologyBackend`` schedules over.

    Pools (and therefore page residency) persist across drains — the
    topology is the warm state; drains come and go.
    """

    def __init__(self, hosts: List[HostMesh], directory: PageDirectory):
        self.hosts = hosts
        self.directory = directory
        self.dead: set = set()          # host_ids lost mid-flight

    def __len__(self) -> int:
        return len(self.hosts)

    def alive(self) -> List[HostMesh]:
        """The hosts still schedulable (host loss is permanent for the
        topology's lifetime — pools persist across drains, corpses
        don't come back)."""
        return [h for h in self.hosts if h.host_id not in self.dead]

    def kill(self, host_id: int) -> None:
        """Lose one host: invalidate its page pool (every resident page
        and stack dropped, directory withdrawn so no d2d fetch is ever
        brokered against its device memory) and mark it dead for
        routing/stealing.  In-flight work recovery is the backend's job
        (``TopologyBackend.kill_host``)."""
        if host_id in self.dead:
            return
        self.dead.add(host_id)
        self.hosts[host_id].pool.invalidate()

    @classmethod
    def _from_meshes(cls, meshes, page_pool_bytes: int) -> "Topology":
        directory = PageDirectory()
        hosts = []
        for i, mesh in enumerate(meshes):
            dev = np.asarray(mesh.devices).flat[0]
            hosts.append(HostMesh(
                host_id=i, mesh=mesh, device=dev,
                pool=PagePool(page_pool_bytes, host_id=i,
                              directory=directory, device=dev)))
        return cls(hosts, directory)

    @classmethod
    def simulated(cls, n_hosts: int,
                  page_pool_bytes: int = 256 * 1024 * 1024) -> "Topology":
        """N simulated hosts over this process's devices (the forced
        host-platform CI path)."""
        from repro.launch.mesh import make_sim_host_meshes
        return cls._from_meshes(make_sim_host_meshes(n_hosts),
                                page_pool_bytes)

    @classmethod
    def from_mesh(cls, mesh,
                  page_pool_bytes: int = 256 * 1024 * 1024) -> "Topology":
        """One host per index of the mesh's leading "pod" axis (the
        production ("pod", "data", "model") meshes); a pod-less mesh
        becomes a single-host topology."""
        from repro.launch.mesh import split_pod_meshes
        return cls._from_meshes(split_pod_meshes(mesh), page_pool_bytes)

    def page_stats(self) -> PageStats:
        """Cluster-wide page accounting (sum of the per-host pools)."""
        out = PageStats()
        for h in self.hosts:
            out = out.merge(h.pool.stats)
        return out


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------
@dataclass
class HostLaneInfo:
    """Per-host-stream accounting for one drain."""
    host_id: int
    n_devices: int
    waves: int = 0
    invocations: int = 0
    buckets_placed: int = 0             # routed here at admission
    steals: int = 0                     # buckets this host stole


@dataclass
class TopologyInfo:
    """Cross-host accounting for one topology drain (session telemetry:
    ``last_run_info.topology``)."""
    n_hosts: int
    hosts: List[HostLaneInfo]
    steals: int = 0
    placements: List[Tuple[object, int, float]] = field(
        default_factory=list)           # (bucket key, host, score)
    host_losses: int = 0                # hosts killed mid-drain
    lost_buckets: int = 0               # in-flight buckets abandoned


@dataclass
class TopologyDrainState(DrainState):
    """One continuous drain over all host streams: the shared bucket
    plan plus the live bucket→host assignment, the round-robin cursor
    the event loop steps with, and one in-flight dispatch queue per
    host mesh (the per-host streams are the dispatch unit)."""
    assignment: Dict[object, int] = field(default_factory=dict)
    cursor: int = 0
    queues: Dict[int, DispatchQueue] = field(default_factory=dict)

    def in_flight_entries(self) -> set:
        out = set()
        for q in self.queues.values():
            out |= q.in_flight_entries()
        return out


# ---------------------------------------------------------------------------
# the backend
# ---------------------------------------------------------------------------
class TopologyBackend(_StreamBackend):
    """Per-mesh drain streams with page-locality routing.

    One ``step(state)`` advances one host stream by one wave: the
    session's event loop therefore steps all streams round-robin, and a
    host's wave is sized by its own autoscaler lane.  Chaos pools draw
    identity-keyed fault verdicts at booking (serverless/chaos.py);
    overdue buckets hedge cross-host; ``kill_host`` loses a mesh
    mid-drain and the survivors finish every admitted request.  The
    reference for its results is the single-host inline path, bitwise.
    """
    name = "topology"

    def __init__(self, pool: Optional[PoolConfig] = None,
                 topology: Optional[Topology] = None,
                 n_hosts: Optional[int] = None):
        self.pool = pool or PoolConfig()
        if topology is None:
            topology = Topology.simulated(
                n_hosts if n_hosts is not None else self.pool.n_hosts,
                self.pool.page_pool_bytes or 0)
        self.topology = topology
        self.compiler = _compile().ProgramCache()
        # per-host sharded program caches (ISSUE 8): lazily built on each
        # host's own mesh so a bucket the axis planner prices as
        # task-parallel-over-the-mesh dispatches as a sharded-fused
        # launch on that mesh.  All host caches feed the shared
        # CompileStats so session telemetry stays one block.
        self._host_compilers: Dict[int, object] = {}
        self.autoscaler = TopologyAutoscaler(self.pool, len(topology)) \
            if self.pool.autoscale else None
        self.pages = None               # per-host pools live on the topology

    @property
    def _programs(self) -> Dict:
        return self.compiler._programs

    # ---- drain lifecycle ---------------------------------------------
    def begin_drain(self) -> TopologyDrainState:
        info = BackendRunInfo(backend=self.name)
        info.compile = self.compiler.stats
        info.pages = self.topology.page_stats()
        info.topology = TopologyInfo(
            n_hosts=len(self.topology),
            hosts=[HostLaneInfo(h.host_id, h.n_devices)
                   for h in self.topology.hosts])
        state = TopologyDrainState(plan=_compile().MegabatchPlan(),
                                   info=info)
        state.chaos = chaos_plan(self.pool)
        # one in-flight queue per host mesh, all feeding one stats block
        info.dispatch = DispatchStats()
        state.queues = {
            h.host_id: DispatchQueue(self.pool.max_inflight,
                                     stats=info.dispatch)
            for h in self.topology.hosts}
        return state

    # admit() is inherited: routing happens lazily in step() (one pass
    # over all unassigned buckets), so batch admission stays linear

    # ---- placement ----------------------------------------------------
    def _bucket_pkeys(self, state, key, entries) -> Tuple:
        """The bucket's page keys, one per request with pending entries
        (canonical blocks launch one request per program, so each page's
        singleton stack is the unit the policy probes)."""
        order: Dict[int, None] = {}
        for ri, _ in entries:
            order.setdefault(ri)
        return tuple(
            PagePool.page_key(state.requests[ri], key.n_pad, key.p_pad)
            for ri in order)

    def _loads(self, state, groups) -> List[int]:
        """Pending invocations currently assigned to each host."""
        loads = [0] * len(self.topology)
        for key, entries in groups.items():
            h = state.assignment.get(key)
            if h is not None:
                loads[h] += len(entries)
        return loads

    def _eligible_hosts(self, key) -> List[int]:
        """The hosts a bucket may be routed to.  Tall-N Gram buckets
        (``n_pad > DEVICE_PAGE_ROWS``: no single device holds the page,
        so the drain must chunk-stream them data-parallel, ISSUE 9) go
        only to hosts whose mesh can stream them — the largest data-axis
        size that divides ``n_pad``; every other bucket runs anywhere.
        Dead hosts are never eligible."""
        hosts = [h.host_id for h in self.topology.alive()]
        from repro.compile.program import bucket_family
        from repro.launch.roofline import DEVICE_PAGE_ROWS, GRAM_FAMILIES
        if key.n_pad <= DEVICE_PAGE_ROWS \
                or bucket_family(key) not in GRAM_FAMILIES:
            return hosts

        def axis_m(h: int) -> int:
            mesh = self.topology.hosts[h].mesh
            return int(mesh.shape["data"]) \
                if "data" in mesh.axis_names else 1

        ok = [h for h in hosts if key.n_pad % axis_m(h) == 0]
        if not ok:                      # nothing divides: route anywhere,
            return hosts                # dispatch falls back to task axis
        best = max(axis_m(h) for h in ok)
        return [h for h in ok if axis_m(h) == best]

    def _route(self, state: TopologyDrainState, groups) -> None:
        """Assign every not-yet-routed bucket to its best host among the
        bucket's eligible set (loads maintained incrementally)."""
        pools = [h.pool for h in self.topology.hosts]
        loads = self._loads(state, groups)
        for key, entries in groups.items():
            if key in state.assignment:
                continue
            elig = self._eligible_hosts(key)
            placed = place_bucket(self._bucket_pkeys(state, key, entries),
                                  [pools[h] for h in elig],
                                  [loads[h] for h in elig])
            host = elig[placed.host]
            state.assignment[key] = host
            loads[host] += len(entries)
            info = state.info.topology
            info.hosts[host].buckets_placed += 1
            info.placements.append((key, host, placed.score))

    def _try_steal(self, state: TopologyDrainState, groups,
                   thief: int) -> List:
        """An idle host takes the least-local bucket from the most
        loaded host; the migration is recorded and the assignment
        flipped so the thief finishes the bucket."""
        queues: Dict[int, List] = {}
        for key in groups:
            h = state.assignment[key]
            # a host can only steal buckets it is eligible to stream
            # (tall-N Gram buckets stay on streaming-capable meshes)
            if h != thief and thief in self._eligible_hosts(key):
                queues.setdefault(h, []).append(key)
        pools = [h.pool for h in self.topology.hosts]
        pick = steal_choice(
            queues, pools,
            lambda k: self._bucket_pkeys(state, k, groups[k]))
        if pick is None:
            return []
        _, key = pick
        state.assignment[key] = thief
        info = state.info.topology
        info.steals += 1
        info.hosts[thief].steals += 1
        return [key]

    # ---- per-bucket axis planning (ISSUE 8) ---------------------------
    def _host_compiler(self, host_id: int):
        """This host's sharded-fused program cache, lazily built on its
        own mesh.  Shares the backend-wide CompileStats so per-host
        caches don't fragment session telemetry."""
        cache = self._host_compilers.get(host_id)
        if cache is None:
            cache = make_sharded_compiler(self.topology.hosts[host_id].mesh)
            cache.stats = self.compiler.stats
            self._host_compilers[host_id] = cache
        return cache

    def _plan_host_axis(self, state, key, entries, host_id: int):
        """Price the bucket's axis candidates on the owning host's mesh
        (once per (bucket, mesh size) per drain) and log the decision."""
        host = self.topology.hosts[host_id]
        memo_key = (key, host.n_devices)
        if memo_key in state.axis_planned:
            return state.axis_planned[memo_key]
        from repro.compile.buckets import plan_bucket_axis
        decision = plan_bucket_axis(key, n_tasks=len(entries),
                                    n_devices=host.n_devices)
        state.axis_planned[memo_key] = decision
        if decision is not None:
            state.info.axis_plans.append(decision)
        return decision

    def _bucket_compiler(self, host_id: int, decision):
        """(program cache, b_align, axis mesh) one bucket dispatches
        through on this host: the host-mesh sharded-fused cache when
        the planner picked an m-way task layout; the shared
        single-device cache *plus the host's mesh* when it picked a
        data/feature layout — ``dispatch_bucket`` lowers those through
        the in-mesh Gram executors (sharding/gram.py, ISSUE 9),
        chunk-paging tall N; the shared cache alone otherwise."""
        if decision is not None and decision.axis == "task" \
                and decision.shards > 1 \
                and self.topology.hosts[host_id].n_devices > 1:
            return self._host_compiler(host_id), decision.shards, None
        if decision is not None and decision.axis in ("data", "feature"):
            return self.compiler, 1, self.topology.hosts[host_id].mesh
        return self.compiler, 1, None

    # ---- the per-host wave --------------------------------------------
    def _wave_capacity(self, state, host_id: int, mine, groups) -> int:
        pool = self.pool
        if pool.worker_schedule is not None:   # legacy static ramp, per
            sched = pool.worker_schedule       # host stream (wave parity)
            waves_done = state.info.topology.hosts[host_id].waves
            w = sched[min(waves_done, len(sched) - 1)]
            return max(1, w * pool.lanes_per_worker())
        if self.autoscaler is None:
            return max(1, pool.n_workers * pool.lanes_per_worker())
        depth = sum(len(groups[k]) for k in mine)
        tasks = sum(
            state.requests[ri].grid.tasks_per_invocation(
                state.requests[ri].scaling)
            for k in mine for ri, _ in groups[k])
        decision = self.autoscaler.decide(
            host_id, depth,
            tasks_per_invocation=max(1, tasks // max(depth, 1)),
            padding_waste=self.compiler.stats.padding.waste_frac,
            # dispatched-but-unharvested work on this host's stream is
            # occupancy, not queue depth — never provisioned for twice
            in_flight=state.queues[host_id].in_flight,
            roofline_inv_s=lambda: roofline_pending_inv_s(
                state.requests, {k: groups[k] for k in mine}))
        state.info.autoscale.append(decision)
        return max(1, decision.n_workers * pool.lanes_per_worker())

    def _book_harvest(self, state: TopologyDrainState, pb: PendingBucket,
                      results: Dict, elapsed: float):
        """Booking callback fired at harvest: ledgers, bills, autoscaler
        EMA for the launching host, wave close-out, checkpoint."""
        per_req = self._book_direct(state, pb.entries, results, elapsed)
        if self.autoscaler is not None and pb.entries:
            self.autoscaler.observe(pb.host, elapsed / len(pb.entries))
        if per_req:                     # a fully-failed slice books nothing
            self._note_wave(state, list(per_req), elapsed)
        state.info.pages = self.topology.page_stats()
        self._checkpoint(state)

    def _host_wave(self, state: TopologyDrainState, host_id: int,
                   mine: List, groups) -> None:
        """Dispatch one wave of this host's buckets WITHOUT waiting —
        the launches land in the host's in-flight queue and are booked
        by a later step's harvest, so every other host's placement,
        stealing, and booking overlaps this mesh's execution."""
        host = self.topology.hosts[host_id]
        # a zero byte budget means "pool off" (PoolConfig contract):
        # fall back to host page stacking instead of churning an
        # always-evicting device pool
        host_pages = host.pool if host.pool.byte_budget > 0 else None
        lane = state.info.topology.hosts[host_id]
        q = state.queues[host_id]
        book = lambda pb, res, el: self._book_harvest(state, pb, res, el)
        capacity = self._wave_capacity(state, host_id, mine, groups)
        # fill the wave bucket-by-bucket, truncating the last bucket to
        # the remaining capacity; each selection takes at least one
        # invocation, so a wave always makes progress
        selected: List[Tuple[object, List]] = []
        taken = 0
        for key in mine:
            if taken >= capacity and selected:
                break
            ents = groups[key][:max(capacity - taken, 1)]
            selected.append((key, ents))
            taken += len(ents)
        for key, ents in selected:
            running: Dict[int, List[int]] = {}
            for ri, inv in ents:
                running.setdefault(ri, []).append(inv)
            for ri, invs in running.items():
                state.requests[ri].ledger.mark_running(invs)
            decision = self._plan_host_axis(state, key, ents, host_id)
            compiler, b_align, axis_mesh = self._bucket_compiler(
                host_id, decision)
            opts = dict(self._dispatch_opts())
            # fusion follows the *chosen* cache, not the shared one: a
            # host's sharded-fused cache fuses, a partition-only cache
            # would not (compile/program.py gate)
            opts["fuse"] = self.pool.fuse and (
                compiler.partition is None
                or compiler.partition_fused is not None)
            bd = _compile().dispatch_bucket(
                state.plan, compiler, key, ents, pages=host_pages,
                b_align=b_align, axis_decision=decision, mesh=axis_mesh,
                **opts)
            self._push_bucket(state, q, bd, book, host=host_id)
            state.seen_buckets.add(key)
        lane.waves += 1
        lane.invocations += taken
        state.info.waves += 1
        state.info.buckets = len(state.seen_buckets)
        state.info.pages = self.topology.page_stats()

    # ---- fault tolerance ----------------------------------------------
    def _maybe_hedge(self, state: TopologyDrainState) -> int:
        """Cross-host hedging: the duplicate leg of an overdue bucket
        lands on the least-loaded *other* live host and dispatches
        through the shared single-device cache — the bitwise-reference
        path — so whichever leg wins the booked rows are identical
        regardless of either host's axis plan."""
        if not self._hedge_armed(state):
            return 0
        ids = [h.host_id for h in self.topology.alive()
               if h.host_id in state.queues]
        n = 0
        for hid in ids:
            q = state.queues[hid]
            for pb in q.overdue():
                others = [i for i in ids if i != hid] or [hid]
                target = min(
                    others, key=lambda i: state.queues[i].in_flight)
                tpool = self.topology.hosts[target].pool
                self._hedge_bucket(
                    state, pb, q, state.queues[target],
                    compiler=self.compiler,
                    pages=tpool if tpool.byte_budget > 0 else None,
                    host=target)
                n += 1
        return n

    def kill_host(self, state: TopologyDrainState, host_id: int) -> int:
        """Lose one host mid-drain (the chaos suite's host-loss fault):
        its pool is invalidated, its queue's in-flight buckets are
        abandoned (sole abandon performer — their ledger rows stay
        RUNNING, so once the dead queue stops shadowing them the
        pending view resurfaces exactly the orphaned invocations), and
        its bucket assignments are cleared so ``_route`` re-places them
        on the survivors, whose pools re-materialize any orphaned pages
        on first touch.  Returns the number of abandoned buckets."""
        topo = self.topology
        if host_id in topo.dead:
            return 0
        topo.kill(host_id)
        q = state.queues.pop(host_id, None)
        orphans = q.abandon() if q is not None else []
        for key in [k for k, h in state.assignment.items()
                    if h == host_id]:
            del state.assignment[key]
        info = state.info.topology
        info.host_losses += 1
        info.lost_buckets += len(orphans)
        state.info.pages = topo.page_stats()
        return len(orphans)

    # ---- the stream scheduler -----------------------------------------
    def step(self, state: TopologyDrainState) -> bool:
        """Advance ONE host stream by one wave (round-robin); False once
        no host has pending or in-flight work.  Every step first books
        any landed buckets on any host (non-blocking) and hedges any
        overdue ones, so harvest is interleaved with — and overlapped
        by — dispatch on other hosts."""
        book = lambda pb, res, el: self._book_harvest(state, pb, res, el)
        for q in state.queues.values():
            q.harvest_ready(book)
        self._maybe_hedge(state)
        groups = state.plan.pending_by_bucket(
            exclude=state.in_flight_entries())
        gate_wait = None
        if state.retry_at:              # failed rows awaiting backoff
            gated = {}
            for key in list(groups):
                ents, wait = self._backoff_filter(state, groups[key])
                if wait is not None:
                    gate_wait = wait if gate_wait is None \
                        else min(gate_wait, wait)
                if ents:
                    gated[key] = ents
            groups = gated
        ids = [h.host_id for h in self.topology.alive()
               if h.host_id in state.queues]
        n = len(ids)
        if not groups:
            if not n:
                return False
            # nothing dispatchable: drain in-flight work.  A blocking
            # harvest would sleep out a held straggler's hold and
            # defeat the hedge race, so with hedging armed poll instead
            if self._hedge_armed(state) \
                    and any(not state.queues[h].empty for h in ids):
                if not sum(state.queues[h].harvest_ready(book)
                           for h in ids):
                    time.sleep(0.001)
                return True
            for off in range(n):
                h = ids[(state.cursor + off) % n]
                if state.queues[h].harvest_next(book):
                    state.cursor = (state.cursor + off + 1) % n
                    return True
            if gate_wait is not None:   # only backoff gates remain
                time.sleep(min(gate_wait, 0.05))
                return True
            return False
        self._route(state, groups)      # retries may resurface buckets
        for off in range(n):
            h = ids[(state.cursor + off) % n]
            mine = [k for k in groups if state.assignment[k] == h]
            if not mine and self.pool.steal:
                mine = self._try_steal(state, groups, h)
            if not mine:
                continue
            self._host_wave(state, h, mine, groups)
            state.cursor = (state.cursor + off + 1) % n
            return True
        return False
