"""Removed single-request executor facade — pure re-exports remain.

``ServerlessExecutor`` (the PR-0 raw-array front door) is gone: every
front-end now goes through the one execution path — ``DMLPlan`` +
``repro.core.session`` (``DMLSession`` / ``estimate``) over the streaming
backends in ``repro.serverless.backends``.  Raw-array workloads with an
opaque learner callable lower through
``repro.core.session.compile_raw_request`` and any backend's
``run_requests``; see README "Migration" for the call-shape mapping.

This module is kept only so old ``from repro.serverless.executor import
PoolConfig`` imports keep working (``DMLSession``/``estimate`` re-export
lazily to avoid a core <-> serverless import cycle).
"""
from __future__ import annotations

from repro.serverless.backends import (                    # noqa: F401
    PoolConfig, RunReport, Segment, WaveBackend, WorkRequest,
)

__all__ = ["DMLSession", "estimate", "PoolConfig", "RunReport", "Segment",
           "WaveBackend", "WorkRequest"]


def __getattr__(name):
    if name in ("DMLSession", "estimate"):
        from repro.core import session
        return getattr(session, name)
    if name == "ServerlessExecutor":
        raise AttributeError(
            "ServerlessExecutor was removed; use repro.core.DMLSession / "
            "estimate(plan, data), or compile_raw_request + "
            "backend.run_requests for raw-array workloads (README "
            "'Migration').")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
