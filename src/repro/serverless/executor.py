"""The serverless-analogue executor (paper §4 adapted to a device mesh).

One *invocation* = the paper's lambda call:
  scaling='n_rep'          -> M*L invocations, each fitting K folds
  scaling='n_folds*n_rep'  -> M*K*L invocations, one fold each

Execution model (DESIGN.md §2): a *wave* dispatches up to
``n_workers * lanes_per_worker`` invocations; all lanes of a wave run as one
fused/vmapped batch (dense MXU work), the TPU-native replacement for FaaS
concurrency.  Between waves the scheduler:

  * injects faults (configurable rate) and re-queues failures (Lambda retry),
  * duplicates straggler invocations (speculative execution, first-result-wins),
  * re-reads the worker count (elastic shrink/grow),
  * checkpoints the ledger (durable task state).

Billing: per-invocation durations are either measured (CPU wall time of the
wave divided over its lanes, ``simulate=False``) or modeled through the
Lambda memory/vCPU speed curve (``simulate=True``, reproduces Fig. 3).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.crossfit import TaskGrid
from repro.serverless.cost import Bill, BillingRecord, speedup_of
from repro.serverless.ledger import DONE, TaskLedger


@dataclass
class PoolConfig:
    """The knobs the paper's user controls (§4.2, §5.2)."""
    n_workers: int = 8                  # concurrent lambda-analogue workers
    memory_mb: int = 1024               # Lambda memory knob
    scaling: str = "n_rep"              # paper's scaling parameter
    timeout_s: float = 900.0            # Lambda 15-min cap
    max_retries: int = 3
    failure_rate: float = 0.0           # fault injection (per invocation)
    straggler_rate: float = 0.0         # P(invocation is a straggler)
    straggler_slowdown: float = 4.0
    speculative_after: float = 2.0      # duplicate if > x median duration
    simulate: bool = False              # model durations via the speed curve
    base_work_s: float = 0.0            # simulated seconds per task @1 vCPU
    dispatch_overhead_s: float = 0.005  # per-wave dispatch latency
    seed: int = 0
    checkpoint_path: Optional[str] = None
    # elasticity: optional schedule of worker counts per wave (grow/shrink)
    worker_schedule: Optional[Sequence[int]] = None


@dataclass
class RunReport:
    fit_time_s: float = 0.0
    response_time_s: float = 0.0
    waves: int = 0
    bill: Bill = field(default_factory=Bill)
    wave_sizes: List[int] = field(default_factory=list)
    failures: int = 0
    stragglers: int = 0

    def summary(self) -> Dict:
        out = {"fit_time_s": self.fit_time_s,
               "response_time_s": self.response_time_s,
               "waves": self.waves, "failures": self.failures,
               "stragglers": self.stragglers}
        out.update(self.bill.summary())
        return out


class ServerlessExecutor:
    """Runs a DML task grid through the wave scheduler.

    learner_fn(x (N,P), y (T,N), w (T,N), key) -> (T,N) — the fused batch
    fit; T is the number of *tasks* in the wave (invocations x K for
    per-split scaling).
    """

    def __init__(self, learner_fn: Callable, grid: TaskGrid,
                 pool: PoolConfig):
        self.learner_fn = learner_fn
        self.grid = grid
        self.pool = pool
        self._rng = np.random.Generator(np.random.Philox(key=pool.seed))

    # -- mapping between invocations and (m, k, l) task tensors -------------
    def _invocation_tasks(self, inv: np.ndarray):
        """(B,) invocation ids -> (B, tpi) flat task ids (m*K+k)*L+l."""
        g, s = self.grid, self.pool.scaling
        if s == "n_rep":
            m, l = np.divmod(inv, g.n_nuisance)
            k = np.arange(g.n_folds)
            return ((m[:, None] * g.n_folds + k[None, :]) * g.n_nuisance
                    + l[:, None])
        return inv[:, None]

    @property
    def tasks_per_invocation(self) -> int:
        return self.grid.n_folds if self.pool.scaling == "n_rep" else 1

    def lanes_per_worker(self) -> int:
        """Worker 'memory' buys lane width (DESIGN.md §2 mapping)."""
        return max(1, self.pool.memory_mb // 256)

    # -- main loop -----------------------------------------------------------
    def run(self, x, targets, train_w, key,
            ledger: Optional[TaskLedger] = None,
            report: Optional[RunReport] = None):
        """x: (N,P); targets: (L,N); train_w: (M,K,L,N) training weights.

        Returns (preds (M,K,L,N), ledger, report).
        """
        g, pool = self.grid, self.pool
        n_obs = x.shape[0]
        n_inv = g.n_invocations(pool.scaling)
        tpi = self.tasks_per_invocation
        if ledger is None:
            ledger = TaskLedger.create(n_inv, n_obs, tpi)
        report = report or RunReport()

        m_idx, k_idx, l_idx = np.meshgrid(
            np.arange(g.n_rep), np.arange(g.n_folds),
            np.arange(g.n_nuisance), indexing="ij")
        flat_m = m_idx.reshape(-1)
        flat_k = k_idx.reshape(-1)
        flat_l = l_idx.reshape(-1)

        t_start = time.perf_counter()
        wave = 0
        durations_seen: List[float] = []
        while not ledger.complete:
            n_workers = pool.n_workers
            if pool.worker_schedule is not None:
                n_workers = pool.worker_schedule[
                    min(wave, len(pool.worker_schedule) - 1)]
            capacity = max(1, n_workers * self.lanes_per_worker())
            pending = ledger.pending()
            batch = pending[:capacity]
            # straggler duplication: re-dispatch slowest-suspect half-done
            # work speculatively when there is spare capacity
            spare = capacity - len(batch)
            spec: np.ndarray = np.empty(0, np.int64)
            if spare > 0 and pool.straggler_rate > 0 and len(batch):
                spec = batch[: min(spare, len(batch))]
            dispatch = np.concatenate([batch, spec]).astype(np.int64)

            task_ids = self._invocation_tasks(dispatch)      # (B, tpi)
            flat_tasks = task_ids.reshape(-1)
            tm, tk, tl = flat_m[flat_tasks], flat_k[flat_tasks], flat_l[flat_tasks]
            y_wave = targets[tl]                             # (B*tpi, N)
            w_wave = train_w[tm, tk, tl]                     # (B*tpi, N)

            key, sub = jax.random.split(key)
            t0 = time.perf_counter()
            preds = self.learner_fn(x, jnp.asarray(y_wave),
                                    jnp.asarray(w_wave), sub)
            preds = np.asarray(jax.block_until_ready(preds), np.float32)
            wave_wall = time.perf_counter() - t0
            preds = preds.reshape(len(dispatch), tpi, n_obs)

            # --- per-invocation durations (measured or simulated) ----------
            if pool.simulate:
                base = pool.base_work_s * tpi / speedup_of(pool.memory_mb)
                noise = self._rng.lognormal(0.0, 0.08, len(dispatch))
                durs = base * noise
            else:
                durs = np.full(len(dispatch),
                               wave_wall / max(len(dispatch), 1))
            # stragglers
            is_strag = self._rng.random(len(dispatch)) < pool.straggler_rate
            durs = np.where(is_strag, durs * pool.straggler_slowdown, durs)
            report.stragglers += int(is_strag.sum())
            # fault injection (first-attempt only so retries converge)
            first_try = ledger.attempts[dispatch] == 0
            failed = (self._rng.random(len(dispatch)) < pool.failure_rate) \
                & first_try
            # lambda timeout cap
            timed_out = durs > pool.timeout_s
            failed |= timed_out

            for i, inv in enumerate(dispatch):
                if ledger.status[inv] == DONE:     # speculative lost the race
                    continue
                if failed[i]:
                    if ledger.attempts[inv] >= pool.max_retries:
                        raise RuntimeError(
                            f"invocation {inv} exceeded retry budget")
                    ledger.record_failure(inv)
                    report.failures += 1
                    continue
                ledger.record_success(int(inv), preds[i])
                report.bill.add(BillingRecord(
                    invocation=int(inv), duration_s=float(durs[i]),
                    memory_mb=pool.memory_mb,
                    retry=int(ledger.attempts[inv]),
                    speculative=bool(i >= len(batch))))
                durations_seen.append(float(durs[i]))

            report.wave_sizes.append(len(dispatch))
            wave += 1
            report.waves = wave
            if pool.checkpoint_path:
                ledger.save(pool.checkpoint_path)
            if pool.simulate:
                # response time = slowest invocation in flight per wave
                report.response_time_s += float(np.max(durs)) \
                    + pool.dispatch_overhead_s

        if not pool.simulate:
            report.response_time_s = time.perf_counter() - t_start
        report.fit_time_s = (time.perf_counter() - t_start
                             if not pool.simulate
                             else report.response_time_s
                             + pool.dispatch_overhead_s)

        # ---- scatter ledger rows back to the (M,K,L,N) tensor -------------
        all_inv = np.arange(n_inv)
        task_ids = self._invocation_tasks(all_inv).reshape(-1)
        out = np.zeros((g.n_rep, g.n_folds, g.n_nuisance, n_obs), np.float32)
        out[flat_m[task_ids], flat_k[task_ids], flat_l[task_ids]] = \
            ledger.preds.reshape(-1, n_obs)
        return out, ledger, report
