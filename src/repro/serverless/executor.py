"""DEPRECATED compat shim — pure re-exports, removed next release.

``ServerlessExecutor`` (the PR-0 raw-array front door) is gone: every
front-end now goes through the one execution path — ``DMLPlan`` +
``repro.core.session`` (``DMLSession`` / ``estimate``) over the streaming
backends in ``repro.serverless.backends``.  Raw-array workloads with an
opaque learner callable lower through
``repro.core.session.compile_raw_request`` and any backend's
``run_requests``; see README "Migration" for the call-shape mapping.

This module is kept only so old ``from repro.serverless.executor import
PoolConfig`` imports keep working (``DMLSession``/``estimate`` re-export
lazily to avoid a core <-> serverless import cycle).  Importing it now
emits a ``DeprecationWarning`` — this is the one release of notice
before the module is deleted; import from ``repro.serverless`` /
``repro.core`` instead.
"""
from __future__ import annotations

import warnings

from repro.serverless.backends import (                    # noqa: F401
    PoolConfig, RunReport, Segment, WaveBackend, WorkRequest,
)

_DEPRECATION_MSG = (
    "repro.serverless.executor is deprecated and will be removed in the "
    "next release: import PoolConfig/RunReport/Segment/WaveBackend/"
    "WorkRequest from repro.serverless, and DMLSession/estimate from "
    "repro.core, instead.")

warnings.warn(_DEPRECATION_MSG, DeprecationWarning, stacklevel=2)

__all__ = ["DMLSession", "estimate", "PoolConfig", "RunReport", "Segment",
           "WaveBackend", "WorkRequest"]


def __getattr__(name):
    if name in ("DMLSession", "estimate"):
        warnings.warn(_DEPRECATION_MSG, DeprecationWarning, stacklevel=2)
        from repro.core import session
        return getattr(session, name)
    if name == "ServerlessExecutor":
        raise AttributeError(
            "ServerlessExecutor was removed; use repro.core.DMLSession / "
            "estimate(plan, data), or compile_raw_request + "
            "backend.run_requests for raw-array workloads (README "
            "'Migration').")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
