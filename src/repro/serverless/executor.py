"""Deprecated single-request executor facade.

The wave scheduler now lives in ``repro.serverless.backends.WaveBackend``
(together with the Sharded and Inline backends) and natively batches many
requests into shared waves over the megabatch compiler.
``ServerlessExecutor`` is kept as a thin adapter for the legacy call shape

    executor = ServerlessExecutor(learner_fn, grid, pool)
    preds, ledger, report = executor.run(x, targets, train_w, key)

Request assembly lives in ``core.session.compile_raw_request`` — the same
single execution path every front-end uses; this module no longer builds
``WorkRequest``s itself.  ``PoolConfig`` and ``RunReport`` are re-exported
from backends for backward compatibility.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.crossfit import TaskGrid
from repro.serverless.backends import (        # noqa: F401  (re-exports)
    PoolConfig, RunReport, Segment, WaveBackend, WorkRequest,
)
from repro.serverless.ledger import TaskLedger


class ServerlessExecutor:
    """Runs one DML task grid through the wave scheduler.

    learner_fn(x (N,P), y (T,N), w (T,N), key) -> (T,N) — the fused batch
    fit; T is the number of *tasks* in the wave (invocations x K for
    per-split scaling).
    """

    def __init__(self, learner_fn: Callable, grid: TaskGrid,
                 pool: PoolConfig):
        self.learner_fn = learner_fn
        self.grid = grid
        self.pool = pool

    # -- legacy introspection helpers ---------------------------------------
    def _invocation_tasks(self, inv: np.ndarray) -> np.ndarray:
        """(B,) invocation ids -> (B, tpi) flat task ids (m*K+k)*L+l."""
        return self.grid.invocation_task_ids(inv, self.pool.scaling)

    @property
    def tasks_per_invocation(self) -> int:
        return self.grid.tasks_per_invocation(self.pool.scaling)

    def lanes_per_worker(self) -> int:
        return self.pool.lanes_per_worker()

    # -- main entry ----------------------------------------------------------
    def run(self, x, targets, train_w, key,
            ledger: Optional[TaskLedger] = None,
            report: Optional[RunReport] = None):
        """x: (N,P); targets: (L,N); train_w: (M,K,L,N) training weights.

        Returns (preds (M,K,L,N), ledger, report).
        """
        from repro.core.session import compile_raw_request
        req = compile_raw_request(self.grid, self.pool.scaling, x, targets,
                                  train_w, self.learner_fn, key,
                                  ledger=ledger, report=report)
        WaveBackend(self.pool).run_requests([req])
        return req.gathered_preds(), req.ledger, req.report
