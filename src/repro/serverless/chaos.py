"""Order-independent fault plans for the chaos-hardened drain (ISSUE 10).

The legacy wave scheduler drew faults from ONE sequential Philox stream
per admitted request, which pinned the *draw order*: any scheduler that
dispatched invocations in a different order (bucket-coherent fill, the
two-deep pipeline, hedged duplicates, retries after a host loss) saw a
different fault pattern, so chaos pools were forced onto the
wave-synchronous slow path.  This module replaces the stream with a
**fault plan**: every verdict is a pure function of the invocation's
identity —

    verdict(slot, invocation, attempt)
        = f(Philox(key=pool.seed, counter=[0, attempt, inv, slot]))

where ``slot`` is the request's admission index.  Distinct identities
occupy disjoint counter blocks of one keyed Philox-4x64 cipher, so the
draws are independent, reproducible, and — the property the fast path
needs — **independent of the order anything asks for them**.  A
bucket-coherent pipelined drain, a host-killed rerouted drain, and a
crash-resumed drain all see the same fault schedule for the same pool.

Semantics (matching the legacy wave scheduler where it had them):

  * an *injected* failure fires only on attempt 0, so retries converge
    within the default budget; simulated durations are redrawn per
    attempt (attempt is part of the counter), so timeout-induced
    failures can repeat and genuinely consume the retry budget;
  * stragglers multiply the billed duration by
    ``pool.straggler_slowdown`` and, when ``pool.straggler_hold_s`` is
    set, delay the bucket's readiness — the synthetic long tail the
    deadline/hedge machinery (serverless/dispatch.py) exists to cut;
  * simulated durations follow the paper's speed curve with lognormal
    noise, exactly as before.

Retry scheduling is **capped exponential backoff**
(``backoff_s(attempt) = min(base * 2**(attempt-1), cap)``): a failed
invocation re-enters the pending view but is not re-dispatched before
its gate matures (backends track the gates in ``DrainState.retry_at``).

``REPRO_CHAOS`` arms a plan on pools that configured none — the CI chaos
job runs the ordinary suites under injected faults this way.  Accepted
forms: ``1`` (default 10% failures, 10% stragglers) or
``fail=<rate>,strag=<rate>``.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

# default rates REPRO_CHAOS=1 arms (the CI chaos job's setting)
ENV_FAILURE_RATE = 0.1
ENV_STRAGGLER_RATE = 0.1


@dataclass(frozen=True)
class Verdict:
    """One invocation-attempt's fate, drawn from its identity stream."""
    failed: bool                 # injected failure (attempt 0 only)
    straggler: bool              # duration multiplied by the slowdown
    noise: float                 # lognormal duration noise (simulate mode)


def env_chaos_rates() -> Optional[Tuple[float, float]]:
    """(failure_rate, straggler_rate) armed by ``REPRO_CHAOS``, or None.

    Read per call (tests flip it with monkeypatch.setenv), like the
    sanitizer's ``REPRO_SANITIZE``.
    """
    raw = os.environ.get("REPRO_CHAOS", "")
    if raw in ("", "0"):
        return None
    if raw == "1":
        return (ENV_FAILURE_RATE, ENV_STRAGGLER_RATE)
    rates = {"fail": ENV_FAILURE_RATE, "strag": ENV_STRAGGLER_RATE}
    for part in raw.split(","):
        k, _, v = part.partition("=")
        k = k.strip()
        if k in rates and v:
            rates[k] = float(v)
    return (rates["fail"], rates["strag"])


@dataclass(frozen=True)
class ChaosPlan:
    """Deterministic per-(slot, invocation, attempt) fault draws.

    Frozen value object: backends build one per pool and share it across
    drains; every query opens a fresh counter-keyed generator, so the
    plan itself carries no mutable stream state to corrupt or reorder.
    """
    failure_rate: float
    straggler_rate: float
    straggler_slowdown: float
    simulate: bool
    seed: int
    backoff_base_s: float = 0.0
    backoff_cap_s: float = 0.25

    def _rng(self, slot: int, inv: int, attempt: int) -> np.random.Generator:
        # Philox-4x64: 128-bit key from the pool seed, 256-bit counter
        # carrying the identity in its high words.  A verdict consumes a
        # handful of 4x64 blocks (low word), so distinct identities can
        # never overlap streams.
        return np.random.Generator(np.random.Philox(
            key=self.seed,
            counter=[0, int(attempt), int(inv), int(slot)]))

    def verdict(self, slot: int, inv: int, attempt: int) -> Verdict:
        """The invocation-attempt's fate.  Pure function of identity:
        any dispatch order, bucketization, hedge race, or resume sees
        the same verdict."""
        rng = self._rng(slot, inv, attempt)
        u_fail = rng.random()
        u_strag = rng.random()
        noise = rng.lognormal(0.0, 0.08) if self.simulate else 1.0
        return Verdict(
            failed=bool(u_fail < self.failure_rate) and attempt == 0,
            straggler=bool(u_strag < self.straggler_rate),
            noise=float(noise))

    def backoff_s(self, attempt: int) -> float:
        """Capped exponential retry backoff after the ``attempt``-th
        failure (attempt >= 1): base, 2*base, 4*base, ... capped."""
        if self.backoff_base_s <= 0:
            return 0.0
        return min(self.backoff_base_s * (2.0 ** (max(attempt, 1) - 1)),
                   self.backoff_cap_s)


def chaos_plan(pool) -> Optional[ChaosPlan]:
    """The pool's fault plan, or None for a fault-free pool (the hot
    path then pays nothing — no draws, no generator inits).

    A pool with its own rates (or ``simulate``) uses them; otherwise
    ``REPRO_CHAOS`` may arm the environment rates (CI chaos job).
    """
    failure, straggler = pool.failure_rate, pool.straggler_rate
    if not (pool.simulate or failure > 0 or straggler > 0):
        env = env_chaos_rates()
        if env is None:
            return None
        failure, straggler = env
    return ChaosPlan(
        failure_rate=failure,
        straggler_rate=straggler,
        straggler_slowdown=pool.straggler_slowdown,
        simulate=pool.simulate,
        seed=pool.seed,
        backoff_base_s=pool.retry_backoff_s,
        backoff_cap_s=pool.retry_backoff_cap_s)
