from repro.serverless.backends import (
    BACKEND_NAMES, BACKENDS, BackendRunInfo, ExecutionBackend, InlineBackend,
    PoolConfig, RunReport, Segment, ShardedBackend, WaveBackend, WorkRequest,
    make_backend,
)
from repro.serverless.cost import Bill, BillingRecord, speedup_of, USD_PER_GB_S
from repro.serverless.executor import ServerlessExecutor
from repro.serverless.ledger import TaskLedger

__all__ = [
    "Bill", "BillingRecord", "speedup_of", "USD_PER_GB_S", "PoolConfig",
    "RunReport", "ServerlessExecutor", "TaskLedger", "ExecutionBackend",
    "BackendRunInfo", "InlineBackend", "WaveBackend", "ShardedBackend",
    "WorkRequest", "Segment", "BACKENDS", "BACKEND_NAMES", "make_backend",
]
