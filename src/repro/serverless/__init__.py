from repro.serverless.autoscale import (
    AutoscaleDecision, OccupancyAutoscaler, TopologyAutoscaler,
)
from repro.serverless.backends import (
    BACKEND_NAMES, BACKENDS, BackendRunInfo, DrainState, ExecutionBackend,
    InlineBackend, PoolConfig, RunReport, Segment, ShardedBackend,
    WaveBackend, WorkRequest, make_backend,
)
from repro.serverless.cost import Bill, BillingRecord, speedup_of, USD_PER_GB_S
from repro.serverless.dispatch import (
    DispatchQueue, DispatchStats, PendingBucket,
)
from repro.serverless.ledger import TaskLedger
from repro.serverless.topology import (
    HostMesh, Topology, TopologyBackend, TopologyInfo,
)

__all__ = [
    "AutoscaleDecision", "OccupancyAutoscaler", "TopologyAutoscaler",
    "Bill", "BillingRecord", "speedup_of", "USD_PER_GB_S", "PoolConfig",
    "RunReport", "TaskLedger", "ExecutionBackend",
    "BackendRunInfo", "DrainState", "InlineBackend", "WaveBackend",
    "ShardedBackend", "WorkRequest", "Segment", "BACKENDS", "BACKEND_NAMES",
    "make_backend",
    "DispatchQueue", "DispatchStats", "PendingBucket",
    "HostMesh", "Topology", "TopologyBackend", "TopologyInfo",
]
