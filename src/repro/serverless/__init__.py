from repro.serverless.cost import Bill, BillingRecord, speedup_of, USD_PER_GB_S
from repro.serverless.executor import PoolConfig, RunReport, ServerlessExecutor
from repro.serverless.ledger import TaskLedger

__all__ = [
    "Bill", "BillingRecord", "speedup_of", "USD_PER_GB_S", "PoolConfig",
    "RunReport", "ServerlessExecutor", "TaskLedger",
]
