"""GB-seconds cost model (paper §2, §5.2).

AWS Lambda bills duration x allocated memory; CPU share scales with memory
(1769 MB ~= 1 vCPU, capped at 6 vCPU / 10240 MB).  The simulator maps a
task's work units through that speed curve, reproducing the paper's
Figure 3 shape: more memory -> faster (diminishing returns) and a U-shaped
cost curve.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

MB_PER_VCPU = 1769.0
MAX_VCPU = 6.0
USD_PER_GB_S = 0.0000166667          # eu-central-1, paper ref [5]
BILLING_GRANULARITY_S = 0.001        # per-ms billing, paper ref [2]


def vcpu_of(memory_mb: int) -> float:
    return min(memory_mb / MB_PER_VCPU, MAX_VCPU)


def speedup_of(memory_mb: int, parallel_frac: float = 0.9) -> float:
    """Amdahl-style speed curve: work parallelizes over the vCPU share.

    parallel_frac < 1 produces the paper's diminishing returns (Fig 3a/b).
    """
    c = vcpu_of(memory_mb)
    return 1.0 / ((1.0 - parallel_frac) + parallel_frac / c)


@dataclass
class BillingRecord:
    invocation: int
    duration_s: float
    memory_mb: int
    retry: int = 0
    speculative: bool = False

    @property
    def billed_gb_s(self) -> float:
        dur = max(
            BILLING_GRANULARITY_S,
            round(self.duration_s / BILLING_GRANULARITY_S)
            * BILLING_GRANULARITY_S)
        return dur * self.memory_mb / 1024.0


@dataclass
class Bill:
    records: List[BillingRecord] = field(default_factory=list)

    def add(self, rec: BillingRecord):
        self.records.append(rec)

    @property
    def total_gb_s(self) -> float:
        return sum(r.billed_gb_s for r in self.records)

    @property
    def total_usd(self) -> float:
        return self.total_gb_s * USD_PER_GB_S

    @property
    def n_invocations(self) -> int:
        return len(self.records)

    def summary(self) -> dict:
        durs = [r.duration_s for r in self.records] or [0.0]
        return {
            "invocations": self.n_invocations,
            "billed_gb_s": self.total_gb_s,
            "usd": self.total_usd,
            "avg_duration_s": sum(durs) / len(durs),
            "max_duration_s": max(durs),
            "retries": sum(1 for r in self.records if r.retry),
            "speculative": sum(1 for r in self.records if r.speculative),
        }
