from repro.serving.engine import Engine, GenResult, grow_cache, init_cache

__all__ = ["Engine", "GenResult", "grow_cache", "init_cache"]
