"""Batched serving engine: prefill + decode with KV cache.

``Engine.generate`` runs greedy decoding for a fixed budget; requests are
served in static batches (continuous batching reduces to refilling finished
slots between decode bursts — ``serve_requests`` demonstrates slot reuse).
The jit'd ``decode_fn`` is exactly what the dry-run lowers for decode cells.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.models.lm import ModelBundle
from repro.models.param import is_decl


def init_cache(bundle: ModelBundle, shape: ShapeConfig):
    decls = bundle.cache_decls(shape)

    def mk(path, d):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if d.dtype == jnp.int32:
            fill = 0 if name == "cur" else -1
            return jnp.full(d.shape, fill, jnp.int32)
        return jnp.zeros(d.shape, d.dtype)

    return jax.tree_util.tree_map_with_path(mk, decls, is_leaf=is_decl)


def grow_cache(cfg, cache, n_extra: int):
    """Extend KV-cache capacity after prefill so decoding does not ring-evict
    live context.  SWA caches stay capped at the window (eviction is then
    semantically correct).  Static cross-attention KV is never grown."""
    if "slot_pos" not in cache:
        return cache                       # recurrent state: O(1), no growth
    cur_cap = cache["slot_pos"].shape[-1]
    window = cfg.attention.sliding_window
    target = cur_cap + n_extra
    if window:
        target = min(target, window)
    grow = target - cur_cap
    if grow <= 0:
        return cache

    def visit(path, arr):
        names = [p.key for p in path if hasattr(p, "key")]
        if "cross_kv" in names:
            return arr
        leaf = names[-1] if names else ""
        if leaf in ("k", "v"):
            axis = arr.ndim - 3
        elif leaf in ("c", "krope"):
            axis = arr.ndim - 2
        elif leaf == "slot_pos":
            axis = arr.ndim - 1
        else:
            return arr
        pads = [(0, 0)] * arr.ndim
        pads[axis] = (0, grow)
        fill = -1 if leaf == "slot_pos" else 0
        return jnp.pad(arr, pads, constant_values=fill)

    return jax.tree_util.tree_map_with_path(visit, cache)


@dataclass
class GenResult:
    tokens: np.ndarray          # (B, n_gen)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class Engine:
    def __init__(self, bundle: ModelBundle, params):
        self.bundle = bundle
        self.params = params
        self._prefill = jax.jit(bundle.prefill_fn)
        self._decode = jax.jit(bundle.decode_fn, donate_argnums=(1,))

    def generate(self, batch: Dict, n_gen: int = 16) -> GenResult:
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch)
        cache = grow_cache(self.bundle.arch, cache, n_gen)
        next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        jax.block_until_ready(next_tok)
        t1 = time.perf_counter()
        out = [np.asarray(next_tok)]
        extra = {k: v for k, v in batch.items()
                 if k not in ("tokens", "targets")}
        for _ in range(n_gen - 1):
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": next_tok, **extra})
            next_tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(np.asarray(next_tok))
        jax.block_until_ready(next_tok)
        t2 = time.perf_counter()
        toks = np.concatenate(out, axis=1)
        bsz = toks.shape[0]
        return GenResult(tokens=toks, prefill_s=t1 - t0, decode_s=t2 - t1,
                         tokens_per_s=bsz * (n_gen - 1) / max(t2 - t1, 1e-9))

    def serve_requests(self, prompts: List[np.ndarray], batch_size: int,
                       prompt_len: int, n_gen: int = 8) -> List[np.ndarray]:
        """Slot-based continuous batching: pad prompts into fixed slots,
        refill slots from the queue between bursts."""
        results: List[Optional[np.ndarray]] = [None] * len(prompts)
        queue = list(range(len(prompts)))
        while queue:
            slots = queue[:batch_size]
            queue = queue[batch_size:]
            toks = np.zeros((batch_size, prompt_len), np.int32)
            for i, ridx in enumerate(slots):
                p = prompts[ridx][-prompt_len:]
                toks[i, -len(p):] = p
            res = self.generate({"tokens": jnp.asarray(toks)}, n_gen=n_gen)
            for i, ridx in enumerate(slots):
                results[ridx] = res.tokens[i]
        return results  # type: ignore
