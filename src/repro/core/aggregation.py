"""Aggregation over repeated sample splits (paper §3, final step).

theta_tilde = Median_m(theta_m); the variance aggregation follows
Chernozhukov et al. (2018) remark 3.1 / the DoubleML package:
sigma^2 = Median_m( sigma_m^2 + (theta_m - theta_tilde)^2 ), which accounts
for the across-split variability.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
from repro.scipy_free_stats import norm_ppf


def aggregate_thetas(thetas, ses, method: str = "median") -> Tuple[float, float]:
    thetas = jnp.asarray(thetas)
    ses = jnp.asarray(ses)
    if method == "median":
        theta = jnp.median(thetas)
        var = jnp.median(ses**2 + (thetas - theta) ** 2)
    elif method == "mean":
        theta = jnp.mean(thetas)
        var = jnp.mean(ses**2 + (thetas - theta) ** 2)
    else:
        raise ValueError(method)
    return float(theta), float(jnp.sqrt(var))


def confint(theta: float, se: float, level: float = 0.95):
    q = norm_ppf(0.5 + level / 2)
    return theta - q * se, theta + q * se
