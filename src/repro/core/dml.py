"""Deprecated one-shot front-end over the declarative API.

``DoubleMLServerless`` predates the three-layer redesign (core/spec.py,
serverless/backends.py, core/session.py) and is kept as a thin shim: it
translates its constructor kwargs into a ``DMLPlan`` and delegates to
``estimate``.  New code should build plans directly:

    plan = DMLPlan.for_model("plr", learner="ridge",
                             learner_params={"reg": 1.0},
                             n_folds=5, n_rep=100, seed=42)
    res = estimate(plan, DMLData.from_dict(data))

See README "Migration" for the full kwarg-to-field table.
"""
from __future__ import annotations

import warnings
from dataclasses import replace
from typing import Optional

from repro.core.scores import SPECS
from repro.core.crossfit import TaskGrid
from repro.core.session import DMLResult, estimate
from repro.core.spec import DMLData, DMLPlan
from repro.serverless.backends import PoolConfig
from repro.serverless.ledger import TaskLedger

__all__ = ["DMLResult", "DoubleMLServerless"]


class DoubleMLServerless:
    """Deprecated: use ``DMLPlan`` + ``estimate`` / ``DMLSession``."""

    def __init__(self, model: str = "plr", n_folds: int = 5, n_rep: int = 100,
                 learner: str = "ridge", learner_params: Optional[dict] = None,
                 scaling: str = "n_rep", pool: Optional[PoolConfig] = None,
                 score: str = "default", seed: int = 42,
                 backend: str = "wave"):
        warnings.warn(
            "DoubleMLServerless is deprecated; build a DMLPlan and call "
            "estimate() or use a DMLSession", DeprecationWarning,
            stacklevel=2)
        self.plan = DMLPlan.for_model(
            model, learner=learner, learner_params=learner_params,
            n_folds=n_folds, n_rep=n_rep, seed=seed, score=score,
            scaling=scaling, backend=backend, pool=pool)
        # legacy introspection attributes
        self.spec = SPECS[model]
        self.model = model
        self.n_folds = n_folds
        self.n_rep = n_rep
        self.scaling = scaling
        self.score = score
        self.seed = seed
        self.learner_name = learner
        self.learner_params = dict(learner_params or {})
        # legacy introspection saw pool.scaling == scaling; give that view
        # on a COPY so the caller's (frozen) config is never touched
        self.pool = replace(pool, scaling=scaling) if pool is not None \
            else PoolConfig(scaling=scaling)
        self.grid = TaskGrid(n_rep, n_folds, self.spec.n_nuisance)

    def fit(self, data, ledger: Optional[TaskLedger] = None,
            n_boot: int = 0) -> DMLResult:
        plan = self.plan
        if n_boot:
            plan = plan.replace(
                inference=replace(plan.inference, n_boot=n_boot))
        res = estimate(plan, DMLData.from_dict(data), ledger=ledger)
        self._psi = res.psi
        return res
