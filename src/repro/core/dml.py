"""The DoubleML-Serverless estimator (paper §4-§5) on the JAX runtime.

``DoubleMLServerless.fit`` mirrors ``DoubleMLPLRServerless.fit_aws_lambda()``:
  1. draw M repeated K-fold partitions (reproducible Philox streams),
  2. build the task grid and dispatch it through the serverless-analogue
     executor at the chosen scaling level,
  3. stitch returned *fold predictions* into cross-fitted nuisance vectors,
  4. evaluate the Neyman-orthogonal score, solve the linear score for
     theta per repetition, aggregate by median,
  5. local inference: sandwich SEs + optional multiplier bootstrap.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_thetas, confint
from repro.core.bootstrap import boot_confint, multiplier_bootstrap
from repro.core.crossfit import (
    TaskGrid, check_partition, draw_fold_masks, stitch_predictions,
    subset_mask,
)
from repro.core.scores import SPECS, evaluate_score, score_se, solve_theta
from repro.learners import get_learner
from repro.serverless.executor import PoolConfig, RunReport, ServerlessExecutor
from repro.serverless.ledger import TaskLedger


@dataclass
class DMLResult:
    theta: float
    se: float
    ci: tuple
    thetas: np.ndarray              # per-repetition estimates (M,)
    ses: np.ndarray
    report: RunReport
    boot_ci: Optional[tuple] = None

    def summary(self) -> Dict:
        out = {"theta": self.theta, "se": self.se, "ci": self.ci}
        out.update({f"exec_{k}": v for k, v in self.report.summary().items()})
        return out


class DoubleMLServerless:
    def __init__(self, model: str = "plr", n_folds: int = 5, n_rep: int = 100,
                 learner: str = "ridge", learner_params: Optional[dict] = None,
                 scaling: str = "n_rep", pool: Optional[PoolConfig] = None,
                 score: str = "default", seed: int = 42):
        self.spec = SPECS[model]
        self.model = model
        self.n_folds = n_folds
        self.n_rep = n_rep
        self.scaling = scaling
        self.score = score
        self.seed = seed
        self.learner_name = learner
        self.learner_params = dict(learner_params or {})
        self.pool = pool or PoolConfig(scaling=scaling)
        self.pool.scaling = scaling
        self.grid = TaskGrid(n_rep, n_folds, self.spec.n_nuisance)

    # ------------------------------------------------------------------
    def _build_tasks(self, data):
        """targets (L, N) and train weights (M, K, L, N)."""
        n = data["x"].shape[0]
        masks = draw_fold_masks(n, self.n_folds, self.n_rep, self.seed)
        assert check_partition(masks)
        targets = np.stack(
            [np.asarray(data[t]) for _, t, _ in self.spec.nuisances])
        train_w = np.empty((self.n_rep, self.n_folds,
                            self.spec.n_nuisance, n), np.float32)
        for l, (_, _, subset) in enumerate(self.spec.nuisances):
            sub = subset_mask(subset, data)
            w = (~masks).astype(np.float32)          # train on I^c_{m,k}
            if sub is not None:
                w = w * sub.astype(np.float32)[None, None, :]
            train_w[:, :, l, :] = w
        return masks, targets, train_w

    def _learner_key(self, nuisance_name: str):
        """(name, params) for a nuisance — propensities are probabilities."""
        params = dict(self.learner_params)
        if nuisance_name in ("ml_m",) and self.model in ("irm", "iivm"):
            if self.learner_name in ("ols", "ridge", "lasso", "kernel_ridge"):
                return "logistic", {"reg": params.get("reg", 1.0)}
            params["classify"] = True
        return self.learner_name, params

    def _learner_for(self, nuisance_name: str):
        name, params = self._learner_key(nuisance_name)
        return get_learner(name, params)

    # ------------------------------------------------------------------
    def fit(self, data, ledger: Optional[TaskLedger] = None,
            n_boot: int = 0) -> DMLResult:
        x = jnp.asarray(data["x"])
        masks, targets, train_w = self._build_tasks(data)

        # one learner callable for the whole grid: nuisance-specific
        # behaviour (classification) is handled by dispatching per nuisance
        # inside a wrapper so the executor stays nuisance-agnostic.
        keys = [self._learner_key(nm) for nm, _, _ in self.spec.nuisances]
        learners = [self._learner_for(nm) for nm, _, _ in self.spec.nuisances]
        uniform = all(k == keys[0] for k in keys)

        if uniform:
            learner_fn = learners[0]
            executor = ServerlessExecutor(learner_fn, self.grid, self.pool)
            preds, ledger, report = executor.run(
                x, jnp.asarray(targets), train_w,
                jax.random.key(self.seed), ledger=ledger)
        else:
            # mixed regression/classification grid: run one sub-grid per
            # nuisance (same wave machinery, ledgers concatenated)
            report = RunReport()
            preds = np.zeros((self.n_rep, self.n_folds,
                              self.spec.n_nuisance, x.shape[0]), np.float32)
            for l, fn in enumerate(learners):
                sub_grid = TaskGrid(self.n_rep, self.n_folds, 1)
                executor = ServerlessExecutor(fn, sub_grid, self.pool)
                p, _, rep = executor.run(
                    x, jnp.asarray(targets[l: l + 1]),
                    train_w[:, :, l: l + 1],
                    jax.random.key(self.seed + l), report=report)
                preds[:, :, l] = p[:, :, 0]
                report = rep

        # ---- stitch to cross-fitted predictions (M, L, N) -----------------
        fitted = {}
        for l, (nm, _, _) in enumerate(self.spec.nuisances):
            fitted[nm] = stitch_predictions(masks, preds[:, :, l])

        # ---- score evaluation & aggregation -------------------------------
        dml_data = {k: jnp.asarray(np.asarray(data[k]))[None]
                    for k in ("y", "d", "z") if k in data}
        pred_tree = {k: jnp.asarray(v) for k, v in fitted.items()}
        psi_a, psi_b = evaluate_score(self.model, dml_data, pred_tree,
                                      self.score)
        thetas = solve_theta(psi_a, psi_b)                  # (M,)
        ses = score_se(psi_a, psi_b, thetas)
        theta, se = aggregate_thetas(thetas, ses)
        ci = confint(theta, se)

        boot_ci = None
        if n_boot:
            bt, se1 = multiplier_bootstrap(
                psi_a[0], psi_b[0], float(thetas[0]),
                jax.random.key(self.seed + 99), n_boot=n_boot)
            boot_ci = boot_confint(float(thetas[0]), se1, bt)

        self._psi = (np.asarray(psi_a), np.asarray(psi_b))
        return DMLResult(theta=theta, se=se, ci=ci,
                         thetas=np.asarray(thetas), ses=np.asarray(ses),
                         report=report, boot_ci=boot_ci)
