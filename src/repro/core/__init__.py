# The paper's primary contribution: serverless-style distributed DML.
from repro.core.crossfit import TaskGrid, draw_fold_masks, stitch_predictions
from repro.core.dml import DMLResult, DoubleMLServerless
from repro.core.scores import SPECS, evaluate_score, score_se, solve_theta

__all__ = [
    "TaskGrid", "draw_fold_masks", "stitch_predictions", "DMLResult",
    "DoubleMLServerless", "SPECS", "evaluate_score", "score_se", "solve_theta",
]
