# The paper's primary contribution: serverless-style distributed DML,
# exposed as a declarative three-layer API (spec -> backend -> session).
from repro.core.crossfit import TaskGrid, draw_fold_masks, stitch_predictions
from repro.core.dml import DoubleMLServerless
from repro.core.scores import SPECS, evaluate_score, score_se, solve_theta
from repro.core.session import DMLResult, DMLSession, estimate
from repro.core.spec import (
    DMLData, DMLPlan, InferenceSpec, NuisanceSpec, ResamplingSpec,
)

__all__ = [
    "TaskGrid", "draw_fold_masks", "stitch_predictions", "DMLResult",
    "DoubleMLServerless", "SPECS", "evaluate_score", "score_se", "solve_theta",
    "DMLData", "DMLPlan", "NuisanceSpec", "ResamplingSpec", "InferenceSpec",
    "DMLSession", "estimate",
]
