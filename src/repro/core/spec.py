"""Declarative estimation front-end: typed data + composable plans.

The public API is three layers (README "Architecture"):

  1. specification  — ``DMLData`` (validated arrays with roles y/d/x/z)
                      and ``DMLPlan`` (what to estimate: score, per-nuisance
                      learners, resampling, inference options),
  2. execution      — an ``ExecutionBackend`` (serverless/backends.py) that
                      runs the compiled task grid,
  3. serving        — ``DMLSession`` (core/session.py) that batches many
                      (plan, data) requests onto one warm backend.

Everything in this module is an immutable value object: plans can be
shared, hashed into caches, and submitted concurrently without aliasing
hazards (``PoolConfig`` is frozen for the same reason).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Mapping, Optional, Tuple

import numpy as np

from repro.core.scores import SPECS
from repro.serverless.backends import (
    BACKEND_NAMES, PoolConfig, fingerprint_array,
)

_ROLES = ("x", "y", "d", "z", "cluster")
_SCALINGS = ("n_rep", "n_folds*n_rep")


def _as_f32(name: str, arr, ndim: int) -> np.ndarray:
    out = np.ascontiguousarray(np.asarray(arr, dtype=np.float32))
    if out.ndim != ndim:
        raise ValueError(f"DMLData.{name}: expected {ndim}-d array, "
                         f"got shape {out.shape}")
    if not np.isfinite(out).all():
        raise ValueError(f"DMLData.{name}: contains NaN/inf")
    return out


@dataclass(frozen=True, eq=False)
class DMLData:
    """Validated estimation dataset with named roles.

    x (N,P) controls; y (N,) outcome; d (N,) treatment; z (N,) optional
    instrument; cluster (N,) optional cluster ids (reserved for clustered
    inference).  ``theta0`` carries the ground truth for synthetic DGPs.
    Arrays are coerced to contiguous float32 once, at construction — the
    pipeline never re-validates or copies.
    """
    x: np.ndarray
    y: np.ndarray
    d: np.ndarray
    z: Optional[np.ndarray] = None
    cluster: Optional[np.ndarray] = None
    theta0: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "x", _as_f32("x", self.x, 2))
        n = self.x.shape[0]
        for name in ("y", "d", "z", "cluster"):
            arr = getattr(self, name)
            if arr is None:
                continue
            arr = _as_f32(name, arr, 1)
            if arr.shape[0] != n:
                raise ValueError(
                    f"DMLData.{name}: {arr.shape[0]} rows but x has {n}")
            object.__setattr__(self, name, arr)

    # ---- construction ----------------------------------------------------
    @classmethod
    def from_dict(cls, data: Mapping) -> "DMLData":
        """Adapter for the legacy raw-dict format (make_*_data outputs)."""
        if isinstance(data, DMLData):
            return data
        known = {k: data[k] for k in _ROLES if k in data}
        t0 = data.get("theta0")
        return cls(theta0=float(t0) if t0 is not None else None, **known)

    def fingerprint(self) -> Tuple[str, Tuple[int, ...]]:
        """Content identity of the feature matrix (cached): the device
        page-pool key, so repeat traffic over the same dataset — same
        object or an equal copy — shares one resident feature page."""
        fp = getattr(self, "_fingerprint", None)
        if fp is None:
            fp = fingerprint_array(self.x)
            object.__setattr__(self, "_fingerprint", fp)
        return fp

    def content_key(self) -> Tuple:
        """Content identity of EVERY role array (cached).  This — not
        ``fingerprint``, which keys only the feature page — is the
        provenance key for caching tensors derived from the outcome/
        treatment columns (e.g. the compiler's stacked block tensors):
        two datasets sharing one X but different y/d/z must never
        collide."""
        ck = getattr(self, "_content_key", None)
        if ck is None:
            ck = tuple((r, fingerprint_array(getattr(self, r)))
                       for r in _ROLES if getattr(self, r) is not None)
            object.__setattr__(self, "_content_key", ck)
        return ck

    # ---- access ----------------------------------------------------------
    @property
    def n_obs(self) -> int:
        return self.x.shape[0]

    @property
    def dim_x(self) -> int:
        return self.x.shape[1]

    def role(self, name: str) -> np.ndarray:
        arr = getattr(self, name, None)
        if arr is None:
            raise KeyError(f"data has no {name!r} column (roles present: "
                           f"{[r for r in _ROLES if getattr(self, r) is not None]})")
        return arr

    def __contains__(self, name: str) -> bool:
        return name in _ROLES and getattr(self, name) is not None

    def __getitem__(self, name: str) -> np.ndarray:
        if name == "theta0":
            return self.theta0
        return self.role(name)

    def score_arrays(self) -> Dict[str, np.ndarray]:
        """The observation arrays the score functions consume."""
        return {k: getattr(self, k) for k in ("y", "d", "z")
                if getattr(self, k) is not None}

    # ---- durability (crash-resumable sessions, ISSUE 10) -----------------
    def to_payload(self) -> Dict:
        """A msgpack-safe dict capturing every role array bit-exactly
        (raw bytes + dtype + shape) — the durable half of a session's
        admitted request spec.  Round-tripping through
        ``from_payload`` reproduces identical fingerprints, so a resumed
        drain hits the same pages, buckets, and compiled programs."""
        out: Dict = {}
        for r in _ROLES:
            arr = getattr(self, r)
            if arr is not None:
                out[r] = {"data": arr.tobytes(), "dtype": str(arr.dtype),
                          "shape": list(arr.shape)}
        if self.theta0 is not None:
            out["theta0"] = float(self.theta0)
        return out

    @classmethod
    def from_payload(cls, p: Mapping) -> "DMLData":
        kw = {}
        for r in _ROLES:
            ent = p.get(r)
            if ent is not None:
                kw[r] = np.frombuffer(ent["data"], dtype=ent["dtype"]) \
                          .reshape(tuple(ent["shape"])).copy()
        t0 = p.get("theta0")
        return cls(theta0=float(t0) if t0 is not None else None, **kw)


# ---------------------------------------------------------------------------
# plan components
# ---------------------------------------------------------------------------
def _hashable(v):
    """Canonicalize hyperparameter values so specs stay hashable
    (lists/dicts arrive from user code; learners receive tuples)."""
    if isinstance(v, (list, tuple)):
        return tuple(_hashable(x) for x in v)
    if isinstance(v, Mapping):
        return tuple(sorted((k, _hashable(x)) for k, x in v.items()))
    return v


@dataclass(frozen=True)
class NuisanceSpec:
    """One nuisance function: its regression target and its learner.

    ``subset`` restricts training rows for conditional nuisances
    (IRM/IIVM), e.g. "d1" = rows with D == 1; "all" = no restriction.
    ``params`` is a hyperparameter tuple of (key, value) pairs so specs
    stay hashable; build from a dict via ``NuisanceSpec.make``.
    """
    name: str                                   # e.g. "ml_l"
    target: str                                 # "y" | "d" | "z"
    learner: str                                # registry key (learners/)
    params: Tuple[Tuple[str, object], ...] = ()
    subset: str = "all"

    @classmethod
    def make(cls, name: str, target: str, learner: str,
             params: Optional[Mapping] = None,
             subset: str = "all") -> "NuisanceSpec":
        items = tuple(sorted((k, _hashable(v))
                             for k, v in (params or {}).items()))
        return cls(name=name, target=target, learner=learner,
                   params=items, subset=subset)

    @property
    def param_dict(self) -> Dict:
        return dict(self.params)

    @property
    def learner_key(self) -> Tuple[str, Tuple[Tuple[str, object], ...]]:
        return (self.learner, self.params)


@dataclass(frozen=True)
class ResamplingSpec:
    """Repeated K-fold cross-fitting (paper §3): M partitions of K folds."""
    n_folds: int = 5
    n_rep: int = 100
    seed: int = 42

    def __post_init__(self):
        if self.n_folds < 2:
            raise ValueError("n_folds must be >= 2 (cross-fitting needs a "
                             "held-out fold)")
        if self.n_rep < 1:
            raise ValueError("n_rep must be >= 1")


@dataclass(frozen=True)
class InferenceSpec:
    level: float = 0.95
    n_boot: int = 0                              # multiplier bootstrap draws
    aggregation: str = "median"                  # across repetitions


@dataclass(frozen=True)
class DMLPlan:
    """Everything needed to estimate one causal parameter — no execution
    state.  Built with ``DMLPlan.for_model`` (uniform learner + the
    standard propensity handling) or assembled nuisance-by-nuisance.
    """
    model: str
    nuisances: Tuple[NuisanceSpec, ...]
    resampling: ResamplingSpec = ResamplingSpec()
    score: str = "default"
    inference: InferenceSpec = InferenceSpec()
    scaling: str = "n_rep"                       # paper's scaling knob (§4.2)
    backend: str = "wave"
    pool: Optional[PoolConfig] = None            # execution substrate knobs

    def __post_init__(self):
        if self.model not in SPECS:
            raise KeyError(f"unknown model {self.model!r}; known: "
                           f"{list(SPECS)}")
        if self.scaling not in _SCALINGS:
            raise ValueError(f"scaling must be one of {_SCALINGS}")
        if self.backend not in BACKEND_NAMES:
            raise ValueError(f"backend must be one of {BACKEND_NAMES}")
        spec = SPECS[self.model]
        want = tuple(nm for nm, _, _ in spec.nuisances)
        got = tuple(ns.name for ns in self.nuisances)
        if got != want:
            raise ValueError(f"model {self.model!r} needs nuisances {want}, "
                             f"plan has {got}")

    # ---- builders --------------------------------------------------------
    @classmethod
    def for_model(cls, model: str, *, learner: str = "ridge",
                  learner_params: Optional[Mapping] = None,
                  n_folds: int = 5, n_rep: int = 100, seed: int = 42,
                  score: str = "default", scaling: str = "n_rep",
                  backend: str = "wave", pool: Optional[PoolConfig] = None,
                  n_boot: int = 0, level: float = 0.95,
                  overrides: Optional[Mapping[str, NuisanceSpec]] = None,
                  ) -> "DMLPlan":
        """One learner for every nuisance, with the standard exception:
        binary-treatment propensities (IRM/IIVM ``ml_m``) get a proper
        probability learner — ``logistic`` for the linear families,
        ``classify=True`` otherwise.  Pass ``overrides={"ml_m": spec}`` to
        replace any nuisance wholesale (this is what used to be the
        hard-coded ``_learner_key`` branch in core/dml.py).
        """
        spec = SPECS[model]
        params = dict(learner_params or {})
        nuisances = []
        for nm, target, subset in spec.nuisances:
            if overrides and nm in overrides:
                ov = overrides[nm]
                nuisances.append(replace(ov, name=nm, target=target,
                                         subset=subset))
                continue
            ln, lp = learner, params
            if nm == "ml_m" and model in ("irm", "iivm"):
                if learner in ("ols", "ridge", "lasso", "kernel_ridge"):
                    ln, lp = "logistic", {"reg": params.get("reg", 1.0)}
                else:
                    lp = {**params, "classify": True}
            nuisances.append(NuisanceSpec.make(nm, target, ln, lp, subset))
        return cls(model=model, nuisances=tuple(nuisances),
                   resampling=ResamplingSpec(n_folds, n_rep, seed),
                   score=score,
                   inference=InferenceSpec(level=level, n_boot=n_boot),
                   scaling=scaling, backend=backend, pool=pool)

    def replace(self, **kw) -> "DMLPlan":
        return replace(self, **kw)

    # ---- durability (crash-resumable sessions, ISSUE 10) -----------------
    def to_payload(self) -> Dict:
        """A msgpack-safe dict of the full plan minus ``pool`` (execution
        substrate knobs belong to the resuming process, not the durable
        spec — a resume may deliberately swap in a healthier pool)."""
        return {
            "model": self.model,
            "nuisances": [
                {"name": ns.name, "target": ns.target,
                 "learner": ns.learner,
                 "params": [[k, v] for k, v in ns.params],
                 "subset": ns.subset}
                for ns in self.nuisances],
            "resampling": [self.resampling.n_folds, self.resampling.n_rep,
                           self.resampling.seed],
            "score": self.score,
            "inference": [self.inference.level, self.inference.n_boot,
                          self.inference.aggregation],
            "scaling": self.scaling,
            "backend": self.backend,
        }

    @classmethod
    def from_payload(cls, p: Mapping) -> "DMLPlan":
        # NuisanceSpec.make re-canonicalizes params (msgpack turns the
        # hashable tuples into lists on the way through)
        nuisances = tuple(
            NuisanceSpec.make(ns["name"], ns["target"], ns["learner"],
                              {k: v for k, v in ns["params"]},
                              ns["subset"])
            for ns in p["nuisances"])
        nf, nr, seed = p["resampling"]
        level, n_boot, agg = p["inference"]
        return cls(model=p["model"], nuisances=nuisances,
                   resampling=ResamplingSpec(nf, nr, seed),
                   score=p["score"],
                   inference=InferenceSpec(level=level, n_boot=n_boot,
                                           aggregation=agg),
                   scaling=p["scaling"], backend=p["backend"])

    # ---- derived ---------------------------------------------------------
    @property
    def n_nuisance(self) -> int:
        return len(self.nuisances)

    @property
    def uniform(self) -> bool:
        """All nuisances share one (learner, params) — one fused grid."""
        return all(ns.learner_key == self.nuisances[0].learner_key
                   for ns in self.nuisances)
