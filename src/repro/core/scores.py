"""Neyman-orthogonal score functions (paper §3; Chernozhukov et al. 2018).

Every score is linear in the causal parameter theta:

    psi(W; theta, eta) = theta * psi_a(W; eta) + psi_b(W; eta)

so the estimate solves  theta = -sum(psi_b) / sum(psi_a)  — the property the
paper exploits to return *predictions only* from workers (§3, §5.1).

Implemented model classes (the four from Chernozhukov et al. 2018 §4-5):
  PLR   partially linear regression            eta = (g, m)          L=2
  PLIV  partially linear IV                    eta = (g, m, r)       L=3
  IRM   interactive regression model           eta = (g0, g1, m)     L=3
  IIVM  interactive IV model                   eta = (g0, g1, m0, m1, r)  L=5*

(*we follow the DoubleML package: p(Z) estimated plus g(d,X), m(z,X) — the
task grid size per split is ``n_nuisance``.)

All functions are pure jnp and vmap/vectorize over leading axes, so M
repetitions evaluate in one shot.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class ScoreSpec:
    """Which nuisance functions a model class needs.

    Each entry: name -> (target_key, conditioning) where target_key selects
    the regression target from the dataset dict and ``subset`` optionally
    restricts the training rows (e.g. to D==1 for IRM's g1).
    """
    name: str
    nuisances: Tuple[Tuple[str, str, str], ...]   # (name, target, subset)

    @property
    def n_nuisance(self) -> int:
        return len(self.nuisances)


PLR = ScoreSpec("plr", (("ml_l", "y", "all"), ("ml_m", "d", "all")))
PLIV = ScoreSpec("pliv", (("ml_l", "y", "all"), ("ml_m", "z", "all"),
                          ("ml_r", "d", "all")))
IRM = ScoreSpec("irm", (("ml_g0", "y", "d0"), ("ml_g1", "y", "d1"),
                        ("ml_m", "d", "all")))
IIVM = ScoreSpec("iivm", (("ml_g0", "y", "z0"), ("ml_g1", "y", "z1"),
                          ("ml_m", "z", "all"),
                          ("ml_r0", "d", "z0"), ("ml_r1", "d", "z1")))

SPECS: Dict[str, ScoreSpec] = {s.name: s for s in (PLR, PLIV, IRM, IIVM)}


def _clip_propensity(p, eps=0.01):
    return jnp.clip(p, eps, 1.0 - eps)


def plr_score(data, preds, score: str = "partialling out"):
    """psi_a, psi_b for the PLR model (paper §5.1).

    data: {"y": (N,), "d": (N,)}; preds: {"ml_l": yhat, "ml_m": dhat} — each
    (..., N) cross-fitted predictions (leading axes = repetitions).
    """
    y, d = data["y"], data["d"]
    v = d - preds["ml_m"]                    # residual treatment
    if score == "IV-type":
        u = y - preds["ml_l"]                # here ml_l ~ g
        psi_a = -v * d
        psi_b = v * u
    else:                                    # "partialling out" (default)
        u = y - preds["ml_l"]
        psi_a = -v * v
        psi_b = v * u
    return psi_a.astype(F32), psi_b.astype(F32)


def pliv_score(data, preds):
    y, d, z = data["y"], data["d"], data["z"]
    u = y - preds["ml_l"]
    w = z - preds["ml_m"]
    v = d - preds["ml_r"]
    psi_a = -w * v
    psi_b = w * u
    return psi_a.astype(F32), psi_b.astype(F32)


def irm_score(data, preds, score: str = "ATE"):
    y, d = data["y"], data["d"]
    g0, g1 = preds["ml_g0"], preds["ml_g1"]
    m = _clip_propensity(preds["ml_m"])
    u0 = y - g0
    u1 = y - g1
    if score == "ATTE":
        p = jnp.mean(d)
        psi_a = -d / p
        psi_b = d * u0 / p - m * (1 - d) * u0 / (p * (1 - m))
    else:
        psi_a = -jnp.ones_like(y)
        psi_b = g1 - g0 + d * u1 / m - (1 - d) * u0 / (1 - m)
    return psi_a.astype(F32), psi_b.astype(F32)


def iivm_score(data, preds):
    y, d, z = data["y"], data["d"], data["z"]
    g0, g1 = preds["ml_g0"], preds["ml_g1"]
    m = _clip_propensity(preds["ml_m"])
    r0, r1 = preds["ml_r0"], preds["ml_r1"]
    u0, u1 = y - g0, y - g1
    psi_b = g1 - g0 + z * u1 / m - (1 - z) * u0 / (1 - m)
    psi_a = -(r1 - r0 + z * (d - r1) / m - (1 - z) * (d - r0) / (1 - m))
    return psi_a.astype(F32), psi_b.astype(F32)


def evaluate_score(model: str, data, preds, score: str = "default"):
    if model == "plr":
        return plr_score(data, preds,
                         "partialling out" if score == "default" else score)
    if model == "pliv":
        return pliv_score(data, preds)
    if model == "irm":
        return irm_score(data, preds, "ATE" if score == "default" else score)
    if model == "iivm":
        return iivm_score(data, preds)
    raise KeyError(model)


def solve_theta(psi_a, psi_b, axis=-1):
    """theta = -sum(psi_b)/sum(psi_a) along the observation axis."""
    return -jnp.sum(psi_b, axis=axis) / jnp.sum(psi_a, axis=axis)


def score_se(psi_a, psi_b, theta, axis=-1):
    """Sandwich standard error from the evaluated score (CCDDHNR18 Thm 3.2)."""
    n = psi_a.shape[axis]
    psi = psi_a * jnp.expand_dims(theta, axis) + psi_b
    j = jnp.mean(psi_a, axis=axis)
    var = jnp.mean(psi * psi, axis=axis) / (j * j)
    return jnp.sqrt(var / n)
