"""Multiplier bootstrap inference on the evaluated score (paper §5.1:
"inference tasks like ... multiplier bootstrap ... done locally").

Given the cross-fitted score components the bootstrap never touches the
data again — it reweights psi with iid multipliers (Bayes / normal / wild),
exactly as in Chernozhukov et al. (2018) §3.3 and the DoubleML package.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

F32 = jnp.float32


def multiplier_bootstrap(psi_a, psi_b, theta: float, key,
                         n_boot: int = 500, method: str = "normal"):
    """t-statistics of the bootstrapped estimator.

    psi_a/psi_b: (N,) evaluated score components for ONE repetition;
    returns (n_boot,) bootstrap t-stats.
    """
    psi_a = jnp.asarray(psi_a, F32)
    psi_b = jnp.asarray(psi_b, F32)
    n = psi_a.shape[0]
    psi = theta * psi_a + psi_b
    j = jnp.mean(psi_a)
    se = jnp.sqrt(jnp.mean(psi * psi) / (j * j) / n)

    if method == "Bayes":
        xi = jax.random.exponential(key, (n_boot, n), F32) - 1.0
    elif method == "wild":
        u = jax.random.normal(key, (n_boot, n), F32)
        v = jax.random.normal(jax.random.fold_in(key, 1), (n_boot, n), F32)
        xi = u / jnp.sqrt(2.0) + (v * v - 1.0) / 2.0
    else:                                  # "normal"
        xi = jax.random.normal(key, (n_boot, n), F32)

    boot_t = jnp.mean(xi * psi[None, :], axis=1) / (j * se)
    return boot_t, float(se)


def boot_confint(theta: float, se: float, boot_t, level: float = 0.95):
    q = jnp.quantile(jnp.abs(boot_t), level)
    return float(theta - q * se), float(theta + q * se)
