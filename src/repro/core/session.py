"""The serving layer: plans + data in, per-request results out.

``DMLSession`` is the multi-request front door, built around a
**continuous-admission drain engine**: ``submit()`` enqueues a request
immediately; the engine admits queued requests into the backend's live
``DrainState`` (extending the megabatch bucket plan incrementally),
dispatches waves without a global barrier, and completes each request's
``TaskLedger`` the moment its buckets land — early requests deliver their
``DMLResult`` (and fire ``on_complete`` callbacks) while later ones are
still executing.  ``poll()`` advances the engine by one wave; ``run()``
and ``estimate()`` are blocking wrappers over the same event loop, so the
batch-synchronous public API is unchanged.

Dispatch is **non-blocking** (ISSUE 5): a ``step()`` launches its
buckets and returns with the results still in flight on device; the
ledgers are booked by a later step's *harvest-on-poll* (each step first
books any landed buckets, blocking only when nothing is left to
dispatch).  Every host-side phase of the loop — admission, placement,
autoscaling, result assembly, callbacks — therefore overlaps device
execution; ``last_run_info.dispatch`` reports the measured overlap.

On the wave backend the requests' task grids fuse into shared dispatch
waves — many concurrent estimations amortize the same capacity cycles
(the batch-processing throughput lever); on the sharded/inline backends
they reuse the same warm compiled programs.  The backend's device-resident
page pool persists across drains, so steady-state serving re-transfers no
feature pages.

On the topology backend (``backend="topology"``) the same event loop
drives many *host-mesh streams*: each ``step()`` advances one host's
wave round-robin, buckets are placed on the host whose page pool already
holds their data, and ledgers complete out of order across hosts exactly
as they do across waves within one — the session code is unchanged
because multi-host is just more streams behind the same three backend
primitives.  Per-host accounting surfaces as
``last_run_info.topology``.

``estimate(plan, data)`` is the one-shot convenience for a single request.

Determinism: a request's result depends only on its own (plan, data) —
fold draws, learner seeds, and score evaluation are keyed off
``plan.resampling.seed``, and per-task PRNG streams are fixed at compile
time — so a session-batched request returns bitwise the predictions it
would get running alone, regardless of admission order or out-of-order
bucket completion.
"""
from __future__ import annotations

import glob
import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.core.aggregation import aggregate_thetas, confint
from repro.core.bootstrap import boot_confint, multiplier_bootstrap
from repro.core.crossfit import (
    TaskGrid, check_partition, draw_fold_masks, stitch_predictions,
    subset_mask,
)
from repro.core.scores import evaluate_score, score_se, solve_theta
from repro.core.spec import DMLData, DMLPlan, _hashable
from repro.learners import resolve_params
from repro.serverless.backends import (
    BackendRunInfo, DrainState, ExecutionBackend, PoolConfig, RunReport,
    Segment, WorkRequest, make_backend,
)
from repro.serverless.ledger import TaskLedger
from repro.serverless.sanitize import check_drained


@dataclass
class DMLResult:
    theta: float
    se: float
    ci: tuple
    thetas: np.ndarray              # per-repetition estimates (M,)
    ses: np.ndarray
    report: RunReport
    boot_ci: Optional[tuple] = None
    request_id: Optional[int] = None

    def summary(self) -> Dict:
        out = {"theta": self.theta, "se": self.se, "ci": self.ci}
        out.update({f"exec_{k}": v for k, v in self.report.summary().items()})
        return out


# ---------------------------------------------------------------------------
# plan + data -> WorkRequest
# ---------------------------------------------------------------------------
def compile_request(plan: DMLPlan, data: DMLData,
                    ledger: Optional[TaskLedger] = None,
                    tag: object = None) -> WorkRequest:
    """Lower a declarative request to executable arrays.

    Builds the fold masks, per-nuisance targets and training weights, and
    groups nuisances that share a (learner, params) pair into one
    ``Segment`` so uniform grids run as a single fused batch while mixed
    grids (IRM/IIVM propensities) get one fused batch per learner.
    """
    data = DMLData.from_dict(data)
    rs = plan.resampling
    n = data.n_obs
    grid = TaskGrid(rs.n_rep, rs.n_folds, plan.n_nuisance)
    masks = draw_fold_masks(n, rs.n_folds, rs.n_rep, rs.seed)
    assert check_partition(masks)

    targets = np.stack([data.role(ns.target) for ns in plan.nuisances])
    train_w = np.empty((rs.n_rep, rs.n_folds, plan.n_nuisance, n), np.float32)
    for l, ns in enumerate(plan.nuisances):
        sub = subset_mask(ns.subset, data)
        w = (~masks).astype(np.float32)          # train on I^c_{m,k}
        if sub is not None:
            w = w * sub.astype(np.float32)[None, None, :]
        train_w[:, :, l, :] = w

    # one segment per distinct (learner, params): uniform grids fuse into a
    # single batch, mixed grids get one fused batch per learner.  Each
    # segment carries the spec the megabatch compiler buckets on —
    # hyperparameters resolved against the *data shape* here (e.g.
    # kernel_ridge's gamma), so padded bucket execution stays
    # padding-invariant — and the base PRNG key tasks fold_in from.
    groups: List[List[int]] = []
    seen: Dict = {}
    for l, ns in enumerate(plan.nuisances):
        gi = seen.get(ns.learner_key)
        if gi is None:
            seen[ns.learner_key] = len(groups)
            groups.append([l])
        else:
            groups[gi].append(l)
    segments = []
    for g in groups:
        ns = plan.nuisances[g[0]]
        params = resolve_params(ns.learner, ns.param_dict,
                                n_obs=n, dim_x=data.dim_x)
        ptuple = tuple(sorted((k, _hashable(v)) for k, v in params.items()))
        segments.append(Segment(l_ids=tuple(g),
                                key=jax.random.key(rs.seed + g[0]),
                                key_ref=("seed", rs.seed + g[0]),
                                cache_key=(ns.learner, ptuple),
                                learner=ns.learner, params=ptuple))

    # content identity of the request's task tensors: fold masks derive
    # from (seed, K, M), targets/train_w from (data CONTENT — all role
    # arrays, not just X — plus roles and subsets), per-task keys from
    # the segment seeds — so this tuple pins every stacked block tensor,
    # letting the compiler reuse them across drains (steady serving
    # re-lowers identical requests every round).  ``content_key`` (not
    # ``fingerprint``) is load-bearing: two datasets sharing one X but
    # different y/d/z must not share cached targets/weights.
    work_key = ("plan-v1", data.content_key(), rs.seed, rs.n_folds,
                rs.n_rep, plan.scaling,
                tuple((ns.target, ns.subset, ns.learner_key)
                      for ns in plan.nuisances))
    req = WorkRequest.create(grid, plan.scaling, data.x, targets, train_w,
                             segments, ledger=ledger, tag=tag,
                             data_key=data.fingerprint(), work_key=work_key)
    req.fold_masks = masks                      # needed for stitching
    return req


def compile_raw_request(grid: TaskGrid, scaling: str, x, targets, train_w,
                        learner_fn, key, *, ledger=None, report=None,
                        tag: object = None) -> WorkRequest:
    """Lower a raw-array request (an opaque user-supplied learner callable
    over explicit grid arrays) onto the same compiled execution path as
    plan-built requests: one opaque-callable segment, executed by the
    megabatch compiler at exact shapes via the vmap adapter."""
    seg = Segment(learner_fn=learner_fn,
                  l_ids=tuple(range(grid.n_nuisance)), key=key)
    return WorkRequest.create(grid, scaling, x, targets, train_w, [seg],
                              ledger=ledger, report=report, tag=tag)


def assemble_result(plan: DMLPlan, data: DMLData, req: WorkRequest,
                    request_id: Optional[int] = None) -> DMLResult:
    """Stitch fold predictions, evaluate the score, run local inference."""
    data = DMLData.from_dict(data)
    preds = req.gathered_preds()                 # (M, K, L, N)
    masks = req.fold_masks

    fitted = {ns.name: stitch_predictions(masks, preds[:, :, l])
              for l, ns in enumerate(plan.nuisances)}
    dml_data = {k: jnp.asarray(v)[None] for k, v in
                data.score_arrays().items()}
    pred_tree = {k: jnp.asarray(v) for k, v in fitted.items()}
    psi_a, psi_b = evaluate_score(plan.model, dml_data, pred_tree, plan.score)
    thetas = solve_theta(psi_a, psi_b)                  # (M,)
    ses = score_se(psi_a, psi_b, thetas)
    theta, se = aggregate_thetas(thetas, ses, plan.inference.aggregation)
    ci = confint(theta, se, plan.inference.level)

    boot_ci = None
    if plan.inference.n_boot:
        bt, se1 = multiplier_bootstrap(
            psi_a[0], psi_b[0], float(thetas[0]),
            jax.random.key(plan.resampling.seed + 99),
            n_boot=plan.inference.n_boot)
        boot_ci = boot_confint(float(thetas[0]), se1, bt)

    res = DMLResult(theta=theta, se=se, ci=ci, thetas=np.asarray(thetas),
                    ses=np.asarray(ses), report=req.report, boot_ci=boot_ci,
                    request_id=request_id)
    res.psi = (np.asarray(psi_a), np.asarray(psi_b))
    return res


# ---------------------------------------------------------------------------
# the session: continuous-admission drain engine
# ---------------------------------------------------------------------------
@dataclass
class _Pending:
    request_id: int
    plan: DMLPlan
    data: DMLData
    ledger: Optional[TaskLedger]
    on_complete: Optional[Callable] = None
    req: Optional[WorkRequest] = None       # set at admission
    admitted: bool = False


class DMLSession:
    """Serves many estimation requests from one warm execution backend
    through a continuous-admission drain engine.

    >>> sess = DMLSession(backend="wave", pool=PoolConfig(n_workers=8))
    >>> a = sess.submit(plan_a, data_a)
    >>> b = sess.submit(plan_b, data_b)
    >>> results = sess.run()            # shared waves; [DMLResult, DMLResult]
    >>> sess.result(a).theta

    ``submit()`` only enqueues; admission into the backend's live
    ``DrainState`` happens lazily, so requests submitted while earlier
    ones are draining join the *same* drain (no barrier between batches).
    ``poll()`` advances the drain by one wave and returns the ids of
    requests that completed in that wave — the non-blocking interface;
    ``wait(rid)`` / ``run()`` / ``estimate()`` are blocking wrappers.
    Completion order is recorded in ``completion_order`` and surfaced
    through per-request ``on_complete`` callbacks the moment a request's
    ledger fills, while other requests are still executing.

    The backend persists across ``run()`` calls (warm pools, cached SPMD
    programs, device-resident feature pages).  ``last_run_info`` exposes
    cross-request wave accounting — ``last_run_info.shared_waves > 0`` is
    the fusion at work; ``.pages`` is the page-pool telemetry;
    ``.autoscale`` the autoscaler's decisions; ``.topology`` the
    per-host stream accounting when the backend is a topology (also
    reachable as ``session.topology_info``).

    If the backend aborts mid-drain (e.g. retry budget exhausted), the
    incomplete requests stay queued with their partially-completed
    ledgers; a later ``run()`` resumes exactly the missing invocations —
    including after swapping ``self.backend`` for a healthier pool.

    **Crash resume** (ISSUE 10): pass ``session_dir`` and the session
    becomes durable — every ``submit()`` persists the request's full
    (plan, data) spec (msgpack, atomic), and every admitted request's
    ``TaskLedger`` is bound to a file the backends checkpoint after each
    booking wave.  If the process dies mid-drain,
    ``DMLSession.resume(session_dir)`` in a FRESH process re-submits the
    saved specs in request-id order with their loaded ledgers: DONE
    invocations are never re-executed, RUNNING rows re-dispatch, and the
    determinism contract makes the resumed thetas bitwise-identical to
    an uninterrupted run.
    """

    def __init__(self, backend: Union[str, ExecutionBackend] = "wave",
                 pool: Optional[PoolConfig] = None,
                 session_dir: Optional[str] = None):
        # calibrate roofline launch-overhead and shard-overhead pricing
        # on THIS runtime (memoized no-op dispatch probes; constant
        # fallbacks on failure) — the analytic SHARD_OVERHEAD_FRAC
        # mispriced 1-device meshes (ISSUE 9)
        try:
            from repro.launch.roofline import (
                measure_launch_overhead_s, measure_shard_overhead_frac,
            )
            measure_launch_overhead_s()
            measure_shard_overhead_frac()
        except Exception:
            pass
        self.backend = make_backend(backend, pool)
        self.session_dir = session_dir
        if session_dir is not None:
            os.makedirs(session_dir, exist_ok=True)
        self._queue: List[_Pending] = []
        self._results: Dict[int, DMLResult] = {}
        self._requests: Dict[int, WorkRequest] = {}
        self._next_id = 0
        self.completion_order: List[int] = []
        self.last_run_info: Optional[BackendRunInfo] = None
        self._state: Optional[DrainState] = None
        self._state_backend: Optional[ExecutionBackend] = None

    # ---- admission ----------------------------------------------------
    def submit(self, plan: DMLPlan, data, *,
               ledger: Optional[TaskLedger] = None,
               on_complete: Optional[Callable] = None) -> int:
        """Queue one estimation request; returns its request id.

        ``on_complete(result)`` fires the moment the request's ledger
        completes — possibly waves before the whole drain finishes.
        """
        data = DMLData.from_dict(data)
        rid = self._next_id
        self._next_id += 1
        self._queue.append(_Pending(rid, plan, data, ledger,
                                    on_complete=on_complete))
        if self.session_dir is not None:
            self._persist_spec(rid, plan, data)
        return rid

    # ---- durability ---------------------------------------------------
    def _spec_path(self, rid: int) -> str:
        return os.path.join(self.session_dir, f"request_{rid:05d}.msgpack")

    def _ledger_path(self, rid: int) -> str:
        return os.path.join(self.session_dir, f"ledger_{rid:05d}.msgpack")

    def _persist_spec(self, rid: int, plan: DMLPlan, data: DMLData):
        """Durably record one admitted request (atomic, like the ledger:
        a crash never leaves a half-written spec)."""
        payload = {"rid": rid, "plan": plan.to_payload(),
                   "data": data.to_payload()}
        path = self._spec_path(rid)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(msgpack.packb(payload, use_bin_type=True))
        os.replace(tmp, path)

    @classmethod
    def resume(cls, session_dir: str, *,
               backend: Union[str, ExecutionBackend] = "wave",
               pool: Optional[PoolConfig] = None) -> "DMLSession":
        """Rebuild a durable session in a fresh process: re-submit every
        persisted request spec in request-id order with its checkpointed
        ledger, so the next ``run()``/``poll()`` re-dispatches exactly
        the not-DONE invocations (RUNNING rows orphaned by the crash
        included — ``TaskLedger.load`` resets them) and completes every
        admitted request with bitwise-identical thetas."""
        sess = cls(backend=backend, pool=pool, session_dir=session_dir)
        for path in sorted(glob.glob(
                os.path.join(session_dir, "request_*.msgpack"))):
            with open(path, "rb") as f:
                p = msgpack.unpackb(f.read(), raw=False)
            ledger = None
            lpath = os.path.join(
                session_dir, f"ledger_{p['rid']:05d}.msgpack")
            if os.path.exists(lpath):
                ledger = TaskLedger.load(lpath)
                ledger.path = lpath         # keep checkpointing here
            rid = sess.submit(DMLPlan.from_payload(p["plan"]),
                              DMLData.from_payload(p["data"]),
                              ledger=ledger)
            assert rid == p["rid"], \
                f"resume id drift: re-submitted as {rid}, saved {p['rid']}"
        return sess

    def _drain_state(self) -> DrainState:
        """The live drain, rebuilt if the backend was swapped (previously
        admitted-but-incomplete requests re-enter with their ledgers, so
        the new drain resumes instead of restarting)."""
        if self._state is None or self._state_backend is not self.backend:
            self._state = self.backend.begin_drain()
            self._state_backend = self.backend
            for p in self._queue:
                p.admitted = False
        return self._state

    def _admit_queued(self):
        if not self._queue and self._state is None:
            return                          # idle: keep last drain's info
        state = self._drain_state()
        for p in self._queue:
            if p.admitted:
                continue
            req = compile_request(p.plan, p.data, ledger=p.ledger,
                                  tag=p.request_id)
            p.ledger = req.ledger           # keep completed rows on failure
            p.req = req
            if self.session_dir is not None and req.ledger.path is None:
                # bind the durable checkpoint file: backends call
                # ledger.checkpoint() after every booking wave
                req.ledger.path = self._ledger_path(p.request_id)
                req.ledger.checkpoint()
            self.backend.admit(state, req)
            p.admitted = True
        self.last_run_info = state.info

    # ---- the event loop -----------------------------------------------
    def _harvest(self) -> List[int]:
        """Assemble results for every admitted request whose ledger just
        completed; fires callbacks; removes them from the queue."""
        finished: List[int] = []
        for p in list(self._queue):
            if not (p.admitted and p.req.ledger.complete):
                continue
            res = assemble_result(p.plan, p.data, p.req,
                                  request_id=p.request_id)
            self._results[p.request_id] = res
            self._requests[p.request_id] = p.req
            self.completion_order.append(p.request_id)
            self._queue.remove(p)
            finished.append(p.request_id)
            if p.on_complete is not None:
                p.on_complete(res)
        return finished

    def _retire_idle_state(self):
        """Drop the drain state once nothing is queued: the next submit
        starts a fresh drain (warm caches live on the *backend* — program
        cache and page pool survive; only the admission bookkeeping and
        its telemetry, already exposed via ``last_run_info``, retire)."""
        if not self._queue and self._state is not None:
            check_drained(self._state, "session retire")
            self._state = None
            self._state_backend = None

    def poll(self) -> List[int]:
        """Admit anything queued, advance the drain by one step (book
        any landed in-flight buckets, then dispatch the next wave
        without blocking), and return the ids of requests that completed
        in that step."""
        if not self._queue and self._state is None:
            return []
        self._admit_queued()
        self.backend.step(self._drain_state())
        done = self._harvest()
        self._retire_idle_state()
        return done

    def wait(self, request_id: int) -> DMLResult:
        """Drive the drain until one request completes; requests admitted
        behind it keep executing in the shared waves meanwhile."""
        if request_id in self._results:
            return self._results[request_id]
        if all(p.request_id != request_id for p in self._queue):
            raise KeyError(f"unknown request id {request_id}")
        self._admit_queued()
        state = self._drain_state()
        self._harvest()                     # resumed-complete ledgers
        while request_id not in self._results:
            progressed = self.backend.step(state)
            self._harvest()
            if not progressed and request_id not in self._results:
                raise RuntimeError(
                    f"drain stalled with request {request_id} incomplete")
        self._retire_idle_state()
        return self._results[request_id]

    def run(self) -> List[DMLResult]:
        """Drain every currently-queued request; returns their results in
        submission order (also retrievable via ``result(id)``).  Requests
        submitted *during* the drain (e.g. from callbacks) are admitted
        into the same drain and may complete here too."""
        self._admit_queued()
        targets = [p.request_id for p in self._queue]
        if not targets:
            return []
        state = self._drain_state()
        self._harvest()                     # resumed-complete ledgers
        while any(rid not in self._results for rid in targets):
            progressed = self.backend.step(state)
            self._harvest()
            self._admit_queued()            # continuous admission
            if not progressed and \
                    any(rid not in self._results for rid in targets):
                raise RuntimeError("drain stalled with incomplete requests")
        self._retire_idle_state()
        return [self._results[rid] for rid in targets]

    # ---- results ------------------------------------------------------
    @property
    def topology_info(self):
        """Per-host stream accounting of the last drain (placements,
        steals, per-host waves) — None on single-stream backends."""
        info = self.last_run_info
        return None if info is None else info.topology

    def result(self, request_id: int) -> DMLResult:
        return self._results[request_id]

    def request(self, request_id: int) -> WorkRequest:
        """The compiled WorkRequest of a completed request (its
        ``gathered_preds()`` is the full prediction tensor — used by the
        parity benchmarks)."""
        return self._requests[request_id]

    def estimate(self, plan: DMLPlan, data, *,
                 ledger: Optional[TaskLedger] = None) -> DMLResult:
        """Submit + drain a single request on this session's backend."""
        rid = self.submit(plan, data, ledger=ledger)
        return self.wait(rid)


def estimate(plan: DMLPlan, data, *,
             ledger: Optional[TaskLedger] = None,
             backend: Union[str, ExecutionBackend, None] = None) -> DMLResult:
    """One-shot estimation: plan + data -> result, backend from the plan."""
    b = backend if backend is not None else plan.backend
    sess = DMLSession(backend=b, pool=plan.pool)
    return sess.estimate(plan, data, ledger=ledger)
