"""The serving layer: plans + data in, per-request results out.

``DMLSession`` is the multi-request front door: submit any number of
(``DMLPlan``, ``DMLData``) pairs, then ``run()`` compiles them all into
``WorkRequest``s and drains them through ONE warm backend.  On the wave
backend the requests' task grids fuse into shared dispatch waves — many
concurrent estimations amortize the same capacity cycles (the
batch-processing throughput lever); on the sharded/inline backends they
reuse the same warm compiled programs.

``estimate(plan, data)`` is the one-shot convenience for a single request.

Determinism: a request's result depends only on its own (plan, data) —
fold draws, learner seeds, and score evaluation are keyed off
``plan.resampling.seed`` — so a session-batched request returns exactly
the theta it would get running alone.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_thetas, confint
from repro.core.bootstrap import boot_confint, multiplier_bootstrap
from repro.core.crossfit import (
    TaskGrid, check_partition, draw_fold_masks, stitch_predictions,
    subset_mask,
)
from repro.core.scores import evaluate_score, score_se, solve_theta
from repro.core.spec import DMLData, DMLPlan, _hashable
from repro.learners import resolve_params
from repro.serverless.backends import (
    BackendRunInfo, ExecutionBackend, PoolConfig, RunReport, Segment,
    WorkRequest, make_backend,
)
from repro.serverless.ledger import TaskLedger


@dataclass
class DMLResult:
    theta: float
    se: float
    ci: tuple
    thetas: np.ndarray              # per-repetition estimates (M,)
    ses: np.ndarray
    report: RunReport
    boot_ci: Optional[tuple] = None
    request_id: Optional[int] = None

    def summary(self) -> Dict:
        out = {"theta": self.theta, "se": self.se, "ci": self.ci}
        out.update({f"exec_{k}": v for k, v in self.report.summary().items()})
        return out


# ---------------------------------------------------------------------------
# plan + data -> WorkRequest
# ---------------------------------------------------------------------------
def compile_request(plan: DMLPlan, data: DMLData,
                    ledger: Optional[TaskLedger] = None,
                    tag: object = None) -> WorkRequest:
    """Lower a declarative request to executable arrays.

    Builds the fold masks, per-nuisance targets and training weights, and
    groups nuisances that share a (learner, params) pair into one
    ``Segment`` so uniform grids run as a single fused batch while mixed
    grids (IRM/IIVM propensities) get one fused batch per learner.
    """
    data = DMLData.from_dict(data)
    rs = plan.resampling
    n = data.n_obs
    grid = TaskGrid(rs.n_rep, rs.n_folds, plan.n_nuisance)
    masks = draw_fold_masks(n, rs.n_folds, rs.n_rep, rs.seed)
    assert check_partition(masks)

    targets = np.stack([data.role(ns.target) for ns in plan.nuisances])
    train_w = np.empty((rs.n_rep, rs.n_folds, plan.n_nuisance, n), np.float32)
    for l, ns in enumerate(plan.nuisances):
        sub = subset_mask(ns.subset, data)
        w = (~masks).astype(np.float32)          # train on I^c_{m,k}
        if sub is not None:
            w = w * sub.astype(np.float32)[None, None, :]
        train_w[:, :, l, :] = w

    # one segment per distinct (learner, params): uniform grids fuse into a
    # single batch, mixed grids get one fused batch per learner.  Each
    # segment carries the spec the megabatch compiler buckets on —
    # hyperparameters resolved against the *data shape* here (e.g.
    # kernel_ridge's gamma), so padded bucket execution stays
    # padding-invariant — and the base PRNG key tasks fold_in from.
    groups: List[List[int]] = []
    seen: Dict = {}
    for l, ns in enumerate(plan.nuisances):
        gi = seen.get(ns.learner_key)
        if gi is None:
            seen[ns.learner_key] = len(groups)
            groups.append([l])
        else:
            groups[gi].append(l)
    segments = []
    for g in groups:
        ns = plan.nuisances[g[0]]
        params = resolve_params(ns.learner, ns.param_dict,
                                n_obs=n, dim_x=data.dim_x)
        ptuple = tuple(sorted((k, _hashable(v)) for k, v in params.items()))
        segments.append(Segment(l_ids=tuple(g),
                                key=jax.random.key(rs.seed + g[0]),
                                cache_key=(ns.learner, ptuple),
                                learner=ns.learner, params=ptuple))

    req = WorkRequest.create(grid, plan.scaling, data.x, targets, train_w,
                             segments, ledger=ledger, tag=tag)
    req.fold_masks = masks                      # needed for stitching
    return req


def compile_raw_request(grid: TaskGrid, scaling: str, x, targets, train_w,
                        learner_fn, key, *, ledger=None, report=None,
                        tag: object = None) -> WorkRequest:
    """Lower a raw-array request (the deprecated ``ServerlessExecutor``
    call shape) onto the same compiled execution path as plan-built
    requests: one opaque-callable segment, executed by the megabatch
    compiler at exact shapes via the vmap adapter."""
    seg = Segment(learner_fn=learner_fn,
                  l_ids=tuple(range(grid.n_nuisance)), key=key)
    return WorkRequest.create(grid, scaling, x, targets, train_w, [seg],
                              ledger=ledger, report=report, tag=tag)


def assemble_result(plan: DMLPlan, data: DMLData, req: WorkRequest,
                    request_id: Optional[int] = None) -> DMLResult:
    """Stitch fold predictions, evaluate the score, run local inference."""
    data = DMLData.from_dict(data)
    preds = req.gathered_preds()                 # (M, K, L, N)
    masks = req.fold_masks

    fitted = {ns.name: stitch_predictions(masks, preds[:, :, l])
              for l, ns in enumerate(plan.nuisances)}
    dml_data = {k: jnp.asarray(v)[None] for k, v in
                data.score_arrays().items()}
    pred_tree = {k: jnp.asarray(v) for k, v in fitted.items()}
    psi_a, psi_b = evaluate_score(plan.model, dml_data, pred_tree, plan.score)
    thetas = solve_theta(psi_a, psi_b)                  # (M,)
    ses = score_se(psi_a, psi_b, thetas)
    theta, se = aggregate_thetas(thetas, ses, plan.inference.aggregation)
    ci = confint(theta, se, plan.inference.level)

    boot_ci = None
    if plan.inference.n_boot:
        bt, se1 = multiplier_bootstrap(
            psi_a[0], psi_b[0], float(thetas[0]),
            jax.random.key(plan.resampling.seed + 99),
            n_boot=plan.inference.n_boot)
        boot_ci = boot_confint(float(thetas[0]), se1, bt)

    res = DMLResult(theta=theta, se=se, ci=ci, thetas=np.asarray(thetas),
                    ses=np.asarray(ses), report=req.report, boot_ci=boot_ci,
                    request_id=request_id)
    res.psi = (np.asarray(psi_a), np.asarray(psi_b))
    return res


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------
@dataclass
class _Pending:
    request_id: int
    plan: DMLPlan
    data: DMLData
    ledger: Optional[TaskLedger]


class DMLSession:
    """Batches many estimation requests onto one warm execution backend.

    >>> sess = DMLSession(backend="wave", pool=PoolConfig(n_workers=8))
    >>> a = sess.submit(plan_a, data_a)
    >>> b = sess.submit(plan_b, data_b)
    >>> results = sess.run()            # shared waves; [DMLResult, DMLResult]
    >>> sess.result(a).theta

    The backend persists across ``run()`` calls (warm pools / cached SPMD
    programs).  ``last_run_info`` exposes cross-request wave accounting —
    ``last_run_info.shared_waves > 0`` is the fusion at work.
    """

    def __init__(self, backend: Union[str, ExecutionBackend] = "wave",
                 pool: Optional[PoolConfig] = None):
        self.backend = make_backend(backend, pool)
        self._queue: List[_Pending] = []
        self._results: Dict[int, DMLResult] = {}
        self._next_id = 0
        self.last_run_info: Optional[BackendRunInfo] = None

    # ------------------------------------------------------------------
    def submit(self, plan: DMLPlan, data, *,
               ledger: Optional[TaskLedger] = None) -> int:
        """Queue one estimation request; returns its request id."""
        data = DMLData.from_dict(data)
        rid = self._next_id
        self._next_id += 1
        self._queue.append(_Pending(rid, plan, data, ledger))
        return rid

    def run(self) -> List[DMLResult]:
        """Execute every queued request in shared waves; returns results
        in submission order (also retrievable via ``result(id)``).

        If the backend aborts mid-drain (e.g. retry budget exhausted),
        the requests stay queued with their partially-completed ledgers,
        so a later ``run()`` resumes instead of restarting.
        """
        if not self._queue:
            return []
        pending = list(self._queue)
        reqs = [compile_request(p.plan, p.data, ledger=p.ledger,
                                tag=p.request_id) for p in pending]
        for p, req in zip(pending, reqs):
            p.ledger = req.ledger           # keep completed rows on failure
        self.last_run_info = self.backend.run_requests(reqs)
        self._queue = self._queue[len(pending):]
        out = []
        for p, req in zip(pending, reqs):
            res = assemble_result(p.plan, p.data, req,
                                  request_id=p.request_id)
            self._results[p.request_id] = res
            out.append(res)
        return out

    def result(self, request_id: int) -> DMLResult:
        return self._results[request_id]

    def estimate(self, plan: DMLPlan, data, *,
                 ledger: Optional[TaskLedger] = None) -> DMLResult:
        """Submit + run a single request on this session's backend."""
        rid = self.submit(plan, data, ledger=ledger)
        self.run()
        return self._results[rid]


def estimate(plan: DMLPlan, data, *,
             ledger: Optional[TaskLedger] = None,
             backend: Union[str, ExecutionBackend, None] = None) -> DMLResult:
    """One-shot estimation: plan + data -> result, backend from the plan."""
    b = backend if backend is not None else plan.backend
    sess = DMLSession(backend=b, pool=plan.pool)
    return sess.estimate(plan, data, ledger=ledger)
