"""Repeated K-fold cross-fitting (paper §3, step 1-2).

The *task grid* is the paper's unit of distribution: one task = fitting one
nuisance function on I^c_{m,k} and predicting on I_{m,k}.  Fold membership is
encoded as dense masks so the whole grid vectorizes: training a task means a
weighted fit with weights = (1 - fold_mask) (x subset mask for IRM/IIVM),
predicting means evaluating on all N rows and keeping the fold rows — exactly
the paper's "return predictions on the test indices" discipline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class TaskKey:
    """Identifies one unit of work at per-fold granularity."""
    rep: int          # m in [M]
    fold: int         # k in [K]
    nuisance: int     # l in [L]

    def flat(self, n_folds: int, n_nuisance: int) -> int:
        return (self.rep * n_folds + self.fold) * n_nuisance + self.nuisance


def draw_fold_masks(n_obs: int, n_folds: int, n_rep: int,
                    seed: int = 42) -> np.ndarray:
    """(M, K, N) boolean; fold_masks[m, k, i] == i in I_{m,k}.

    Partitions are exact (sizes differ by <=1 when K does not divide N) and
    reproducible via numpy Philox streams keyed on (seed, m) — workers can
    re-derive their split without any data movement (paper §6
    "Reproducibility and seeds").
    """
    masks = np.zeros((n_rep, n_folds, n_obs), dtype=bool)
    for m in range(n_rep):
        rng = np.random.Generator(np.random.Philox(key=seed + 7919 * m))
        perm = rng.permutation(n_obs)
        for k, chunk in enumerate(np.array_split(perm, n_folds)):
            masks[m, k, chunk] = True
    return masks


def check_partition(masks: np.ndarray) -> bool:
    """Every rep's folds partition [N]."""
    return bool((masks.sum(axis=1) == 1).all())


def subset_mask(subset: str, data) -> Optional[np.ndarray]:
    """Row restriction for conditional nuisances (IRM/IIVM)."""
    if subset == "all":
        return None
    var, val = subset[0], int(subset[1])
    return np.asarray(data[{"d": "d", "z": "z"}[var]]) == val


@dataclass(frozen=True)
class TaskGrid:
    """The full M x K x L grid plus the two paper scaling levels (§4.2)."""
    n_rep: int
    n_folds: int
    n_nuisance: int

    @property
    def n_tasks(self) -> int:
        return self.n_rep * self.n_folds * self.n_nuisance

    def keys(self):
        for m in range(self.n_rep):
            for k in range(self.n_folds):
                for l in range(self.n_nuisance):
                    yield TaskKey(m, k, l)

    def n_invocations(self, scaling: str) -> int:
        if scaling == "n_rep":
            return self.n_rep * self.n_nuisance          # paper: M*L
        if scaling == "n_folds*n_rep":
            return self.n_rep * self.n_folds * self.n_nuisance
        raise ValueError(scaling)

    def invocation_of(self, key: TaskKey, scaling: str) -> int:
        """Which invocation (lambda analogue) a task belongs to."""
        if scaling == "n_rep":
            return key.rep * self.n_nuisance + key.nuisance
        return key.flat(self.n_folds, self.n_nuisance)

    def tasks_of_invocation(self, inv: int, scaling: str) -> Tuple[TaskKey, ...]:
        if scaling == "n_rep":
            m, l = divmod(inv, self.n_nuisance)
            return tuple(TaskKey(m, k, l) for k in range(self.n_folds))
        rest, l = divmod(inv, self.n_nuisance)
        m, k = divmod(rest, self.n_folds)
        return (TaskKey(m, k, l),)

    def tasks_per_invocation(self, scaling: str) -> int:
        return self.n_folds if scaling == "n_rep" else 1

    def invocation_task_ids(self, inv: np.ndarray, scaling: str) -> np.ndarray:
        """Vectorized ``tasks_of_invocation``: (B,) invocation ids ->
        (B, tasks_per_invocation) flat task ids ((m*K + k)*L + l)."""
        inv = np.asarray(inv, np.int64)
        if scaling == "n_rep":
            m, l = np.divmod(inv, self.n_nuisance)
            k = np.arange(self.n_folds)
            return ((m[:, None] * self.n_folds + k[None, :])
                    * self.n_nuisance + l[:, None])
        return inv[:, None]

    def segment_invocations(self, l_ids, scaling: str) -> np.ndarray:
        """Invocation ids owned by a learner segment (both scaling levels
        place the nuisance index in the low digit) — the unit the megabatch
        bucket planner groups."""
        inv = np.arange(self.n_invocations(scaling), dtype=np.int64)
        return inv[np.isin(inv % self.n_nuisance, np.asarray(l_ids))]

    def task_coords(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(m, k, l) arrays of length n_tasks indexed by flat task id."""
        t = np.arange(self.n_tasks, dtype=np.int64)
        l = t % self.n_nuisance
        k = (t // self.n_nuisance) % self.n_folds
        m = t // (self.n_nuisance * self.n_folds)
        return m, k, l


def pow2_bucket(n: int, min_size: int = 8) -> int:
    """Smallest power of two >= max(n, min_size) — the shape-bucketing rule
    the megabatch compiler uses for N, P, and page axes.  Pow2 growth
    bounds padding waste at <2x while collapsing the long tail of request
    shapes onto a handful of compiled programs."""
    n = max(int(n), int(min_size))
    return 1 << (n - 1).bit_length()


def aligned_bucket(n: int, quantum: int = 8, align: int = 1) -> int:
    """Smallest multiple of ``quantum`` (and of ``align``) >= n — the
    bucketing rule for the task-batch B axis.

    The wave scheduler already caps a launch at the wave capacity, so B
    lands on capacity-sized slices; aligning to a small quantum (8 lanes,
    the Pallas sublane width) bounds per-launch padding at < quantum
    lanes instead of pow2's < 2x, which on small sessions cuts B-axis
    waste from ~46% to a few percent (see BENCH_megabatch.json history).
    ``align`` further rounds to the shard count for shard_map'd programs.
    """
    n = max(int(n), 1)
    b = ((n + quantum - 1) // quantum) * quantum
    if align > 1:
        b = ((b + align - 1) // align) * align
    return b


@dataclass(frozen=True)
class PaddingStats:
    """Padding accounting for one set of bucketed program launches.

    Waste decomposes per axis: B (padded lanes), N (padded rows inside
    real lanes), and P (padded feature columns inside real lanes) — so a
    regression on one axis is visible instead of hiding in the blended
    cell fraction.
    """
    true_cells: int = 0                 # sum over tasks of their true N
    padded_cells: int = 0               # sum over launches of B_pad * N_pad
    tasks: int = 0
    padded_tasks: int = 0
    padded_tasks_pow2: int = 0          # what pow2 B-bucketing would have cost
    # what the cross-shape coalescing scheduler costs on the B axis
    # (ISSUE 7): equals padded_tasks when coalescing is on, the packed
    # counterfactual when it is off — benches report both so the
    # coalescing win is visible per-axis
    padded_tasks_morphed: int = 0
    lane_cells: int = 0                 # sum over launches of tasks * N_pad
    lane_cells_pow2: int = 0            # what pow2 N-bucketing would have cost
    true_feats: int = 0                 # sum over tasks of their true P
    padded_feats: int = 0               # sum over tasks of P_pad

    def merge(self, other: "PaddingStats") -> "PaddingStats":
        return PaddingStats(
            true_cells=self.true_cells + other.true_cells,
            padded_cells=self.padded_cells + other.padded_cells,
            tasks=self.tasks + other.tasks,
            padded_tasks=self.padded_tasks + other.padded_tasks,
            padded_tasks_pow2=self.padded_tasks_pow2
            + other.padded_tasks_pow2,
            padded_tasks_morphed=self.padded_tasks_morphed
            + other.padded_tasks_morphed,
            lane_cells=self.lane_cells + other.lane_cells,
            lane_cells_pow2=self.lane_cells_pow2 + other.lane_cells_pow2,
            true_feats=self.true_feats + other.true_feats,
            padded_feats=self.padded_feats + other.padded_feats)

    @property
    def waste_frac(self) -> float:
        """Fraction of padded program cells that carry no real data."""
        if not self.padded_cells:
            return 0.0
        return 1.0 - self.true_cells / self.padded_cells

    @property
    def b_waste_frac(self) -> float:
        """Fraction of B-axis lanes that are padding (aligned bucketing)."""
        if not self.padded_tasks:
            return 0.0
        return 1.0 - self.tasks / self.padded_tasks

    @property
    def b_waste_frac_pow2(self) -> float:
        """The B-axis waste the old pow2 rule would have produced on the
        same launches — kept so benchmarks report before/after."""
        if not self.padded_tasks_pow2:
            return 0.0
        return 1.0 - self.tasks / self.padded_tasks_pow2

    @property
    def b_waste_frac_morphed(self) -> float:
        """The B-axis waste under the cross-shape coalescing scheduler
        (actual when coalescing is on, counterfactual when off)."""
        if not self.padded_tasks_morphed:
            return 0.0
        return 1.0 - self.tasks / self.padded_tasks_morphed

    @property
    def n_waste_frac(self) -> float:
        """Fraction of rows inside *real* lanes that are N padding."""
        if not self.lane_cells:
            return 0.0
        return 1.0 - self.true_cells / self.lane_cells

    @property
    def n_waste_frac_pow2(self) -> float:
        """The N-axis waste the old pow2 rule would have produced on the
        same launches — kept so benchmarks report before/after."""
        if not self.lane_cells_pow2:
            return 0.0
        return 1.0 - self.true_cells / self.lane_cells_pow2

    @property
    def p_waste_frac(self) -> float:
        """Fraction of feature columns inside real lanes that are P
        padding."""
        if not self.padded_feats:
            return 0.0
        return 1.0 - self.true_feats / self.padded_feats


def stitch_predictions(fold_masks: np.ndarray, fold_preds: np.ndarray):
    """Combine per-fold test predictions into full-N cross-fitted vectors.

    fold_masks: (M, K, N) bool; fold_preds: (M, K, N) where entry [m,k,:]
    is the prediction vector of task (m,k) (only fold rows are used).
    Returns (M, N).
    """
    return np.einsum("mkn,mkn->mn", fold_masks.astype(fold_preds.dtype),
                     fold_preds)
