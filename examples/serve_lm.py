"""Batched serving example (deliverable (b)): prefill + KV-cache decode with
slot-based continuous batching over a request queue.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-moe-a2.7b
"""
import argparse
try:
    import _bootstrap  # noqa: F401  (run as a script from examples/)
except ModuleNotFoundError:          # imported as examples.<module>
    from examples import _bootstrap  # noqa: F401

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import build_model, init_tree
from repro.serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=True)
    bundle = build_model(cfg, remat="none", attn_chunk=32)
    params = init_tree(bundle.decls, jax.random.key(0))
    engine = Engine(bundle, params)
    print(f"serving reduced {cfg.name} "
          f"({'MLA latent cache' if cfg.attention.is_mla else 'GQA KV cache'})")

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(8, args.prompt_len)))
               .astype(np.int32) for _ in range(args.requests)]
    outs = engine.serve_requests(prompts, args.batch, args.prompt_len,
                                 n_gen=args.gen)
    for i in range(min(3, len(outs))):
        print(f"  req{i}: prompt[{len(prompts[i])}] -> {outs[i]}")

    toks = np.stack([np.resize(p, args.prompt_len)
                     for p in prompts[:args.batch]])
    res = engine.generate({"tokens": jax.numpy.asarray(toks)}, n_gen=args.gen)
    print(f"\nbatch={args.batch}: prefill {res.prefill_s*1e3:.0f} ms, "
          f"decode {res.decode_s*1e3:.0f} ms, {res.tokens_per_s:.1f} tok/s")


if __name__ == "__main__":
    main()
