"""DML with TEXT confounders: the nuisance functions g0/m0 are estimated by
a small transformer encoder over token sequences — the modern use case that
ties the LM architecture zoo to the paper's estimation layer.

DGP: each unit i has a token sequence X_i (its "document"); both treatment
propensity and outcome depend on latent sequence features (pattern counts).
Per cross-fitting task, an encoder (embedding -> attention/MLP blocks ->
mean-pool -> linear head) is trained on the fold's training rows only, and
returns held-out predictions — the same prediction-only discipline as every
other learner in the grid.

Run:  PYTHONPATH=src python examples/dml_text_confounders.py
"""
try:
    import _bootstrap  # noqa: F401  (run as a script from examples/)
except ModuleNotFoundError:          # imported as examples.<module>
    from examples import _bootstrap  # noqa: F401

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.crossfit import draw_fold_masks, stitch_predictions
from repro.core.scores import plr_score, score_se, solve_theta
from repro.core.aggregation import aggregate_thetas, confint
from repro.models.layers import attn_decls, attn_forward, mlp_forward, rms_norm
from repro.models.param import PDecl, init_tree
from repro.configs.base import AttentionConfig
from repro.sharding.axes import SMALL_DP

F32 = jnp.float32
VOCAB, SEQ, D = 64, 24, 32
ATTN = AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=8, causal=False)


def make_text_data(n_obs=300, theta=0.5, seed=0):
    """Sequences whose pattern statistics confound treatment and outcome."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, VOCAB, (n_obs, SEQ)).astype(np.int32)
    # latent features: frequency of "low" tokens and of repeated bigrams
    f1 = (toks < VOCAB // 4).mean(axis=1)
    f2 = (toks[:, 1:] == toks[:, :-1]).mean(axis=1)
    conf = 2.0 * f1 + 4.0 * f2
    d = conf + 0.5 * rng.standard_normal(n_obs)
    y = theta * d + 2.0 * np.tanh(conf) + 0.5 * rng.standard_normal(n_obs)
    return {"tokens": toks, "y": y.astype(np.float32),
            "d": d.astype(np.float32), "theta0": theta}


def encoder_decls():
    def layer():
        return {
            "ln1": PDecl((D,), (None,), init="ones"),
            "attn": attn_decls(ATTN, D),
            "ln2": PDecl((D,), (None,), init="ones"),
            "mlp": {"wi": PDecl((D, 2, 2 * D), ("embed", None, "ff")),
                    "wo": PDecl((2 * D, D), ("ff", "embed"))},
        }
    return {
        "emb": PDecl((VOCAB, D), (None, None), dtype=F32),
        "l0": layer(), "l1": layer(),
        "head": PDecl((D, 1), (None, None), dtype=F32),
    }


def encode(params, toks):
    b, s = toks.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    h = params["emb"][toks].astype(jnp.bfloat16)
    for lname in ("l0", "l1"):
        lp = params[lname]
        a, _ = attn_forward(lp["attn"], ATTN, rms_norm(h, lp["ln1"]),
                            pos, SMALL_DP, use_rope=True, chunk=1024)
        h = h + a
        m = mlp_forward(lp["mlp"], rms_norm(h, lp["ln2"]), "gelu", True,
                        SMALL_DP)
        h = h + m
    pooled = jnp.mean(h.astype(F32), axis=1)
    return (pooled @ params["head"])[:, 0]


def lm_learner(toks, y, w, key, steps=150, lr=3e-3):
    """One encoder per task; tasks trained sequentially (tiny sizes)."""
    params = init_tree(encoder_decls(), key)
    params = jax.tree.map(lambda p: p.astype(F32), params)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def loss_fn(params):
        pred = encode(params, toks)
        return jnp.sum(w * (pred - y) ** 2) / jnp.maximum(jnp.sum(w), 1.0)

    @jax.jit
    def step(params, m, v, i):
        g = jax.grad(loss_fn)(params)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        params = jax.tree.map(
            lambda p, mm, vv: p - lr * mm / (jnp.sqrt(vv) + 1e-8), params, m, v)
        return params, m, v

    for i in range(steps):
        params, m, v = step(params, m, v, i)
    return np.asarray(encode(params, toks))


def run_small(n_obs=300, n_rep=2, n_folds=4, theta=0.5, steps=150, seed=0):
    data = make_text_data(n_obs, theta, seed)
    masks = draw_fold_masks(n_obs, n_folds, n_rep, seed)
    toks = jnp.asarray(data["tokens"])
    targets = {"ml_l": data["y"], "ml_m": data["d"]}
    preds = {k: np.zeros((n_rep, n_folds, n_obs), np.float32)
             for k in targets}
    key = jax.random.key(seed)
    for mrep in range(n_rep):
        for kf in range(n_folds):
            w = jnp.asarray((~masks[mrep, kf]).astype(np.float32))
            for nm, tgt in targets.items():
                key, sub = jax.random.split(key)
                preds[nm][mrep, kf] = lm_learner(
                    toks, jnp.asarray(tgt), w, sub, steps=steps)
    fitted = {nm: stitch_predictions(masks, preds[nm]) for nm in targets}
    pa, pb = plr_score(
        {"y": jnp.asarray(data["y"])[None], "d": jnp.asarray(data["d"])[None]},
        {nm: jnp.asarray(v) for nm, v in fitted.items()})
    thetas = solve_theta(pa, pb)
    ses = score_se(pa, pb, thetas)
    th, se = aggregate_thetas(thetas, ses)
    return {"theta": th, "se": se, "ci": confint(th, se),
            "theta0": data["theta0"]}


if __name__ == "__main__":
    res = run_small()
    print(f"theta_hat = {res['theta']:+.4f} (se {res['se']:.4f}), "
          f"CI [{res['ci'][0]:+.3f}, {res['ci'][1]:+.3f}], "
          f"true {res['theta0']}")
