"""Quickstart — the paper's §5.1 snippet on the declarative API.

Paper (DoubleML-Serverless):                      Here:
    dml_data = DoubleMLDataS3(...)                  data = DMLData.from_dict(
                                                        make_bonus_data())
    learner = RandomForestRegressor(...)            learner="kernel_ridge"
    dml_plr = DoubleMLPLRServerless(                plan = DMLPlan.for_model(
        lambda_function_name=...,                       "plr", n_folds=5,
        dml_data, ml_g, ml_m, n_folds=5,                n_rep=..., scaling=
        n_rep=100, scaling='n_rep')                     "n_rep", pool=...)
    dml_plr.fit_aws_lambda()                        res = estimate(plan, data)

Run:  python examples/quickstart.py          (pip install -e ., or in-tree)
"""
try:
    import _bootstrap  # noqa: F401  (run as a script from examples/)
except ModuleNotFoundError:          # imported as examples.<module>
    from examples import _bootstrap  # noqa: F401

from repro.configs.dml_plr_bonus import USD_PER_GB_S
from repro.core import DMLData, DMLPlan, estimate
from repro.data import make_bonus_data
from repro.serverless import PoolConfig


def main(n_rep: int = 20):
    data = DMLData.from_dict(make_bonus_data())
    print(f"bonus replica: N={data.n_obs}, p={data.dim_x} controls, "
          f"planted effect {data.theta0}")

    plan = DMLPlan.for_model(
        "plr", n_folds=5, n_rep=n_rep,
        learner="kernel_ridge",                  # RF stand-in (DESIGN.md §2)
        learner_params={"reg": 1.0, "n_landmarks": 256},
        scaling="n_rep",                          # paper's per-split scaling
        n_boot=500,
        pool=PoolConfig(n_workers=8, memory_mb=1024))
    res = estimate(plan, data)

    print(f"\ntheta_hat = {res.theta:+.4f}  (se {res.se:.4f})")
    print(f"95% CI     = [{res.ci[0]:+.4f}, {res.ci[1]:+.4f}]")
    print(f"boot CI    = [{res.boot_ci[0]:+.4f}, {res.boot_ci[1]:+.4f}]")
    s = res.report.summary()
    print(f"\ninvocations={s['invocations']} waves={s['waves']} "
          f"fit_time={s['fit_time_s']:.2f}s")
    print(f"billed {s['billed_gb_s']:.1f} GB-s = "
          f"${s['billed_gb_s'] * USD_PER_GB_S:.5f}")


if __name__ == "__main__":
    main()
