"""Make ``repro`` importable when the package is not installed.

With ``pip install -e .`` (see pyproject.toml) this module is a no-op;
from a bare source checkout it falls back to the in-tree ``src/`` layout,
independent of the current working directory.
"""
import os
import sys

try:
    import repro  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    os.pardir, "src"))
